"""Fault-tolerant sweep execution (ISSUE 10 tentpole):

  * crash-safe checkpoints: atomic writes with a content sha256 and
    keep-last-2 rotation; truncated / zero-length / bit-flipped
    artifacts raise the typed `CheckpointCorrupt` instead of raw
    unpickling errors, and the resume path falls back to the last good
    checkpoint;
  * `RetryPolicy`: capped exponential backoff with an injectable sleep;
  * chunk-level fault isolation in `run_chunked`: a sweep surviving k
    injected chunk faults (within the retry budget) is BIT-identical to
    the fault-free run — the headline invariant, plus a property test
    over random fault schedules;
  * node dropout (`run_mc(participation=)`): p = 1.0 statically
    disables the mask stream and is bit-identical to today; p < 1 is
    one extra hoisted stream and a per-row p sweep is one compile.

Serving-level fault tolerance (deadlines, quarantine, server retry)
lives in tests/test_serving_mc.py next to the rest of the server suite.
"""
import os
import warnings

import numpy as np
import pytest

from _fault_harness import ChunkFaultSchedule, bit_flip, torn_write
from _hypothesis_compat import given, settings, strategies as st
from benchmarks.common import MSDProblem
from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import CheckpointCorrupt
from repro.core.channel import ChannelConfig
from repro.core.mc import ExecPlan, RetryPolicy, validate_plan
from repro.core.mc import exec as exec_mod
from repro.core.montecarlo import run_mc

N, D, STEPS, SEEDS = 10, 6, 8, 8


@pytest.fixture(scope="module")
def mc():
    return MSDProblem.make(N, dim=D).to_mc()


def _ch(**kw):
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "n": np.int64(5)}


# --------------------------------------------------------------------------
# crash-safe checkpoints
# --------------------------------------------------------------------------
class TestCheckpointCorruption:
    def test_roundtrip_carries_and_strips_the_sha(self, tmp_path):
        path = str(tmp_path / "c.npz")
        ckpt.save(path, _tree())
        raw = ckpt.peek(path)
        assert set(raw) == {"a", "n"}  # the sha leaf never leaks out
        np.testing.assert_array_equal(raw["a"], _tree()["a"])
        with np.load(path) as f:  # but it IS in the artifact
            assert "__sha256__" in f and f["__sha256__"].shape == (32,)

    def test_zero_length_file_raises_typed_corrupt(self, tmp_path):
        path = str(tmp_path / "c.npz")
        open(path, "wb").close()
        with pytest.raises(CheckpointCorrupt) as ei:
            ckpt.peek(path)
        assert ei.value.path == path
        assert "zero-length" in ei.value.reason

    def test_torn_write_raises_typed_corrupt(self, tmp_path):
        path = str(tmp_path / "c.npz")
        ckpt.save(path, _tree())
        torn_write(path)
        with pytest.raises(CheckpointCorrupt) as ei:
            ckpt.peek(path)
        assert ei.value.path == path
        with pytest.raises(CheckpointCorrupt):
            ckpt.restore(path, _tree())

    def test_bit_flip_in_payload_raises_typed_corrupt(self, tmp_path):
        # a raw on-disk flip trips the archive's CRC first — still the
        # typed error, never a raw zipfile/numpy exception
        path = str(tmp_path / "c.npz")
        tree = _tree()
        ckpt.save(path, tree)
        bit_flip(path, needle=tree["a"].tobytes())
        with pytest.raises(CheckpointCorrupt) as ei:
            ckpt.peek(path)
        assert ei.value.path == path

    def test_silent_payload_tamper_raises_sha_mismatch(self, tmp_path):
        # CRC-consistent tampering (archive rewritten with one value
        # changed but the stale sha leaf kept) only the content sha sees
        path = str(tmp_path / "c.npz")
        ckpt.save(path, _tree())
        with np.load(path) as f:
            flat = {k: f[k].copy() for k in f.files}
        flat["a"].flat[0] += 1.0
        with open(path, "wb") as f:
            np.savez(f, **flat)
        with pytest.raises(CheckpointCorrupt, match="sha256 mismatch"):
            ckpt.peek(path)

    def test_keep_last_2_rotation(self, tmp_path):
        path = str(tmp_path / "c.npz")
        first = _tree()
        ckpt.save(path, first)
        second = {"a": first["a"] + 1.0, "n": np.int64(6)}
        ckpt.save(path, second)
        np.testing.assert_array_equal(ckpt.peek(path)["a"], second["a"])
        prev = ckpt.peek(path + ckpt.PREV_SUFFIX)
        np.testing.assert_array_equal(prev["a"], first["a"])

    def test_legacy_artifact_without_sha_still_loads(self, tmp_path):
        path = str(tmp_path / "legacy.npz")
        np.savez(path[:-4], **{k: np.asarray(v)
                               for k, v in _tree().items()})
        raw = ckpt.peek(path)
        np.testing.assert_array_equal(raw["a"], _tree()["a"])

    def test_missing_file_raises_typed_corrupt(self, tmp_path):
        with pytest.raises(CheckpointCorrupt, match="does not exist"):
            ckpt.peek(str(tmp_path / "never.npz"))


# --------------------------------------------------------------------------
# RetryPolicy
# --------------------------------------------------------------------------
class TestRetryPolicy:
    def test_capped_exponential_delays(self):
        rp = RetryPolicy(max_attempts=6, base_delay_s=0.05, cap_delay_s=0.3)
        assert [rp.delay_s(a) for a in range(1, 6)] == \
            [0.05, 0.1, 0.2, 0.3, 0.3]

    def test_wait_uses_the_injected_sleep(self):
        slept = []
        rp = RetryPolicy(base_delay_s=0.5, sleep=slept.append)
        rp.wait(1)
        rp.wait(2)
        assert slept == [0.5, 1.0]

    def test_validate_plan_rejects_bad_policies(self):
        with pytest.raises(ValueError, match="max_attempts"):
            validate_plan(ExecPlan(retry=RetryPolicy(max_attempts=0)),
                          seeds=8, n_rows=1)
        with pytest.raises(ValueError, match="nonnegative"):
            validate_plan(ExecPlan(retry=RetryPolicy(base_delay_s=-1.0)),
                          seeds=8, n_rows=1)

    def test_asdict_records_the_sleep_by_name(self):
        plan = ExecPlan(retry=RetryPolicy(sleep=_ch))
        d = plan.asdict()
        assert d["retry"]["sleep"] == _ch.__qualname__
        assert d["retry"]["max_attempts"] == 3
        assert ExecPlan().asdict()["retry"] is None


# --------------------------------------------------------------------------
# chunk-level fault isolation: the headline bit-identity invariant
# --------------------------------------------------------------------------
def _retry(**kw):
    kw.setdefault("max_attempts", 3)
    kw.setdefault("sleep", lambda dt: None)  # no wall-clock sleeps
    return RetryPolicy(**kw)


class TestChunkRetry:
    def test_k_faults_bit_identical_moments(self, mc):
        args = (mc, [_ch(), _ch(noise_std=1.0)], "gbma", [0.01, 0.02],
                STEPS, SEEDS)
        plan = ExecPlan(seed_chunk=2, keep_seed_curves=False)
        clean = run_mc(*args, plan=plan)
        slept = []
        with ChunkFaultSchedule({0: 1, 4: 2}) as faults:
            survived = run_mc(*args, plan=plan.replace(
                retry=_retry(sleep=slept.append)))
        assert len(faults.fired) == 3  # k = 3 injected faults
        assert slept == [0.05, 0.05, 0.1]  # backoff restarts per chunk
        np.testing.assert_array_equal(survived.mean, clean.mean)
        np.testing.assert_array_equal(survived.ci95, clean.ci95)

    def test_k_faults_bit_identical_curves(self, mc):
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        plan = ExecPlan(seed_chunk=2)
        clean = run_mc(*args, plan=plan)
        with ChunkFaultSchedule({2: 1, 6: 1}):
            survived = run_mc(*args, plan=plan.replace(retry=_retry()))
        np.testing.assert_array_equal(survived.risks, clean.risks)
        np.testing.assert_array_equal(survived.cum_energy,
                                      clean.cum_energy)
        np.testing.assert_array_equal(survived.mean, clean.mean)

    def test_no_retry_policy_fails_fast(self, mc):
        with ChunkFaultSchedule({0: 1}):
            with pytest.raises(RuntimeError, match="injected chunk fault"):
                run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                       plan=ExecPlan(seed_chunk=2, keep_seed_curves=False))

    def test_exhausted_budget_reraises(self, mc):
        plan = ExecPlan(seed_chunk=2, keep_seed_curves=False,
                        retry=_retry(max_attempts=2))
        with ChunkFaultSchedule({2: 2}) as faults:  # needs 3 attempts
            with pytest.raises(RuntimeError, match="injected chunk fault"):
                run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                       plan=plan)
        assert len(faults.fired) == 2  # both attempts burned

    def test_checkpoint_save_stays_outside_the_retry_scope(
            self, mc, tmp_path, monkeypatch):
        """A failing ckpt.save is NOT a chunk fault: it propagates even
        under a retry policy (the interrupted-resume contract depends on
        fail-fast saves)."""
        def dying_save(path, tree):
            raise RuntimeError("simulated disk death")

        monkeypatch.setattr(ckpt, "save", dying_save)
        with pytest.raises(RuntimeError, match="disk death"):
            run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                   plan=ExecPlan(seed_chunk=2, keep_seed_curves=False,
                                 retry=_retry()),
                   resume_dir=str(tmp_path))


_PROP_CACHE = {}


def _prop_baseline():
    """Cached (args, plan, fault-free result) for the property test —
    module-level because the hypothesis shim's wrapper signature hides
    pytest fixtures from the collector."""
    if not _PROP_CACHE:
        mc = MSDProblem.make(N, dim=D).to_mc()
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        plan = ExecPlan(seed_chunk=2, keep_seed_curves=False)
        _PROP_CACHE["x"] = (args, plan, run_mc(*args, plan=plan))
    return _PROP_CACHE["x"]


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_random_fault_schedules_preserve_moments(seed):
    """Property: ANY fault schedule within the retry budget leaves the
    final reduced moments identical to the fault-free run."""
    args, plan, clean = _prop_baseline()
    rng = np.random.default_rng(seed)
    schedule = {off: int(rng.integers(0, 3))
                for off in range(0, SEEDS, 2) if rng.random() < 0.6}
    with ChunkFaultSchedule(schedule) as faults:
        survived = run_mc(*args, plan=plan.replace(
            retry=_retry(max_attempts=3)))
    assert len(faults.fired) == sum(schedule.values())
    np.testing.assert_array_equal(survived.mean, clean.mean)
    np.testing.assert_array_equal(survived.ci95, clean.ci95)


# --------------------------------------------------------------------------
# resume fallback on corrupt checkpoints
# --------------------------------------------------------------------------
class TestResumeFallback:
    def _interrupted(self, mc, tmp_path, monkeypatch):
        """Run to completion once (leaves main + .prev artifacts)."""
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        kw = dict(plan=ExecPlan(seed_chunk=2, keep_seed_curves=False),
                  resume_dir=str(tmp_path))
        return args, kw, run_mc(*args, **kw)

    def test_corrupt_main_falls_back_to_prev(self, mc, tmp_path,
                                             monkeypatch):
        args, kw, clean = self._interrupted(mc, tmp_path, monkeypatch)
        main = str(tmp_path / exec_mod._RESUME_FILE)
        assert int(ckpt.peek(main)["next_off"]) == SEEDS
        assert int(ckpt.peek(main + ckpt.PREV_SUFFIX)["next_off"]) \
            == SEEDS - 2
        torn_write(main)
        offs = []
        real_merge = exec_mod._mc_moments_merge

        def counting_merge(am, am2, n_prev, *a, **k):
            offs.append(int(np.asarray(n_prev)))
            return real_merge(am, am2, n_prev, *a, **k)

        monkeypatch.setattr(exec_mod, "_mc_moments_merge", counting_merge)
        with pytest.warns(UserWarning, match="corrupt resume checkpoint"):
            resumed = run_mc(*args, **kw)
        assert offs == [SEEDS - 2]  # resumed from .prev: one chunk redone
        np.testing.assert_array_equal(resumed.mean, clean.mean)
        np.testing.assert_array_equal(resumed.ci95, clean.ci95)

    def test_both_corrupt_restarts_fresh_with_warning(self, mc, tmp_path,
                                                      monkeypatch):
        args, kw, clean = self._interrupted(mc, tmp_path, monkeypatch)
        main = str(tmp_path / exec_mod._RESUME_FILE)
        torn_write(main)
        open(main + ckpt.PREV_SUFFIX, "wb").close()
        with pytest.warns(UserWarning, match="restarting the sweep"):
            restarted = run_mc(*args, **kw)
        np.testing.assert_array_equal(restarted.mean, clean.mean)
        np.testing.assert_array_equal(restarted.ci95, clean.ci95)

    def test_foreign_fingerprint_still_rejected(self, mc, tmp_path):
        kw = dict(plan=ExecPlan(seed_chunk=2, keep_seed_curves=False),
                  resume_dir=str(tmp_path))
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, **kw)
        with pytest.raises(ValueError, match="fingerprint"):
            run_mc(mc, [_ch()], "gbma", [0.02], STEPS, SEEDS, **kw)


# --------------------------------------------------------------------------
# node dropout / partial participation
# --------------------------------------------------------------------------
class TestParticipation:
    def test_full_participation_is_bit_identical(self, mc):
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        base = run_mc(*args)
        on = run_mc(*args, participation=1.0)
        np.testing.assert_array_equal(on.risks, base.risks)
        np.testing.assert_array_equal(on.cum_energy, base.cum_energy)
        np.testing.assert_array_equal(on.mean, base.mean)
        per_row = run_mc(*args, participation=[1.0])
        np.testing.assert_array_equal(per_row.risks, base.risks)

    def test_dropout_changes_results_and_costs_energy(self, mc):
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        base = run_mc(*args)
        dropped = run_mc(*args, participation=0.6)
        assert not np.array_equal(dropped.mean, base.mean)
        # silent nodes transmit nothing, so the energy ledger moves too
        assert not np.array_equal(dropped.cum_energy, base.cum_energy)

    def test_validation(self, mc):
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        with pytest.raises(ValueError, match="participation"):
            run_mc(*args, participation=0.0)
        with pytest.raises(ValueError, match="participation"):
            run_mc(*args, participation=1.5)
        with pytest.raises(ValueError, match="one participation per row"):
            run_mc(*args, participation=[0.5, 0.9])

    def test_per_row_p_sweep_is_one_compile(self, mc):
        if not exec_mod.clear_cache():
            pytest.skip("jit cache clearing unavailable")
        run_mc(mc, [_ch()] * 3, "gbma", [0.01] * 3, STEPS, SEEDS,
               participation=[0.9, 0.7, 0.5], keep_seed_curves=False)
        assert exec_mod.trace_count() == 1

    def test_full_participation_shares_the_resume_fingerprint(
            self, mc, tmp_path):
        """p = 1.0 is the no-knob workload: a checkpoint written without
        the knob short-circuits a participation=1.0 rerun (no foreign-
        fingerprint error), while p < 1 IS a different workload."""
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        kw = dict(plan=ExecPlan(seed_chunk=2, keep_seed_curves=False),
                  resume_dir=str(tmp_path))
        first = run_mc(*args, **kw)
        again = run_mc(*args, participation=1.0, **kw)
        np.testing.assert_array_equal(again.mean, first.mean)
        with pytest.raises(ValueError, match="fingerprint"):
            run_mc(*args, participation=0.5, **kw)

    def test_chunked_dropout_matches_single_shot(self, mc):
        args = (mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
        single = run_mc(*args, participation=0.7)
        chunked = run_mc(*args, participation=0.7,
                         plan=ExecPlan(seed_chunk=2))
        np.testing.assert_array_equal(chunked.risks, single.risks)
        np.testing.assert_array_equal(chunked.mean, single.mean)

    def test_memory_model_counts_the_mask_stream(self):
        base = exec_mod.estimate_peak_bytes(
            n_rows=2, seeds=8, steps=10, n_max=16, dim=4)
        on = exec_mod.estimate_peak_bytes(
            n_rows=2, seeds=8, steps=10, n_max=16, dim=4,
            participation_on=True)
        assert on["rng_draw_bytes"] - base["rng_draw_bytes"] \
            == 2 * 8 * 10 * 16 * 4  # rows * seeds * steps * n_max * f32
