"""Attention implementation properties: blockwise == full oracle, window and
softcap semantics, cache-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.attention import (blockwise_attention, cache_write,
                                    decode_attention, full_attention,
                                    init_kv_cache)


@given(
    sq=st.sampled_from([64, 96, 128, 200]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    window=st.sampled_from([None, 16, 64]),
    softcap=st.sampled_from([None, 20.0]),
)
@settings(max_examples=6, deadline=None)
def test_blockwise_equals_full(sq, h, g, window, softcap):
    d = 16
    ks = jax.random.split(jax.random.key(sq * h * g), 3)
    q = jax.random.normal(ks[0], (1, h * g, sq, d))
    k = jax.random.normal(ks[1], (1, h, sq, d))
    v = jax.random.normal(ks[2], (1, h, sq, d))
    full = full_attention(q, k, v, scale=0.25, causal=True, window=window,
                          softcap=softcap)
    blk = blockwise_attention(q, k, v, scale=0.25, causal=True,
                              window=window, softcap=softcap,
                              block_q=32, block_kv=32)
    np.testing.assert_allclose(np.array(blk), np.array(full), atol=2e-5,
                               rtol=1e-4)


def test_window_masks_out_distant_tokens():
    """With window=1 each token attends only to itself -> output == v."""
    d, s = 8, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 1, s, d))
    k = jax.random.normal(ks[1], (1, 1, s, d))
    v = jax.random.normal(ks[2], (1, 1, s, d))
    out = full_attention(q, k, v, scale=1.0, causal=True, window=1)
    np.testing.assert_allclose(np.array(out), np.array(v), atol=1e-5)


def test_is_global_flag_disables_window():
    d, s = 8, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 2, s, d))
    k = jax.random.normal(ks[1], (1, 2, s, d))
    v = jax.random.normal(ks[2], (1, 2, s, d))
    glob = full_attention(q, k, v, scale=0.3, causal=True, window=None)
    flagged = full_attention(q, k, v, scale=0.3, causal=True, window=4,
                             is_global=jnp.asarray(True))
    np.testing.assert_allclose(np.array(flagged), np.array(glob), atol=1e-5)


def test_ring_buffer_cache_decode_matches_windowed_attention():
    """Decoding with a ring buffer of size W == full attention with window W."""
    cfg = get_config("gemma2-9b").reduced()
    d = cfg.head_dim
    hkv = cfg.n_kv_heads
    s_total, w = 24, 8
    ks = jax.random.split(jax.random.key(2), 3)
    k_all = jax.random.normal(ks[0], (1, hkv, s_total, d))
    v_all = jax.random.normal(ks[1], (1, hkv, s_total, d))
    q_last = jax.random.normal(ks[2], (1, cfg.n_heads, 1, d))

    cache = init_kv_cache(1, w, cfg)
    for t in range(s_total):
        cache = cache_write(cache, k_all[:, :, t:t + 1], v_all[:, :, t:t + 1],
                            jnp.asarray(t))
    out_ring = decode_attention(q_last, cache, jnp.asarray(s_total - 1), cfg,
                                window=w)
    # reference: full attention of the last query over the last w keys
    ref = full_attention(q_last, k_all[:, :, -w:], v_all[:, :, -w:],
                         scale=d**-0.5, causal=False,
                         softcap=cfg.attn_softcap)
    np.testing.assert_allclose(np.array(out_ring), np.array(ref), atol=2e-5,
                               rtol=1e-4)
