"""Doc-drift guards: the documentation surface cannot silently diverge
from the registries it documents.

  * every `montecarlo.ALGOS` entry (the `mc.slots` algo registry) has a
    heading in `docs/algorithms.md`;
  * every `mc.problems.PROBLEMS` kind has a heading in
    `docs/montecarlo.md`'s problem-registry section;
  * every `benchmarks/fig*.py` script is registered in `benchmarks/run.py`
    and listed in the README figure table;
  * every `repro.compat.__all__` name is documented in
    `docs/algorithms.md`'s compat section;
  * the docs the README links to exist in the repo.

Adding an algorithm, a problem kind, a figure script, or a compat symbol
without documenting/registering it fails tier-1.
"""
import pathlib
import re

from repro import compat
from repro.core.montecarlo import ALGOS, PROBLEMS

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _figure_scripts():
    figs = sorted((ROOT / "benchmarks").glob("fig*.py"))
    assert len(figs) >= 7  # fig2..fig8 at time of writing
    return figs


def test_every_algo_has_a_heading_in_algorithms_md():
    text = (ROOT / "docs" / "algorithms.md").read_text()
    for algo in ALGOS:
        assert re.search(rf"^#+ .*`{algo}`", text, re.M), (
            f"algo {algo!r} is in montecarlo.ALGOS but has no heading in "
            "docs/algorithms.md — document its update rule, RNG semantics, "
            "energy accounting and slot path there")


def test_every_problem_kind_has_a_heading_in_montecarlo_md():
    text = (ROOT / "docs" / "montecarlo.md").read_text()
    for kind in PROBLEMS:
        assert re.search(rf"^#+ .*`{kind}`", text, re.M), (
            f"problem kind {kind!r} is registered in mc.problems.PROBLEMS "
            "but has no heading in docs/montecarlo.md — document its "
            "objective, risk metric and pad semantics in the problem-"
            "registry section")


def test_every_figure_script_is_registered_in_run_py():
    run_src = (ROOT / "benchmarks" / "run.py").read_text()
    for fig in _figure_scripts():
        assert fig.stem in run_src, (
            f"benchmarks/{fig.name} is not registered in benchmarks/run.py")


def test_every_figure_script_is_in_the_readme_table():
    readme = (ROOT / "README.md").read_text()
    for fig in _figure_scripts():
        assert f"benchmarks/{fig.name}" in readme, (
            f"benchmarks/{fig.name} is missing from the README figure "
            "table")


def test_compat_public_surface_is_documented():
    text = (ROOT / "docs" / "algorithms.md").read_text()
    for name in compat.__all__:
        assert f"`{name}`" in text, (
            f"repro.compat.{name} is exported (__all__) but undocumented "
            "in docs/algorithms.md")


def test_readme_doc_links_resolve():
    readme = (ROOT / "README.md").read_text()
    for rel in re.findall(r"\]\((docs/[^)#]+)", readme):
        assert (ROOT / rel).is_file(), f"README links to missing {rel}"


def test_performance_md_documents_the_exec_knobs():
    """docs/performance.md is the execution layer's contract: every
    exec-layer `run_mc` knob and the benchmark artifact it explains must
    appear there, and both the README and docs/montecarlo.md must link
    it."""
    import inspect

    from repro.core.montecarlo import run_mc

    text = (ROOT / "docs" / "performance.md").read_text()
    sig = inspect.signature(run_mc)
    exec_knobs = [n for n in ("rng_plan", "seed_chunk", "keep_seed_curves")
                  if n in sig.parameters]
    assert exec_knobs, "run_mc lost its execution-layer knobs"
    for knob in exec_knobs:
        assert f"`{knob}`" in text, (
            f"run_mc({knob}=...) is an execution-layer knob but "
            "docs/performance.md does not document it")
    assert "BENCH_montecarlo.json" in text
    assert "estimate_peak_bytes" in text, (
        "performance.md must document the memory model")
    for linker in ("README.md", "docs/montecarlo.md"):
        assert "performance.md" in (ROOT / linker).read_text(), (
            f"{linker} must cross-link docs/performance.md")


def test_performance_md_documents_the_exec_plan_surface():
    """The plan/place/run/reduce pipeline is part of the execution-layer
    contract: every `ExecPlan` field, the plan entry points, the resume
    knob and the placed benchmark entry must appear in
    docs/performance.md — adding a plan field without documenting it
    fails tier-1."""
    import dataclasses

    from repro.core.mc import ExecPlan

    text = (ROOT / "docs" / "performance.md").read_text()
    for f in dataclasses.fields(ExecPlan):
        assert f"`{f.name}`" in text, (
            f"ExecPlan.{f.name} is an execution-plan field but "
            "docs/performance.md does not document it")
    for name in ("ExecPlan", "auto_plan", "resume_dir", "chan_merge",
                 "shard_map", "large_chunked_placed", "topology",
                 "fingerprint", "xla_force_host_platform_device_count"):
        assert name in text, (
            f"docs/performance.md must document {name!r} (plan/placement/"
            "resume sections)")
    bench_src = (ROOT / "benchmarks" / "bench_montecarlo.py").read_text()
    assert "large_chunked_placed" in bench_src, (
        "the documented large_chunked_placed entry left the benchmark")


def test_performance_md_documents_the_cost_model():
    """The measured cost model is part of the execution-layer contract:
    every `CalibrationConfig` knob and the artifact/consumer vocabulary
    must appear in docs/performance.md — adding a calibration knob
    without documenting it fails tier-1."""
    import dataclasses

    from repro.core.mc import CalibrationConfig

    text = (ROOT / "docs" / "performance.md").read_text()
    for f in dataclasses.fields(CalibrationConfig):
        assert f"`{f.name}`" in text, (
            f"CalibrationConfig.{f.name} is a calibration knob but "
            "docs/performance.md does not document it")
    for name in ("costmodel", "CALIBRATION_mc.json",
                 "REPRO_CALIBRATION_PATH", "predict_run_us",
                 "load_cost_model", "cached_machine_peaks",
                 'cost_model="measured"', "measured_plan",
                 "--write-bench"):
        assert name in text, (
            f"docs/performance.md must document {name!r} (measured "
            "cost model / calibration artifact section)")


def test_serving_md_pins_the_mc_server_surface():
    """docs/serving.md is the sweep-server contract: every request and
    config field must appear in its schema/knob tables, the typed errors
    and the coalescing/preemption vocabulary must be documented, the
    harness pieces it names must exist, and the README must link it."""
    import dataclasses

    from repro.serving.mc_server import McServeConfig, SweepRequest

    text = (ROOT / "docs" / "serving.md").read_text()
    for f in dataclasses.fields(SweepRequest):
        assert f"`{f.name}`" in text, (
            f"SweepRequest.{f.name} is a request field but "
            "docs/serving.md's schema table does not document it")
    for f in dataclasses.fields(McServeConfig):
        assert f"`{f.name}`" in text, (
            f"McServeConfig.{f.name} is a server knob but "
            "docs/serving.md does not document it")
    for name in ("static_signature", "estimate_peak_bytes",
                 "slice_result", "host_seed_stats", "trace_count",
                 "AdmissionError", "RequestError", "ServeError",
                 "quantum", "coalesc", "serve_sync", "serve_forever",
                 "InlineExecutor", "ManualClock", "TracingExecutor",
                 "serve_coalesce", "--selftest", "pad_flops_ratio",
                 "bucket_occupancy", "predict_run_us", "cache_epoch",
                 "shape class", "monolithic_warm_s", "`layouts`",
                 "demanded node"):
        assert name in text, (
            f"docs/serving.md must document {name!r} (signature/"
            "admission/preemption/harness sections)")
    assert (ROOT / "tests" / "_serving_harness.py").is_file()
    assert "serving.md" in (ROOT / "README.md").read_text(), (
        "README.md must cross-link docs/serving.md")


def test_fault_tolerance_docs_pin_the_retry_and_checkpoint_surface():
    """The fault-tolerance contract spans both guides: every
    `RetryPolicy` field and the checkpoint/retry vocabulary must appear
    in docs/performance.md, the serving-side degradation vocabulary in
    docs/serving.md, and the participation knob in docs/montecarlo.md —
    adding a policy field or typed error without documenting it fails
    tier-1."""
    import dataclasses

    from repro.core.mc import RetryPolicy

    perf = (ROOT / "docs" / "performance.md").read_text()
    for f in dataclasses.fields(RetryPolicy):
        assert f"`{f.name}`" in perf, (
            f"RetryPolicy.{f.name} is a retry knob but "
            "docs/performance.md does not document it")
    for name in ("RetryPolicy", "CheckpointCorrupt", "sha256",
                 "os.replace", "`.prev`", "install_chunk_fault_hook",
                 "bit-identical", "_fault_harness"):
        assert name in perf, (
            f"docs/performance.md must document {name!r} (fault-"
            "tolerance section)")
    serving = (ROOT / "docs" / "serving.md").read_text()
    for name in ("PartialResult", "QuarantinedError", "`deadline_s`",
                 "default_deadline_s", "hang_threshold_s",
                 "seeds_completed", "seeds_requested", "watchdog",
                 "deadline_expired", "quarantined", "--chaos",
                 "chaos-smoke", "ClockJump", "FlakyOnce"):
        assert name in serving, (
            f"docs/serving.md must document {name!r} (fault-tolerance "
            "section)")
    mc_doc = (ROOT / "docs" / "montecarlo.md").read_text()
    for name in ("`participation`", 'b"part"', "one compile"):
        assert name in mc_doc, (
            f"docs/montecarlo.md must document {name!r} (node-dropout "
            "section)")
    assert (ROOT / "tests" / "_fault_harness.py").is_file()


def test_training_md_pins_the_transport_surface():
    """docs/training.md is the training-route contract: every registry
    aggregator must appear in its routing table, the transport knobs it
    documents must exist on TransportConfig, and both the README and
    docs/algorithms.md must link it."""
    import dataclasses

    from repro.core.transport import TransportConfig

    text = (ROOT / "docs" / "training.md").read_text()
    for algo in ALGOS:
        assert f"`{algo}`" in text, (
            f"aggregator {algo!r} is in the MAC registry but missing from "
            "docs/training.md's routing table — say which route it takes")
    fields = {f.name for f in dataclasses.fields(TransportConfig)}
    for knob in ("block_d", "transmit_dtype", "ota_impl", "mc_steps",
                 "power_budget"):
        assert knob in fields, f"TransportConfig lost documented knob {knob}"
        assert knob in text, (
            f"TransportConfig.{knob} is undocumented in docs/training.md")
    for phrase in ("FULL_CONCAT", "init_state", "tx_energy", "grad_norm",
                   "clip_frac", "hoist_draws"):
        assert phrase in text, (
            f"docs/training.md must document {phrase!r}")
    for linker in ("README.md", "docs/algorithms.md"):
        assert "training.md" in (ROOT / linker).read_text(), (
            f"{linker} must cross-link docs/training.md")
