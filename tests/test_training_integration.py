"""End-to-end training integration: a small transformer trained with GBMA
aggregation converges, tracks the centralized baseline at high SNR, and
degrades gracefully at low SNR — the system-level analogue of the paper's
Fig. 4 experiment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMAConfig
from repro.data.synthetic import SyntheticTokens, TokenDatasetConfig
from repro.models.model import build_model
from repro.optim.gd import gd, momentum
from repro.training.loop import run_training
from repro.training.train_step import TrainConfig, build_train_step


def _tiny_model():
    cfg = get_config("repro-100m").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, logit_chunk=32, attn_block_q=16,
        attn_block_kv=32)
    return build_model(cfg)


def _run(aggregator, noise_std, steps=30, seed=0):
    m = _tiny_model()
    params = m.init_params(jax.random.key(seed))
    ds = SyntheticTokens(TokenDatasetConfig(
        vocab_size=m.cfg.vocab_size, seq_len=16, global_batch=8, seed=3))
    tcfg = TrainConfig(
        aggregator=aggregator,
        gbma=GBMAConfig(n_nodes=4, channel=ChannelConfig(
            fading="rayleigh", noise_std=noise_std, energy=1.0)))
    opt = momentum(0.05)
    step = build_train_step(m, tcfg, opt)
    batches = ({"tokens": t} for t in ds)
    params, _, hist = run_training(
        step, params, opt.init(params), batches, steps, log_every=steps - 1)
    return hist[0]["loss"], hist[-1]["loss"]


def test_gbma_training_converges():
    first, last = _run("gbma", noise_std=0.01)
    assert last < first * 0.9


@pytest.mark.slow
def test_gbma_tracks_centralized_at_high_snr():
    _, last_gbma = _run("gbma", noise_std=1e-4)
    _, last_cent = _run("centralized", noise_std=0.0)
    assert abs(last_gbma - last_cent) / last_cent < 0.15


@pytest.mark.slow
def test_low_snr_hurts_more_than_high_snr():
    _, hi = _run("gbma", noise_std=1e-3, seed=1)
    _, lo = _run("gbma", noise_std=0.5, seed=1)
    assert lo >= hi - 0.05


def test_fdm_noise_is_sqrt_n_worse():
    """Same channel: FDM averaged-noise std is sqrt(N) x GBMA's."""
    import math

    from repro.training.train_step import _fdm_noise
    from repro.core.gbma import perturb_gradients

    gcfg = GBMAConfig(n_nodes=16, channel=ChannelConfig(noise_std=1.0,
                                                        energy=1.0))
    zeros = {"w": jnp.zeros((100_000,))}
    g_gbma = perturb_gradients(zeros, jax.random.key(0), gcfg)
    g_fdm = _fdm_noise(zeros, jax.random.key(0), gcfg)
    ratio = float(jnp.std(g_fdm["w"])) / float(jnp.std(g_gbma["w"]))
    np.testing.assert_allclose(ratio, math.sqrt(16), rtol=0.05)
