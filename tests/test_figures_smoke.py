"""Tier-1 smoke coverage of the figure scripts: every `benchmarks/fig*.py`
`run()` (plus the ablation sweeps) executes end to end at tiny, monkeypatched
module constants, so figure-script regressions surface without `--runslow` —
including the per-figure one-compile guarantee (each script's N-sweep /
algorithm comparison must stay a single `_mc_core` compile).

The scripts expose their operating points as module constants (STEPS, SEEDS,
N / N_GRID, EPS_GRID) precisely so this test can shrink them.
"""
import importlib

import pytest

from repro.core import montecarlo as mc_mod

TINY = {
    "STEPS": 6,
    "SEEDS": 2,
    "N": 16,
    "N_GRID": (8, 13),   # odd size: exercises the padded sweep's odd branch
    "EPS_GRID": (1.0, 1.5),
}

# engine compiles each run() is allowed: the N-sweep (a) and, for fig2/fig3,
# the energy sweep (b) — never one compile per N / per algorithm
FIG_MODULES = [
    ("fig2_equal_gains", 2),
    ("fig3_rayleigh", 2),
    ("fig4_fdm_comparison", 1),
    ("fig5_localization", 1),
    ("fig6_energy_scaling", 1),
    # ablations sweeps ~a dozen engine compiles even at tiny scale — worth
    # smoke coverage, but only under --runslow
    pytest.param("ablations", None, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("name,max_compiles", FIG_MODULES)
def test_figure_script_runs_at_tiny_scale(name, max_compiles, monkeypatch):
    mod = importlib.import_module(f"benchmarks.{name}")
    for attr, val in TINY.items():
        if hasattr(mod, attr):
            monkeypatch.setattr(mod, attr, val)
    cleared = mc_mod.clear_cache()
    c0 = mc_mod.trace_count()
    rows = mod.run(verbose=False)
    assert rows, f"{name}.run() returned no rows"
    assert all(isinstance(r, str) and r for r in rows)
    if max_compiles is not None and cleared:
        compiles = mc_mod.trace_count() - c0
        assert compiles <= max_compiles, (
            f"{name}.run() compiled _mc_core {compiles}x "
            f"(allowed {max_compiles}) — per-N/per-algo compile regression")
