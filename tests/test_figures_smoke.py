"""Tier-1 smoke coverage of the figure scripts, auto-discovered: every
`benchmarks/fig*.py` module — current and future — gets its `run()`
executed end to end at tiny, monkeypatched module constants, so
figure-script regressions surface without `--runslow`, including the
per-figure compile guarantee: each script declares `SMOKE_COMPILES`, the
exact number of `_mc_core` compiles its run() performs (one per engine
sweep — never one per N / per algorithm / per antenna count), and the
test asserts the count exactly.

The scripts expose their operating points as module constants (STEPS,
SEEDS, N / N_GRID, EPS_GRID, M / M_GRID) precisely so this test can
shrink them; new figure scripts inherit the smoke + compile-count
coverage just by matching `benchmarks/fig*.py`.
"""
import importlib
import pathlib

import pytest

from repro.core import montecarlo as mc_mod

TINY = {
    "STEPS": 6,
    "SEEDS": 2,
    "N": 16,
    "N_GRID": (8, 13),   # odd size: exercises the padded sweep's odd branch
    "EPS_GRID": (1.0, 1.5),
    "M": 3,
    "M_GRID": (1, 4),    # distinct counts: exercises the antenna replay
}

_BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
FIG_MODULES = sorted(p.stem for p in _BENCH_DIR.glob("fig*.py"))


def test_discovery_finds_the_figure_scripts():
    assert len(FIG_MODULES) >= 6  # fig2..fig7 at time of writing


@pytest.mark.parametrize("name", FIG_MODULES)
def test_figure_script_runs_at_tiny_scale(name, monkeypatch):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert hasattr(mod, "SMOKE_COMPILES"), (
        f"benchmarks/{name}.py must declare SMOKE_COMPILES — the exact "
        "number of _mc_core compiles its run() performs (one per engine "
        "sweep)")
    for attr, val in TINY.items():
        if hasattr(mod, attr):
            monkeypatch.setattr(mod, attr, val)
    cleared = mc_mod.clear_cache()  # also zeroes the trace counter
    rows = mod.run(verbose=False)
    assert rows, f"{name}.run() returned no rows"
    assert all(isinstance(r, str) and r for r in rows)
    if cleared:
        compiles = mc_mod.trace_count()
        assert compiles == mod.SMOKE_COMPILES, (
            f"{name}.run() compiled _mc_core {compiles}x, declared "
            f"SMOKE_COMPILES={mod.SMOKE_COMPILES} — a per-N/per-algo/"
            "per-M compile regression (or an undeclared new sweep)")


# ablations sweeps ~a dozen engine compiles even at tiny scale — worth
# smoke coverage, but only under --runslow
@pytest.mark.slow
def test_ablations_run_at_tiny_scale(monkeypatch):
    mod = importlib.import_module("benchmarks.ablations")
    for attr, val in TINY.items():
        if hasattr(mod, attr):
            monkeypatch.setattr(mod, attr, val)
    rows = mod.run(verbose=False)
    assert rows and all(isinstance(r, str) and r for r in rows)
