"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (<=2 layers, d_model<=512, <=4 experts) runs one forward/train
step and one prefill+decode step on CPU with finite outputs + right shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMAConfig
from repro.models.model import build_model
from repro.optim.gd import gd
from repro.training.train_step import TrainConfig, build_train_step

# Tier-1 runs one representative dense arch end-to-end; the full per-arch
# matrix (each ~8-18s of compile-dominated wall time) runs with --runslow.
# Per-component coverage (MoE dispatch, attention variants, wkv, mla) lives
# in the dedicated unit tests and stays in tier-1.
FAST_ARCHS = ("olmo-1b",)
ARCH_PARAMS = [
    a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


def _make_batch(m, key, bsz, seq):
    cfg = m.cfg
    batch = {"tokens": jax.random.randint(key, (bsz, seq + 1), 0,
                                          cfg.vocab_size)}
    if cfg.n_patches:
        batch = {
            "tokens": jax.random.randint(key, (bsz, seq - cfg.n_patches + 1),
                                         0, cfg.vocab_size),
            "patch_embed": jax.random.normal(key, (bsz, cfg.n_patches,
                                                   cfg.d_model)),
        }
    if m.kind == "encdec":
        batch["frames"] = jax.random.normal(key, (bsz, cfg.enc_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    key = jax.random.key(0)
    params = m.init_params(key)
    batch = _make_batch(m, key, bsz=2, seq=32)
    losses, metrics = m.train_loss_per_example(params, batch)
    assert losses.shape == (2,)
    assert np.isfinite(np.array(losses, np.float32)).all()
    assert float(metrics["loss"]) > 0.0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_reduced_gbma_training_reduces_loss(arch):
    """One GBMA train step with high-SNR channel must not produce NaNs and
    a few steps must reduce the loss on a repeated batch."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    key = jax.random.key(1)
    params = m.init_params(key)
    tcfg = TrainConfig(
        aggregator="gbma",
        gbma=GBMAConfig(n_nodes=2, channel=ChannelConfig(
            fading="rayleigh", noise_std=0.01, energy=1.0)))
    opt = gd(stepsize=0.2 if not cfg.n_experts else 0.05)
    step = jax.jit(build_train_step(m, tcfg, opt))
    batch = _make_batch(m, key, bsz=2, seq=16)
    opt_state = opt.init(params)
    first = None
    for i in range(8):
        params, opt_state, metrics = step(params, opt_state, batch, i)
        assert np.isfinite(float(metrics["loss"])), (arch, i)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first, arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    key = jax.random.key(2)
    params = m.init_params(key)
    bsz, seq = 2, 16
    batch = _make_batch(m, key, bsz, seq)
    batch = {k: (v[:, :seq] if k == "tokens" else v)
             for k, v in batch.items()}
    logits, cache = m.prefill(params, batch, max_len=seq + 4)
    assert logits.shape == (bsz, cfg.vocab_size)
    assert np.isfinite(np.array(logits, np.float32)).all()
    pos = batch["tokens"].shape[1] + (cfg.n_patches or 0) + (cfg.meta_tokens
                                                             or 0)
    tok = jnp.argmax(logits, -1)
    for i in range(3):
        logits, cache = m.decode_step(params, cache, tok,
                                      jnp.asarray(pos + i, jnp.int32))
        assert logits.shape == (bsz, cfg.vocab_size)
        assert np.isfinite(np.array(logits, np.float32)).all(), (arch, i)
        tok = jnp.argmax(logits, -1)


def test_decode_matches_teacher_forcing_dense():
    """Decode with cache must equal the full-sequence forward (olmo family:
    exact match expected in f32)."""
    cfg = get_config("olmo-1b").reduced()
    m = build_model(cfg)
    key = jax.random.key(3)
    params = m.init_params(key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    # full forward logits at last position
    from repro.models import transformer as tfm

    x = tfm.embed_tokens(params, toks, cfg)
    h, _, _ = tfm.decoder_forward(params, x, cfg,
                                  positions=jnp.arange(12))
    full_logits = tfm.logits_fn(params, h[:, -1:], cfg)[:, 0]
    # prefill on first 11 + decode token 12
    logits_p, cache = m.prefill(params, {"tokens": toks[:, :11]},
                                max_len=16)
    logits_d, _ = m.decode_step(params, cache, toks[:, 11],
                                jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(np.array(logits_d), np.array(full_logits),
                               atol=2e-3, rtol=1e-3)
