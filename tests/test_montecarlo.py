"""Monte Carlo engine (`repro.core.montecarlo`) correctness:

  * engine trajectories == reference simulators (`GBMASimulator`, `FDMGD`,
    `PowerControlOTA`, `CentralizedGD`) under a fixed key — the engine
    mirrors their PRNG split order;
  * on-device closed-form excess risk == the numpy objective-difference
    oracle (`benchmarks.common.MSDProblem.excess_risk`);
  * a batched (vmapped) config sweep == the same configs run one at a time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import MSDProblem
from repro.core.baselines import CentralizedGD, FDMGD, PowerControlOTA
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.montecarlo import (ChannelBatch, energy_to_target,
                                   quadratic_mc_problem, run_mc)
from repro.core.theory import stepsize_theorem1

N, STEPS, SEEDS = 40, 60, 2


@pytest.fixture(scope="module")
def prob():
    return MSDProblem.make(N, dim=24)


@pytest.fixture(scope="module")
def mc(prob):
    return prob.to_mc()


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


def test_engine_matches_gbma_simulator_fixed_key(prob, mc):
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    res = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS)
    for s in range(SEEDS):
        traj = GBMASimulator(prob.grad_fn(), ch, beta).run(
            jnp.zeros(prob.pc.dim), STEPS, jax.random.key(s))
        oracle = prob.excess_risk(traj)
        np.testing.assert_allclose(res.risks[0, s], oracle, rtol=1e-4,
                                   atol=1e-8)


def test_on_device_risk_matches_numpy_oracle(prob, mc):
    """Closed-form 0.5 (θ-θ*)ᵀH(θ-θ*) == objective(θ) - F* (f64 numpy)."""
    thetas = np.random.default_rng(0).standard_normal((8, prob.pc.dim))
    f_star = prob.objective(prob.theta_star)
    for t in thetas:
        dev = float(mc.risk_fn(jnp.asarray(t, jnp.float32)))
        host = prob.objective(t) - f_star
        np.testing.assert_allclose(dev, host, rtol=2e-4)


def test_batched_configs_equal_individual_runs(prob, mc):
    chs = [_ch(energy=e) for e in (1.0, 0.1, 0.01)]
    betas = [stepsize_theorem1(prob.pc, c, N, safety=0.8) for c in chs]
    batched = run_mc(mc, chs, "gbma", betas, STEPS, SEEDS)
    for i, (c, b) in enumerate(zip(chs, betas)):
        single = run_mc(mc, [c], "gbma", [b], STEPS, SEEDS)
        np.testing.assert_allclose(batched.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


@pytest.mark.parametrize("algo,invert", [
    ("centralized", False),
    ("fdm", False),
    ("fdm", True),
    ("power_control", False),
])
def test_engine_matches_reference_baselines(prob, mc, algo, invert):
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    g = prob.grad_fn()
    if algo == "centralized":
        runner = CentralizedGD(g, beta)
    elif algo == "fdm":
        runner = FDMGD(g, ch, beta, invert_channel=invert)
    else:
        runner = PowerControlOTA(g, ch, beta, h_min=0.3)
    res = run_mc(mc, [ch], algo, [beta], STEPS, 1, invert_channel=invert,
                 h_min=0.3)
    traj = runner.run(jnp.zeros(prob.pc.dim), STEPS, jax.random.key(0))
    np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                               rtol=1e-4, atol=1e-8)


def test_engine_matches_multiantenna_reference(prob, mc):
    """n_antennas=M mirrors `ota_aggregate_multiantenna`'s key splitting
    (including the extra split for M=1)."""
    from repro.core.gbma import ota_aggregate_multiantenna

    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    g = prob.grad_fn()
    for m_ant in (1, 2):
        res = run_mc(mc, [ch], "gbma", [beta], STEPS, 1, n_antennas=m_ant)

        def body(theta, k):
            v = ota_aggregate_multiantenna(g(theta), k, ch, m_ant)
            return theta - beta * v, theta

        keys = jax.random.split(jax.random.key(0), STEPS)
        theta_fin, traj = jax.lax.scan(body, jnp.zeros(prob.pc.dim), keys)
        traj = jnp.concatenate([traj, theta_fin[None]])
        np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                                   rtol=1e-4, atol=1e-8)


def test_channel_batch_rejects_mixed_fading():
    with pytest.raises(ValueError):
        ChannelBatch.stack([_ch(), _ch(fading="equal")])


def test_energy_accounting_and_target(prob, mc):
    """cum_energy is a per-step cumsum of E_N ||g_k||²; energy_to_target
    picks the hit step on the risk curve."""
    ch = _ch(fading="equal", noise_std=0.0, energy=0.5)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    res = run_mc(mc, [ch], "gbma", [beta], STEPS, 1)
    cum = res.cum_energy[0, 0]
    assert np.all(np.diff(cum) > 0.0)  # nonzero gradients along the path
    # replicate by hand from the deterministic (noiseless, equal) trajectory
    traj = GBMASimulator(prob.grad_fn(), ch, beta).run(
        jnp.zeros(prob.pc.dim), STEPS, jax.random.key(0))
    g_sq = [float(jnp.sum(prob.grad_fn()(t) ** 2)) for t in traj[:-1]]
    np.testing.assert_allclose(cum, 0.5 * np.cumsum(g_sq), rtol=1e-4)
    target = float(res.risks[0, 0, STEPS // 2])
    tot = energy_to_target(res, target)[0]
    hit = int(np.argmax(res.risks[0, 0] <= target))
    np.testing.assert_allclose(tot, cum[min(hit, STEPS - 1)], rtol=1e-6)
