"""Monte Carlo engine (`repro.core.montecarlo`) correctness:

  * engine trajectories == reference simulators (`GBMASimulator`, `FDMGD`,
    `PowerControlOTA`, `CentralizedGD`) under a fixed key — the engine
    mirrors their PRNG split order;
  * on-device closed-form excess risk == the numpy objective-difference
    oracle (`benchmarks.common.MSDProblem.excess_risk`);
  * a batched (vmapped) config sweep == the same configs run one at a time;
  * a padded/masked NODE-COUNT sweep compiles `_mc_core` exactly once and
    reproduces the per-N runs; per-row algo batching likewise;
  * `_sample_gains` (the engine's traceable twin) == `channel.sample_gains`
    across all fading families × phase-error settings (property test);
  * energy bookkeeping: `energy_to_target` charges exactly the slots before
    the first target hit (hand-computed regression).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from benchmarks.common import MSDProblem
from repro.core import channel as channel_mod
from repro.core import montecarlo as mc_mod
from repro.core.baselines import CentralizedGD, FDMGD, PowerControlOTA
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator, ota_aggregate
from repro.core.montecarlo import (ChannelBatch, MCProblem, MCProblemBatch,
                                   MCResult, energy_to_target,
                                   quadratic_mc_problem, run_mc, trace_count)
from repro.core.theory import stepsize_theorem1

N, STEPS, SEEDS = 40, 60, 2


@pytest.fixture(scope="module")
def prob():
    return MSDProblem.make(N, dim=24)


@pytest.fixture(scope="module")
def mc(prob):
    return prob.to_mc()


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


def test_engine_matches_gbma_simulator_fixed_key(prob, mc):
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    res = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS)
    for s in range(SEEDS):
        traj = GBMASimulator(prob.grad_fn(), ch, beta).run(
            jnp.zeros(prob.pc.dim), STEPS, jax.random.key(s))
        oracle = prob.excess_risk(traj)
        np.testing.assert_allclose(res.risks[0, s], oracle, rtol=1e-4,
                                   atol=1e-8)


def test_on_device_risk_matches_numpy_oracle(prob, mc):
    """Closed-form 0.5 (θ-θ*)ᵀH(θ-θ*) == objective(θ) - F* (f64 numpy)."""
    thetas = np.random.default_rng(0).standard_normal((8, prob.pc.dim))
    f_star = prob.objective(prob.theta_star)
    for t in thetas:
        dev = float(mc.risk_fn(jnp.asarray(t, jnp.float32)))
        host = prob.objective(t) - f_star
        np.testing.assert_allclose(dev, host, rtol=2e-4)


def test_batched_configs_equal_individual_runs(prob, mc):
    chs = [_ch(energy=e) for e in (1.0, 0.1, 0.01)]
    betas = [stepsize_theorem1(prob.pc, c, N, safety=0.8) for c in chs]
    batched = run_mc(mc, chs, "gbma", betas, STEPS, SEEDS)
    for i, (c, b) in enumerate(zip(chs, betas)):
        single = run_mc(mc, [c], "gbma", [b], STEPS, SEEDS)
        np.testing.assert_allclose(batched.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


@pytest.mark.parametrize("algo,invert", [
    ("centralized", False),
    ("fdm", False),
    ("fdm", True),
    ("power_control", False),
])
def test_engine_matches_reference_baselines(prob, mc, algo, invert):
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    g = prob.grad_fn()
    if algo == "centralized":
        runner = CentralizedGD(g, beta)
    elif algo == "fdm":
        runner = FDMGD(g, ch, beta, invert_channel=invert)
    else:
        runner = PowerControlOTA(g, ch, beta, h_min=0.3)
    res = run_mc(mc, [ch], algo, [beta], STEPS, 1, invert_channel=invert,
                 h_min=0.3)
    traj = runner.run(jnp.zeros(prob.pc.dim), STEPS, jax.random.key(0))
    np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                               rtol=1e-4, atol=1e-8)


def test_engine_matches_multiantenna_reference(prob, mc):
    """n_antennas=M mirrors `ota_aggregate_multiantenna`'s key splitting
    (including the extra split for M=1)."""
    from repro.core.gbma import ota_aggregate_multiantenna

    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    g = prob.grad_fn()
    for m_ant in (1, 2):
        res = run_mc(mc, [ch], "gbma", [beta], STEPS, 1, n_antennas=m_ant)

        def body(theta, k):
            v = ota_aggregate_multiantenna(g(theta), k, ch, m_ant)
            return theta - beta * v, theta

        keys = jax.random.split(jax.random.key(0), STEPS)
        theta_fin, traj = jax.lax.scan(body, jnp.zeros(prob.pc.dim), keys)
        traj = jnp.concatenate([traj, theta_fin[None]])
        np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                                   rtol=1e-4, atol=1e-8)


def test_channel_batch_rejects_mixed_fading():
    with pytest.raises(ValueError):
        ChannelBatch.stack([_ch(), _ch(fading="equal")])


def test_energy_accounting_and_target(prob, mc):
    """cum_energy is a per-step cumsum of E_N ||g_k||²; energy_to_target
    charges exactly the slots transmitted before the risk first hits the
    target (a hit at index k has consumed k slots -> cum_energy[k-1])."""
    ch = _ch(fading="equal", noise_std=0.0, energy=0.5)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    res = run_mc(mc, [ch], "gbma", [beta], STEPS, 1)
    cum = res.cum_energy[0, 0]
    assert np.all(np.diff(cum) > 0.0)  # nonzero gradients along the path
    # replicate by hand from the deterministic (noiseless, equal) trajectory
    traj = GBMASimulator(prob.grad_fn(), ch, beta).run(
        jnp.zeros(prob.pc.dim), STEPS, jax.random.key(0))
    g_sq = [float(jnp.sum(prob.grad_fn()(t) ** 2)) for t in traj[:-1]]
    np.testing.assert_allclose(cum, 0.5 * np.cumsum(g_sq), rtol=1e-4)
    target = float(res.risks[0, 0, STEPS // 2])
    tot = energy_to_target(res, target)[0]
    hit = int(np.argmax(res.risks[0, 0] <= target))
    assert hit > 0
    np.testing.assert_allclose(tot, cum[hit - 1], rtol=1e-6)


def _fake_result(risks, cum_energy):
    risks = np.asarray(risks, np.float64)
    mean = risks.mean(axis=1)
    return MCResult(risks=risks, mean=mean, ci95=np.zeros_like(mean),
                    cum_energy=np.asarray(cum_energy, np.float64),
                    bounds=None)


def test_energy_to_target_hand_computed():
    """3-step trajectory, all hit cases: risks [4, 2, 1, .5] with per-slot
    cumulative energy [3, 5, 6]. A first hit at index k costs the first k
    slots; a hit at initialization costs nothing; a never-hit seed spends
    the full horizon."""
    res = _fake_result([[[4.0, 2.0, 1.0, 0.5]]], [[[3.0, 5.0, 6.0]]])
    assert energy_to_target(res, 2.0)[0] == 3.0   # hit at k=1: slot 1 only
    assert energy_to_target(res, 1.0)[0] == 5.0   # hit at k=2: slots 1-2
    assert energy_to_target(res, 0.5)[0] == 6.0   # hit at final k=3
    assert energy_to_target(res, 4.0)[0] == 0.0   # already met at theta_0
    assert energy_to_target(res, 0.1)[0] == 6.0   # never hit: full horizon


def test_nsweep_one_compile_matches_per_n():
    """A node-count sweep (padded/masked to N_max) compiles `_mc_core`
    exactly once and reproduces each per-N run within 1e-5 relative."""
    grid = (12, 19, 32)  # odd size included: exercises the threefry pad
    probs = [MSDProblem.make(n, dim=16) for n in grid]
    chs = [_ch(energy=float(n) ** (-1.0)) for n in grid]
    betas = [stepsize_theorem1(p.pc, c, n, safety=0.8)
             for p, c, n in zip(probs, chs, grid)]
    mcs = [p.to_mc() for p in probs]
    singles = [run_mc(mc, [c], "gbma", [b], STEPS, SEEDS, pc=p.pc)
               for mc, c, b, p in zip(mcs, chs, betas, probs)]
    mc_mod.clear_cache()  # also zeroes the trace counter
    sweep = run_mc(mcs, chs, "gbma", betas, STEPS, SEEDS,
                   pc=[p.pc for p in probs])
    assert trace_count() == 1
    for i, single in enumerate(singles):
        np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(sweep.cum_energy[i],
                                   single.cum_energy[0], rtol=1e-5)
        np.testing.assert_allclose(sweep.bounds[i], single.bounds[0],
                                   rtol=1e-6)


def test_nsweep_localization_problems():
    """The localization problem batches/pads too (far-away pad sensors keep
    the padded rows' 1/d² terms finite)."""
    from repro.core.montecarlo import localization_mc_problem
    from repro.data.synthetic import localization_field

    parts = [localization_field(n, signal_a=100.0, snr_db=-10.0, seed=i)
             for i, n in enumerate((10, 17))]
    locs = [localization_mc_problem(r, x, src, 100.0)
            for r, x, src, _ in parts]
    ch = _ch(noise_std=0.3)
    theta0 = np.array([45.0, 45.0])
    sweep = run_mc(locs, [ch, ch], "gbma", [0.5, 0.5], STEPS, SEEDS,
                   theta0=theta0)
    assert np.all(np.isfinite(sweep.risks))
    for i, loc in enumerate(locs):
        single = run_mc(loc, [ch], "gbma", [0.5], STEPS, SEEDS,
                        theta0=theta0)
        np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


def test_algo_batch_one_compile_matches_individual(prob, mc):
    """Per-row algos (the fig4/fig5 shape) run in one `_mc_core` compile
    and match the per-algo runs."""
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    algos = ("gbma", "fdm", "centralized")
    mc_mod.clear_cache()  # also zeroes the trace counter
    multi = run_mc(mc, [ch] * 3, algos, [beta] * 3, STEPS, SEEDS)
    assert trace_count() == 1
    for i, a in enumerate(algos):
        single = run_mc(mc, [ch], a, [beta], STEPS, SEEDS)
        np.testing.assert_allclose(multi.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


def test_momentum_matches_reference_recursion(prob, mc):
    """algo='momentum'/'nesterov' == a hand-rolled heavy-ball / Nesterov
    loop over the reference OTA slot (`gbma.ota_aggregate`), same keys."""
    ch = _ch()
    beta = 0.5 * stepsize_theorem1(prob.pc, ch, N, safety=0.5)
    gamma = 0.6
    g = prob.grad_fn()
    for algo, nest in (("momentum", 0.0), ("nesterov", 1.0)):
        res = run_mc(mc, [ch], algo, [beta], STEPS, 1, momentum=gamma)

        def body(carry, k):
            theta, m = carry
            g_k = g(theta - nest * beta * gamma * m)
            v = ota_aggregate(g_k, k, ch)
            m = gamma * m + v
            return (theta - beta * m, m), theta

        keys = jax.random.split(jax.random.key(0), STEPS)
        (theta_fin, _), traj = jax.lax.scan(
            body, (jnp.zeros(prob.pc.dim), jnp.zeros(prob.pc.dim)), keys)
        traj = jnp.concatenate([traj, theta_fin[None]])
        np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                                   rtol=1e-4, atol=1e-8)


def test_momentum_zero_gamma_is_vanilla(prob, mc):
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    r_mom = run_mc(mc, [ch], "momentum", [beta], STEPS, SEEDS, momentum=0.0)
    r_van = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS)
    np.testing.assert_array_equal(r_mom.risks, r_van.risks)


def test_shard_seeds_matches_plain(prob, mc):
    """The shard_map('mc' mesh) seed axis is transparent: forcing it on the
    available devices reproduces the plain path bit-for-bit."""
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    plain = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS,
                   shard_seeds=False)
    sharded = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS,
                     shard_seeds=True)
    np.testing.assert_array_equal(plain.risks, sharded.risks)
    np.testing.assert_array_equal(plain.cum_energy, sharded.cum_energy)


def test_problem_batch_rejects_unstackable():
    handbuilt = MCProblem(grad_fn=lambda t: t[None, :], risk_fn=jnp.sum,
                          dim=3, n_nodes=1)
    with pytest.raises(ValueError):
        MCProblemBatch.stack([handbuilt, handbuilt])
    q = quadratic_mc_problem(np.eye(3, dtype=np.float32),
                             np.zeros(3, np.float32), 0.1, np.zeros(3))
    with pytest.raises(ValueError):
        MCProblemBatch.stack([q, handbuilt])


def test_problem_batch_rejects_mixed_kinds_and_dims():
    from repro.core.montecarlo import localization_mc_problem
    from repro.data.synthetic import localization_field

    q3 = quadratic_mc_problem(np.eye(3, dtype=np.float32),
                              np.zeros(3, np.float32), 0.1, np.zeros(3))
    q4 = quadratic_mc_problem(np.eye(4, dtype=np.float32),
                              np.zeros(4, np.float32), 0.1, np.zeros(4))
    r, x, src, _ = localization_field(5, signal_a=10.0, seed=0)
    loc = localization_mc_problem(r, x, src, 10.0)
    # mixed kinds
    with pytest.raises(ValueError, match="one\\s+kind"):
        MCProblemBatch.stack([q3, loc])
    # same kind, mismatched dims
    with pytest.raises(ValueError, match="dim"):
        MCProblemBatch.stack([q3, q4])
    # unregistered kind
    import dataclasses
    alien = dataclasses.replace(q3, kind="no_such_kind")
    with pytest.raises(ValueError, match="not registered"):
        MCProblemBatch.stack([alien, alien])


def test_localization_pad_sentinel_keeps_padded_gradients_zero():
    """The r=1e6 pad sentinel places padded sensors far from the search
    region so 1/d² stays finite — padded rows must come out EXACTLY zero
    (after masking) and finite (before the mask they must not be inf/nan,
    or 0·inf would poison the row)."""
    from repro.core.mc.problems import (PROBLEMS, localization_mc_problem)
    from repro.data.synthetic import localization_field

    parts = [localization_field(n, signal_a=100.0, snr_db=-10.0, seed=i)
             for i, n in enumerate((4, 9))]
    locs = [localization_mc_problem(r, x, src, 100.0)
            for r, x, src, _ in parts]
    batch = MCProblemBatch.stack(locs)
    assert batch.n_max == 9
    grad_row = PROBLEMS["localization"].grad_row
    theta = jnp.asarray([45.0, 45.0], jnp.float32)
    for i, n in enumerate((4, 9)):
        row = {k: v[i] for k, v in batch.data.items()}
        g = np.asarray(grad_row(row, theta))
        assert np.all(np.isfinite(g))
        assert np.all(g[n:] == 0.0), "padded sensor rows must be exact 0"
        # the pad value itself (not the mask alone) keeps things finite:
        # an unmasked evaluation at the pad sentinel is tiny but finite
        row_nomask = dict(row, mask=jnp.ones_like(row["mask"]))
        g_nomask = np.asarray(grad_row(row_nomask, theta))
        assert np.all(np.isfinite(g_nomask))


def test_quadratic_pad_zero_keeps_padded_gradients_zero():
    from repro.core.mc.problems import PROBLEMS

    probs = [MSDProblem.make(n, dim=6).to_mc() for n in (5, 8)]
    batch = MCProblemBatch.stack(probs)
    grad_row = PROBLEMS["quadratic"].grad_row
    theta = jnp.ones(6, jnp.float32)
    row = {k: v[0] for k, v in batch.data.items()}
    g = np.asarray(grad_row(row, theta))
    assert np.all(g[5:] == 0.0)
    assert np.all(np.isfinite(g))


def test_ota_impl_ref_parity(prob, mc):
    """run_mc(ota_impl='ref') routes the OTA slot through the
    `repro.kernels.ota` jnp oracle; trajectories must match the inline
    path (same RNG stream, same math up to association order)."""
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    r_inline = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS)
    r_ref = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS, ota_impl="ref")
    np.testing.assert_allclose(r_ref.risks, r_inline.risks, rtol=1e-5,
                               atol=1e-9)
    # momentum shares the slot path
    r_mom = run_mc(mc, [ch], "momentum", [beta], STEPS, SEEDS,
                   momentum=0.5)
    r_mom_ref = run_mc(mc, [ch], "momentum", [beta], STEPS, SEEDS,
                       momentum=0.5, ota_impl="ref")
    np.testing.assert_allclose(r_mom_ref.risks, r_mom.risks, rtol=1e-5,
                               atol=1e-9)


def test_ota_impl_pallas_parity_interpret(prob, mc):
    """The Pallas kernel path (interpret mode off-TPU) matches inline —
    the ROADMAP 'pallas path for the per-slot aggregation' item. Short
    horizon: interpret mode is slow."""
    ch = _ch()
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    steps = 5
    r_inline = run_mc(mc, [ch], "gbma", [beta], steps, 1)
    r_pallas = run_mc(mc, [ch], "gbma", [beta], steps, 1,
                      ota_impl="pallas")
    np.testing.assert_allclose(r_pallas.risks, r_inline.risks, rtol=1e-5,
                               atol=1e-9)


def test_ota_impl_rejects_padded_sweeps_and_bad_values(prob, mc):
    probs = [MSDProblem.make(n, dim=8) for n in (6, 9)]
    mcs = [p.to_mc() for p in probs]
    chs = [_ch(), _ch()]
    with pytest.raises(ValueError, match="single node count"):
        run_mc(mcs, chs, "gbma", [0.01, 0.01], 4, 1, ota_impl="ref")
    with pytest.raises(ValueError, match="ota_impl"):
        run_mc(mc, [_ch()], "gbma", [0.01], 4, 1, ota_impl="fast")
    # 'auto' on a padded sweep silently keeps the inline path
    res = run_mc(mcs, chs, "gbma", [0.01, 0.01], 4, 1, ota_impl="auto")
    assert np.all(np.isfinite(res.risks))


@settings(max_examples=24, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       scale=st.floats(0.2, 2.0),
       phase=st.sampled_from([0.0, 0.3, 0.78]),
       rician_k=st.floats(0.5, 8.0),
       seed=st.integers(0, 2**16))
def test_sample_gains_twin_matches_reference(fading, scale, phase, rician_k,
                                             seed):
    """The engine's traceable sampler must never drift from the reference
    `channel.sample_gains` (same key -> same draws), across all four fading
    families × phase-error settings."""
    cfg = ChannelConfig(fading=fading, scale=scale, rician_k=rician_k,
                        phase_error_max=phase)
    p = {"scale": jnp.float32(scale), "rician_k": jnp.float32(rician_k),
         "phase_error_max": jnp.float32(phase)}
    key = jax.random.key(seed)
    ref = channel_mod.sample_gains(key, cfg, (23,))
    twin = mc_mod._sample_gains(key, fading, p, (23,))
    np.testing.assert_allclose(np.asarray(twin), np.asarray(ref), rtol=1e-5,
                               atol=1e-7)


@settings(max_examples=16, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       n=st.sampled_from([5, 8, 23, 31, 32]),
       seed=st.integers(0, 2**16))
def test_dynamic_n_sampler_matches_shaped_draws(fading, n, seed):
    """`_sample_gains_dynamic_n` (static-shape counts-as-data threefry)
    reproduces the (n,)-shaped draw in lanes [0, n) — to float rounding
    (fused-multiply-add differences only) — and zero-pads the rest."""
    from repro import compat

    if compat.threefry2x32 is None or not compat.threefry_is_default():
        pytest.skip("raw threefry primitive unavailable")
    p = {"scale": jnp.float32(0.9), "rician_k": jnp.float32(4.0),
         "phase_error_max": jnp.float32(0.4), "n_nodes": jnp.float32(n)}
    key = jax.random.key(seed)
    ref = mc_mod._sample_gains(key, fading, p, (n,))
    dyn = mc_mod._sample_gains_dynamic_n(key, fading, p, 32)
    np.testing.assert_allclose(np.asarray(dyn[:n]), np.asarray(ref),
                               rtol=5e-7, atol=0)
    assert np.all(np.asarray(dyn[n:]) == 0.0)


def test_nsweep_fdm_matches_per_n():
    """fdm node-count sweeps (the per-node noise draw is shape-dependent
    too, handled by `_normal_dynamic_n`) reproduce the per-N runs."""
    probs = [MSDProblem.make(n, dim=12) for n in (10, 17)]
    chs = [_ch() for _ in probs]
    mcs = [p.to_mc() for p in probs]
    for invert in (False, True):
        sweep = run_mc(mcs, chs, "fdm", [0.01, 0.01], STEPS, SEEDS,
                       invert_channel=invert)
        for i, mc in enumerate(mcs):
            single = run_mc(mc, [chs[i]], "fdm", [0.01], STEPS, SEEDS,
                            invert_channel=invert)
            np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                       rtol=1e-5, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([5, 8, 13]), d=st.sampled_from([3, 7]),
       seed=st.integers(0, 2**16))
def test_dynamic_normal_matches_shaped_draws(n, d, seed):
    from repro import compat

    if compat.threefry2x32 is None or not compat.threefry_is_default():
        pytest.skip("raw threefry primitive unavailable")
    key = jax.random.key(seed)
    ref = jax.random.normal(key, (n, d))
    dyn = mc_mod._normal_dynamic_n(key, jnp.int32(n), 16, d)
    np.testing.assert_allclose(np.asarray(dyn[:n]), np.asarray(ref),
                               rtol=5e-7, atol=1e-7)
    assert np.all(np.asarray(dyn[n:]) == 0.0)


def test_energy_to_target_vectorizes_over_configs_and_seeds():
    res = _fake_result(
        [[[4.0, 2.0, 1.0, 0.5], [4.0, 3.0, 2.0, 1.0]],
         [[9.0, 8.0, 7.0, 6.0], [0.5, 0.4, 0.3, 0.2]]],
        [[[3.0, 5.0, 6.0], [1.0, 2.0, 10.0]],
         [[1.0, 2.0, 3.0], [4.0, 8.0, 12.0]]])
    out = energy_to_target(res, 2.0)
    # config 0: seed 0 hits at k=1 (3.0), seed 1 at k=2 (2.0) -> mean 2.5
    # config 1: seed 0 never hits (3.0), seed 1 at k=0 (0.0)  -> mean 1.5
    np.testing.assert_allclose(out, [2.5, 1.5])
