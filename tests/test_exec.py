"""Execution-layer (`repro.core.mc.exec`) semantics:

  * RNG-plan stream equivalence: every algorithm's `hoist_draws` twin is
    BIT-identical to the per-slot in-scan draw chain it replaces, across
    fading families × antenna modes × phase settings (property test), and
    the hoisted minibatch-index stream matches the in-scan index draws;
  * trajectory equivalence: `rng_plan='hoisted'` == `'inscan'` across
    algo families × stochastic on/off (identical streams; only XLA fusion
    rounding may differ);
  * seed chunking: chunked curves match unchunked (1e-6 criterion),
    chunk validation errors, and the donated-stats path matches the host
    reduction; `keep_seed_curves=False` returns (mean, ci95) only and
    `energy_to_target` refuses reduced results;
  * `params['b_count']` is int32: lane counts at 2^24-scale survive
    exactly (the float32 carry they replace does not), and the engine
    hands an integer lane count to the stochastic gradient;
  * `trace_count(reset=)` / `clear_cache()` bookkeeping;
  * `estimate_peak_bytes` scaling sanity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.mc import exec as exec_mod
from repro.core.mc import problems as prob_mod
from repro.core.mc import sampling
from repro.core.mc.exec import estimate_peak_bytes
from repro.core.mc.slots import ALGO_REGISTRY, SlotCtx
from repro.core.montecarlo import (clear_cache, energy_to_target,
                                   logistic_mc_problem, run_mc, trace_count)
from repro.data.synthetic import logistic_classification

N, D, STEPS, SEEDS = 14, 10, 12, 4


@pytest.fixture(scope="module")
def mc():
    return MSDProblem.make(N, dim=D).to_mc()


@pytest.fixture(scope="module")
def logistic_prob():
    X, y, _ = logistic_classification(60, dim=8, seed=3)
    return logistic_mc_problem(X, y, 10, lam=0.1)


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


def _row_params(fading, phase, m_row=None):
    p = {"scale": jnp.float32(0.9), "rician_k": jnp.float32(3.0),
         "phase_error_max": jnp.float32(phase),
         "noise_std": jnp.float32(0.5), "energy": jnp.float32(0.7),
         "n_nodes": jnp.float32(N), "n_idx": jnp.int32(0)}
    if m_row is not None:
        p["n_antennas"] = jnp.float32(m_row)
        p["m_idx"] = jnp.int32(0)
    return p


def _ctx(fading, phase, *, n_antennas=None, m_sizes=(), invert=False,
         m_row=None):
    return SlotCtx(fading=fading, p=_row_params(fading, phase, m_row),
                   mask=jnp.ones((N,), jnp.float32), n_sizes=(N,),
                   n_antennas=n_antennas, m_sizes=m_sizes,
                   invert_channel=invert, h_min=0.3,
                   phase_zero=(phase == 0.0))


def _inscan_ota_draw(key, ctx):
    """The draw chain `_ota_slot` runs in-scan (phase stream included)."""
    k_h, k_w = jax.random.split(key)
    h = sampling._row_gains(k_h, ctx.fading, ctx.p, ctx.n_sizes, N)
    return h, jax.random.normal(k_w, (D,), jnp.float32)


@settings(max_examples=16, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       phase=st.sampled_from([0.0, 0.4]),
       mode=st.sampled_from(["single", "static_m", "per_row_m"]),
       seed=st.integers(0, 2**16))
def test_gbma_hoist_streams_bit_identical_to_inscan(fading, phase, mode,
                                                    seed):
    """`_gbma_hoist_draws` replays the in-scan k → antennas → (k_h, k_w)
    chain bit-for-bit — including the static phase-zero shortcut (cos(0)
    is exactly 1, so skipping the phase stream changes no value)."""
    if mode == "single":
        ctx = _ctx(fading, phase)
    elif mode == "static_m":
        ctx = _ctx(fading, phase, n_antennas=3)
    else:
        ctx = _ctx(fading, phase, m_sizes=(2, 4), m_row=2)
    step_keys = jax.random.split(jax.random.key(seed), 5)
    draws = ALGO_REGISTRY["gbma"].hoist_draws(step_keys, ctx, N, D)
    for t in range(5):
        if mode == "single":
            akeys = [step_keys[t]]
        elif mode == "static_m":
            akeys = list(jax.random.split(step_keys[t], 3))
        else:
            akeys = list(sampling._antenna_keys(step_keys[t], (2, 4),
                                                ctx.p))
        for a_i, ak in enumerate(akeys):
            h, w = _inscan_ota_draw(ak, ctx)
            got_h = draws.get("h")
            got_w = draws["w"]
            sel = (lambda x: x[t]) if mode == "single" \
                else (lambda x: x[t, a_i])
            if got_h is None:
                assert ctx.fading == "equal" and ctx.phase_zero
            else:
                np.testing.assert_array_equal(np.asarray(sel(got_h)),
                                              np.asarray(h))
            np.testing.assert_array_equal(np.asarray(sel(got_w)),
                                          np.asarray(w))


@settings(max_examples=12, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       seed=st.integers(0, 2**16))
def test_blind_hoist_streams_bit_identical_to_inscan(fading, seed):
    ctx = _ctx(fading, 0.0, n_antennas=3)
    step_keys = jax.random.split(jax.random.key(seed), 4)
    draws = ALGO_REGISTRY["blind"].hoist_draws(step_keys, ctx, N, D)
    for t in range(4):
        for a_i, ak in enumerate(jax.random.split(step_keys[t], 3)):
            k_h, k_w = jax.random.split(ak)
            a, b = sampling._row_complex_gains(k_h, fading, ctx.p,
                                               (N,), N)
            z = jax.random.normal(k_w, (2, D), jnp.float32)
            np.testing.assert_array_equal(np.asarray(draws["a"][t, a_i]),
                                          np.asarray(a))
            np.testing.assert_array_equal(np.asarray(draws["b"][t, a_i]),
                                          np.asarray(b))
            np.testing.assert_array_equal(np.asarray(draws["z"][t, a_i]),
                                          np.asarray(z))


@settings(max_examples=12, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "lognormal"]),
       invert=st.booleans(), seed=st.integers(0, 2**16))
def test_fdm_and_pc_hoist_streams_bit_identical_to_inscan(fading, invert,
                                                          seed):
    ctx = _ctx(fading, 0.0, invert=invert)
    step_keys = jax.random.split(jax.random.key(seed), 4)
    fdm = ALGO_REGISTRY["fdm"].hoist_draws(step_keys, ctx, N, D)
    pc = ALGO_REGISTRY["power_control"].hoist_draws(step_keys, ctx, N, D)
    for t in range(4):
        k_h, k_w = jax.random.split(step_keys[t])
        raw = sampling._normal_padded(k_w, ctx.p["n_idx"], (N,), N, D,
                                      jnp.float32)
        np.testing.assert_array_equal(np.asarray(fdm["noise_raw"][t]),
                                      np.asarray(raw))
        if not invert and not (fading == "equal" and ctx.phase_zero):
            h = sampling._row_gains(k_h, fading, ctx.p, (N,), N)
            np.testing.assert_array_equal(np.asarray(fdm["h"][t]),
                                          np.asarray(h))
        h_pc, w_pc = _inscan_ota_draw(step_keys[t], ctx)
        if "h" in pc:
            np.testing.assert_array_equal(np.asarray(pc["h"][t]),
                                          np.asarray(h_pc))
        np.testing.assert_array_equal(np.asarray(pc["w"][t]),
                                      np.asarray(w_pc))


def test_minibatch_index_stream_bit_identical(logistic_prob):
    """The hoisted minibatch-index stream == the in-scan per-slot index
    draws (the data-key chain is untouched by hoisting)."""
    spec = prob_mod.PROBLEMS["logistic"]
    batch = prob_mod.MCProblemBatch.stack([logistic_prob])
    row = {k: v[0] for k, v in batch.data.items()}
    key = jax.random.key(5)
    data_keys = jax.random.split(
        jax.random.fold_in(key, exec_mod._DATA_STREAM), 6)
    hoisted = jax.vmap(lambda dk: spec.sample_indices_row(row, dk, 3))(
        data_keys)
    for t in range(6):
        np.testing.assert_array_equal(
            np.asarray(hoisted[t]),
            np.asarray(spec.sample_indices_row(row, data_keys[t], 3)))


@settings(max_examples=10, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       algo=st.sampled_from(["gbma", "fdm", "power_control", "momentum",
                             "blind", "blind_ec"]),
       stochastic=st.booleans())
def test_rng_plans_produce_equivalent_trajectories(fading, algo,
                                                   stochastic):
    """hoisted == inscan trajectories: the streams are identical, so any
    difference is XLA fusion rounding (bounded well inside the sweep
    reproduction tolerance)."""
    if stochastic:
        X, y, _ = logistic_classification(48, dim=6, seed=1)
        problem = logistic_mc_problem(X, y, 8, lam=0.1)
        kw = {"batch_frac": 0.5}
        beta = 0.3
    else:
        problem = MSDProblem.make(N, dim=D).to_mc()
        kw = {}
        beta = 0.01
    if algo in ("blind", "blind_ec"):
        kw["n_antennas"] = 2
    ch = _ch(fading=fading)
    r_h = run_mc(problem, [ch], algo, [beta], STEPS, 2, rng_plan="hoisted",
                 **kw)
    r_i = run_mc(problem, [ch], algo, [beta], STEPS, 2, rng_plan="inscan",
                 **kw)
    np.testing.assert_allclose(r_h.risks, r_i.risks, rtol=2e-4, atol=1e-7)
    np.testing.assert_allclose(r_h.cum_energy, r_i.cum_energy, rtol=1e-5,
                               atol=1e-9)


def test_rng_plan_validation(mc):
    with pytest.raises(ValueError, match="rng_plan"):
        run_mc(mc, [_ch()], "gbma", [0.01], 4, 1, rng_plan="fast")


def test_algo_without_hoist_twin_keeps_legacy_nsweep_hoist(monkeypatch):
    """A single-algo call whose algorithm registered no hoist_draws twin
    must fall through to the LEGACY plan — including PR 2's N-sweep gain
    hoist — not run a strictly worse draws-free hoisted program. Byte
    equality with rng_plan='inscan' proves the same program ran."""
    import dataclasses as dc

    from repro.core.mc import slots as slots_mod

    spec = ALGO_REGISTRY["gbma"]
    monkeypatch.setitem(
        slots_mod.ALGO_REGISTRY, "custom_no_twin",
        dc.replace(spec, name="custom_no_twin", hoist_draws=None,
                   theorem1=False))
    probs = [MSDProblem.make(n, dim=8).to_mc() for n in (6, 9)]
    r_h = run_mc(probs, [_ch(), _ch()], "custom_no_twin", [0.01] * 2,
                 STEPS, 2, rng_plan="hoisted")
    r_i = run_mc(probs, [_ch(), _ch()], "custom_no_twin", [0.01] * 2,
                 STEPS, 2, rng_plan="inscan")
    np.testing.assert_array_equal(r_h.risks, r_i.risks)
    # and it matches the registered gbma path (same slot fn, same keys)
    r_g = run_mc(probs, [_ch(), _ch()], "gbma", [0.01] * 2, STEPS, 2,
                 rng_plan="inscan")
    np.testing.assert_array_equal(r_h.risks, r_g.risks)


def test_mixed_algo_calls_keep_the_inscan_body(mc):
    """Hoisting is gated to homogeneous calls: a mixed-algo batch under
    the hoisted plan runs the legacy in-scan body BYTE-for-byte (every
    trajectory would otherwise materialize every algorithm's streams)."""
    algos = ("gbma", "fdm", "centralized")
    r_h = run_mc(mc, [_ch()] * 3, algos, [0.01] * 3, STEPS, 2,
                 rng_plan="hoisted")
    r_i = run_mc(mc, [_ch()] * 3, algos, [0.01] * 3, STEPS, 2,
                 rng_plan="inscan")
    np.testing.assert_array_equal(r_h.risks, r_i.risks)
    np.testing.assert_array_equal(r_h.cum_energy, r_i.cum_energy)


# --------------------------------------------------------------------------
# seed chunking
# --------------------------------------------------------------------------
def test_chunked_matches_unchunked_across_families(mc, logistic_prob):
    """The 1e-6 criterion: chunked curves reproduce the single-shot call
    for every algo family (in practice bit-identical on one device — each
    trajectory depends only on its seed)."""
    cases = [
        (mc, "gbma", 0.01, {}),
        (mc, "fdm", 0.01, {}),
        (mc, "centralized", 0.01, {}),
        (mc, "power_control", 0.01, {}),
        (mc, "nesterov", 0.01, {"momentum": 0.6}),
        (mc, "blind", 0.01, {"n_antennas": 2}),
        (mc, "blind_ec", 0.01, {"n_antennas": 2, "power_budget": 0.05}),
        (logistic_prob, "gbma", 0.3, {"batch_frac": 0.5}),
    ]
    for problem, algo, beta, kw in cases:
        full = run_mc(problem, [_ch()], algo, [beta], STEPS, SEEDS, **kw)
        chunked = run_mc(problem, [_ch()], algo, [beta], STEPS, SEEDS,
                         seed_chunk=2, **kw)
        np.testing.assert_allclose(chunked.risks, full.risks, rtol=1e-6,
                                   atol=1e-10, err_msg=algo)
        np.testing.assert_allclose(chunked.cum_energy, full.cum_energy,
                                   rtol=1e-6, atol=1e-10, err_msg=algo)
        np.testing.assert_allclose(chunked.mean, full.mean, rtol=1e-6,
                                   atol=1e-10, err_msg=algo)


def test_chunked_one_compile(mc):
    """All chunks reuse ONE compiled program (the chunk's seed ints are
    data, not shape)."""
    clear_cache()
    run_mc(mc, [_ch()], "gbma", [0.01], STEPS, 8, seed_chunk=2)
    assert trace_count() == 1


def test_chunk_validation(mc):
    with pytest.raises(ValueError, match="divide"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, 5, seed_chunk=2)
    with pytest.raises(ValueError, match="positive"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, 4, seed_chunk=0)


def test_reduced_stats_match_host_reduction(mc):
    """keep_seed_curves=False (single-shot AND chunked/donated) returns
    the same mean/ci95 the host computes from materialized curves."""
    full = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
    for kw in ({}, {"seed_chunk": 2}):
        red = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                     keep_seed_curves=False, **kw)
        assert red.risks is None and red.cum_energy is None
        np.testing.assert_allclose(red.mean, full.mean, rtol=1e-5,
                                   atol=1e-9)
        np.testing.assert_allclose(red.ci95, full.ci95, rtol=5e-3,
                                   atol=1e-7)
    with pytest.raises(ValueError, match="keep_seed_curves"):
        energy_to_target(
            run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                   keep_seed_curves=False), 0.1)


def test_single_seed_reduced_stats(mc):
    red = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, 1,
                 keep_seed_curves=False)
    assert np.all(red.ci95 == 0.0)
    full = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, 1)
    np.testing.assert_allclose(red.mean, full.mean, rtol=1e-6)


def test_finalize_merged_stats_deterministic_rows():
    """Deterministic rows: M2 is exactly 0 (centered sums of identical
    values), so ci95 is exactly 0 — no cancellation, no NaN."""
    curves = np.full((1, 5), 0.123456, np.float32)
    m2 = np.zeros_like(curves)
    mean, ci = exec_mod.finalize_merged_stats(curves, m2, 4)
    np.testing.assert_allclose(mean, curves, rtol=1e-6)
    assert np.all(ci == 0.0)


# --------------------------------------------------------------------------
# b_count int32 (satellite)
# --------------------------------------------------------------------------
def test_b_count_survives_2_24_scale(monkeypatch):
    """Lane counts are integral: carried as int32 they survive 2^24-scale
    sample counts exactly; the float32 carry they replace does not. The
    fake kind's sample axis has a zero-size trailing dim, so the
    2^24+1-sample shape allocates nothing."""
    from repro.core.mc.engine import _resolve_batch_frac
    from repro.core.mc.problems import MCProblem

    big_k = 2**24 + 1
    spec = dataclasses.replace(
        prob_mod.PROBLEMS["logistic"], kind="bigk_test")
    monkeypatch.setitem(prob_mod.PROBLEMS, "bigk_test", spec)
    problem = MCProblem(
        grad_fn=lambda t: t[None, :], risk_fn=jnp.sum, dim=2, n_nodes=1,
        kind="bigk_test", data={"Xn": np.zeros((1, big_k, 0))},
        stochastic=True)
    _, b_max, b_counts = _resolve_batch_frac(1.0 - 1e-9, 1, None, problem)
    assert b_counts == (big_k,)
    carried = jnp.asarray(b_counts, jnp.int32)
    assert int(carried[0]) == big_k, "int32 lane count must be exact"
    # the bug this guards against: a float32 carry silently rounds
    assert int(jnp.asarray(b_counts, jnp.float32)[0]) != big_k
    assert b_max == big_k


def test_engine_hands_integer_lane_count_to_sgrad(logistic_prob,
                                                  monkeypatch):
    """The engine's params['b_count'] reaches the stochastic gradient as
    an integer dtype (both RNG plans)."""
    seen = []
    spec = prob_mod.PROBLEMS["logistic"]

    def recording_sgrad(row, theta, key, b_count, b_max):
        seen.append(b_count.dtype)
        return spec.stochastic_grad_row(row, theta, key, b_count, b_max)

    def recording_from_idx(row, theta, idx, b_count):
        seen.append(b_count.dtype)
        return spec.stochastic_grad_from_idx(row, theta, idx, b_count)

    monkeypatch.setitem(
        prob_mod.PROBLEMS, "logistic",
        dataclasses.replace(spec, stochastic_grad_row=recording_sgrad,
                            stochastic_grad_from_idx=recording_from_idx))
    for plan in ("hoisted", "inscan"):
        run_mc(logistic_prob, [_ch()], "gbma", [0.3], 3, 1,
               batch_frac=0.5, rng_plan=plan)
    assert seen and all(np.issubdtype(d, np.integer) for d in seen), seen


# --------------------------------------------------------------------------
# trace-count bookkeeping (satellite)
# --------------------------------------------------------------------------
def test_clear_cache_resets_trace_count(mc):
    run_mc(mc, [_ch()], "gbma", [0.01], 3, 1)
    assert trace_count() >= 1
    cleared = clear_cache()
    assert trace_count() == 0
    run_mc(mc, [_ch()], "gbma", [0.01], 3, 1)
    if cleared:
        assert trace_count() == 1


def test_trace_count_reset_flag(mc):
    clear_cache()
    run_mc(mc, [_ch()], "gbma", [0.01], 3, 1)
    c = trace_count(reset=True)
    if c:  # 0 only if clear_cache is unsupported AND the program cached
        assert c >= 1
    assert trace_count() == 0


# --------------------------------------------------------------------------
# memory model
# --------------------------------------------------------------------------
def test_estimate_peak_bytes_scales_with_chunk():
    base = dict(n_rows=2, seeds=64, steps=100, n_max=256, dim=16,
                algo_set=("gbma",))
    all_live = estimate_peak_bytes(**base)
    chunked = estimate_peak_bytes(**base, seed_chunk=8)
    assert chunked["device_peak_bytes"] < all_live["device_peak_bytes"]
    assert chunked["s_live"] == 8 and all_live["s_live"] == 64
    # chunking bounds the O(S·steps·n_max) terms by the chunk ratio
    assert chunked["rng_draw_bytes"] * 8 == all_live["rng_draw_bytes"]
    blind = estimate_peak_bytes(**{**base, "algo_set": ("blind",)},
                                n_antennas=4)
    assert blind["rng_draw_bytes"] > all_live["rng_draw_bytes"]


def test_estimate_counts_minibatch_index_stream():
    base = dict(n_rows=1, seeds=8, steps=50, n_max=32, dim=8,
                algo_set=("gbma",))
    with_idx = estimate_peak_bytes(**base, b_max=6)
    without = estimate_peak_bytes(**base)
    assert with_idx["rng_draw_bytes"] > without["rng_draw_bytes"]
