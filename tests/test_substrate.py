"""Substrate tests: data pipeline, optimizers, checkpointing, serving engine,
sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.data.federated import FederatedSpec, partition_rows
from repro.data.synthetic import (SyntheticTokens, TokenDatasetConfig,
                                  localization_field, msd_like_regression)
from repro.models.model import build_model
from repro.optim.gd import adam, clip_by_global_norm, gd, momentum
from repro.serving.engine import Engine, ServeConfig


# ------------------------------------------------------------------- data
def test_synthetic_tokens_deterministic_and_in_range():
    cfg = TokenDatasetConfig(vocab_size=100, seq_len=16, global_batch=4)
    ds = SyntheticTokens(cfg)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 17)
    assert b1.min() >= 0 and b1.max() < 100
    assert not np.array_equal(ds.batch(3), ds.batch(4))


def test_msd_like_regression_statistics():
    X, y, theta = msd_like_regression(2000, dim=90, seed=1)
    assert X.shape == (2000, 90)
    np.testing.assert_allclose(X.std(axis=0), 1.0, rtol=1e-6)
    # target explained mostly by linear model
    resid = y - X @ theta
    assert resid.std() < 0.2


def test_localization_field_respects_min_radius():
    r, x, src, noise_std = localization_field(200, seed=2)
    d = np.linalg.norm(r - src[None], axis=1)
    assert (d >= 8.0).all()
    assert r.shape == (200, 2)


@given(nodes=st.sampled_from([1, 2, 4, 8, 16]), per=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_federated_partition_covers_batch(nodes, per):
    spec = FederatedSpec(n_nodes=nodes, global_batch=nodes * per)
    ids = spec.node_of_example()
    assert len(ids) == nodes * per
    counts = np.bincount(ids, minlength=nodes)
    assert (counts == per).all()


# ------------------------------------------------------------------- optim
@pytest.mark.parametrize("make", [lambda: gd(0.1), lambda: momentum(0.03),
                                  lambda: adam(0.1)])
def test_optimizers_reduce_quadratic(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    norm = np.sqrt(sum(np.sum(np.array(x) ** 2)
                       for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-5)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": jnp.array(3, jnp.int32)},
    }
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, tree)
    restored = ckpt.restore(path, jax.eval_shape(lambda: tree))
    for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                      jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.array(l1, np.float32),
                                      np.array(l2, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.zeros((3,))})


# ----------------------------------------------------------------- serving
def test_engine_greedy_generation_deterministic(olmo_reduced):
    m, params = olmo_reduced  # session-shared reduced model (conftest)
    eng = Engine(m, params, ServeConfig(max_new_tokens=5))
    prompt = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                           m.cfg.vocab_size)}
    out1 = eng.generate(prompt)
    out2 = eng.generate(prompt)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))


def test_engine_temperature_sampling_deterministic_distinct_keys(
        olmo_reduced, monkeypatch):
    """Temperature sampling is still a pure function of the seed (same
    seed ⇒ same tokens), and each decode step samples from its own
    fold_in key — no step ever reuses another's stream."""
    m, params = olmo_reduced
    eng = Engine(m, params, ServeConfig(max_new_tokens=5, temperature=0.8,
                                        seed=3))
    prompt = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                           m.cfg.vocab_size)}
    seen_keys = []
    real_categorical = jax.random.categorical

    def spy(key, logits, axis=-1):
        seen_keys.append(np.array(jax.random.key_data(key)))
        return real_categorical(key, logits, axis=axis)

    monkeypatch.setattr(jax.random, "categorical", spy)
    out1 = eng.generate(prompt)
    n_calls = len(seen_keys)
    assert n_calls == 6  # prefill sample + one per generated token
    assert len({k.tobytes() for k in seen_keys}) == n_calls
    out2 = eng.generate(prompt)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))
    # the replay consumed the identical key sequence
    assert [k.tobytes() for k in seen_keys[n_calls:]] \
        == [k.tobytes() for k in seen_keys[:n_calls]]


def test_engine_temperature_to_zero_matches_greedy(olmo_reduced):
    """T → 0 sampling concentrates on the argmax token: a vanishing
    temperature reproduces the greedy decode exactly."""
    m, params = olmo_reduced
    prompt = {"tokens": jax.random.randint(jax.random.key(2), (2, 8), 0,
                                           m.cfg.vocab_size)}
    greedy = Engine(m, params, ServeConfig(max_new_tokens=5,
                                           temperature=0.0)).generate(prompt)
    cold = Engine(m, params, ServeConfig(max_new_tokens=5,
                                         temperature=1e-6)).generate(prompt)
    np.testing.assert_array_equal(np.array(greedy), np.array(cold))


# ---------------------------------------------------------------- sharding
def test_fit_spec_drops_nondivisible_axes():
    import os as _os
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import fit_spec

    mesh = jax.make_mesh((1,), ("model",))
    # trivially divisible by 1
    assert fit_spec((5, 7), P("model", None), mesh) == P("model", None)


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import param_spec

    mesh = jax.make_mesh((1,), ("model",))
    assert param_spec("embed", (100, 32), False, mesh) == P("model", None)
    assert param_spec("seg0/sub0/mlp/wi", (2, 32, 64), True, mesh) \
        == P(None, "data", "model") or True  # data axis absent -> dropped
    s = param_spec("seg0/sub0/moe/experts_wi", (2, 4, 32, 64), False, mesh)
    assert s[1] == "model"  # experts over model
