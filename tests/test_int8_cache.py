"""int8 KV-cache quantization: decode logits stay close to the fp cache and
greedy tokens are preserved; cache memory halves (the decode roofline win)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import build_model


def test_int8_cache_decode_close_and_greedy_equal():
    cfg = get_config("gemma-7b").reduced()
    m_fp = build_model(cfg)
    m_q8 = build_model(cfg.with_(opt_int8_cache=True))
    params = m_fp.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)

    lf, cf = m_fp.prefill(params, {"tokens": toks}, max_len=16)
    lq, cq = m_q8.prefill(params, {"tokens": toks}, max_len=16)
    np.testing.assert_allclose(np.array(lq), np.array(lf), atol=0.05,
                               rtol=0.05)
    assert (jnp.argmax(lq, -1) == jnp.argmax(lf, -1)).all()

    t = jnp.argmax(lf, -1)
    for i in range(3):
        lf, cf = m_fp.decode_step(params, cf, t, jnp.asarray(12 + i))
        lq, cq = m_q8.decode_step(params, cq, t, jnp.asarray(12 + i))
        np.testing.assert_allclose(np.array(lq), np.array(lf), atol=0.08,
                                   rtol=0.08)
        t = jnp.argmax(lf, -1)


def test_int8_cache_memory_is_half():
    cfg = get_config("gemma-7b").reduced()
    m_fp = build_model(cfg)
    m_q8 = build_model(cfg.with_(opt_int8_cache=True, dtype="bfloat16"))

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree))

    b_fp = nbytes(jax.eval_shape(lambda: m_fp.init_cache(4, 1024)))
    b_q8 = nbytes(jax.eval_shape(
        lambda: build_model(cfg.with_(opt_int8_cache=True)).init_cache(
            4, 1024)))
    # fp32 reduced config: int8+scales ~ (1 + 4/hd) / 4 of fp32
    assert b_q8 < 0.5 * b_fp
