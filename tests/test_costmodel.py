"""Measured cost model: predictions, the calibration artifact, planner
routing, and the bench-runner clobber guard.

The load-bearing pins:

  * `predict_run_us` is monotone non-decreasing in N, seeds and steps —
    every fitted coefficient is clamped >= 0 and the working-set profile
    factors are cummax'd, so the planner can trust comparisons.
  * The calibration artifact is versioned and keyed by
    `<platform>/<device_count>`: a version bump, a foreign key, or a
    peaks-only entry (no fitted coefficients) is *stale* and
    `load_cost_model` returns None.
  * `auto_plan(cost_model="measured")` with no calibration entry is the
    analytic path EXACTLY (behavior pin); with an injected model it
    re-prices the seed chunk by predicted wall-clock.
  * An unfiltered `python -m benchmarks.run` routes tracked-record
    benches to the `.smoke.json` path unless `--write-bench` is passed
    (the bench-clobber footgun).
"""
from __future__ import annotations

import json
import sys

import pytest

from repro.core.mc.costmodel import (
    CALIBRATION_VERSION,
    CalibrationConfig,
    CostModel,
    Workload,
    analytic_cost_model,
    cached_machine_peaks,
    load_cost_model,
    mc_slot_model,
    platform_key,
)
from repro.core.mc.plan import ExecPlan, auto_plan


# --------------------------------------------------------------------------
# fixtures: synthetic artifacts / models
# --------------------------------------------------------------------------
def _entry(**over) -> dict:
    entry = {
        "coeffs": {"gbma": {"c0_us": 10.0, "c1_us": 1e-3},
                   "blind": {"c0_us": 20.0, "c1_us": 2e-3}},
        "dispatch_us": 300.0,
        "compile_s": 1.5,
        "chunk_profile": [[1 << 20, 1.0], [64 << 20, 1.7]],
        "peaks": {"peak_gflops": 4.0, "peak_gibs": 3.0},
    }
    entry.update(over)
    return entry


def _write_artifact(path, entry=None, key=None,
                    version=CALIBRATION_VERSION) -> None:
    data = {"version": version,
            "entries": {key if key else platform_key():
                        _entry() if entry is None else entry}}
    path.write_text(json.dumps(data))


def _synthetic(dispatch_us=0.0, compile_s=0.0, c0=0.0, c1=1.0,
               chunk_profile=()) -> CostModel:
    return CostModel(
        coeffs=(("blind", c0, c1), ("gbma", c0, c1)),
        dispatch_us=dispatch_us, compile_s=compile_s,
        chunk_profile=chunk_profile,
        peaks=(("peak_gflops", 1.0), ("peak_gibs", 1.0)),
        source="measured")


_PLAN = ExecPlan(seed_chunk=4, n_shards=0, row_shards=1,
                 keep_seed_curves=False)


def _wl(**over) -> Workload:
    base = dict(n_rows=2, seeds=8, steps=50, n_max=64, dim=8)
    base.update(over)
    return Workload(**base)


# --------------------------------------------------------------------------
# slot model + prediction properties
# --------------------------------------------------------------------------
def test_slot_model_families_and_roofline_delegate():
    g = mc_slot_model("gbma", 64, 8)
    assert g["flops"] == 8 * 64 * 8 + 2 * 8 * 8
    assert g["bytes"] == (5 * 64 * 8 + 64) * 4
    b = mc_slot_model("blind", 64, 8, m=4)
    assert b["flops"] > g["flops"]
    from benchmarks.roofline import mc_slot_model as roofline_model
    assert roofline_model("blind", 64, 8, 4) == b
    with pytest.raises(ValueError, match="no slot model"):
        mc_slot_model("warp", 8, 8)


@pytest.mark.parametrize("model", [analytic_cost_model(),
                                   _synthetic(dispatch_us=300.0, c0=5.0,
                                              c1=1e-3)])
def test_predict_run_us_monotone_in_n_seeds_steps(model):
    """The planner comparison contract: predicted wall-clock never
    decreases when the workload grows along any axis."""
    for axis, grid in (("n_max", (16, 64, 256, 1024)),
                       ("seeds", (4, 8, 16, 64)),
                       ("steps", (10, 50, 200, 1000))):
        preds = [model.predict_run_us(_PLAN, _wl(**{axis: v}),
                                      device_count=1) for v in grid]
        assert preds == sorted(preds), (axis, preds)
        assert all(p > 0 for p in preds)


def test_profile_factor_interpolates_and_clamps():
    m = _synthetic(chunk_profile=((100.0, 1.0), (200.0, 2.0)))
    assert m._profile_factor(50.0) == 1.0    # below the probed range
    assert m._profile_factor(150.0) == pytest.approx(1.5)
    assert m._profile_factor(10_000.0) == 2.0  # clamped beyond it
    assert _synthetic()._profile_factor(123.0) == 1.0  # no profile


def test_predict_step_us_prices_the_worst_family():
    m = _synthetic(c0=1.0, c1=1e-3)
    wl = _wl(algo_set=("gbma", "blind"), m_sizes=(2,))
    blind_only = m.predict_step_us(_PLAN, _wl(algo_set=("blind",),
                                              m_sizes=(2,)),
                                   device_count=1)
    assert m.predict_step_us(_PLAN, wl, device_count=1) == blind_only


# --------------------------------------------------------------------------
# the calibration artifact
# --------------------------------------------------------------------------
def test_load_cost_model_roundtrip(tmp_path):
    p = tmp_path / "cal.json"
    _write_artifact(p)
    m = load_cost_model(str(p))
    assert m is not None and m.source == "measured"
    assert dict((f, (a, b)) for f, a, b in m.coeffs) == \
        {"gbma": (10.0, 1e-3), "blind": (20.0, 2e-3)}
    assert m.dispatch_us == 300.0 and m.compile_s == 1.5
    assert m.chunk_profile == ((float(1 << 20), 1.0),
                               (float(64 << 20), 1.7))


def test_stale_artifacts_are_not_loaded(tmp_path):
    missing = tmp_path / "nope.json"
    assert load_cost_model(str(missing)) is None

    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    assert load_cost_model(str(garbage)) is None

    stale = tmp_path / "stale.json"
    _write_artifact(stale, version=CALIBRATION_VERSION + 1)
    assert load_cost_model(str(stale)) is None

    foreign = tmp_path / "foreign.json"
    _write_artifact(foreign, key="tpu/8")
    assert load_cost_model(str(foreign)) is None
    assert load_cost_model(str(foreign), platform="tpu",
                           device_count=8) is not None

    peaks_only = tmp_path / "peaks.json"
    _write_artifact(peaks_only,
                    entry={"peaks": {"peak_gflops": 1.0,
                                     "peak_gibs": 1.0}})
    assert load_cost_model(str(peaks_only)) is None  # no coefficients


def test_cached_machine_peaks_measures_once(tmp_path):
    p = tmp_path / "cal.json"
    calls = []

    def fake(dim=1536, reps=3):
        calls.append(dim)
        return {"peak_gflops": 1.0, "peak_gibs": 2.0}

    first = cached_machine_peaks(dim=64, reps=1, path=str(p), measure=fake)
    assert first == {"peak_gflops": 1.0, "peak_gibs": 2.0}
    assert calls == [64]
    # second call is served from the artifact entry — no re-measure
    second = cached_machine_peaks(dim=64, reps=1, path=str(p),
                                  measure=fake)
    assert second == first and calls == [64]
    # a different device count is a different entry key: measured afresh
    cached_machine_peaks(dim=64, reps=1, path=str(p), device_count=7,
                         measure=fake)
    assert calls == [64, 64]
    data = json.loads(p.read_text())
    assert data["version"] == CALIBRATION_VERSION
    assert set(data["entries"]) == {platform_key(), platform_key(7)}


def test_smoke_calibration_config_is_strictly_smaller():
    full, smoke = CalibrationConfig(), CalibrationConfig.smoke()
    assert max(smoke.n_grid) < max(full.n_grid)
    assert smoke.probe_seeds < full.probe_seeds
    assert smoke.peaks_dim < full.peaks_dim


# --------------------------------------------------------------------------
# auto_plan routing
# --------------------------------------------------------------------------
_AUTO_KW = dict(n_rows=4, seeds=64, steps=400, n_max=512, dim=16,
                memory_budget_bytes=1 << 30, device_count=1)


def test_auto_plan_measured_without_calibration_is_analytic(tmp_path):
    """The behavior pin: no matching calibration entry -> the analytic
    plan, field for field."""
    analytic = auto_plan(**_AUTO_KW)
    measured = auto_plan(**_AUTO_KW, cost_model="measured",
                         calibration_path=str(tmp_path / "absent.json"))
    assert measured == analytic


def test_auto_plan_rejects_unknown_cost_model():
    with pytest.raises(ValueError, match="cost_model"):
        auto_plan(**_AUTO_KW, cost_model="vibes")


def test_auto_plan_injected_model_reprices_the_chunk():
    """A dispatch-dominated model makes every extra engine call a loss:
    the measured branch picks the all-live call (one dispatch) where the
    analytic cache-target heuristic would chunk."""
    analytic = auto_plan(**_AUTO_KW, target_chunk_bytes=1 << 24)
    assert analytic.seed_chunk is not None  # the heuristic chunks
    plan = auto_plan(**_AUTO_KW, target_chunk_bytes=1 << 24,
                     cost_model="measured",
                     _model=_synthetic(dispatch_us=1e9, c0=0.0, c1=0.0))
    assert plan.seed_chunk is None  # one call, everything else equal
    assert (plan.n_shards, plan.row_shards) == \
        (analytic.n_shards, analytic.row_shards)


def test_auto_plan_keeps_analytic_chunk_inside_the_tie_band():
    """A flat model (every chunk predicts identically) must not move the
    choice off the analytic chunk — conservative within 5%."""
    analytic = auto_plan(**_AUTO_KW, target_chunk_bytes=1 << 24)
    plan = auto_plan(**_AUTO_KW, target_chunk_bytes=1 << 24,
                     cost_model="measured",
                     _model=_synthetic(dispatch_us=0.0, c0=1.0, c1=0.0))
    assert plan == analytic


# --------------------------------------------------------------------------
# the bench-clobber footgun
# --------------------------------------------------------------------------
def test_unfiltered_bench_run_never_writes_tracked_record(monkeypatch):
    """`python -m benchmarks.run [bench_montecarlo]` must route the
    tracked-record bench to its smoke path unless `--write-bench` is
    passed; the flag flips the kwarg."""
    import benchmarks.bench_montecarlo as bm
    import benchmarks.run as runner

    seen = []

    def fake_run(verbose=True, smoke=False, write_bench=True):
        seen.append(write_bench)
        return {}

    monkeypatch.setattr(bm, "run", fake_run)
    monkeypatch.setattr(sys, "argv", ["run", "bench_montecarlo"])
    runner.main()
    monkeypatch.setattr(sys, "argv",
                        ["run", "bench_montecarlo", "--write-bench"])
    runner.main()
    assert seen == [False, True]
