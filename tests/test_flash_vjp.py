"""Flash custom-VJP attention: forward AND gradients must match the
materializing full-attention oracle under jax autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import full_attention
from repro.models.flash_vjp import flash_attention


@pytest.mark.parametrize("sq,hq,hkv,d,kw", [
    (96, 2, 2, 16, {}),
    # GQA-plain and the single-flag variants are subsumed by the combined
    # GQA+window+softcap case below; they still run with --runslow
    pytest.param(128, 4, 2, 32, {}, marks=pytest.mark.slow),   # GQA
    (100, 2, 2, 16, {}),                      # padding path
    pytest.param(96, 2, 2, 16, {"window": 24}, marks=pytest.mark.slow),
    pytest.param(96, 2, 2, 16, {"softcap": 15.0}, marks=pytest.mark.slow),
    (128, 2, 1, 16, {"window": 40, "softcap": 25.0}),
])
def test_flash_vjp_matches_oracle(sq, hq, hkv, d, kw):
    kw = dict(kw)
    ks = jax.random.split(jax.random.key(sq * hq + d), 4)
    q = jax.random.normal(ks[0], (1, hq, sq, d))
    k = jax.random.normal(ks[1], (1, hkv, sq, d))
    v = jax.random.normal(ks[2], (1, hkv, sq, d))
    t = jax.random.normal(ks[3], (1, hq, sq, d))

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, scale=d**-0.5, causal=True,
                            block_q=32, block_kv=32, **kw)
        return jnp.sum(o * t)

    def loss_ref(q, k, v):
        o = full_attention(q, k, v, scale=d**-0.5, causal=True,
                           softcap=kw.get("softcap"),
                           window=kw.get("window"))
        return jnp.sum(o * t)

    o1 = flash_attention(q, k, v, scale=d**-0.5, causal=True,
                         block_q=32, block_kv=32, **kw)
    o2 = full_attention(q, k, v, scale=d**-0.5, causal=True,
                        softcap=kw.get("softcap"), window=kw.get("window"))
    np.testing.assert_allclose(np.array(o1), np.array(o2), atol=2e-5,
                               rtol=1e-4)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=5e-4,
                                   rtol=5e-3, err_msg=f"d{name}")


@pytest.mark.slow
def test_flash_vjp_in_model_matches_blockwise():
    """opt_flash_vjp=True must not change losses or gradients of a dense
    model (olmo reduced)."""
    from repro.configs.registry import get_config
    from repro.models.model import build_model

    cfg = get_config("olmo-1b").reduced()
    m0 = build_model(cfg)
    m1 = build_model(cfg.with_(opt_flash_vjp=True))
    params = m0.init_params(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 33), 0,
                                          cfg.vocab_size)}

    def mean_loss(model):
        def f(p):
            losses, _ = model.train_loss_per_example(p, batch)
            return jnp.mean(losses)
        return f

    l0, g0 = jax.value_and_grad(mean_loss(m0))(params)
    l1, g1 = jax.value_and_grad(mean_loss(m1))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-4,
                                   rtol=1e-2)
