"""Back-compat guard for the montecarlo -> repro.core.mc package split.

Every name that was importable from `repro.core.montecarlo` (and
re-exported through `repro.core`) before the split must still resolve
through the shim — downstream scripts and notebooks import both the
public API and, in tests, the underscore sampler helpers. The shim must
also stay *live*: registry-derived views (`ALGOS`, `PROBLEMS`) reflect
late `register_*` calls.
"""
import importlib

import pytest

# the public surface of the pre-split module
PUBLIC_NAMES = [
    "ALGOS",
    "ChannelBatch",
    "MCProblem",
    "MCProblemBatch",
    "MCResult",
    "clear_cache",
    "energy_to_target",
    "localization_mc_problem",
    "quadratic_mc_problem",
    "run_mc",
    "trace_count",
]

# private helpers exercised by tests / notebooks against the old module
PRIVATE_NAMES = [
    "_OTA_ALGOS",
    "_BLIND_ALGOS",
    "_PER_NODE_FIELDS",
    "_ROW_FNS",
    "_antenna_keys",
    "_bits_to_u01",
    "_dynamic_bits",
    "_dynamic_threefry_ok",
    "_magnitude_m2",
    "_mc_core",
    "_normal_dynamic_n",
    "_normal_padded",
    "_ota_slot",
    "_resolve_n_shards",
    "_row_complex_gains",
    "_row_gains",
    "_sample_complex_gains",
    "_sample_complex_gains_dynamic_n",
    "_sample_complex_gains_padded",
    "_sample_gains",
    "_sample_gains_dynamic_n",
    "_sample_gains_padded",
    "_sample_magnitude",
    "_sample_magnitude_dynamic_n",
    "_slot_update",
]


@pytest.mark.parametrize("name", PUBLIC_NAMES + PRIVATE_NAMES)
def test_name_resolves_through_the_shim(name):
    mod = importlib.import_module("repro.core.montecarlo")
    assert getattr(mod, name) is not None, (
        f"repro.core.montecarlo.{name} no longer resolves — the "
        "back-compat shim over repro.core.mc lost it")


def test_shim_objects_are_the_package_objects():
    """The shim re-exports, it does not duplicate: engine state (the
    compile counter, the jit cache) must be shared."""
    shim = importlib.import_module("repro.core.montecarlo")
    engine = importlib.import_module("repro.core.mc.engine")
    problems = importlib.import_module("repro.core.mc.problems")
    sampling = importlib.import_module("repro.core.mc.sampling")
    assert shim.run_mc is engine.run_mc
    assert shim._mc_core is engine._mc_core
    assert shim.trace_count is engine.trace_count
    assert shim.MCProblem is problems.MCProblem
    assert shim._sample_gains is sampling._sample_gains


def test_repro_core_reexports_still_resolve():
    core = importlib.import_module("repro.core")
    for name in core.__all__:
        assert getattr(core, name) is not None, f"repro.core.{name} broke"
    # the historical montecarlo re-exports specifically
    for name in ("ChannelBatch", "MCProblem", "MCResult", "run_mc",
                 "localization_mc_problem", "quadratic_mc_problem"):
        assert getattr(core, name) is not None


def test_algos_view_is_live(monkeypatch):
    """Registering a new algorithm shows up through the shim's ALGOS (the
    old module-level tuple is now a registry view)."""
    from repro.core.mc import slots

    shim = importlib.import_module("repro.core.montecarlo")
    before = shim.ALGOS
    assert "test_dummy_algo" not in before
    monkeypatch.setitem(
        slots.ALGO_REGISTRY, "test_dummy_algo",
        slots.AlgoSpec(name="test_dummy_algo",
                       slot_fn=slots._centralized_slot))
    assert "test_dummy_algo" in shim.ALGOS
    assert "test_dummy_algo" not in shim._OTA_ALGOS  # not flagged ota


def test_problems_view_is_live(monkeypatch):
    from repro.core.mc import problems

    shim = importlib.import_module("repro.core.montecarlo")
    assert set(shim._PER_NODE_FIELDS) == set(problems.PROBLEMS)
    spec = problems.PROBLEMS["quadratic"]
    monkeypatch.setitem(problems.PROBLEMS, "test_dummy_problem", spec)
    assert "test_dummy_problem" in shim._PER_NODE_FIELDS
    assert shim._ROW_FNS["test_dummy_problem"] == (spec.grad_row,
                                                   spec.risk_row)


def test_duplicate_registration_is_rejected():
    from repro.core.mc.problems import PROBLEMS, register_problem
    from repro.core.mc.slots import ALGO_REGISTRY, register_algo

    spec = PROBLEMS["quadratic"]
    with pytest.raises(ValueError):
        register_problem("quadratic", spec.grad_row, spec.risk_row,
                         spec.pad_values)
    with pytest.raises(ValueError):
        register_algo("gbma", ALGO_REGISTRY["gbma"].slot_fn)
