"""Prefill+decode must equal teacher forcing for the stateful families too
(rwkv state carry, hymba ssm+kv, whisper cross-attn) — the serving-path
correctness contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.model import build_model


# tier-1 keeps one pure-state family (rwkv) and the trickiest kv family
# (deepseek MLA); the hybrid/cross-attn/windowed variants run with --runslow
@pytest.mark.parametrize("arch,atol", [
    ("rwkv6-7b", 5e-3),
    pytest.param("hymba-1.5b", 5e-3, marks=pytest.mark.slow),
    pytest.param("whisper-small", 5e-3, marks=pytest.mark.slow),
    pytest.param("gemma2-9b", 5e-3, marks=pytest.mark.slow),
    ("deepseek-v3-671b", 2e-2),  # MLA absorbed decode vs expanded train path
])
def test_decode_matches_incremental_prefill(arch, atol):
    """Greedy decoding token t given prefill(0..t-1) must match
    prefill(0..t) logits at the last position. MoE archs run dropless
    (capacity_factor high): capacity dropping differs between the grouped
    prefill and the single-token decode by design (GShard semantics)."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=100.0)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    extra = {}
    if m.kind == "encdec":
        extra["frames"] = jax.random.normal(jax.random.key(2),
                                            (1, cfg.enc_seq, cfg.d_model))

    # reference: prefill over all 10 tokens -> logits at position 9
    ref_logits, _ = m.prefill(params, {"tokens": toks, **extra},
                              max_len=12)
    # incremental: prefill 9, decode token 9
    _, cache = m.prefill(params, {"tokens": toks[:, :9], **extra},
                         max_len=12)
    pos = 9 + (cfg.meta_tokens or 0)
    inc_logits, _ = m.decode_step(params, cache, toks[:, 9],
                                  jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(np.array(inc_logits), np.array(ref_logits),
                               atol=atol, rtol=1e-2)
