"""Roofline analysis unit tests: HLO collective parsing + term arithmetic."""
import numpy as np

from repro.launch.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms,
                                   _shape_bytes, collective_bytes)

HLO = """
HloModule jit_step

%fused (p: f32[4,4]) -> f32[4,4] {
  ROOT %x = f32[4,4] add(%p, %p)
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %ag = bf16[128,256]{1,0} all-gather(%a), dimensions={0}
  %ar = f32[64]{0} all-reduce(%b), to_apply=%add
  %a2a = bf16[32,16]{1,0} all-to-all(%c), dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%d), dimensions={0}
  %cp = bf16[16]{0} collective-permute(%e), source_target_pairs={{0,1}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("f32[64]") == 256
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_collective_bytes_parses_all_kinds():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["all-to-all"] == 32 * 16 * 2
    assert out["reduce-scatter"] == 8 * 8 * 4
    assert out["collective-permute"] == 16 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_roofline_terms_math():
    t = RooflineTerms(hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=50e9,
                      model_flops=98.5e12, chips=256)
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 1.0)
    np.testing.assert_allclose(t.collective_s, 1.0)
    assert t.useful_ratio == 0.5
    t2 = RooflineTerms(hlo_flops=1.0, hlo_bytes=819e9, coll_bytes=0,
                       model_flops=500e12, chips=256)
    # analytic model flops bind when HLO undercounts (scan bodies)
    np.testing.assert_allclose(t2.compute_s, 500e12 / PEAK_FLOPS)
    assert t2.dominant == "compute"
