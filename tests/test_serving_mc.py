"""MC sweep server: coalescing, scheduling and fault semantics.

Every test here is deterministic — no wall-clock sleeps, no threads:
async scenarios run on a private event loop (`_serving_harness.run`)
with the inline executor (engine quanta execute synchronously in issue
order) and, where the coalesce window matters, a manual clock.

The load-bearing assertions:

  * K signature-compatible concurrent requests execute as ONE `_mc_core`
    compile (`trace_count()`), and each demuxed per-request result
    matches a dedicated solo `run_mc` to <= 1e-6 (acceptance criterion).
  * Incompatible signatures never merge (property test over problem ×
    algo × N × fading × batch_frac).
  * Seed-quantum round-robin: a many-seed whale's batch is preempted so
    small batches finish first.
  * Faults stay contained: a cancelled client detaches without touching
    batchmates, an over-budget request is rejected at submit with a
    typed error, malformed payloads never reach the queue, an engine
    failure resolves only its own batch's futures.
"""
from __future__ import annotations

import asyncio
import dataclasses

import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.core.mc import (
    MCProblemBatch,
    clear_cache,
    logistic_mc_problem,
    quadratic_mc_problem,
    run_mc,
    trace_count,
)
from repro.core.mc.costmodel import CostModel
from repro.core.mc.plan import RetryPolicy
from repro.serving.mc_server import (
    AdmissionError,
    InlineExecutor,
    McServeConfig,
    McSweepServer,
    PartialResult,
    QuarantinedError,
    RequestError,
    ServeError,
    SweepRequest,
    serve_sync,
)
from tests._fault_harness import ClockJump, FlakyOnce
from tests._hypothesis_compat import given, settings, strategies
from tests._serving_harness import (
    ManualClock,
    ScriptedClient,
    TracingExecutor,
    run,
    submit_all,
)

STEPS, SEEDS, DIM = 6, 4, 3


# --------------------------------------------------------------------------
# request builders
# --------------------------------------------------------------------------
def _quad(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, DIM)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    return quadratic_mc_problem(x, y, 0.1, np.zeros(DIM, np.float32))


def _logistic(n: int, seed: int = 0, k: int = 4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n * k, DIM))
    y = np.sign(rng.normal(size=(n * k,))) + (rng.normal(size=(n * k,)) == 0)
    return logistic_mc_problem(x, y, n, 0.1)


def _req(n=8, noise=0.5, beta=0.08, *, kind="quadratic", algo="gbma",
         fading="rayleigh", steps=STEPS, seeds=SEEDS, seed0=0,
         batch_frac=1.0, n_antennas=None, data_seed=0, **kw):
    prob = _quad(n, data_seed) if kind == "quadratic" \
        else _logistic(n, data_seed)
    return SweepRequest(
        problem=prob, channels=[ChannelConfig(fading=fading,
                                              noise_std=noise)],
        algo=algo, betas=[beta], steps=steps, seeds=seeds, seed0=seed0,
        batch_frac=batch_frac, n_antennas=n_antennas, **kw)


def _solo(req: SweepRequest):
    """Dedicated-call reference on the same row-based engine path."""
    probs = list(req.problem) if isinstance(req.problem, (list, tuple)) \
        else [req.problem] * len(req.channels)
    return run_mc(MCProblemBatch.stack(probs), req.channels, req.algo,
                  req.betas, req.steps, req.seeds, seed0=req.seed0,
                  batch_frac=req.batch_frac, n_antennas=req.n_antennas,
                  power_budget=req.power_budget, momentum=req.momentum,
                  theta0=req.theta0, shard_seeds=False)


def _assert_matches_solo(res, req, tol=1e-6):
    solo = _solo(req)
    np.testing.assert_allclose(res.risks, solo.risks, rtol=tol, atol=tol)
    np.testing.assert_allclose(res.mean, solo.mean, rtol=tol, atol=tol)
    np.testing.assert_allclose(res.ci95, solo.ci95, rtol=tol, atol=tol)
    np.testing.assert_allclose(res.cum_energy, solo.cum_energy,
                               rtol=tol, atol=tol)


def _sig(req) -> str:
    return McSweepServer()._normalize(req).signature


# --------------------------------------------------------------------------
# coalescing correctness
# --------------------------------------------------------------------------
def test_compatible_requests_coalesce_to_one_compile_and_demux():
    """Three requests differing only in row data (N, noise, stepsize)
    are one batch, one compile, and each client's slice matches its
    dedicated solo run — the acceptance criterion."""
    reqs = [_req(6, 0.5, 0.08, data_seed=0),
            _req(12, 1.0, 0.05, data_seed=1),
            _req(9, 0.1, 0.10, data_seed=2)]
    assert len({_sig(r) for r in reqs}) == 1
    clear_cache()
    results = serve_sync(reqs, McServeConfig(quantum_seeds=SEEDS))
    assert trace_count() == 1
    stats = serve_sync.last_stats
    assert [b["requests"] for b in stats.batches] == [3]
    assert stats.batches[0]["rows"] == 3
    for res, req in zip(results, reqs):
        assert res.risks.shape == (1, SEEDS, STEPS + 1)
        _assert_matches_solo(res, req)


def test_one_compile_per_distinct_signature():
    """Five mixed requests spanning three real static signatures (steps,
    algo) compile exactly three times."""
    reqs = [
        _req(6, 0.5, 0.08, data_seed=0),
        _req(10, 1.0, 0.05, data_seed=1),
        _req(8, 0.3, 0.08, algo="momentum", data_seed=2),
        _req(8, 0.5, 0.08, steps=STEPS + 4, data_seed=3),
        _req(7, 0.2, 0.06, data_seed=4),
    ]
    assert len({_sig(r) for r in reqs}) == 3
    clear_cache()
    serve_sync(reqs, McServeConfig(quantum_seeds=SEEDS))
    assert trace_count() == 3
    stats = serve_sync.last_stats
    assert sorted(b["requests"] for b in stats.batches) == [1, 1, 3]


@settings(max_examples=4, deadline=None)
@given(kind=strategies.sampled_from(("quadratic", "logistic")),
       n_a=strategies.sampled_from((6, 10)),
       n_b=strategies.sampled_from((6, 10)),
       algo=strategies.sampled_from(("gbma", "momentum")),
       fading=strategies.sampled_from(("rayleigh", "equal")),
       minibatch=strategies.booleans())
def test_property_coalescing_equivalence(kind, n_a, n_b, algo, fading,
                                         minibatch):
    """Property: any two compatible requests (same problem kind, algo,
    fading, steps, seeds, batch_frac mode; any N mix) coalesce into one
    batch whose demuxed curves match solo runs <= 1e-6; a request whose
    signature differs (longer horizon) is never merged with them."""
    frac = 0.5 if (minibatch and kind == "logistic") else 1.0
    a = _req(n_a, 0.5, 0.08, kind=kind, algo=algo, fading=fading,
             batch_frac=frac, data_seed=0)
    b = _req(n_b, 1.0, 0.05, kind=kind, algo=algo, fading=fading,
             batch_frac=frac, data_seed=1)
    other = _req(n_a, 0.5, 0.08, kind=kind, algo=algo, fading=fading,
                 batch_frac=frac, steps=STEPS + 4, data_seed=2)
    assert _sig(a) == _sig(b) != _sig(other)
    results = serve_sync([a, b, other], McServeConfig(quantum_seeds=SEEDS))
    stats = serve_sync.last_stats
    assert [b_["requests"] for b_ in stats.batches] == [2, 1]
    assert stats.batches[0]["rows"] == 2
    for res, req in zip(results, [a, b, other]):
        _assert_matches_solo(res, req)


def test_full_batch_never_merges_with_minibatch():
    """batch_frac=1.0 rides the exact no-sampling path; merging it into
    a frac<1 batch would silently convert it to with-replacement
    sampling, so the stochastic mode is a signature facet."""
    exact = _req(6, kind="logistic", batch_frac=1.0)
    mini = _req(6, kind="logistic", batch_frac=0.5)
    assert _sig(exact) != _sig(mini)
    serve_sync([exact, mini], McServeConfig(quantum_seeds=SEEDS))
    assert [b["requests"] for b in serve_sync.last_stats.batches] == [1, 1]


def test_multi_row_requests_and_antenna_rows_coalesce():
    """Requests carrying several rows each (their own mini-sweeps) and
    per-row antenna counts still pack into one batch and demux whole."""
    a = SweepRequest(problem=_quad(6, 0), algo="gbma",
                     channels=[ChannelConfig(noise_std=0.5),
                               ChannelConfig(noise_std=1.0)],
                     betas=[0.08, 0.05], steps=STEPS, seeds=SEEDS,
                     n_antennas=[1, 4])
    b = SweepRequest(problem=_quad(9, 1), algo="gbma",
                     channels=[ChannelConfig(noise_std=0.2)],
                     betas=[0.1], steps=STEPS, seeds=SEEDS,
                     n_antennas=2)
    assert _sig(a) == _sig(b)
    results = serve_sync([a, b], McServeConfig(quantum_seeds=SEEDS))
    stats = serve_sync.last_stats
    assert [s["requests"] for s in stats.batches] == [2]
    assert stats.batches[0]["rows"] == 3
    assert results[0].risks.shape == (2, SEEDS, STEPS + 1)
    assert results[1].risks.shape == (1, SEEDS, STEPS + 1)
    for res, req in zip(results, [a, b]):
        _assert_matches_solo(res, req)


def test_row_cap_splits_batches_of_one_signature():
    reqs = [_req(6, 0.1 * (i + 1), data_seed=i) for i in range(4)]
    serve_sync(reqs, McServeConfig(quantum_seeds=SEEDS, max_batch_rows=3))
    stats = serve_sync.last_stats
    assert [b["requests"] for b in stats.batches] == [3, 1]


# --------------------------------------------------------------------------
# scheduling: seed-quantum preemption
# --------------------------------------------------------------------------
def test_whale_cannot_starve_minnows():
    """One 24-seed whale and two 6-seed minnows, quantum 6: the round
    robin interleaves the whale's first quantum then lets each minnow
    finish before the whale's remaining quanta run."""
    whale = _req(6, 0.5, seeds=24, data_seed=0)
    m1 = _req(6, 1.0, seeds=6, data_seed=1)
    m2 = _req(6, 0.3, seeds=6, seed0=100, data_seed=2)
    s_w, s_1, s_2 = (_sig(r)[:12] for r in (whale, m1, m2))
    assert len({s_w, s_1, s_2}) == 3
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=6), executor=ex)

    async def inner():
        tasks = await submit_all(srv, [whale, m1, m2])
        await srv.drain()
        return await asyncio.gather(*tasks)

    res_w, res_1, res_2 = run(inner())
    assert [c["signature"] for c in ex.calls] == \
        [s_w, s_1, s_2, s_w, s_w, s_w]
    assert [c["off"] for c in ex.calls] == [0, 0, 0, 6, 12, 18]
    # the minnows' batches finish (stats order) before the whale's
    assert [b["signature"] for b in srv.stats.batches] == [s_1, s_2, s_w]
    for res, req in ((res_w, whale), (res_1, m1), (res_2, m2)):
        _assert_matches_solo(res, req)


def test_ragged_final_quantum_completes_exactly():
    """A seed count that is not a multiple of the quantum: the tail
    quantum is smaller, and the stitched curves still match solo."""
    req = _req(6, 0.5, seeds=10, data_seed=0)
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex)

    async def inner():
        (task,) = await submit_all(srv, [req])
        await srv.drain()
        return await task

    res = run(inner())
    assert [c["quantum"] for c in ex.calls] == [4, 4, 2]
    _assert_matches_solo(res, req)


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------
def test_cancel_mid_batch_batchmates_unaffected():
    """A client cancelling after the batch's first quantum detaches its
    future; the batch runs to completion and the other two clients'
    slices still match their solos."""
    reqs = [_req(6, 0.5, seeds=8, data_seed=0),
            _req(9, 1.0, seeds=8, data_seed=1),
            _req(7, 0.2, seeds=8, data_seed=2)]
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex)

    async def inner():
        clients = [ScriptedClient(srv, r).submit() for r in reqs]
        await asyncio.sleep(0)
        ex.after_call(0, clients[1].cancel)
        await srv.drain()
        await asyncio.gather(*(c.task for c in clients),
                             return_exceptions=True)
        return clients

    clients = run(inner())
    assert len(ex.calls) == 2  # both quanta still ran
    assert clients[1].task.cancelled()
    assert srv.stats.cancelled == 1
    assert srv.stats.batches[0]["requests"] == 3
    assert srv.stats.batches[0]["cancelled"] == 1
    for i in (0, 2):
        _assert_matches_solo(clients[i].result(), reqs[i])


def test_cancel_all_drops_remaining_quanta():
    """When every client of a batch cancels, the scheduler frees the
    batch instead of computing seeds nobody will read."""
    reqs = [_req(6, 0.5, seeds=8, data_seed=0),
            _req(9, 1.0, seeds=8, data_seed=1)]
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex)

    async def inner():
        clients = [ScriptedClient(srv, r).submit() for r in reqs]
        await asyncio.sleep(0)
        ex.after_call(0, clients[0].cancel)
        ex.after_call(0, clients[1].cancel)
        await srv.drain()
        await asyncio.gather(*(c.task for c in clients),
                             return_exceptions=True)

    run(inner())
    assert len(ex.calls) == 1  # second quantum never ran
    assert srv.stats.cancelled == 2
    assert srv.stats.batches == []  # the batch never completed


def test_over_budget_request_rejected_small_one_served():
    """Admission control: the analytic `estimate_peak_bytes` working set
    gates entry — an over-budget whale gets a typed AdmissionError at
    submit, and an affordable request submitted right after is served
    normally (the queue is not poisoned)."""
    small = _req(6, 0.5, data_seed=0)
    big = SweepRequest(problem=_quad(64, 1),
                       channels=[ChannelConfig(noise_std=0.5)] * 8,
                       algo="gbma", betas=[0.05] * 8, steps=STEPS,
                       seeds=256)
    probe = McSweepServer(McServeConfig(quantum_seeds=SEEDS))
    est_small = probe._estimate([probe._normalize(small)])
    est_big = probe._estimate([probe._normalize(big)])
    budget = (est_small + est_big) // 2
    assert est_small < budget < est_big
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS,
                                      memory_budget_bytes=budget))

    async def inner():
        with pytest.raises(AdmissionError, match="estimate_peak_bytes"):
            await srv.submit(big)
        task = asyncio.ensure_future(srv.submit(small))
        await asyncio.sleep(0)
        await srv.drain()
        return await task

    res = run(inner())
    assert srv.stats.rejected == 1 and srv.stats.admitted == 1
    _assert_matches_solo(res, small)


def test_budget_splits_batches_instead_of_rejecting():
    """Two affordable requests that do not fit one batch together run as
    two batches of the same signature, both served."""
    reqs = [_req(6, 0.5, data_seed=0), _req(6, 1.0, data_seed=1)]
    probe = McSweepServer(McServeConfig(quantum_seeds=SEEDS))
    est_one = probe._estimate([probe._normalize(reqs[0])])
    est_two = probe._estimate([probe._normalize(r) for r in reqs])
    budget = (est_one + est_two) // 2
    assert est_one < budget < est_two
    results = serve_sync(reqs, McServeConfig(quantum_seeds=SEEDS,
                                             memory_budget_bytes=budget))
    stats = serve_sync.last_stats
    assert [b["requests"] for b in stats.batches] == [1, 1]
    for res, req in zip(results, reqs):
        _assert_matches_solo(res, req)


@pytest.mark.parametrize("mutation, match", [
    (dict(algo="warp"), "unknown algo"),
    (dict(betas=[0.1, 0.2]), "one stepsize per row"),
    (dict(algo="blind"), "needs n_antennas"),
    (dict(batch_frac=0.0), "batch_frac"),
    (dict(batch_frac=0.5), "stochastic"),  # quadratic has no minibatch
    (dict(steps=0), "steps"),
    (dict(channels=[]), "no rows"),
    (dict(theta0=np.zeros(7, np.float32)), "theta0 shape"),
])
def test_malformed_requests_fail_fast(mutation, match):
    """Malformed payloads raise RequestError at submit — before the
    queue — and a valid request afterwards is served normally."""
    base = dict(problem=_quad(6, 0),
                channels=[ChannelConfig(noise_std=0.5)], algo="gbma",
                betas=[0.08], steps=STEPS, seeds=SEEDS)
    bad = SweepRequest(**{**base, **mutation})
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS))

    async def inner():
        with pytest.raises(RequestError, match=match):
            await srv.submit(bad)
        assert srv._queue == []  # never enqueued
        task = asyncio.ensure_future(srv.submit(SweepRequest(**base)))
        await asyncio.sleep(0)
        await srv.drain()
        return await task

    res = run(inner())
    assert srv.stats.admitted == 1
    assert res.risks.shape == (1, SEEDS, STEPS + 1)


def test_unregistered_problem_rejected():
    """Hand-built MCProblems (closure path, no data dict) cannot batch
    with strangers' rows; the server refuses them up front."""
    from repro.core.mc import MCProblem

    prob = MCProblem(grad_fn=lambda t: t, risk_fn=lambda t: 0.0,
                     dim=DIM, n_nodes=4)
    req = SweepRequest(problem=prob, channels=[ChannelConfig()],
                       algo="gbma", betas=[0.08], steps=STEPS,
                       seeds=SEEDS)

    async def inner():
        with pytest.raises(RequestError, match="registered"):
            await McSweepServer().submit(req)

    run(inner())


def test_engine_failure_contained_to_its_batch():
    """A quantum blowing up resolves only its own batch's futures with a
    ServeError; the other signature's batch completes untouched."""
    pair = [_req(6, 0.5, data_seed=0), _req(9, 1.0, data_seed=1)]
    lone = _req(6, 0.5, steps=STEPS + 4, data_seed=2)
    ex = TracingExecutor()
    ex.fail_when(lambda info: info["rows"] == 2, RuntimeError("boom"))
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS), executor=ex)

    async def inner():
        tasks = await submit_all(srv, pair + [lone])
        await srv.drain()
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = run(inner())
    assert all(isinstance(e, ServeError) for e in out[:2])
    assert all("boom" in str(e) for e in out[:2])
    assert srv.stats.failed_batches == 1
    assert [b["requests"] for b in srv.stats.batches] == [1]
    _assert_matches_solo(out[2], lone)


# --------------------------------------------------------------------------
# pad-waste-aware bucketing
# --------------------------------------------------------------------------
def _cost_model(dispatch_us=0.0, compile_s=0.0, c0=0.0, c1=1.0):
    """A synthetic routing model: compute = c0 + c1 * slot_flops, with
    dispatch/compile charges the test controls exactly."""
    return CostModel(coeffs=(("blind", c0, c1), ("gbma", c0, c1)),
                     dispatch_us=dispatch_us, compile_s=compile_s,
                     chunk_profile=(),
                     peaks=(("peak_gflops", 1.0), ("peak_gibs", 1.0)),
                     source="measured")


def test_bucket_shape_classes():
    srv = McSweepServer()
    assert [srv._bucket(n) for n in (1, 2, 3, 5, 8, 12, 17)] == \
        [1, 2, 4, 8, 8, 16, 32]
    assert srv._bucketing
    assert not McSweepServer(McServeConfig(bucket_base=0))._bucketing
    assert not McSweepServer(McServeConfig(bucket_base=1.0))._bucketing


def test_pad_ratio_and_occupancy_recorded_on_merge():
    """A cross-bucket group on a fresh server merges (compiles dominate)
    and the batch entry records exactly the pad tax it paid."""
    reqs = [_req(6, 0.5, data_seed=0), _req(12, 1.0, data_seed=1)]
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS),
                        executor=InlineExecutor(),
                        cost_model=_cost_model(compile_s=10.0))
    results = serve_sync(reqs, server=srv)
    assert [b["requests"] for b in srv.stats.batches] == [2]
    batch = srv.stats.batches[0]
    assert batch["n_max"] == 12 and batch["bucket"] == 16
    assert batch["pad_flops_ratio"] == round(2 * 12 / 18, 4)
    assert srv.stats.bucket_occupancy == {8: 1, 16: 1}
    for res, req in zip(results, reqs):
        _assert_matches_solo(res, req)


def test_bucketing_disabled_is_the_monolithic_router():
    """bucket_base <= 1 restores the pre-cost-model behavior: every
    signature group merges, nothing is bucketed or recorded."""
    reqs = [_req(3, 0.5, data_seed=0), _req(24, 1.0, data_seed=1)]
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS, bucket_base=0),
                        executor=InlineExecutor(),
                        cost_model=_cost_model())  # split-happy model
    serve_sync(reqs, server=srv)
    assert [b["requests"] for b in srv.stats.batches] == [2]
    assert srv.stats.batches[0]["bucket"] == 0
    assert srv.stats.bucket_occupancy == {}


def test_first_sight_merges_then_steady_state_splits():
    """The merge decision over a persistent server: round 1 merges the
    cross-bucket group (two unseen shape classes vs one — compiles
    dominate), round 2 splits it (everything compiled, pad waste is the
    only term), and `clear_cache()` forgets the registry so round 3
    merges again."""
    mk = lambda: [_req(4, 0.5, data_seed=0), _req(24, 1.0, data_seed=1)]
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS), executor=ex,
                        cost_model=_cost_model(compile_s=10.0))

    def round_():
        reqs = mk()

        async def inner():
            tasks = await submit_all(srv, reqs)
            await srv.drain()
            return await asyncio.gather(*tasks)

        results = run(inner())
        for res, req in zip(results, reqs):
            _assert_matches_solo(res, req)

    round_()
    assert [b["requests"] for b in srv.stats.batches] == [2]
    round_()
    assert [b["requests"] for b in srv.stats.batches] == [2, 1, 1]
    assert [c["rows"] for c in ex.calls] == [2, 1, 1]
    assert [b["pad_flops_ratio"] for b in srv.stats.batches[1:]] == \
        [1.0, 1.0]
    clear_cache()  # bumps exec.cache_epoch() -> the registry resets
    round_()
    assert [b["requests"] for b in srv.stats.batches] == [2, 1, 1, 2]


def test_layout_loop_explores_then_exploits_measured_winner():
    """The within-bucket measured layout loop over a persistent server:
    first sight merges (compile amortization), the warm `merged` and
    `exact` layouts are each explored once (recompile-polluted rounds
    don't count as observations), and steady state exploits whichever
    µs-per-node observation is cheaper — injected here so the exploit
    choice is deterministic."""
    clear_cache()  # deterministic compile rounds for this jit cache
    reqs = lambda: [_req(20, 0.5, data_seed=0), _req(28, 1.0, data_seed=1)]
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS),
                        executor=InlineExecutor(),
                        cost_model=_cost_model(compile_s=10.0))
    key = (_sig(reqs()[0]), srv._bucket(28))

    def round_():
        rs = reqs()
        for res, req in zip(serve_sync(rs, server=srv), rs):
            _assert_matches_solo(res, req)
        return [b["requests"] for b in srv.stats.batches]

    assert round_() == [2]            # r1: first sight merges (compiles)
    assert srv._layout_obs == {}      # ...so nothing was observed
    assert round_() == [2, 2]         # r2: explore merged, warm -> obs
    assert list(srv._layout_obs[key]) == ["merged"]
    # r3: explore exact — its rows=1 shapes are already compiled (the
    # solo verification calls above share the jit cache), so the round
    # is warm and the observation lands immediately
    assert round_() == [2, 2, 1, 1]
    assert sorted(srv._layout_obs[key]) == ["exact", "merged"]
    assert srv.stats.layouts == {
        f"{key[0][:12]}/{key[1]}": {
            k: round(v[0] / v[1], 2)
            for k, v in srv._layout_obs[key].items()}}
    # exploit: the measured-cheaper layout wins, whichever it is
    srv._layout_obs[key] = {"merged": [1.0, 100], "exact": [9.0, 100]}
    assert round_()[-1:] == [2]
    assert srv.stats.batches[-1]["layout"] == "merged"
    srv._layout_obs[key] = {"merged": [9.0, 100], "exact": [1.0, 100]}
    assert round_()[-2:] == [1, 1]
    assert [b["layout"] for b in srv.stats.batches[-2:]] == \
        ["exact", "exact"]
    assert [b["pad_flops_ratio"] for b in srv.stats.batches[-2:]] == \
        [1.0, 1.0]


def test_measure_layouts_off_is_the_purely_predicted_router():
    """measure_layouts=False keeps within-bucket groups merged in steady
    state (the pre-feedback behavior) and tags nothing."""
    mk = lambda: [_req(20, 0.5, data_seed=0), _req(28, 1.0, data_seed=1)]
    srv = McSweepServer(
        McServeConfig(quantum_seeds=SEEDS, measure_layouts=False),
        executor=InlineExecutor(), cost_model=_cost_model(compile_s=10.0))
    for _ in range(3):
        serve_sync(mk(), server=srv)
    assert [b["requests"] for b in srv.stats.batches] == [2, 2, 2]
    assert all(b["layout"] is None for b in srv.stats.batches)
    assert srv._layout_obs == {}


def test_stack_cache_reuses_padded_packs(monkeypatch):
    """A persistent server re-serving the same problem objects pads and
    stacks them once; later rounds reuse the cached pack (and still
    demux correctly)."""
    from repro.serving import mc_server as srv_mod

    calls = []
    orig = MCProblemBatch.stack
    monkeypatch.setattr(
        srv_mod.MCProblemBatch, "stack",
        classmethod(lambda cls, probs: (calls.append(1), orig(probs))[1]))
    req = _req(9, 0.5, data_seed=3)
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS),
                        executor=InlineExecutor())
    serve_sync([req], server=srv)
    first_round = len(calls)
    assert first_round >= 1
    res2 = serve_sync([req], server=srv)[0]
    assert len(calls) == first_round  # cache hit: no re-stack
    _assert_matches_solo(res2, req)


@settings(max_examples=4, deadline=None)
@given(kind=strategies.sampled_from(("quadratic", "logistic")),
       algo=strategies.sampled_from(("gbma", "momentum")),
       n_small=strategies.sampled_from((3, 5)),
       n_big=strategies.sampled_from((24, 40)),
       minibatch=strategies.booleans())
def test_property_bucketed_split_demux_matches_solo(kind, algo, n_small,
                                                    n_big, minibatch):
    """Property: whatever the routing decides, the numbers are invisible
    — here a zero-compile model always splits the N-spread pair, and
    each bucketed batch's demux still matches a dedicated solo run
    <= 1e-6 (counter-based RNG makes routing a pure scheduling choice)."""
    frac = 0.5 if (minibatch and kind == "logistic") else 1.0
    a = _req(n_small, 0.5, 0.08, kind=kind, algo=algo, batch_frac=frac,
             data_seed=0)
    b = _req(n_big, 1.0, 0.05, kind=kind, algo=algo, batch_frac=frac,
             data_seed=1)
    assert _sig(a) == _sig(b)
    srv = McSweepServer(McServeConfig(quantum_seeds=SEEDS),
                        executor=InlineExecutor(),
                        cost_model=_cost_model())
    results = serve_sync([a, b], server=srv)
    assert [s["requests"] for s in srv.stats.batches] == [1, 1]
    assert all(s["pad_flops_ratio"] == 1.0 for s in srv.stats.batches)
    assert set(srv.stats.bucket_occupancy) == \
        {srv._bucket(n_small), srv._bucket(n_big)}
    for res, req in zip(results, [a, b]):
        _assert_matches_solo(res, req)


# --------------------------------------------------------------------------
# the router loop under the manual clock
# --------------------------------------------------------------------------
def test_serve_forever_holds_coalesce_window_without_wall_sleeps():
    """start()/stop() lifecycle under the manual clock: the router
    wakes on the first submission, holds the coalesce window open (a
    virtual 2.5 s — recorded, not slept), then drains both requests as
    one batch."""
    reqs = [_req(6, 0.5, data_seed=0), _req(9, 1.0, data_seed=1)]
    clock, ex = ManualClock(), TracingExecutor()
    srv = McSweepServer(
        McServeConfig(quantum_seeds=SEEDS, coalesce_window=2.5),
        clock=clock, executor=ex)

    async def inner():
        srv.start()
        results = await asyncio.gather(
            *(srv.submit(r) for r in reqs))
        await srv.stop()
        return results

    results = run(inner())
    assert clock.sleeps == [2.5]
    assert clock.now == 2.5
    assert [b["requests"] for b in srv.stats.batches] == [2]
    for res, req in zip(results, reqs):
        _assert_matches_solo(res, req)


def test_submissions_during_drain_are_picked_up():
    """A request submitted while the router is mid-drain (scripted after
    the first quantum) is served in the same drain pass."""
    first = _req(6, 0.5, seeds=8, data_seed=0)
    late = _req(9, 1.0, seeds=8, data_seed=1)
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex)

    async def inner():
        (t1,) = await submit_all(srv, [first])
        holder = {}
        ex.after_call(0, lambda: holder.setdefault(
            "t2", asyncio.ensure_future(srv.submit(late))))
        await srv.drain()
        return await t1, await holder["t2"]

    r1, r2 = run(inner())
    assert len(srv.stats.batches) == 2
    _assert_matches_solo(r1, first)
    _assert_matches_solo(r2, late)


# --------------------------------------------------------------------------
# deadlines, quarantine, retry (fault tolerance)
# --------------------------------------------------------------------------
def _partial_ref(req, seeds_completed):
    """Dedicated-run reference for a PartialResult: the same request
    truncated to the seeds the batch had completed at expiry."""
    return dataclasses.replace(req, seeds=seeds_completed,
                               deadline_s=None)


def test_deadline_mid_run_resolves_partial_batchmates_unaffected():
    """Acceptance: a deadline expiring mid-run resolves that request
    with a typed PartialResult whose statistics match a dedicated
    `run_mc` over the completed seeds to <= 1e-6, while its batchmate
    runs to completion and still matches its solo."""
    hurried = _req(6, 0.5, seeds=8, data_seed=0, deadline_s=5.0)
    patient = _req(9, 1.0, seeds=8, data_seed=1)
    clock = ManualClock()
    ex = TracingExecutor()
    ex.after_call(0, ClockJump(clock, 10.0))  # quantum 0 "takes" 10 s
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex,
                        clock=clock)

    async def inner():
        tasks = await submit_all(srv, [hurried, patient])
        await srv.drain()
        return await asyncio.gather(*tasks)

    part, full = run(inner())
    assert isinstance(part, PartialResult)
    assert part.seeds_completed == 4 and part.seeds_requested == 8
    _assert_matches_solo(part.result, _partial_ref(hurried, 4))
    _assert_matches_solo(full, patient)  # batchmate untouched
    assert [c["off"] for c in ex.calls] == [0, 4]  # batch ran to the end
    assert srv.stats.deadline_expired == 1
    assert srv.stats.cancelled == 0  # expiry is not a cancellation
    assert srv.stats.batches[0]["expired"] == 1


def test_deadline_expiring_before_any_quantum_yields_empty_partial():
    """A request whose deadline passes before its first quantum resolves
    with seeds_completed == 0 and result None, and its lone job is
    dropped without computing anything."""
    req = _req(6, 0.5, seeds=8, data_seed=0, deadline_s=1.0)
    clock = ManualClock()
    ex = TracingExecutor()
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex,
                        clock=clock)

    async def inner():
        (task,) = await submit_all(srv, [req])
        clock.now += 2.0  # deadline passes while queued
        await srv.drain()
        return await task

    part = run(inner())
    assert isinstance(part, PartialResult)
    assert part.result is None and part.seeds_completed == 0
    assert ex.calls == []  # nothing was computed for an expired request
    assert srv.stats.cancelled == 0


def test_all_clients_expired_drops_remaining_quanta():
    """When every client of a batch has expired, the scheduler frees the
    batch instead of computing seeds nobody will read."""
    reqs = [_req(6, 0.5, seeds=12, data_seed=0, deadline_s=5.0),
            _req(9, 1.0, seeds=12, data_seed=1, deadline_s=6.0)]
    clock = ManualClock()
    ex = TracingExecutor()
    ex.after_call(0, ClockJump(clock, 10.0))
    srv = McSweepServer(McServeConfig(quantum_seeds=4), executor=ex,
                        clock=clock)

    async def inner():
        tasks = await submit_all(srv, reqs)
        await srv.drain()
        return await asyncio.gather(*tasks)

    p1, p2 = run(inner())
    assert len(ex.calls) == 1  # quanta 2 and 3 never ran
    assert {p.seeds_completed for p in (p1, p2)} == {4}
    assert srv.stats.deadline_expired == 2
    assert srv.stats.cancelled == 0
    assert srv.stats.batches == []  # the batch never completed


@settings(max_examples=4, deadline=None)
@given(jump_after=strategies.integers(min_value=0, max_value=1),
       quantum=strategies.sampled_from([2, 4]))
def test_deadline_expiry_never_blocks_batchmates(jump_after, quantum):
    """Property: wherever the deadline lands in the quantum schedule,
    the expired request gets a well-formed PartialResult and the
    deadline-free batchmate always completes and matches its solo."""
    hurried = _req(6, 0.5, seeds=8, data_seed=0, deadline_s=3.0)
    patient = _req(9, 1.0, seeds=8, data_seed=1)
    clock = ManualClock()
    ex = TracingExecutor()
    ex.after_call(jump_after, ClockJump(clock, 10.0))
    srv = McSweepServer(McServeConfig(quantum_seeds=quantum),
                        executor=ex, clock=clock)

    async def inner():
        tasks = await submit_all(srv, [hurried, patient])
        await srv.drain()
        return await asyncio.gather(*tasks)

    part, full = run(inner())
    assert isinstance(part, PartialResult)
    done = min((jump_after + 1) * quantum, 8)
    assert part.seeds_completed == done and part.seeds_requested == 8
    if done:
        _assert_matches_solo(part.result, _partial_ref(hurried, done))
    _assert_matches_solo(full, patient)


def test_hung_engine_call_quarantines_the_signature():
    """Watchdog: an engine call exceeding hang_threshold_s (measured on
    the injected clock — no racing timers) fails the batch with
    QuarantinedError, and later submits of the same signature are
    rejected at submit with the original cause; other signatures are
    unaffected."""
    hung = _req(6, 0.5, seeds=SEEDS, data_seed=0)
    other = _req(6, 0.5, steps=STEPS + 4, data_seed=1)  # distinct sig
    assert _sig(hung) != _sig(other)
    clock = ManualClock()
    ex = TracingExecutor()
    ex.after_call(0, ClockJump(clock, 9.0))
    srv = McSweepServer(
        McServeConfig(quantum_seeds=SEEDS, hang_threshold_s=1.0),
        executor=ex, clock=clock)

    async def inner():
        tasks = await submit_all(srv, [hung, other])
        await srv.drain()
        first = await asyncio.gather(*tasks, return_exceptions=True)
        try:  # same signature again: fenced off at submit
            await srv.submit(_req(6, 0.5, seeds=SEEDS, data_seed=5))
            resubmit = None
        except QuarantinedError as e:
            resubmit = e
        return first, resubmit

    (res_hung, res_other), resubmit = run(inner())
    assert isinstance(res_hung, QuarantinedError)
    assert "hang_threshold_s" in str(res_hung)
    _assert_matches_solo(res_other, other)  # other signature unaffected
    assert isinstance(resubmit, QuarantinedError)
    assert "took 9.000s" in str(resubmit)  # original cause preserved
    assert srv.stats.quarantined == 1
    assert srv.stats.failed_batches == 0  # quarantine has its own ledger
    assert srv.stats.rejected == 1


def test_transient_engine_failure_retried_to_success():
    """cfg.retry: a quantum failing once is replayed under the policy's
    backoff (waited on the server clock) and — counter-based RNG — the
    final result still matches the dedicated solo run exactly."""
    req = _req(6, 0.5, seeds=8, data_seed=0)
    clock = ManualClock()
    ex = TracingExecutor()
    ex.fail_when(FlakyOnce(lambda info: info["off"] == 4),
                 RuntimeError("transient device loss"))
    srv = McSweepServer(
        McServeConfig(quantum_seeds=4,
                      retry=RetryPolicy(max_attempts=3, base_delay_s=0.5)),
        executor=ex, clock=clock)

    async def inner():
        (task,) = await submit_all(srv, [req])
        await srv.drain()
        return await task

    res = run(inner())
    _assert_matches_solo(res, req)
    assert [c["off"] for c in ex.calls] == [0, 4, 4]  # one replay
    assert clock.sleeps == [0.5]  # backoff waited on the server clock
    assert srv.stats.retries == 1
    assert srv.stats.failed_batches == 0


def test_retry_budget_exhausted_routes_failure_to_clients():
    """A persistently failing quantum burns the retry budget and then
    fails its batch exactly like the no-retry path."""
    req = _req(6, 0.5, seeds=8, data_seed=0)
    clock = ManualClock()
    ex = TracingExecutor()
    ex.fail_when(lambda info: info["off"] == 0,
                 RuntimeError("dead device"))
    srv = McSweepServer(
        McServeConfig(quantum_seeds=4,
                      retry=RetryPolicy(max_attempts=2, base_delay_s=0.5)),
        executor=ex, clock=clock)

    async def inner():
        (task,) = await submit_all(srv, [req])
        await srv.drain()
        return await asyncio.gather(task, return_exceptions=True)

    (err,) = run(inner())
    assert isinstance(err, ServeError)
    assert "dead device" in str(err)
    assert srv.stats.retries == 1  # one re-attempt, then give up
    assert srv.stats.failed_batches == 1


def test_deadline_validation_and_config_default():
    """deadline_s must be positive; a request without one inherits
    McServeConfig.default_deadline_s (and can expire under it)."""
    srv = McSweepServer()
    with pytest.raises(RequestError, match="deadline_s"):
        srv._normalize(_req(6, 0.5, deadline_s=0.0))
    with pytest.raises(RequestError, match="deadline_s"):
        srv._normalize(_req(6, 0.5, deadline_s=-1.0))

    req = _req(6, 0.5, seeds=8, data_seed=0)  # no per-request deadline
    clock = ManualClock()
    ex = TracingExecutor()
    ex.after_call(0, ClockJump(clock, 10.0))
    srv = McSweepServer(
        McServeConfig(quantum_seeds=4, default_deadline_s=5.0),
        executor=ex, clock=clock)

    async def inner():
        (task,) = await submit_all(srv, [req])
        await srv.drain()
        return await task

    part = run(inner())
    assert isinstance(part, PartialResult)
    assert part.seeds_completed == 4
    # and the per-request knob overrides the config default
    assert srv._normalize(
        _req(6, 0.5, deadline_s=42.0)).deadline_s == 42.0
