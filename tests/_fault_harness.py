"""Composable fault injectors for the fault-tolerance suite.

Layered on the deterministic serving harness (`tests/_serving_harness`):
nothing here touches the wall clock or threads — every fault is a
scripted, replayable event.

* `ChunkFaultSchedule` — context manager injecting executor-level chunk
  failures into `exec.run_chunked` through the
  `exec.install_chunk_fault_hook` seam: `{off: n_failures}` makes the
  chunk at seed offset `off` fail its first `n_failures` attempts and
  succeed after. Records every fired fault for assertions.
* `ClockJump`        — callable that jumps a `ManualClock` forward by
  `dt`; hung-engine-call scenarios attach it with
  `TracingExecutor.after_call` so the watchdog's post-hoc elapsed check
  sees a "hang" without any real waiting.
* `FlakyOnce`        — predicate for `TracingExecutor.fail_when` that
  matches its first `times` matching calls only — fail-then-succeed at
  the serving level (`fail_when` alone fails EVERY matching call, which
  can never recover).
* `torn_write` / `bit_flip` — file corruptors for checkpoint tests:
  truncate to half (a torn write) or flip one payload bit (silent
  storage corruption). `bit_flip` takes an optional `needle` so the
  flip provably lands in array data rather than zip/npy header padding
  the loader would shrug off.
"""
from __future__ import annotations

import os

from repro.core.mc import exec as exec_mod


class ChunkFaultSchedule:
    """Deterministic chunk-failure schedule for `run_chunked`.

    schedule: {seed_offset: n_failures} — the chunk starting at that
    offset raises `RuntimeError` on its first n attempts (attempts are
    1-based), then succeeds. Use as a context manager; `fired` collects
    the injected-fault info dicts in order.
    """

    def __init__(self, schedule: dict):
        self.schedule = dict(schedule)
        self.fired = []
        self._remove = None

    def __call__(self, info: dict) -> None:
        if self.schedule.get(info["off"], 0) >= info["attempt"]:
            self.fired.append(dict(info))
            raise RuntimeError(
                f"injected chunk fault at off={info['off']} "
                f"attempt={info['attempt']}")

    def __enter__(self) -> "ChunkFaultSchedule":
        self._remove = exec_mod.install_chunk_fault_hook(self)
        return self

    def __exit__(self, *exc) -> None:
        if self._remove is not None:
            self._remove()
            self._remove = None


class ClockJump:
    """Jump a `ManualClock` forward by `dt` when called — the
    deterministic 'hang': attach via `TracingExecutor.after_call(k, ...)`
    and the k-th quantum's elapsed virtual time exceeds any threshold
    below `dt` without a single real sleep."""

    def __init__(self, clock, dt: float):
        self.clock = clock
        self.dt = dt

    def __call__(self) -> None:
        self.clock.now += self.dt


class FlakyOnce:
    """`fail_when` predicate matching only the first `times` calls that
    satisfy `match` — a transient (recoverable) engine failure."""

    def __init__(self, match, times: int = 1):
        self.match = match
        self.times = times
        self.hits = 0

    def __call__(self, info: dict) -> bool:
        if self.hits < self.times and self.match(info):
            self.hits += 1
            return True
        return False


def torn_write(path: str) -> None:
    """Truncate `path` to half its size — the on-disk state of a write
    torn by a crash (no atomic-replace discipline)."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)


def bit_flip(path: str, needle: bytes = None) -> None:
    """Flip one bit of `path`. With `needle` (e.g. an array's
    `.tobytes()`), the flipped byte is inside that payload — guaranteed
    content corruption; without it, the middle byte flips (which may
    land in inert archive padding)."""
    with open(path, "r+b") as f:
        blob = f.read()
        pos = len(blob) // 2
        if needle is not None:
            at = blob.find(needle)
            if at < 0:
                raise AssertionError(
                    "needle not found in file — not a stored payload")
            pos = at + len(needle) // 2
        f.seek(pos)
        f.write(bytes([blob[pos] ^ 0x01]))
