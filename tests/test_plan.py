"""Execution plans (`repro.core.mc.plan`) + the placed, resumable
scheduler:

  * `auto_plan` derivation: chunk sizing against the per-device memory
    target/budget, topology-driven (rows x mc) placement, the hand-tuned
    LARGE benchmark configuration reproduced analytically;
  * `run_mc(plan=...)` routing: ExecPlan / "auto" / legacy-kwargs shim
    equivalence (bit-identical), conflict and validation errors, the
    resolved plan recorded on `MCResult.plan`;
  * Chan's parallel moment merge: hand-computed merges vs numpy ddof=1,
    the catastrophic-cancellation regression the one-pass (Σx, Σx²)
    accumulator failed, chunked engine ci95 vs the host two-pass;
  * resume: interrupt at chunk k -> restore -> bit-identical moments vs
    uninterrupted for gbma / blind / stochastic-logistic families,
    finished-sweep short-circuit, fingerprint mismatch, validation;
  * placement invariance: chunk streams identical across n_shards in
    {1, 2, 4} and under row sharding (multi-device: these run in the CI
    forced-host-device job; a subprocess twin keeps one placed
    configuration covered on single-device tier-1).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from benchmarks.common import MSDProblem
from repro.checkpoint import ckpt
from repro.core.channel import ChannelConfig
from repro.core.mc import exec as exec_mod
from repro.core.mc import plan as plan_mod
from repro.core.mc.exec import chan_merge, finalize_merged_stats
from repro.core.mc.plan import ExecPlan, auto_plan, validate_plan
from repro.core.montecarlo import logistic_mc_problem, run_mc
from repro.data.synthetic import logistic_classification

N, D, STEPS, SEEDS = 12, 8, 10, 8

multidev = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (CI runs this under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def mc():
    return MSDProblem.make(N, dim=D).to_mc()


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


# --------------------------------------------------------------------------
# auto_plan derivation
# --------------------------------------------------------------------------
def test_auto_plan_small_workload_runs_all_live():
    p = auto_plan(n_rows=1, seeds=8, steps=10, n_max=16, dim=4,
                  device_count=1)
    assert p.seed_chunk is None
    assert p.keep_seed_curves is True
    assert p.n_shards == 0 and p.row_shards == 1


def test_auto_plan_chunks_against_the_target():
    # force a tiny per-device target: the chunk must divide the seeds,
    # fit the target, and flip keep_seed_curves to the reduced path
    p = auto_plan(n_rows=1, seeds=64, steps=50, n_max=256, dim=16,
                  device_count=1, target_chunk_bytes=512 * 1024)
    assert p.seed_chunk is not None and 64 % p.seed_chunk == 0
    est = exec_mod.estimate_peak_bytes(
        n_rows=1, seeds=64, steps=50, n_max=256, dim=16,
        seed_chunk=p.seed_chunk, keep_seed_curves=False)
    assert est["per_device_peak_bytes"] <= 512 * 1024
    assert p.keep_seed_curves is False


def test_auto_plan_reproduces_the_hand_tuned_large_config():
    """The planner's 128 MiB cache target re-derives the benchmark's
    hand-tuned chunk=32 on the full-scale LARGE workload (seeds=1024 x
    N=4096) — the analytic anchor for the default target."""
    p = auto_plan(n_rows=1, seeds=1024, steps=150, n_max=4096, dim=24,
                  device_count=1, memory_budget_bytes=2 * 2**30)
    assert p.seed_chunk == 32
    assert p.keep_seed_curves is False


def test_auto_plan_places_over_the_topology():
    p = auto_plan(n_rows=3, seeds=16, steps=10, n_max=16, dim=4,
                  device_count=4)
    assert p.n_shards == 4 and p.row_shards == 1
    # seed axis does not divide: the row axis picks up the devices
    p = auto_plan(n_rows=4, seeds=9, steps=10, n_max=16, dim=4,
                  device_count=4)
    assert p.n_shards == 0 and p.row_shards == 4


def test_auto_plan_chunk_is_a_multiple_of_the_seed_shards():
    p = auto_plan(n_rows=1, seeds=64, steps=50, n_max=256, dim=16,
                  device_count=4, target_chunk_bytes=512 * 1024)
    if p.seed_chunk is not None and p.n_shards > 1:
        assert p.seed_chunk % p.n_shards == 0


def test_validate_plan_errors():
    with pytest.raises(ValueError, match="rng_plan"):
        validate_plan(ExecPlan(rng_plan="nope"), seeds=8, n_rows=1)
    with pytest.raises(ValueError, match="divide"):
        validate_plan(ExecPlan(seed_chunk=3), seeds=8, n_rows=1)
    with pytest.raises(ValueError, match="positive"):
        validate_plan(ExecPlan(seed_chunk=0), seeds=8, n_rows=1)
    with pytest.raises(ValueError, match="n_shards"):
        validate_plan(ExecPlan(n_shards=3), seeds=8, n_rows=1)
    with pytest.raises(ValueError, match="row_shards"):
        validate_plan(ExecPlan(row_shards=2), seeds=8, n_rows=3)


def test_resolve_seed_shards_oversubscription():
    plan = ExecPlan(n_shards=2, row_shards=2)
    with pytest.raises(ValueError, match="device"):
        plan_mod.resolve_seed_shards(plan, 8, device_count=2)


# --------------------------------------------------------------------------
# run_mc(plan=...) routing
# --------------------------------------------------------------------------
def test_plan_conflicts_with_legacy_knobs(mc):
    with pytest.raises(ValueError, match="seed_chunk"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
               plan=ExecPlan(), seed_chunk=4)
    with pytest.raises(ValueError, match="plan must be"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, plan="fastest")
    with pytest.raises(ValueError, match="memory_budget_bytes"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
               memory_budget_bytes=2**30)


def test_kwargs_shim_is_behavior_pinned(mc):
    """The legacy kwargs build the equivalent ExecPlan: same bits."""
    kw = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                rng_plan="hoisted", seed_chunk=4, keep_seed_curves=False)
    pl = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                plan=ExecPlan(seed_chunk=4, keep_seed_curves=False))
    np.testing.assert_array_equal(kw.mean, pl.mean)
    np.testing.assert_array_equal(kw.ci95, pl.ci95)


def test_result_records_the_resolved_plan(mc):
    res = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
    assert res.plan == ExecPlan()
    res = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, plan="auto")
    assert isinstance(res.plan, ExecPlan)
    assert res.plan.n_shards is not None  # auto plans are fully concrete


def test_plan_auto_matches_the_default_path(mc):
    base = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
    auto = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, plan="auto")
    np.testing.assert_allclose(auto.mean, base.mean, rtol=1e-6)


# --------------------------------------------------------------------------
# Chan's parallel moment merge
# --------------------------------------------------------------------------
def test_chan_merge_matches_numpy_over_chunks():
    rng = np.random.default_rng(0)
    x = rng.normal(3.0, 0.7, size=(2, 20, 6)).astype(np.float32)
    mean = np.zeros((2, 6), np.float32)
    m2 = np.zeros((2, 6), np.float32)
    n = np.float32(0.0)
    for off in range(0, 20, 5):
        blk = x[:, off:off + 5]
        bmean = blk.mean(axis=1)
        bm2 = ((blk - bmean[:, None, :]) ** 2).sum(axis=1)
        mean, m2 = chan_merge(mean, m2, n, bmean, bm2, np.float32(5.0))
        n = n + np.float32(5.0)
    np.testing.assert_allclose(mean, x.mean(axis=1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m2) / 19.0,
                               x.var(axis=1, ddof=1), rtol=1e-4)
    _, ci = finalize_merged_stats(np.asarray(mean), np.asarray(m2), 20)
    ref = 1.96 * x.std(axis=1, ddof=1) / np.sqrt(20)
    np.testing.assert_allclose(ci, ref, rtol=1e-4)


def test_chan_merge_first_chunk_is_exact():
    bmean = np.float32([1.5, -2.25])
    bm2 = np.float32([0.5, 0.125])
    mean, m2 = chan_merge(np.zeros(2, np.float32), np.zeros(2, np.float32),
                          np.float32(0.0), bmean, bm2, np.float32(4.0))
    np.testing.assert_array_equal(np.asarray(mean), bmean)
    np.testing.assert_array_equal(np.asarray(m2), bm2)


def test_chan_merge_survives_the_one_pass_cancellation():
    """The PR-5 wart this replaces: near-deterministic rows with a large
    mean. In f32 the one-pass Σx² − n·mean² cancels to 0 (or negative,
    then clamped); the Chan path keeps the true variance."""
    rng = np.random.default_rng(1)
    x = (1e4 + rng.normal(0, 0.05, size=(1, 16, 4))).astype(np.float32)
    true_sd = np.float64(x).std(axis=1, ddof=1)

    # the retired one-pass accumulator, verbatim: the Σx² − n·mean²
    # subtraction of two ~1e9 f32 numbers is quantized at their ulp
    # (~128), so it returns 0 or ulp-scale garbage — never the true
    # M2 ≈ 0.04
    s = x.sum(axis=1)
    sq = (x * x).sum(axis=1)
    sd_onepass = np.sqrt(np.maximum(0.0, (sq - 16 * (s / 16) ** 2) / 15))
    assert np.all(np.abs(sd_onepass - true_sd) > 0.5 * true_sd), \
        "workload no longer triggers the cancellation — tighten it"

    mean = np.zeros((1, 4), np.float32)
    m2 = np.zeros((1, 4), np.float32)
    n = np.float32(0.0)
    for off in range(0, 16, 4):
        blk = x[:, off:off + 4]
        bmean = blk.mean(axis=1)
        bm2 = ((blk - bmean[:, None, :]) ** 2).sum(axis=1)
        mean, m2 = chan_merge(mean, m2, n, bmean, bm2, np.float32(4.0))
        n = n + np.float32(4.0)
    sd_chan = np.sqrt(np.asarray(m2) / 15)
    np.testing.assert_allclose(sd_chan, true_sd, rtol=1e-2)


def test_chunked_ci95_matches_host_two_pass(mc):
    full = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS)
    red = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                 seed_chunk=2, keep_seed_curves=False)
    np.testing.assert_allclose(red.mean, full.mean, rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(red.ci95, full.ci95, rtol=1e-4, atol=1e-8)


# --------------------------------------------------------------------------
# resume
# --------------------------------------------------------------------------
def _families(mc):
    lg_X, lg_y, _ = logistic_classification(40, dim=6, seed=3)
    logistic = logistic_mc_problem(lg_X, lg_y, 8, lam=0.1)
    return {
        "gbma": dict(problem=mc, algo="gbma", kw={}),
        "blind": dict(problem=mc, algo="blind", kw={"n_antennas": 2}),
        "logistic": dict(problem=logistic, algo="gbma",
                         kw={"batch_frac": 0.5}),
    }


@pytest.mark.parametrize("family", ["gbma", "blind", "logistic"])
def test_interrupted_resume_is_bit_identical(family, mc, tmp_path,
                                             monkeypatch):
    """Interrupt at chunk k (ckpt.save raises after k saves), rerun with
    the same resume_dir: moments are bit-identical to the uninterrupted
    sweep, and the resumed run starts at the first unfinished chunk."""
    spec = _families(mc)[family]
    args = (spec["problem"], [_ch()], spec["algo"], [0.01], STEPS, SEEDS)
    kw = dict(seed_chunk=2, keep_seed_curves=False, **spec["kw"])
    uninterrupted = run_mc(*args, **kw)

    real_save = ckpt.save
    calls = {"n": 0}

    def dying_save(path, tree):
        real_save(path, tree)
        calls["n"] += 1
        if calls["n"] >= 2:
            raise RuntimeError("simulated preemption")

    monkeypatch.setattr(ckpt, "save", dying_save)
    with pytest.raises(RuntimeError, match="preemption"):
        run_mc(*args, resume_dir=str(tmp_path), **kw)
    monkeypatch.setattr(ckpt, "save", real_save)

    raw = ckpt.peek(str(tmp_path / exec_mod._RESUME_FILE))
    assert int(raw["next_off"]) == 4  # 2 chunks of 2 seeds survived

    real_merge = exec_mod._mc_moments_merge
    offs = []

    def counting_merge(acc_mean, acc_m2, n_prev, *a, **k):
        offs.append(int(np.asarray(n_prev)))
        return real_merge(acc_mean, acc_m2, n_prev, *a, **k)

    monkeypatch.setattr(exec_mod, "_mc_moments_merge", counting_merge)
    resumed = run_mc(*args, resume_dir=str(tmp_path), **kw)
    assert offs == [4, 6]  # only the unfinished chunks ran
    np.testing.assert_array_equal(resumed.mean, uninterrupted.mean)
    np.testing.assert_array_equal(resumed.ci95, uninterrupted.ci95)


def test_finished_sweep_resume_short_circuits(mc, tmp_path, monkeypatch):
    kw = dict(seed_chunk=2, keep_seed_curves=False,
              resume_dir=str(tmp_path))
    first = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, **kw)

    def no_merge(*a, **k):  # pragma: no cover - failure path
        raise AssertionError("finished sweep must not re-run chunks")

    monkeypatch.setattr(exec_mod, "_mc_moments_merge", no_merge)
    again = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, **kw)
    np.testing.assert_array_equal(first.mean, again.mean)
    np.testing.assert_array_equal(first.ci95, again.ci95)


def test_resume_rejects_a_foreign_checkpoint(mc, tmp_path):
    kw = dict(seed_chunk=2, keep_seed_curves=False,
              resume_dir=str(tmp_path))
    run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS, **kw)
    with pytest.raises(ValueError, match="fingerprint"):
        # different stepsize = different workload, same directory
        run_mc(mc, [_ch()], "gbma", [0.02], STEPS, SEEDS, **kw)


def test_resume_requires_chunked_reduced_path(mc, tmp_path):
    with pytest.raises(ValueError, match="seed_chunk"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
               resume_dir=str(tmp_path))
    with pytest.raises(ValueError, match="keep_seed_curves"):
        run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
               seed_chunk=2, resume_dir=str(tmp_path))


# --------------------------------------------------------------------------
# placement invariance
# --------------------------------------------------------------------------
@multidev
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_chunked_moments_placement_invariant(mc, n_shards):
    """The hoisted counter-based RNG plan makes chunk streams
    location-independent by construction: only the psum reduction order
    differs across placements (f32 ulp scale)."""
    base = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                  plan=ExecPlan(seed_chunk=4, n_shards=0,
                                keep_seed_curves=False))
    placed = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                    plan=ExecPlan(seed_chunk=4, n_shards=n_shards,
                                  keep_seed_curves=False))
    np.testing.assert_allclose(placed.mean, base.mean, rtol=1e-6)
    np.testing.assert_allclose(placed.ci95, base.ci95, rtol=1e-5,
                               atol=1e-9)


@multidev
def test_curves_bitwise_across_the_rows_mc_mesh(mc):
    """Per-seed curves never cross a reduction: a (rows x mc) placement
    returns the single-device bits exactly."""
    chs = [_ch(), _ch(noise_std=0.7)]
    plain = run_mc(mc, chs, "gbma", [0.01, 0.02], STEPS, SEEDS)
    placed = run_mc(mc, chs, "gbma", [0.01, 0.02], STEPS, SEEDS,
                    plan=ExecPlan(n_shards=2, row_shards=2))
    np.testing.assert_array_equal(plain.risks, placed.risks)
    np.testing.assert_array_equal(plain.cum_energy, placed.cum_energy)


_SUBPROC_SNIPPET = """
import json
import numpy as np
from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.mc import ExecPlan, run_mc

mc = MSDProblem.make({n}, dim={d}).to_mc()
ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
res = run_mc(mc, [ch], "gbma", [0.01], {steps}, {seeds},
             plan=ExecPlan(seed_chunk=4, n_shards=4,
                           keep_seed_curves=False))
print(json.dumps(res.mean.tolist()))
"""


def test_forced_host_devices_match_in_process(mc):
    """Single-device tier-1 coverage of a genuinely placed run: a
    subprocess forces 4 host devices (XLA_FLAGS must be set before jax
    imports, hence the subprocess) and its 4-shard chunked moments must
    match this process's run to f32 reduction tolerance."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH"), "src") if p)
    snippet = _SUBPROC_SNIPPET.format(n=N, d=D, steps=STEPS, seeds=SEEDS)
    out = subprocess.run(
        [sys.executable, "-c", snippet], env=env, capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr
    sub_mean = np.asarray(json.loads(out.stdout.strip()), np.float32)
    here = run_mc(mc, [_ch()], "gbma", [0.01], STEPS, SEEDS,
                  seed_chunk=4, keep_seed_curves=False)
    np.testing.assert_allclose(sub_mean, here.mean, rtol=1e-6)
