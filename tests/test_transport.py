"""Channel-transport layer (`repro.core.transport`) tests.

Four pillars:
  * tiling — block-tiled aggregation matches the untiled FULL_CONCAT slot
    to <= 1e-6 for every registered algorithm (the draws match bitwise;
    the tolerance absorbs XLA's per-shape reassociation of the f32 node
    superposition), and the bf16-transmit path stays f32-out.
  * engine parity — a transport-driven GD loop reproduces `run_mc`
    trajectories for ALL registered algorithms on the quadratic problem,
    driven from the same `split(key(seed), steps)` slot-key stream
    (`TransportConfig.mc_steps`); and `build_train_step`'s transport route
    does the same end-to-end with a quadratic "model".
  * golden compat — the fused gbma/fdm/centralized production training
    paths and the tier-(i) `ota_aggregate`/`GBMASimulator` veneers
    reproduce the pre-transport HEAD captures (tests/golden/*.npz):
    bit-for-bit for the fused tree paths, <= 1e-6 for the veneers (named
    cause: channel-constant arithmetic moved from host f64 to traced f32,
    a one-ulp rounding difference).
  * training surface — pre-clip grad_norm + clip_frac metrics
    (hand-computed), the stateful opt_state threading, the
    `rng_impl='rbg'` smoke, and the full-registry launcher matrix.
"""
from __future__ import annotations

import dataclasses
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transport
from repro.core.channel import ChannelConfig
from repro.core.mc.engine import run_mc
from repro.core.mc.problems import quadratic_mc_problem
from repro.core.mc.slots import ALGO_REGISTRY, slot_update_block

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"

# one (run_mc kwargs, TransportConfig kwargs) pair per registered algo —
# new registry entries must be added here or the coverage test fails
ALGO_SETUPS = {
    "gbma": ({}, {}),
    "centralized": ({}, {}),
    "fdm": ({}, {}),
    "power_control": ({}, {}),
    "momentum": ({"momentum": 0.9}, {"gamma": 0.9}),
    "nesterov": ({"momentum": 0.9}, {"gamma": 0.9}),
    "blind": ({"n_antennas": 3}, {"n_antennas": 3}),
    "blind_ec": ({"n_antennas": 3, "power_budget": 2.0},
                 {"n_antennas": 3, "power_budget": 2.0}),
}


def test_algo_setups_cover_registry():
    assert set(ALGO_SETUPS) == set(ALGO_REGISTRY)


def _chan(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.4)
    kw.setdefault("energy", 1.5)
    return ChannelConfig(**kw)


def _grad_tree(n=4, key=5):
    return {"a": jax.random.normal(jax.random.key(key), (n, 5, 3)),
            "b": {"c": jax.random.normal(jax.random.key(key + 1), (n, 7))}}


def _tree_max_diff(t1, t2):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(t1), jax.tree_util.tree_leaves(t2)))


def _cfg_and_state(algo, n=4, **extra):
    _, tkw = ALGO_SETUPS[algo]
    cfg = transport.TransportConfig(n_nodes=n, channel=_chan(),
                                    **{**tkw, **extra})
    params = jax.tree_util.tree_map(lambda g: g[0], _grad_tree(n))
    state = (transport.init_state(algo, params, cfg)
             if transport.has_state(algo) else None)
    return cfg, state


# --------------------------------------------------------------------------
# tiling
# --------------------------------------------------------------------------
@pytest.mark.parametrize("algo", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("block_d", [None, 2, 4, 64])
def test_tiled_matches_untiled(algo, block_d):
    """Every block_d (per-leaf, narrow tiles, tiles wider than any leaf)
    matches the single FULL_CONCAT slot call to <= 1e-6."""
    tree = _grad_tree()
    key = jax.random.key(0)
    cfg, state = _cfg_and_state(algo, block_d=transport.FULL_CONCAT)
    ref, ref_state, ref_aux = transport.aggregate(algo, tree, key, cfg, state)

    cfg_t, state_t = _cfg_and_state(algo, block_d=block_d)
    out, out_state, aux = transport.aggregate(algo, tree, key, cfg_t, state_t)
    assert _tree_max_diff(ref, out) <= 1e-6
    np.testing.assert_allclose(float(aux["tx_energy"]),
                               float(ref_aux["tx_energy"]), rtol=1e-5)
    if out_state is not None and "e" in out_state:
        assert _tree_max_diff(ref_state["e"], out_state["e"]) <= 1e-6


def test_tiled_draws_are_bitwise_same_stream():
    """The per-coordinate guarantee behind the tiling: block [lo, hi) of a
    slot consumes exactly coordinates [lo, hi) of THE slot's draw streams
    (not a fresh per-block draw)."""
    n, d = 4, 12
    g = jax.random.normal(jax.random.key(1), (n, d))
    key = jax.random.key(2)
    cfg, _ = _cfg_and_state("gbma")
    spec = transport.resolve("gbma")
    ctx = transport.make_ctx(cfg, spec)
    draws = spec.hoist_draws(key[None], ctx, n, d)
    draws = jax.tree_util.tree_map(lambda a: a[0], draws)
    ctx = dataclasses.replace(ctx, draws=draws)
    full = slot_update_block("gbma", g, key, ctx, 0, d)
    lo, hi = 3, 9
    blk = slot_update_block("gbma", g[:, lo:hi], key, ctx, lo, hi)
    # identical shapes inside the block -> identical reduction order ->
    # exact equality coordinate-for-coordinate is NOT guaranteed across
    # different widths, but the noise coordinates are: zero gradients
    # isolate the sliced stream
    z_full = slot_update_block("gbma", jnp.zeros_like(g), key, ctx, 0, d)
    z_blk = slot_update_block("gbma", jnp.zeros_like(g[:, lo:hi]), key, ctx,
                              lo, hi)
    np.testing.assert_array_equal(np.asarray(z_full[lo:hi]),
                                  np.asarray(z_blk))
    np.testing.assert_allclose(np.asarray(full[lo:hi]), np.asarray(blk),
                               atol=1e-6)


def test_block_guard_rejects_random_algo_without_draws():
    cfg, _ = _cfg_and_state("gbma")
    spec = transport.resolve("gbma")
    ctx = transport.make_ctx(cfg, spec)  # draws=None
    with pytest.raises(ValueError, match="pre-materialized draws"):
        slot_update_block("gbma", jnp.ones((4, 3)), jax.random.key(0), ctx,
                          0, 3)


def test_bf16_transmit_accumulates_f32():
    """bf16-transmit: output stays f32, deviation from the f32 path is
    bf16-quantization-sized (nonzero but small); `centralized` is exempt
    and stays bitwise."""
    tree = _grad_tree()
    key = jax.random.key(3)
    for algo in ("gbma", "blind", "fdm"):
        cfg, state = _cfg_and_state(algo)
        cfg_bf = dataclasses.replace(cfg, transmit_dtype="bfloat16")
        ref, _, _ = transport.aggregate(algo, tree, key, cfg, state)
        out, _, _ = transport.aggregate(algo, tree, key, cfg_bf, state)
        for leaf in jax.tree_util.tree_leaves(out):
            assert leaf.dtype == jnp.float32
        dev = _tree_max_diff(ref, out)
        assert 0 < dev < 0.05, f"{algo}: bf16 dev {dev}"
    cfg, _ = _cfg_and_state("centralized")
    cfg_bf = dataclasses.replace(cfg, transmit_dtype="bfloat16")
    ref, _, _ = transport.aggregate("centralized", tree, key, cfg)
    out, _, _ = transport.aggregate("centralized", tree, key, cfg_bf)
    assert _tree_max_diff(ref, out) == 0.0


def test_blind_ec_budget_saturates_tx_energy():
    """With every node over budget, the transmitted energy is exactly
    E_N * N * B (each node truncated to the budget sphere)."""
    tree = _grad_tree()
    cfg, state = _cfg_and_state("blind_ec", power_budget=0.5)
    _, _, aux = transport.aggregate("blind_ec", tree, jax.random.key(0),
                                    cfg, state)
    np.testing.assert_allclose(float(aux["tx_energy"]),
                               cfg.channel.energy * cfg.n_nodes * 0.5,
                               rtol=1e-6)


def test_stateful_aggregators_require_state():
    tree = _grad_tree()
    for algo in ("momentum", "blind_ec"):
        cfg, _ = _cfg_and_state(algo)
        with pytest.raises(ValueError, match="transport state"):
            transport.aggregate(algo, tree, jax.random.key(0), cfg, None)


def test_resolve_unknown_algo():
    with pytest.raises(ValueError, match="unknown algo"):
        transport.resolve("nope")


def test_step_key_replays_engine_schedule():
    base = jax.random.key(7)
    ref = jax.random.split(base, 10)
    for k in (0, 3, 9):
        np.testing.assert_array_equal(
            jax.random.key_data(transport.step_key(base, k, mc_steps=10)),
            jax.random.key_data(ref[k]))
    # default schedule is fold_in
    np.testing.assert_array_equal(
        jax.random.key_data(transport.step_key(base, 4)),
        jax.random.key_data(jax.random.fold_in(base, 4)))


# --------------------------------------------------------------------------
# engine parity
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def quad():
    rng = np.random.default_rng(0)
    n, d = 6, 9
    X = rng.normal(size=(n, d)).astype(np.float32)
    theta_star = rng.normal(size=(d,)).astype(np.float32)
    y = X @ theta_star
    return quadratic_mc_problem(X, y, 0.1, theta_star), n, d


@pytest.mark.parametrize("algo", sorted(ALGO_SETUPS))
def test_transport_loop_matches_run_mc(quad, algo):
    """A hand GD loop over `transport.aggregate` — grads at the (nesterov)
    lookahead, theta <- theta - beta * update — reproduces the engine's
    risk AND cumulative-energy curves from the same slot-key stream.
    Documented tolerance: f32 ulp accumulation (traced-f32 channel
    constants, reduction order); observed <= 3e-7 absolute on this
    problem."""
    prob, n, d = quad
    ch = _chan(noise_std=0.4, phase_error_max=0.25)
    steps, beta, seed = 12, 0.05, 7
    mkw, tkw = ALGO_SETUPS[algo]
    res = run_mc(prob, [ch], algo, [beta], steps, 1, seed0=seed, **mkw)
    curve = np.asarray(res.risks)[0, 0]
    cum_e = np.asarray(res.cum_energy)[0, 0]

    cfg = transport.TransportConfig(n_nodes=n, channel=ch, mc_steps=steps,
                                    stepsize=beta, **tkw)
    base = jax.random.key(seed)
    theta = jnp.zeros((d,), jnp.float32)
    params = jnp.zeros((d,), jnp.float32)
    state = (transport.init_state(algo, params, cfg)
             if transport.has_state(algo) else None)
    Hj, ts = prob.data["H"], prob.data["theta_star"]
    Xj, yj = prob.data["X"], prob.data["y"]
    risks, energies = [], []
    for k in range(steps):
        th_eval = transport.lookahead_params(algo, theta, state, cfg)
        g = (Xj @ th_eval - yj)[:, None] * Xj + 0.1 * th_eval[None, :]
        diff = theta - ts
        risks.append(float(0.5 * diff @ (Hj @ diff)))
        u, state, aux = transport.aggregate(
            algo, g, transport.step_key(base, k, mc_steps=steps), cfg, state)
        energies.append(float(aux["tx_energy"]))
        theta = theta - beta * u
    diff = theta - ts
    risks.append(float(0.5 * diff @ (Hj @ diff)))
    np.testing.assert_allclose(np.asarray(risks, np.float32), curve,
                               rtol=1e-4, atol=5e-6)
    np.testing.assert_allclose(np.cumsum(energies), cum_e, rtol=1e-4)


class _QuadModel:
    """Quadratic 'model' for `build_train_step`: per-example loss
    0.5 (x·theta - y)^2 + 0.5 lam |theta|^2, so node n's local gradient
    (one example per node) is exactly `_quadratic_grad_row`'s
    (x_n·theta - y_n) x_n + lam theta."""

    kind = "quad"
    lam = 0.1

    class cfg:
        fsdp = False

    def train_loss_per_example(self, params, batch):
        r = batch["x"] @ params["theta"] - batch["y"]
        reg = 0.5 * self.lam * jnp.sum(params["theta"] ** 2)
        return 0.5 * r ** 2 + reg, None


@pytest.mark.parametrize("algo", ["gbma", "blind", "blind_ec", "nesterov"])
def test_build_train_step_matches_run_mc(quad, algo):
    """End-to-end: the transport route of `build_train_step` (per-node
    grads via vmap, slot through transport, gd optimizer, stateful
    opt_state threading) reproduces `run_mc` on the quadratic problem from
    the same `split(key(seed), steps)` stream (mc_steps parity mode).
    Tolerance as in `test_transport_loop_matches_run_mc`."""
    from repro.optim.gd import gd
    from repro.training.train_step import TrainConfig, build_train_step

    prob, n, d = quad
    ch = _chan(noise_std=0.4, phase_error_max=0.25)
    steps, beta, seed = 10, 0.05, 3
    mkw, tkw = ALGO_SETUPS[algo]
    res = run_mc(prob, [ch], algo, [beta], steps, 1, seed0=seed, **mkw)
    curve = np.asarray(res.risks)[0, 0]

    model = _QuadModel()
    tcfg = TrainConfig(
        aggregator=algo, seed=seed, route="transport",
        transport=transport.TransportConfig(
            n_nodes=n, channel=ch, mc_steps=steps, stepsize=beta, **tkw))
    step = build_train_step(model, tcfg, gd(beta))
    step_fn = jax.jit(step)
    params = {"theta": jnp.zeros((d,), jnp.float32)}
    opt_state = step.init_state(params)
    batch = {"x": prob.data["X"], "y": prob.data["y"]}
    Hj, ts = prob.data["H"], prob.data["theta_star"]

    def risk(p):
        diff = p["theta"] - ts
        return float(0.5 * diff @ (Hj @ diff))

    risks = [risk(params)]
    for k in range(steps):
        params, opt_state, metrics = step_fn(params, opt_state, batch, k)
        risks.append(risk(params))
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["tx_energy"]))
    np.testing.assert_allclose(np.asarray(risks, np.float32), curve,
                               rtol=1e-4, atol=5e-6)


def test_build_train_step_rbg_smoke(quad):
    """`rng_impl='rbg'` composes with the transport route (the fold_in
    schedule; rbg has no mc_steps parity claim) — finite losses, params
    move."""
    from repro.optim.gd import gd
    from repro.training.train_step import TrainConfig, build_train_step

    prob, n, d = quad
    tcfg = TrainConfig(
        aggregator="gbma", rng_impl="rbg", route="transport",
        transport=transport.TransportConfig(n_nodes=n, channel=_chan()))
    model = _QuadModel()
    step = build_train_step(model, tcfg, gd(0.05))
    step_fn = jax.jit(step)
    params = {"theta": jnp.zeros((d,), jnp.float32)}
    opt_state = step.init_state(params)
    batch = {"x": prob.data["X"], "y": prob.data["y"]}
    for k in range(3):
        params, opt_state, metrics = step_fn(params, opt_state, batch, k)
        assert np.isfinite(float(metrics["loss"]))
    assert float(jnp.sum(jnp.abs(params["theta"]))) > 0


# --------------------------------------------------------------------------
# clip metrics (pre-clip grad_norm + clip_frac)
# --------------------------------------------------------------------------
def test_clip_metrics_hand_computed():
    """grads (3, 4) -> global norm 5 exactly. clip_norm=2.5 engages
    (scale 0.5, clip_frac 1) but `grad_norm` still reports the PRE-clip 5;
    clip_norm=10 doesn't engage; clip_norm=None reports clip_frac 0."""
    from repro.training.train_step import TrainConfig, _clip_and_metrics

    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    out, m = _clip_and_metrics(grads, TrainConfig(clip_norm=2.5))
    assert float(m["grad_norm"]) == 5.0
    assert float(m["clip_frac"]) == 1.0
    np.testing.assert_allclose(np.asarray(out["a"]), [1.5])
    np.testing.assert_allclose(np.asarray(out["b"]), [2.0])

    out, m = _clip_and_metrics(grads, TrainConfig(clip_norm=10.0))
    assert float(m["grad_norm"]) == 5.0
    assert float(m["clip_frac"]) == 0.0
    np.testing.assert_array_equal(np.asarray(out["a"]), [3.0])

    out, m = _clip_and_metrics(grads, TrainConfig(clip_norm=None))
    assert float(m["grad_norm"]) == 5.0
    assert float(m["clip_frac"]) == 0.0


def test_clip_by_global_norm_accepts_precomputed_norm():
    from repro.optim.gd import clip_by_global_norm, global_norm

    grads = {"a": jnp.asarray([3.0, 4.0])}
    ref = clip_by_global_norm(grads, 2.5)
    out = clip_by_global_norm(grads, 2.5, norm=global_norm(grads))
    np.testing.assert_array_equal(np.asarray(ref["a"]), np.asarray(out["a"]))


# --------------------------------------------------------------------------
# golden compat (pre-transport HEAD captures)
# --------------------------------------------------------------------------
def _tiny_model():
    from repro.configs.registry import get_config
    from repro.models.model import build_model

    cfg = get_config("repro-100m").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, logit_chunk=32, attn_block_q=16,
        attn_block_kv=32)
    return build_model(cfg)


class TestGoldenCompat:
    """Pin the refactor against trajectories captured at the pre-transport
    HEAD (tests/golden/capture.py). The fused training paths must be
    BIT-FOR-BIT; the tier-(i) veneers <= 1e-6 (named cause: channel
    constants now traced f32 — the captured operating points include
    energy != 1 specifically to exercise that rounding)."""

    @pytest.mark.parametrize("name,aggregator,noise_std,clip", [
        ("gbma", "gbma", 0.05, None),
        ("fdm", "fdm", 0.05, None),
        ("centralized", "centralized", 0.0, None),
        ("gbma_clip", "gbma", 0.05, 0.5),
    ])
    def test_training_bitwise(self, name, aggregator, noise_std, clip):
        from repro.core.gbma import GBMAConfig
        from repro.data.synthetic import SyntheticTokens, TokenDatasetConfig
        from repro.optim.gd import momentum
        from repro.training.loop import run_training
        from repro.training.train_step import TrainConfig, build_train_step

        gold = np.load(GOLDEN / "train_head.npz")
        m = _tiny_model()
        params = m.init_params(jax.random.key(0))
        ds = SyntheticTokens(TokenDatasetConfig(
            vocab_size=m.cfg.vocab_size, seq_len=16, global_batch=8,
            seed=3))
        tcfg = TrainConfig(
            aggregator=aggregator,
            gbma=GBMAConfig(n_nodes=4, channel=ChannelConfig(
                fading="rayleigh", noise_std=noise_std, energy=1.0,
                phase_error_max=0.3)),
            clip_norm=clip)
        step = build_train_step(m, tcfg, momentum(0.05))
        batches = ({"tokens": t} for t in ds)
        params, _, hist = run_training(
            step, params, step.init_state(params), batches, 4, log_every=1)
        losses = np.asarray([h["loss"] for h in hist], np.float32)
        flat = np.concatenate([np.asarray(x, np.float32).ravel()
                               for x in jax.tree_util.tree_leaves(params)])
        np.testing.assert_array_equal(losses, gold[f"{name}_losses"])
        np.testing.assert_array_equal(flat, gold[f"{name}_params"])

    def test_tier_i_veneers(self):
        from repro.core.gbma import (GBMAConfig, GBMASimulator,
                                     ota_aggregate, perturb_gradients)
        from repro.training.train_step import _fdm_noise

        gold = np.load(GOLDEN / "tier_i_head.npz")
        grads = jax.random.normal(jax.random.key(7), (8, 33))
        for tag, cfg in {
            "rayleigh": ChannelConfig(fading="rayleigh", noise_std=1.0,
                                      energy=2.0, phase_error_max=0.3),
            "equal": ChannelConfig(fading="equal", noise_std=0.5,
                                   energy=1.0),
        }.items():
            v = np.asarray(ota_aggregate(grads, jax.random.key(11), cfg))
            assert np.abs(v - gold[f"ota_{tag}"]).max() <= 1e-6

        cfg = ChannelConfig(fading="rayleigh", noise_std=1.0, energy=1.0)
        target = jnp.linspace(-1.0, 1.0, 12)
        wts = jnp.linspace(0.5, 1.5, 6)
        sim = GBMASimulator(
            grad_fn=lambda th: wts[:, None] * (th - target)[None, :],
            channel=cfg, stepsize=0.2)
        traj = np.asarray(sim.run(jnp.zeros(12), 20, jax.random.key(5)),
                          np.float32)
        assert np.abs(traj - gold["sim_traj"]).max() <= 1e-5

        gcfg = GBMAConfig(n_nodes=4, channel=ChannelConfig(
            fading="rayleigh", noise_std=0.7, energy=2.0))
        tree = {"a": jnp.ones((5, 3), jnp.float32),
                "b": {"c": jnp.full((4,), 2.0, jnp.bfloat16)}}
        pg = perturb_gradients(tree, jax.random.key(21), gcfg)
        np.testing.assert_array_equal(
            np.asarray(pg["a"], np.float32), gold["perturb_a"])
        np.testing.assert_array_equal(
            np.asarray(pg["b"]["c"].astype(jnp.float32)), gold["perturb_b"])
        fd = _fdm_noise(tree, jax.random.key(22), gcfg)
        np.testing.assert_array_equal(
            np.asarray(fd["a"], np.float32), gold["fdm_a"])
        np.testing.assert_array_equal(
            np.asarray(fd["b"]["c"].astype(jnp.float32)), gold["fdm_b"])


# --------------------------------------------------------------------------
# launcher matrix: every registry aggregator trains end-to-end
# --------------------------------------------------------------------------
@pytest.mark.parametrize("algo", sorted(ALGO_SETUPS))
def test_launcher_aggregator_matrix(algo, monkeypatch, capsys):
    """`repro.launch.train` accepts every registered aggregator and runs
    two steps at a monkeypatched-tiny size."""
    import repro.launch.train as launch

    tiny = _tiny_model().cfg
    monkeypatch.setattr(launch, "get_config", lambda name: tiny)
    argv = ["train", "--steps", "2", "--batch", "4", "--seq", "16",
            "--nodes", "4", "--aggregator", algo, "--optimizer", "gd",
            "--noise-std", "0.05"]
    if ALGO_REGISTRY[algo].blind:
        argv += ["--antennas", "2"]
    if algo == "blind_ec":
        argv += ["--power-budget", "10"]
    monkeypatch.setattr("sys.argv", argv)
    launch.main()
    out = capsys.readouterr().out
    assert "final loss" in out
    assert math.isfinite(float(out.rsplit("final loss", 1)[1].split()[0]))
