"""The three GBMA tiers must agree: loss-weighting (production) == explicit
shard_map protocol == vectorized simulation, given the same gains/noise."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, shard_map
from repro.core.channel import ChannelConfig, sample_gains
from repro.core.gbma import (GBMAConfig, gbma_value_and_grad, node_weights,
                             ota_aggregate, perturb_gradients,
                             shard_map_aggregate)


def _quad_loss(params, batch):
    """Per-example quadratic losses: params dict {'w': (d,)}."""
    X, y = batch
    r = X @ params["w"] - y
    return 0.5 * r * r


def test_loss_weighting_equals_manual_superposition():
    """d/dw [mean_n h_n f_n] == (1/N) sum h_n g_n exactly."""
    d, n_nodes, per = 6, 8, 4
    key = jax.random.key(0)
    X = jax.random.normal(key, (n_nodes * per, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n_nodes * per,))
    params = {"w": jax.random.normal(jax.random.fold_in(key, 2), (d,))}
    gcfg = GBMAConfig(n_nodes=n_nodes, channel=ChannelConfig(noise_std=0.0))
    w = node_weights(jax.random.key(3), gcfg, n_nodes * per)

    vg = gbma_value_and_grad(_quad_loss)
    _, grads = vg(params, (X, y), w)

    # manual: per-node gradient of the node's mean loss, scaled by its gain
    h = w.reshape(n_nodes, per)[:, 0]
    manual = jnp.zeros(d)
    for i in range(n_nodes):
        sl = slice(i * per, (i + 1) * per)
        g_n = jax.grad(
            lambda p: jnp.mean(_quad_loss(p, (X[sl], y[sl]))))(params)["w"]
        manual = manual + h[i] * g_n
    manual = manual / n_nodes
    np.testing.assert_allclose(np.array(grads["w"]), np.array(manual),
                               rtol=1e-5, atol=1e-6)


def test_shard_map_tier_matches_loss_weighting():
    """Explicit psum protocol over a 1D device mesh == weighted-loss tier."""
    d, n_nodes, per = 4, 1, 8  # single device -> single node
    key = jax.random.key(5)
    X = jax.random.normal(key, (per, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (per,))
    params = {"w": jnp.zeros(d)}
    ch = ChannelConfig(noise_std=0.4, energy=1.0)
    gcfg = GBMAConfig(n_nodes=n_nodes, channel=ch)
    k_h, k_w = jax.random.split(jax.random.key(7))
    weights = jnp.repeat(sample_gains(k_h, ch, (n_nodes,)), per)

    vg = gbma_value_and_grad(_quad_loss)
    _, g1 = vg(params, (X, y), weights)
    g1 = perturb_gradients(g1, k_w, gcfg)

    mesh = make_mesh((1,), ("data",))
    local_gain = sample_gains(k_h, ch, (n_nodes,))[0]

    @jax.jit
    def protocol():
        def body(xb, yb):
            g = jax.grad(lambda p: jnp.mean(_quad_loss(p, (xb, yb))))(params)
            return shard_map_aggregate(g, local_gain, k_w, gcfg, ("data",))

        return shard_map(body, mesh=mesh,
                         in_specs=(jax.sharding.PartitionSpec("data"),) * 2,
                         out_specs=jax.sharding.PartitionSpec())(X, y)

    g2 = protocol()
    np.testing.assert_allclose(np.array(g1["w"]), np.array(g2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_ota_kernel_path_matches_ref_path():
    ch = ChannelConfig(fading="rayleigh", noise_std=0.2)
    g = jax.random.normal(jax.random.key(1), (128, 512))
    v_ref = ota_aggregate(g, jax.random.key(2), ch, use_kernel=False)
    v_ker = ota_aggregate(g, jax.random.key(2), ch, use_kernel=True)
    np.testing.assert_allclose(np.array(v_ref), np.array(v_ker),
                               rtol=1e-4, atol=1e-5)
