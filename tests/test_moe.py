"""MoE routing/dispatch unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.moe import moe_apply, moe_params


def _cfg(**kw):
    base = get_config("llama4-maverick-400b-a17b").reduced()
    return base.with_(**kw)


def _dense_reference(x, p, cfg):
    """Route each token to its top-k experts WITHOUT capacity limits."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d).astype(jnp.float32)
    logits = xt @ p["router"]
    if cfg.router_scoring == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    out = jnp.zeros_like(xt)
    sel = scores
    gate_sum = jnp.zeros(t)
    acc = jnp.zeros_like(xt)
    for _ in range(cfg.top_k):
        eid = jnp.argmax(sel, axis=-1)
        gate = jnp.take_along_axis(scores, eid[:, None], -1)[:, 0]
        wi = p["experts_wi"][eid].astype(jnp.float32)
        wg = p["experts_wg"][eid].astype(jnp.float32)
        wo = p["experts_wo"][eid].astype(jnp.float32)
        h = jnp.einsum("td,tdf->tf", xt, wi)
        hg = jax.nn.silu(jnp.einsum("td,tdf->tf", xt, wg))
        e_out = jnp.einsum("tf,tfd->td", hg * h, wo)
        acc = acc + gate[:, None] * e_out
        gate_sum = gate_sum + gate
        sel = sel - 1e9 * jax.nn.one_hot(eid, cfg.n_experts)
    if cfg.top_k > 1:
        acc = acc / jnp.maximum(gate_sum, 1e-9)[:, None]
    out = acc
    if cfg.n_shared_experts:
        hs = jnp.einsum("td,df->tf", xt, p["shared_wi"].astype(jnp.float32))
        hg = jax.nn.silu(
            jnp.einsum("td,df->tf", xt, p["shared_wg"].astype(jnp.float32)))
        out = out + jnp.einsum("tf,fd->td", hg * hs,
                               p["shared_wo"].astype(jnp.float32))
    return out.reshape(b, s, d)


@pytest.mark.parametrize("top_k,scoring,shared", [
    (1, "softmax", 0), (2, "softmax", 0), (2, "sigmoid", 1),
])
def test_moe_matches_dense_reference_without_drops(top_k, scoring, shared):
    """With capacity >= tokens no token is dropped, so the grouped-dispatch
    implementation must equal dense per-token routing."""
    cfg = _cfg(top_k=top_k, router_scoring=scoring, n_shared_experts=shared,
               capacity_factor=100.0, dtype="float32")
    key = jax.random.key(0)
    p = moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    out, aux = moe_apply(x, p, cfg, n_groups=1)
    ref = _dense_reference(x, p, cfg)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-4,
                               rtol=1e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens_gracefully():
    cfg = _cfg(top_k=1, capacity_factor=0.25, dtype="float32")
    key = jax.random.key(1)
    p = moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    out, aux = moe_apply(x, p, cfg, n_groups=1)
    assert np.isfinite(np.array(out)).all()


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_moe_aux_loss_bounds(seed):
    """Switch aux loss: >= 1 (balanced) and <= E (fully collapsed)."""
    cfg = _cfg(top_k=1, dtype="float32")
    p = moe_params(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 64, cfg.d_model))
    _, aux = moe_apply(x, p, cfg, n_groups=1)
    assert 0.5 <= float(aux) <= cfg.n_experts + 1e-3


def test_moe_gradients_flow_to_all_param_groups():
    cfg = _cfg(top_k=2, n_shared_experts=1, router_scoring="sigmoid",
               dtype="float32")
    p = moe_params(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 16, cfg.d_model))

    def loss(p_):
        out, aux = moe_apply(x, p_, cfg, n_groups=1)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(p)
    for name in ("router", "experts_wi", "experts_wo", "shared_wi"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0.0, name
