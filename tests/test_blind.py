"""Blind-transmitter family (`algo="blind"/"blind_ec"`) correctness:

  * engine trajectories == the reference `gbma.blind_ota_aggregate` scan
    under a fixed key (same split order), including the energy account;
  * complex-gain samplers: the engine's traceable twin ==
    `channel.sample_complex_gains` across fading families (property test),
    and the dynamic-count twin == the shaped draws;
  * per-row antenna counts: the counts-as-data key split replays
    `jax.random.split(key, m)` exactly, an M-sweep batches in ONE
    `_mc_core` compile and matches the static per-M runs; node-count
    sweeps likewise;
  * degenerate cases: a large-M blind slot approaches the equal-gain GBMA
    (= mean-gradient) update at the documented O(sqrt(N/(M m2)))
    tolerance; `blind_ec` with a non-binding budget is bit-identical to
    `blind`; with zero noise and many antennas both converge like
    centralized GD and agree at the horizon;
  * a hand-computed single-step value (equal-gain family, M=1) pins the
    MRC combiner formula and its RNG discipline;
  * `blind_ec` budget: per-slot transmitted energy never exceeds E_N·N·B.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from benchmarks.common import MSDProblem
from repro.core import channel as channel_mod
from repro.core import montecarlo as mc_mod
from repro.core.channel import ChannelConfig
from repro.core.gbma import blind_ota_aggregate
from repro.core.montecarlo import run_mc, trace_count

N, STEPS, SEEDS = 24, 40, 2


@pytest.fixture(scope="module")
def prob():
    return MSDProblem.make(N, dim=16)


@pytest.fixture(scope="module")
def mc(prob):
    return prob.to_mc()


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.5)
    return ChannelConfig(**kw)


def test_engine_matches_blind_reference(prob, mc):
    """Engine algo='blind' == a hand scan over `blind_ota_aggregate` with
    the same keys; cum_energy == E_N Σ‖g_n‖² along that trajectory."""
    ch = _ch(energy=0.25)
    beta = 0.02
    g = prob.grad_fn()
    for m_ant in (1, 3):
        res = run_mc(mc, [ch], "blind", [beta], STEPS, 1, n_antennas=m_ant)

        def body(theta, k):
            v = blind_ota_aggregate(g(theta), k, ch, m_ant)
            return theta - beta * v, theta

        keys = jax.random.split(jax.random.key(0), STEPS)
        theta_fin, traj = jax.lax.scan(body, jnp.zeros(prob.pc.dim), keys)
        traj = jnp.concatenate([traj, theta_fin[None]])
        np.testing.assert_allclose(res.risks[0, 0], prob.excess_risk(traj),
                                   rtol=1e-4, atol=1e-8)
        g_sq = [float(jnp.sum(g(t) ** 2)) for t in traj[:-1]]
        np.testing.assert_allclose(res.cum_energy[0, 0],
                                   ch.energy * np.cumsum(g_sq), rtol=1e-4)


def test_blind_large_m_approaches_equal_gain_update():
    """Degeneracy (documented in docs/algorithms.md): with many antennas
    the blind MRC combine concentrates on the equal-gain GBMA update — the
    plain mean gradient at zero noise. Deviation is O(sqrt(N/(M m2)));
    at N=8, M=4096 the fixed-seed relative L2 error is ~1%, asserted
    at the documented 5% tolerance."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
    ch = _ch(noise_std=0.0)
    for seed in (0, 1):
        v = np.asarray(blind_ota_aggregate(g, jax.random.key(seed), ch,
                                           4096))
        vc = np.asarray(jnp.mean(g, axis=0))
        rel = np.linalg.norm(v - vc) / np.linalg.norm(vc)
        assert rel < 0.05, f"seed {seed}: rel L2 {rel:.3f} >= 5%"


def test_blind_single_step_hand_computed():
    """Equal-gain family, M=1, N=2: recompute the MRC combine by hand from
    the raw draws — pins both the formula v = (A y_r + B y_i)/(N m2) and
    the key-split discipline (slot -> antenna -> (k_h, k_w) -> (mag, ph))."""
    scale, noise_std, energy = 1.3, 0.7, 0.25
    cfg = ChannelConfig(fading="equal", scale=scale, noise_std=noise_std,
                        energy=energy)
    g = np.asarray([[1.0, -2.0, 0.5], [0.25, 3.0, -1.0]], np.float32)
    key = jax.random.key(11)
    v = np.asarray(blind_ota_aggregate(jnp.asarray(g), key, cfg, 1))
    # replay the draws with the documented split order
    (k_ant,) = jax.random.split(key, 1)
    k_h, k_w = jax.random.split(k_ant)
    _, k_ph = jax.random.split(k_h)  # k_mag unused for the 'equal' family
    phi = np.asarray(jax.random.uniform(k_ph, (2,), minval=-np.pi,
                                        maxval=np.pi))
    z = np.asarray(jax.random.normal(k_w, (2, 3)))
    a, b = scale * np.cos(phi), scale * np.sin(phi)
    std = noise_std / np.sqrt(energy)
    y_r = a @ g + std * z[0]
    y_i = b @ g + std * z[1]
    expect = (a.sum() * y_r + b.sum() * y_i) / (1 * 2 * scale**2)
    np.testing.assert_allclose(v, expect, rtol=1e-5, atol=1e-7)


def test_blind_ec_non_binding_budget_is_bit_identical(prob, mc):
    """With the default unbounded budget nothing is ever truncated: the
    residual stays 0 and blind_ec == blind bit-for-bit."""
    ch = _ch()
    r_ec = run_mc(mc, [ch], "blind_ec", [0.02], STEPS, SEEDS, n_antennas=3)
    r_bl = run_mc(mc, [ch], "blind", [0.02], STEPS, SEEDS, n_antennas=3)
    np.testing.assert_array_equal(r_ec.risks, r_bl.risks)
    np.testing.assert_array_equal(r_ec.cum_energy, r_bl.cum_energy)


def test_blind_ec_zero_noise_large_m_matches_blind(prob, mc):
    """Zero noise + many antennas: the channel is effectively perfect, the
    budget binds only while gradients are large, and the residual
    re-injects exactly what was cut — blind_ec converges to the same
    optimum as blind (== centralized here), tracking its trajectory with
    a bounded delay (the truncation shifts, not breaks, the exponential
    tail)."""
    ch = _ch(noise_std=0.0)
    g0 = np.asarray(mc.grad_fn(jnp.zeros(prob.pc.dim, jnp.float32)))
    budget = 0.5 * float(np.mean(np.sum(g0**2, axis=1)))
    steps = 150
    r_bl = run_mc(mc, [ch], "blind", [0.02], steps, 1, n_antennas=256)
    r_ec = run_mc(mc, [ch], "blind_ec", [0.02], steps, 1, n_antennas=256,
                  power_budget=budget)
    init = r_bl.risks[0, 0, 0]
    assert r_bl.risks[0, 0, -1] < 1e-2 * init
    assert r_ec.risks[0, 0, -1] < 1e-2 * init
    # ec's horizon risk is within blind's trajectory a bounded number of
    # steps earlier (observed delay ≈ 42 slots at this budget; bound 60)
    assert r_ec.risks[0, 0, -1] <= r_bl.risks[0, 0, steps - 60]


def test_blind_ec_budget_caps_slot_energy(prob, mc):
    """Per-slot transmitted energy is at most E_N · N · B when the budget
    binds (each node transmits at most B in squared norm)."""
    ch = _ch(energy=0.5)
    budget = 1e-3
    res = run_mc(mc, [ch], "blind_ec", [0.05], STEPS, 1, n_antennas=8,
                 power_budget=budget)
    inc = np.diff(np.concatenate(
        [np.zeros((1,)), res.cum_energy[0, 0]]))
    cap = ch.energy * N * budget
    assert np.all(inc <= cap * (1.0 + 1e-4))  # f32 cumsum rounding slack
    assert inc.max() > 0.5 * cap  # the budget actually binds here


def test_ec_flag_select_does_not_leak_nan_into_other_rows():
    """A non-ec row whose per-node squared norm overflows f32 (sq = inf)
    while its budget is the default inf makes the (unused) α expression
    inf/inf = NaN; the per-row select must keep that row on the exact
    x = g path instead of NaN-poisoning its trajectory from step one."""
    from repro.core.montecarlo import MCProblem

    big = 1.0e19  # Σ_d big² overflows f32; g and the trajectory stay finite
    n, d = 4, 8
    problem = MCProblem(
        grad_fn=lambda theta: jnp.full((n, d), big) + 0.0 * theta[None, :],
        risk_fn=lambda theta: jnp.sum(theta**2),
        dim=d, n_nodes=n)
    ch = _ch(fading="equal", noise_std=0.0)
    res = run_mc(problem, [ch, ch], ("gbma", "blind_ec"), [1e-18, 1e-18],
                 8, 1, n_antennas=(1, 2), power_budget=[np.inf, 1.0])
    assert not np.any(np.isnan(res.risks))
    # the gbma row really stepped on the huge gradients (θ_k = -β·big·k)
    np.testing.assert_allclose(res.risks[0, 0, 1], d * 10.0**2, rtol=1e-4)


def test_blind_msweep_one_compile_matches_static(prob, mc):
    """Per-row antenna counts (the fig7b shape) run in ONE `_mc_core`
    compile and match the static per-M runs."""
    ch = _ch()
    ms = (1, 3, 8)
    mc_mod.clear_cache()  # also zeroes the trace counter
    multi = run_mc(mc, [ch] * 3, "blind", [0.02] * 3, STEPS, SEEDS,
                   n_antennas=ms)
    assert trace_count() == 1
    for i, m in enumerate(ms):
        single = run_mc(mc, [ch], "blind", [0.02], STEPS, SEEDS,
                        n_antennas=m)
        np.testing.assert_allclose(multi.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


def test_gbma_per_row_antennas_match_static_mrc(prob, mc):
    """The per-row antenna axis also covers the gbma MRC path."""
    ch = _ch()
    multi = run_mc(mc, [ch] * 2, "gbma", [0.02] * 2, STEPS, SEEDS,
                   n_antennas=(2, 4))
    for i, m in enumerate((2, 4)):
        single = run_mc(mc, [ch], "gbma", [0.02], STEPS, SEEDS,
                        n_antennas=m)
        np.testing.assert_allclose(multi.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


def test_blind_nsweep_one_compile_matches_per_n():
    """A blind node-count sweep (padded N axis + per-antenna complex
    draws) compiles once and reproduces the per-N runs."""
    grid = (9, 14)
    probs = [MSDProblem.make(n, dim=8) for n in grid]
    mcs = [p.to_mc() for p in probs]
    ch = _ch()
    mc_mod.clear_cache()  # also zeroes the trace counter
    sweep = run_mc(mcs, [ch, ch], "blind", [0.02] * 2, STEPS, SEEDS,
                   n_antennas=4)
    assert trace_count() == 1
    for i, m in enumerate(mcs):
        single = run_mc(m, [ch], "blind", [0.02], STEPS, SEEDS,
                        n_antennas=4)
        np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                   rtol=1e-5, atol=1e-9)


def test_blind_requires_antennas(mc):
    with pytest.raises(ValueError):
        run_mc(mc, [_ch()], "blind", [0.02], 4, 1)


@settings(max_examples=16, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       scale=st.floats(0.2, 2.0),
       rician_k=st.floats(0.5, 8.0),
       seed=st.integers(0, 2**16))
def test_complex_sampler_twin_matches_reference(fading, scale, rician_k,
                                                seed):
    """The engine's traceable complex sampler must never drift from the
    reference `channel.sample_complex_gains` (same key -> same draws)."""
    cfg = ChannelConfig(fading=fading, scale=scale, rician_k=rician_k)
    p = {"scale": jnp.float32(scale), "rician_k": jnp.float32(rician_k)}
    key = jax.random.key(seed)
    ra, rb = channel_mod.sample_complex_gains(key, cfg, (17,))
    ta, tb = mc_mod._sample_complex_gains(key, fading, p, (17,))
    np.testing.assert_allclose(np.asarray(ta), np.asarray(ra), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(tb), np.asarray(rb), rtol=1e-5,
                               atol=1e-7)


@settings(max_examples=12, deadline=None)
@given(fading=st.sampled_from(["equal", "rayleigh", "rician", "lognormal"]),
       n=st.sampled_from([5, 8, 23, 32]),
       seed=st.integers(0, 2**16))
def test_dynamic_complex_sampler_matches_shaped_draws(fading, n, seed):
    """`_sample_complex_gains_dynamic_n` == the (n,)-shaped draw in lanes
    [0, n), zero elsewhere — the blind family's N-sweep fast path."""
    if not mc_mod._dynamic_threefry_ok():
        pytest.skip("raw threefry primitive unavailable")
    p = {"scale": jnp.float32(0.9), "rician_k": jnp.float32(4.0),
         "n_nodes": jnp.float32(n)}
    key = jax.random.key(seed)
    ra, rb = mc_mod._sample_complex_gains(key, fading, p, (n,))
    da, db = mc_mod._sample_complex_gains_dynamic_n(key, fading, p, 32)
    for ref, dyn in ((ra, da), (rb, db)):
        # rounding (fma association) differences only; atol covers the
        # sin(phi)-near-zero lanes where rtol alone is meaningless
        np.testing.assert_allclose(np.asarray(dyn[:n]), np.asarray(ref),
                                   rtol=5e-7, atol=5e-7)
        assert np.all(np.asarray(dyn[n:]) == 0.0)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([1, 2, 5, 8]), seed=st.integers(0, 2**16))
def test_antenna_key_replay_matches_split(m, seed):
    """`_antenna_keys`' counts-as-data replay == `jax.random.split(key, m)`
    in the first m lanes (the per-row M-sweep RNG discipline)."""
    from repro import compat

    if compat.threefry2x32 is None \
            or not compat.threefry_split_is_original():
        pytest.skip("original threefry split layout unavailable")
    key = jax.random.key(seed)
    p = {"n_antennas": jnp.float32(m), "m_idx": jnp.int32(0)}
    keys = mc_mod._antenna_keys(key, (1, 8), p)  # len > 1: dynamic path
    ref = jax.random.key_data(jax.random.split(key, m))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(keys))[:m], np.asarray(ref))
