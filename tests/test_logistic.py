"""Stochastic-problem support (`logistic` kind) + the problem registry.

  * host-side Newton θ* is a stationary point of the on-device full-batch
    gradient; excess risk is 0 at θ* and positive elsewhere;
  * `batch_frac=1.0` (the static full-batch path) is BIT-identical to a
    deterministic registration of the same problem — the stochastic flag
    must cost nothing when no sampling happens;
  * minibatch gradients are unbiased-ish: averaged over many draws they
    approach the full-batch gradient;
  * lane masking: `b_count` lanes beyond the row's fraction contribute
    exactly nothing (frac rows reproduce the dedicated-run trajectories);
  * a batch-fraction sweep runs in ONE `_mc_core` compile and each row
    matches the same fraction run alone;
  * the non-iid partition is label-sorted and shard-skewed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import montecarlo as mc_mod
from repro.core.channel import ChannelConfig
from repro.core.mc import problems as prob_mod
from repro.core.montecarlo import (logistic_mc_problem, run_mc, trace_count)
from repro.data.federated import partition_noniid
from repro.data.synthetic import logistic_classification

N, K, DIM = 10, 6, 8
STEPS, SEEDS = 40, 2


@pytest.fixture(scope="module")
def data():
    return logistic_classification(N * K, dim=DIM, seed=3)


@pytest.fixture(scope="module")
def prob(data):
    X, y, _ = data
    return logistic_mc_problem(X, y, N, lam=0.1)


def _ch(**kw):
    kw.setdefault("fading", "rayleigh")
    kw.setdefault("noise_std", 0.3)
    return ChannelConfig(**kw)


def test_newton_solution_is_stationary(prob):
    ts = prob.data["theta_star"]
    g = np.asarray(jnp.mean(prob.grad_fn(ts), axis=0))
    assert np.linalg.norm(g) < 1e-5
    assert abs(float(prob.risk_fn(ts))) < 1e-6
    assert float(prob.risk_fn(jnp.zeros(DIM))) > 1e-3


def test_risk_matches_numpy_objective(data, prob):
    """On-device excess risk == f64 numpy objective difference."""
    X, y, _ = data
    lam = 0.1
    rng = np.random.default_rng(0)
    f_star = float(np.mean(np.logaddexp(
        0.0, -y * (X @ np.asarray(prob.data["theta_star"], np.float64))))
        + 0.5 * lam * np.sum(np.asarray(
            prob.data["theta_star"], np.float64) ** 2))
    for t in rng.standard_normal((4, DIM)) * 0.3:
        host = float(np.mean(np.logaddexp(0.0, -y * (X @ t)))
                     + 0.5 * lam * np.sum(t * t)) - f_star
        dev = float(prob.risk_fn(jnp.asarray(t, jnp.float32)))
        np.testing.assert_allclose(dev, host, rtol=1e-3, atol=1e-6)


def test_fullbatch_bit_identical_to_deterministic_registration(
        prob, monkeypatch):
    """batch_frac=1.0 never samples: the stochastic-capable kind and a
    deterministic registration of the same rows produce bit-identical
    trajectories (the full-batch limit of the acceptance criteria)."""
    spec = prob_mod.PROBLEMS["logistic"]
    det_spec = dataclasses.replace(spec, kind="logistic_det_test",
                                   stochastic_grad_row=None,
                                   sample_axis_field=None)
    monkeypatch.setitem(prob_mod.PROBLEMS, "logistic_det_test", det_spec)
    det = dataclasses.replace(prob, kind="logistic_det_test",
                              stochastic=False)
    ch = _ch()
    r_sto = run_mc(prob, [ch], "gbma", [0.3], STEPS, SEEDS)
    r_det = run_mc(det, [ch], "gbma", [0.3], STEPS, SEEDS)
    np.testing.assert_array_equal(r_sto.risks, r_det.risks)
    np.testing.assert_array_equal(r_sto.cum_energy, r_det.cum_energy)


def test_minibatch_gradient_is_unbiased(prob):
    """Averaging the minibatch gradient over many index draws approaches
    the full-batch gradient (with-replacement sampling is unbiased)."""
    batch = prob_mod.MCProblemBatch.stack([prob])
    row = {k: v[0] for k, v in batch.data.items()}
    sgrad = prob_mod.PROBLEMS["logistic"].stochastic_grad_row
    theta = jnp.asarray(np.random.default_rng(1).standard_normal(DIM) * 0.3,
                        jnp.float32)
    full = prob_mod.PROBLEMS["logistic"].grad_row(row, theta)
    draws = jax.vmap(lambda k: sgrad(row, theta, k, jnp.float32(3), 3))(
        jax.random.split(jax.random.key(0), 4096))
    np.testing.assert_allclose(np.mean(np.asarray(draws), axis=0),
                               np.asarray(full), atol=0.05)


def test_sgrad_lane_mask_exact(prob):
    """Lanes >= b_count contribute exactly nothing: a draw with b_max
    lanes but b_count=b equals the mean over the first b sampled lanes."""
    batch = prob_mod.MCProblemBatch.stack([prob])
    row = {k: v[0] for k, v in batch.data.items()}
    sgrad = prob_mod.PROBLEMS["logistic"].stochastic_grad_row
    theta = jnp.ones(DIM, jnp.float32) * 0.2
    key = jax.random.key(7)
    g = sgrad(row, theta, key, jnp.float32(2), 5)
    # replicate by hand: same per-(lane, node) scalar draws, first 2 lanes
    idx = np.stack(
        [[int(jax.random.randint(
            jax.random.fold_in(jax.random.fold_in(key, j), n), (), 0, K))
          for j in range(2)] for n in range(N)])
    Xn = np.asarray(row["Xn"], np.float64)
    yn = np.asarray(row["yn"], np.float64)
    t = np.asarray(theta, np.float64)
    acc = np.zeros((N, DIM))
    for n in range(N):
        for j in idx[n]:
            m = yn[n, j] * (Xn[n, j] @ t)
            acc[n] += -1.0 / (1.0 + np.exp(m)) * yn[n, j] * Xn[n, j]
    ref = acc / 2 + 0.1 * t[None, :]
    np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-5, atol=1e-6)


def test_frac_sweep_one_compile_matches_individual(prob):
    fracs = (0.5, 0.25)
    ch = _ch()
    singles = [run_mc(prob, [ch], "gbma", [0.3], STEPS, SEEDS, batch_frac=f)
               for f in fracs]
    mc_mod.clear_cache()  # also zeroes the trace counter
    sweep = run_mc(prob, [ch] * 2, "gbma", [0.3] * 2, STEPS, SEEDS,
                   batch_frac=fracs)
    assert trace_count() == 1
    # index draws are per-lane (b_max-independent) so the trajectories are
    # the same up to XLA fusion differences between the C=1 and C=2
    # programs — f32 rounding, ~1e-7 absolute on O(1e-2) risks
    for i, single in enumerate(singles):
        np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                   rtol=1e-4, atol=1e-7)


def test_stochastic_nsweep_matches_dedicated_runs():
    """A padded node-count sweep of stochastic rows reproduces the
    dedicated per-N runs — the minibatch index draws are per-(lane, node)
    scalars, so they cannot depend on the sweep-wide n_max/b_max padding
    (the same invariant the channel samplers keep)."""
    probs = []
    for n in (6, 10):
        X, y, _ = logistic_classification(n * K, dim=DIM, seed=3)
        probs.append(logistic_mc_problem(X, y, n, lam=0.1))
    chs = [_ch(energy=1.0 / n) for n in (6, 10)]
    sweep = run_mc(probs, chs, "gbma", [0.3, 0.3], STEPS, SEEDS,
                   batch_frac=0.5)
    for i, p in enumerate(probs):
        single = run_mc(p, [chs[i]], "gbma", [0.3], STEPS, SEEDS,
                        batch_frac=0.5)
        np.testing.assert_allclose(sweep.risks[i], single.risks[0],
                                   rtol=1e-4, atol=1e-7)


def test_stochastic_nsweep_with_mixed_algos(data):
    """The fig8 shape: node-count sweep × (gbma, blind, centralized) rows
    with minibatching, one compile, finite and converging."""
    probs, chs, algos, ants = [], [], [], []
    for n in (6, 10):
        X, y, _ = logistic_classification(n * K, dim=DIM, seed=3)
        p = logistic_mc_problem(X, y, n, lam=0.1)
        for a, m in (("gbma", 1), ("blind", 3), ("centralized", 1)):
            probs.append(p)
            chs.append(_ch(energy=1.0 / n))
            algos.append(a)
            ants.append(m)
    mc_mod.clear_cache()  # also zeroes the trace counter
    res = run_mc(probs, chs, tuple(algos), [0.3] * 6, STEPS, SEEDS,
                 n_antennas=tuple(ants), batch_frac=0.5)
    assert trace_count() == 1
    assert np.all(np.isfinite(res.risks))
    assert np.all(res.mean[:, -1] < res.mean[:, 0])


def test_batch_frac_validation(prob):
    ch = _ch()
    q = mc_mod.quadratic_mc_problem(np.eye(4), np.zeros(4), 0.1,
                                    np.zeros(4))
    with pytest.raises(ValueError, match="stochastic"):
        run_mc(q, [ch], "gbma", [0.1], 4, 1, batch_frac=0.5)
    with pytest.raises(ValueError, match="batch_frac"):
        run_mc(prob, [ch], "gbma", [0.1], 4, 1, batch_frac=0.0)
    with pytest.raises(ValueError, match="batch_frac"):
        run_mc(prob, [ch], "gbma", [0.1], 4, 1, batch_frac=(0.5,) * 3)


def test_partition_noniid_is_label_sorted():
    X, y, _ = logistic_classification(40, dim=4, seed=0)
    parts = partition_noniid(X, y, 4)
    means = [float(np.mean(py)) for _, py in parts]
    assert means == sorted(means)
    # shards are label-skewed: the extremes are (near-)pure
    assert means[0] < 0.0 < means[-1]
    # rows keep their features attached to their labels
    flat_X = np.concatenate([px for px, _ in parts])
    flat_y = np.concatenate([py for _, py in parts])
    order = np.argsort(y, kind="stable")
    np.testing.assert_array_equal(flat_X, X[order])
    np.testing.assert_array_equal(flat_y, y[order])


def test_logistic_rejects_bad_labels_and_uneven_split():
    X, y, _ = logistic_classification(12, dim=4, seed=0)
    with pytest.raises(ValueError, match="±1"):
        logistic_mc_problem(X, y * 2.0, 4)
    with pytest.raises(ValueError, match="evenly"):
        logistic_mc_problem(X, y, 5)
