"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import multi_head_attention
from repro.kernels.ota.ops import ota_edge_aggregate
from repro.kernels.ota.ref import ota_edge_aggregate_ref
from repro.kernels.wkv.ops import wkv6


# ---------------------------------------------------------------- OTA kernel
@pytest.mark.parametrize("n,d", [(128, 512), (256, 1024), (100, 300),
                                 (64, 128), (8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_kernel_matches_ref(n, d, dtype):
    k = jax.random.key(n * d)
    g = jax.random.normal(k, (n, d), dtype=dtype)
    h = jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (n,)))
    w = jax.random.normal(jax.random.fold_in(k, 2), (d,))
    ref = ota_edge_aggregate_ref(g, h, w, noise_scale=0.37)
    ker = ota_edge_aggregate(g, h, w, noise_scale=0.37, impl="pallas",
                             interpret=True)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.array(ker, np.float32),
                               np.array(ref, np.float32), atol=atol,
                               rtol=1e-2)


@pytest.mark.parametrize("n,d", [(5, 7), (1, 90), (130, 513), (200, 90)])
def test_ota_kernel_padding_path_odd_shapes(n, d):
    """Regression for the non-divisible (N, d) path: padded node rows carry
    zero gain and the kernel normalizes by the TRUE N, so both the
    superposition normalization and the edge-noise scale must come out
    exact — no residual (N+pad)/N factor on either term."""
    k = jax.random.key(n * 1000 + d)
    kg, kh, kw = jax.random.split(k, 3)
    g = jax.random.normal(kg, (n, d))
    h = jax.random.uniform(kh, (n,))
    w = jax.random.normal(kw, (d,))
    ref = ota_edge_aggregate_ref(g, h, w, noise_scale=0.37)
    ker = ota_edge_aggregate(g, h, w, noise_scale=0.37, impl="pallas",
                             interpret=True)
    np.testing.assert_allclose(np.array(ker), np.array(ref), atol=1e-6,
                               rtol=1e-5)
    # noise-only probe: zero gradients isolate the noise term, which must be
    # exactly noise_scale * w (the old wrapper rescaled it by (N+pad)/N and
    # subtracted the excess after an output-dtype round-trip)
    noise_only = ota_edge_aggregate(jnp.zeros_like(g), h, w,
                                    noise_scale=0.37, impl="pallas",
                                    interpret=True)
    np.testing.assert_allclose(np.array(noise_only), 0.37 * np.array(w),
                               atol=1e-7)


def test_ota_noise_scale_is_traced_one_compile():
    """Regression: `noise_scale` is a traced operand, so sweeping noise
    levels (or N, whose edge-noise std depends on it) at fixed shapes must
    compile the wrapper exactly once per (shape, impl) — not once per
    float value. Values must still track the operand exactly."""
    from repro.kernels.ota import ops as ota_ops

    if not ota_ops.clear_cache():
        pytest.skip("jit cache clearing unsupported on this JAX")
    k = jax.random.key(3)
    g = jax.random.normal(k, (8, 64))
    h = jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (8,)))
    w = jax.random.normal(jax.random.fold_in(k, 2), (64,))
    outs = [np.array(ota_edge_aggregate(g, h, w, noise_scale=s,
                                        impl="pallas", interpret=True))
            for s in (0.0, 0.1, 0.37, 2.5)]
    assert ota_ops.trace_count() == 1, "noise_scale retriggered compilation"
    base = outs[0]
    for s, out in zip((0.1, 0.37, 2.5), outs[1:]):
        np.testing.assert_allclose(out - base, s * np.array(w), atol=1e-6)
    # a python float and a traced scalar hit the same compiled program
    ota_edge_aggregate(g, h, w, noise_scale=jnp.float32(1.3), impl="pallas",
                       interpret=True)
    assert ota_ops.trace_count() == 1
    # ref impl is its own (impl,) cache entry, also traced-once
    ota_ops.clear_cache()
    for s in (0.2, 0.9):
        ota_edge_aggregate(g, h, w, noise_scale=s, impl="ref")
    assert ota_ops.trace_count() == 1


# ---------------------------------------------------------- attention kernel
@pytest.mark.parametrize("b,hq,hkv,s,d,kw", [
    (2, 4, 4, 256, 64, {}),
    (1, 8, 2, 256, 64, {}),                      # GQA
    (1, 4, 4, 384, 128, {"window": 100}),        # sliding window
    (1, 4, 4, 256, 64, {"softcap": 30.0}),       # gemma2 softcap
    (1, 2, 2, 200, 64, {}),                      # padding path
    (1, 2, 2, 256, 32, {"causal": False}),
    (1, 4, 4, 512, 256, {"window": 128, "softcap": 50.0}),
])
def test_attention_kernel_matches_ref(b, hq, hkv, s, d, kw):
    kw = dict(kw)
    kw.setdefault("causal", True)
    ks = jax.random.split(jax.random.key(s + d), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    ref = multi_head_attention(q, k, v, scale=d**-0.5, impl="ref", **kw)
    ker = multi_head_attention(q, k, v, scale=d**-0.5, impl="pallas",
                               interpret=True, **kw)
    np.testing.assert_allclose(np.array(ker), np.array(ref), atol=5e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_attention_kernel_bf16(dtype):
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), dtype=dtype)
    k = jax.random.normal(ks[1], (1, 4, 256, 64), dtype=dtype)
    v = jax.random.normal(ks[2], (1, 4, 256, 64), dtype=dtype)
    ref = multi_head_attention(q, k, v, scale=0.125, impl="ref")
    ker = multi_head_attention(q, k, v, scale=0.125, impl="pallas",
                               interpret=True)
    np.testing.assert_allclose(np.array(ker, np.float32),
                               np.array(ref, np.float32), atol=3e-2)


# ---------------------------------------------------------------- wkv kernel
@pytest.mark.parametrize("b,h,t,d", [(2, 2, 128, 64), (1, 4, 100, 32),
                                     (2, 1, 64, 64), (1, 2, 256, 16)])
def test_wkv6_kernel_matches_scan(b, h, t, d):
    ks = jax.random.split(jax.random.key(t * d), 6)
    r = jax.random.normal(ks[0], (b, h, t, d))
    k = jax.random.normal(ks[1], (b, h, t, d))
    v = jax.random.normal(ks[2], (b, h, t, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, t, d))))
    u = 0.5 * jax.random.normal(ks[4], (h, d))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, d, d))
    o_ref, s_ref = wkv6(r, k, v, w, u, s0, impl="ref")
    o_ker, s_ker = wkv6(r, k, v, w, u, s0, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.array(o_ker), np.array(o_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.array(s_ker), np.array(s_ref), atol=1e-4,
                               rtol=1e-4)


def test_wkv6_state_chaining_matches_full_sequence():
    """Running two halves with state carry == one full pass (decode vs
    prefill consistency)."""
    b, h, t, d = 1, 2, 64, 32
    ks = jax.random.split(jax.random.key(4), 5)
    r = jax.random.normal(ks[0], (b, h, t, d))
    k = jax.random.normal(ks[1], (b, h, t, d))
    v = jax.random.normal(ks[2], (b, h, t, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, t, d))))
    u = 0.5 * jax.random.normal(ks[4], (h, d))
    o_full, s_full = wkv6(r, k, v, w, u, impl="ref")
    half = t // 2
    o1, s1 = wkv6(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                  w[:, :, :half], u, impl="ref")
    o2, s2 = wkv6(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                  w[:, :, half:], u, s1, impl="ref")
    np.testing.assert_allclose(np.array(jnp.concatenate([o1, o2], axis=2)),
                               np.array(o_full), atol=1e-5)
    np.testing.assert_allclose(np.array(s2), np.array(s_full), atol=1e-5)
