"""GBMA convergence properties against Theorems 1 and 2 (the paper's own
claims), plus statistical invariants of the OTA aggregation. The multi-seed
empirical-vs-bound checks run on the batched Monte Carlo engine (all seeds in
one compiled call) instead of per-seed Python loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.baselines import CentralizedGD
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator, ota_aggregate
from repro.core.montecarlo import quadratic_mc_problem, run_mc
from repro.core.theory import (ProblemConstants, contraction_c,
                               stepsize_theorem1, stepsize_theorem2,
                               theorem1_bound, theorem2_bound)


def _quadratic_data(n, d, seed):
    """Single source of the test dataset: `quadratic_problem` (host oracle)
    and `quadratic_mc` (engine problem) must see identical (X, y)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    y = X @ rng.standard_normal(d) + 0.1 * rng.standard_normal(n)
    return X, y


def quadratic_problem(n=80, d=8, lam=0.5, seed=0):
    X, y = _quadratic_data(n, d, seed)
    Xj, yj = jnp.array(X), jnp.array(y)

    def grad_fn(theta):
        return (Xj @ theta - yj)[:, None] * Xj + lam * theta[None, :]

    A = X.T @ X / n
    theta_star = np.linalg.solve(A + lam * np.eye(d), X.T @ y / n)

    def objective(theta):
        t = np.asarray(theta)
        return float(0.5 * np.mean((X @ t - y) ** 2)
                     + lam / 2 * np.sum(t * t))

    eig = np.linalg.eigvalsh(A)
    pc = ProblemConstants(
        mu=float(eig[0] + lam), L=float(eig[-1] + lam),
        L_bar=float(np.max(np.sum(X**2, axis=1)) + lam),
        delta=4.0, r0_sq=float(np.sum(theta_star**2)), dim=d)
    return grad_fn, objective, theta_star, pc


def quadratic_mc(n=80, d=8, lam=0.5, seed=0):
    """Same dataset as `quadratic_problem`, as an on-device `MCProblem`."""
    X, y = _quadratic_data(n, d, seed)
    A = X.T @ X / n
    theta_star = np.linalg.solve(A + lam * np.eye(d), X.T @ y / n)
    return quadratic_mc_problem(X, y, lam, theta_star)


def test_ota_aggregate_unbiased_scaled_by_mu_h():
    """E[v_k] = mu_h * grad(F) (Eq. 31)."""
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    g = jax.random.normal(jax.random.key(0), (64, 16))
    keys = jax.random.split(jax.random.key(1), 4000)
    vs = jax.vmap(lambda k: ota_aggregate(g, k, ch))(keys)
    expected = ch.mu_h * np.mean(np.array(g), axis=0)
    np.testing.assert_allclose(np.array(vs.mean(axis=0)), expected,
                               atol=4 * float(vs.std()) / np.sqrt(4000))


def test_ota_variance_formula():
    """E||v||^2 = mu_h^2||gbar||^2 + sigma_h^2/N^2 sum||g_n||^2 + d sw^2/(E N^2)
    (Eq. 34)."""
    ch = ChannelConfig(fading="rayleigh", noise_std=0.3, energy=2.0)
    n, d = 32, 8
    g = jax.random.normal(jax.random.key(2), (n, d))
    keys = jax.random.split(jax.random.key(3), 30_000)
    vs = jax.vmap(lambda k: ota_aggregate(g, k, ch))(keys)
    emp = float(jnp.mean(jnp.sum(vs.astype(jnp.float64)**2, axis=-1)))
    gbar = np.mean(np.array(g), axis=0)
    expected = (ch.mu_h**2 * np.sum(gbar**2)
                + ch.sigma_h2 / n**2 * np.sum(np.array(g)**2)
                + d * ch.noise_std**2 / (ch.energy * n**2))
    np.testing.assert_allclose(emp, expected, rtol=0.05)


def test_remark1_noiseless_equal_gains_matches_centralized():
    """Remark 1: sigma_h=0, sigma_w=0, h=1 -> GBMA == centralized GD."""
    grad_fn, _, _, _ = quadratic_problem()
    ch = ChannelConfig(fading="equal", scale=1.0, noise_std=0.0)
    beta = 0.05
    sim = GBMASimulator(grad_fn, ch, beta)
    cen = CentralizedGD(grad_fn, beta)
    t0 = jnp.zeros(8)
    traj_g = sim.run(t0, 50, jax.random.key(0))
    traj_c = cen.run(t0, 50)
    np.testing.assert_allclose(np.array(traj_g), np.array(traj_c), atol=1e-5)


@pytest.mark.parametrize("fading", ["equal", "rayleigh"])
def test_theorem1_bound_holds_empirically(fading):
    _, _, _, pc = quadratic_problem()
    mc = quadratic_mc()
    ch = ChannelConfig(fading=fading, noise_std=0.5, energy=1.0)
    beta = stepsize_theorem1(pc, ch, 80, safety=0.5)
    c = contraction_c(beta, pc, ch, 80)
    assert 0.0 < c < 1.0
    # average excess risk over seeds (one vmapped engine call); bound is on
    # the expectation
    res = run_mc(mc, [ch], "gbma", [beta], 200, 8)
    bound = theorem1_bound(np.array([200]), beta, pc, ch, 80)[0]
    assert res.mean[0][-1] <= bound * 1.05


def test_theorem2_rate_equal_gains():
    """Convex case, equal gains: error <= r0^2/(2 beta k) + beta d sw^2/(E N^2)."""
    _, _, _, pc = quadratic_problem(lam=0.0)
    mc = quadratic_mc(lam=0.0)
    ch = ChannelConfig(fading="equal", scale=1.0, noise_std=0.3)
    beta = stepsize_theorem2(pc, ch, safety=0.5)
    res = run_mc(mc, [ch], "gbma", [beta], 300, 6)
    bound = theorem2_bound(np.array([300]), beta, pc, ch, 80, b_of_n=0.0,
                           equal_gains=True)[0]
    assert res.mean[0][-1] <= bound * 1.05


@given(n_small=st.integers(20, 60))
@settings(max_examples=8, deadline=None)
def test_more_nodes_reduce_steady_state_error(n_small):
    """Theorem 1: distortion + noise terms decay with N."""
    _, _, _, pc = quadratic_problem()
    ch = ChannelConfig(fading="rayleigh", noise_std=1.0)
    beta = stepsize_theorem1(pc, ch, n_small, safety=0.5)
    b_small = theorem1_bound(np.array([10_000]), beta, pc, ch, n_small)[0]
    b_large = theorem1_bound(np.array([10_000]), beta, pc, ch,
                             n_small * 100)[0]
    assert b_large < b_small


def test_gbma_beats_fdm_at_equal_low_energy():
    """Paper Fig. 4 qualitative claim: at very low per-node energy, GBMA's
    noise (sigma_w/(N sqrt(E))) beats FDM's (sigma_w/(sqrt(N) sqrt(E)))."""
    _, _, _, pc = quadratic_problem(n=100)
    mc = quadratic_mc(n=100)
    e_n = 100.0 ** (-1.5)
    ch = ChannelConfig(fading="rayleigh", noise_std=1.0, energy=e_n)
    beta = stepsize_theorem1(pc, ch, 100, safety=0.5)
    res_g = run_mc(mc, [ch], "gbma", [beta], 150, 5)
    # FDMGD defaults to per-link channel inversion; seed keys 100..104 as in
    # the original per-seed loop
    res_f = run_mc(mc, [ch], "fdm", [beta], 150, 5, seed0=100,
                   invert_channel=True)
    assert res_g.mean[0][-1] < res_f.mean[0][-1]
