"""Deterministic test harness for the MC sweep server.

The server takes two injection points (`repro.serving.mc_server`
docstring): a clock and an executor. The harness provides determinism-
first implementations of both, plus a scripted client, so scheduling and
fault tests run without wall-clock sleeps, threads, or timing races:

* `ManualClock`   — virtual time: `sleep(dt)` advances a counter and
                    yields once (`asyncio.sleep(0)`), recording every
                    requested sleep for assertions. A test that "waits
                    out" the coalesce window finishes in microseconds.
* `TracingExecutor` — the server's deterministic `InlineExecutor` plus a
                    call log (the router's quantum `info` dicts, in
                    exactly the order the scheduler issued them) and
                    scripted `after_call(k, hook)` hooks — the
                    fault-injection point for "client cancels after
                    quantum k" scenarios.
* `ScriptedClient` — one client's lifecycle as explicit steps: `submit`
                    wraps the server coroutine in a task, `cancel`
                    detaches it mid-batch, `result`/`error` read the
                    outcome.
* `submit_all`    — enqueue several submissions and run each up to its
                    internal future await (one `asyncio.sleep(0)` tick),
                    so a following `server.drain()` sees them all queued.
* `run`           — `asyncio.run` shorthand: every test drives its own
                    private event loop to completion; nothing leaks
                    between tests.
"""
from __future__ import annotations

import asyncio

from repro.serving.mc_server import InlineExecutor


class ManualClock:
    """Virtual time. `sleep` never touches the wall clock — it advances
    `now`, appends to `sleeps`, and yields control once so concurrently
    scheduled submissions interleave exactly as they would under a real
    sleep."""

    def __init__(self):
        self.now = 0.0
        self.sleeps = []

    def time(self) -> float:
        return self.now

    async def sleep(self, dt: float) -> None:
        self.sleeps.append(dt)
        self.now += dt
        await asyncio.sleep(0)


class TracingExecutor(InlineExecutor):
    """Inline (synchronous, deterministic order) execution with a call
    trace and scripted fault hooks.

    calls:   list of the router's `info` dicts — one per engine quantum,
             in issue order: {"signature", "off", "quantum", "rows"}.
    after_call(k, hook): run `hook()` right after the k-th (0-based)
             quantum completes — e.g. cancelling a client mid-batch.
    fail_when(pred, exc): raise `exc` instead of running any quantum
             whose `info` satisfies `pred` — scripted engine failure.
    """

    def __init__(self):
        self.calls = []
        self._hooks = {}
        self._fail = None

    def after_call(self, k: int, hook) -> None:
        self._hooks.setdefault(k, []).append(hook)

    def fail_when(self, pred, exc: Exception) -> None:
        self._fail = (pred, exc)

    async def run(self, fn, info=None):
        idx = len(self.calls)
        self.calls.append(dict(info or {}))
        if self._fail is not None and self._fail[0](info or {}):
            raise self._fail[1]
        out = await super().run(fn, info=info)
        for hook in self._hooks.get(idx, ()):
            hook()
        return out


class ScriptedClient:
    """One client, scripted: submit -> (optionally cancel) -> result."""

    def __init__(self, server, request):
        self.server = server
        self.request = request
        self.task = None

    def submit(self) -> "ScriptedClient":
        self.task = asyncio.ensure_future(self.server.submit(self.request))
        return self

    def cancel(self) -> None:
        self.task.cancel()

    @property
    def done(self) -> bool:
        return self.task.done()

    def result(self):
        return self.task.result()

    def error(self):
        return self.task.exception()


async def submit_all(server, requests) -> list:
    """Enqueue every request and tick the loop once, so each submission
    has validated, been admitted, and parked on its future — the state
    `server.drain()` coalesces from."""
    tasks = [asyncio.ensure_future(server.submit(r)) for r in requests]
    await asyncio.sleep(0)
    return tasks


def run(coro):
    """Drive one test coroutine on a fresh private event loop."""
    return asyncio.run(coro)
