"""Capture golden trajectories of the training + aggregation stack.

Run from the repo root (`PYTHONPATH=src python tests/golden/capture.py`)
at the commit whose behaviour is the reference. The npz files it writes
are consumed by `tests/test_transport.py::TestGoldenCompat` to pin the
gbma/fdm/centralized production training paths and the tier-(i)
`ota_aggregate` / `GBMASimulator` helpers across refactors: trajectories
must reproduce bit-for-bit (or at the documented <=1e-6 tolerance where a
float32-vs-float64 scalar-constant rounding is the named cause).

The captured operating points deliberately exercise the awkward corners:
a non-zero phase error (the precoded-phase stream), energy != 1 (the
edge-noise std constant is computed in python float64 and rounds to f32
differently than a traced-f32 chain), and an active clip_norm.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

HERE = pathlib.Path(__file__).resolve().parent


def _tiny_model():
    from repro.configs.registry import get_config
    from repro.models.model import build_model

    cfg = get_config("repro-100m").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, logit_chunk=32, attn_block_q=16,
        attn_block_kv=32)
    return build_model(cfg)


def capture_training() -> dict:
    from repro.core.channel import ChannelConfig
    from repro.core.gbma import GBMAConfig
    from repro.data.synthetic import SyntheticTokens, TokenDatasetConfig
    from repro.optim.gd import momentum
    from repro.training.loop import run_training
    from repro.training.train_step import TrainConfig, build_train_step

    out = {}
    runs = {
        "gbma": dict(aggregator="gbma", noise_std=0.05, clip=None),
        "fdm": dict(aggregator="fdm", noise_std=0.05, clip=None),
        "centralized": dict(aggregator="centralized", noise_std=0.0,
                            clip=None),
        "gbma_clip": dict(aggregator="gbma", noise_std=0.05, clip=0.5),
    }
    for name, r in runs.items():
        m = _tiny_model()
        params = m.init_params(jax.random.key(0))
        ds = SyntheticTokens(TokenDatasetConfig(
            vocab_size=m.cfg.vocab_size, seq_len=16, global_batch=8,
            seed=3))
        tcfg = TrainConfig(
            aggregator=r["aggregator"],
            gbma=GBMAConfig(n_nodes=4, channel=ChannelConfig(
                fading="rayleigh", noise_std=r["noise_std"], energy=1.0,
                phase_error_max=0.3)),
            clip_norm=r["clip"])
        opt = momentum(0.05)
        step = build_train_step(m, tcfg, opt)
        batches = ({"tokens": t} for t in ds)
        params, _, hist = run_training(
            step, params, opt.init(params), batches, 4, log_every=1)
        leaves = jax.tree_util.tree_leaves(params)
        out[f"{name}_losses"] = np.asarray(
            [h["loss"] for h in hist], np.float32)
        out[f"{name}_params"] = np.concatenate(
            [np.asarray(x, np.float32).ravel() for x in leaves])
    return out


def capture_tier_i() -> dict:
    from repro.core.channel import ChannelConfig
    from repro.core.gbma import GBMASimulator, ota_aggregate

    out = {}
    grads = jax.random.normal(jax.random.key(7), (8, 33))
    for tag, cfg in {
        "rayleigh": ChannelConfig(fading="rayleigh", noise_std=1.0,
                                  energy=2.0, phase_error_max=0.3),
        "equal": ChannelConfig(fading="equal", noise_std=0.5, energy=1.0),
    }.items():
        v = ota_aggregate(grads, jax.random.key(11), cfg)
        out[f"ota_{tag}"] = np.asarray(v, np.float32)

    cfg = ChannelConfig(fading="rayleigh", noise_std=1.0, energy=1.0)
    target = jnp.linspace(-1.0, 1.0, 12)
    wts = jnp.linspace(0.5, 1.5, 6)
    sim = GBMASimulator(
        grad_fn=lambda th: wts[:, None] * (th - target)[None, :],
        channel=cfg, stepsize=0.2)
    traj = sim.run(jnp.zeros(12), steps=20, key=jax.random.key(5))
    out["sim_traj"] = np.asarray(traj, np.float32)
    return out


def capture_tree_noise() -> dict:
    from repro.core.channel import ChannelConfig
    from repro.core.gbma import GBMAConfig, perturb_gradients
    from repro.training.train_step import _fdm_noise

    gcfg = GBMAConfig(n_nodes=4, channel=ChannelConfig(
        fading="rayleigh", noise_std=0.7, energy=2.0))
    tree = {
        "a": jnp.ones((5, 3), jnp.float32),
        "b": {"c": jnp.full((4,), 2.0, jnp.bfloat16)},
    }
    pg = perturb_gradients(tree, jax.random.key(21), gcfg)
    fd = _fdm_noise(tree, jax.random.key(22), gcfg)
    return {
        "perturb_a": np.asarray(pg["a"], np.float32),
        "perturb_b": np.asarray(pg["b"]["c"].astype(jnp.float32)),
        "fdm_a": np.asarray(fd["a"], np.float32),
        "fdm_b": np.asarray(fd["b"]["c"].astype(jnp.float32)),
    }


def main() -> None:
    np.savez_compressed(HERE / "train_head.npz", **capture_training())
    np.savez_compressed(HERE / "tier_i_head.npz",
                        **capture_tier_i(), **capture_tree_noise())
    for f in ("train_head.npz", "tier_i_head.npz"):
        with np.load(HERE / f) as z:
            print(f, {k: z[k].shape for k in z.files})


if __name__ == "__main__":
    main()
