import jax
import pytest

# CPU determinism; do NOT set xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device (the 512-device world belongs
# exclusively to launch/dryrun.py).
jax.config.update("jax_default_matmul_precision", "highest")


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow (the full per-arch "
             "matrix and other long-runners; tier-1 skips them)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="long-runner; re-enable with --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def olmo_reduced():
    """Shared reduced olmo-1b model + params: several modules smoke-test
    against the same tiny dense transformer; building (and jitting around)
    it once per session trims repeated setup cost."""
    from repro.configs.registry import get_config
    from repro.models.model import build_model

    cfg = get_config("olmo-1b").reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    return m, params
