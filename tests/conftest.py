import jax
import pytest

# CPU determinism; do NOT set xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device (the 512-device world belongs
# exclusively to launch/dryrun.py).
jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
