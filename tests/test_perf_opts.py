"""§Perf optimization switches must preserve semantics:
microbatch accumulation == single-batch gradients; pad_heads/bf16_dispatch
preserve model outputs; dp_over_model context changes only shardings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMAConfig
from repro.models.model import build_model
from repro.optim.gd import gd
from repro.training.train_step import TrainConfig, build_train_step


def _setup(arch="olmo-1b", **cfg_kw):
    cfg = get_config(arch).reduced().with_(**cfg_kw)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 17), 0,
                                          cfg.vocab_size)}
    return m, params, batch


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    m, params, batch = _setup()
    gcfg = GBMAConfig(n_nodes=4, channel=ChannelConfig(noise_std=0.05))
    opt = gd(0.1)
    step1 = build_train_step(m, TrainConfig(gbma=gcfg), opt)
    step4 = build_train_step(m, TrainConfig(gbma=gcfg, microbatches=4), opt)
    p1, _, m1 = jax.jit(step1)(params, opt.init(params), batch, 0)
    p4, _, m4 = jax.jit(step4)(params, opt.init(params), batch, 0)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32),
                                   atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", [
    "minitron-4b",
    pytest.param("hymba-1.5b", marks=pytest.mark.slow),
    pytest.param("whisper-small", marks=pytest.mark.slow),
])
def test_pad_heads_preserves_loss(arch):
    cfg = get_config(arch).reduced()
    m0 = build_model(cfg)
    m1 = build_model(cfg.with_(opt_pad_heads=True))
    params = m0.init_params(jax.random.key(2))
    batch = {"tokens": jax.random.randint(jax.random.key(3), (2, 17), 0,
                                          cfg.vocab_size)}
    if m0.kind == "encdec":
        batch["frames"] = jax.random.normal(jax.random.key(4),
                                            (2, cfg.enc_seq, cfg.d_model))
    l0, _ = m0.train_loss_per_example(params, batch)
    l1, _ = m1.train_loss_per_example(params, batch)
    np.testing.assert_allclose(np.array(l0), np.array(l1), atol=1e-3,
                               rtol=1e-4)


def test_dp_over_model_context_is_scoped():
    from repro.sharding.specs import data_axes, tp_axis, use_dp_over_model

    assert tp_axis() == "model"
    with use_dp_over_model():
        assert tp_axis() is None
        assert "model" in data_axes()
    assert tp_axis() == "model"


def test_rng_impl_rbg_trains(olmo_reduced):
    m, params = olmo_reduced  # session-shared reduced model (conftest)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 17), 0,
                                          m.cfg.vocab_size)}
    gcfg = GBMAConfig(n_nodes=4, channel=ChannelConfig(noise_std=0.05))
    opt = gd(0.1)
    step = jax.jit(build_train_step(
        m, TrainConfig(gbma=gcfg, rng_impl="rbg"), opt))
    p, _, metrics = step(params, opt.init(params), batch, 0)
    assert np.isfinite(float(metrics["loss"]))
