"""Sample-level waveform simulation must reproduce the abstract MAC model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import waveform as wf


@pytest.mark.parametrize("d,T,N", [(4, 16, 3), (8, 32, 5), (16, 64, 20)])
def test_matched_filter_equals_abstract_model(d, T, N):
    key = jax.random.key(d * T * N)
    g = jax.random.normal(key, (N, d))
    gains = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    s = wf.shaping_waveforms(d, T)
    # orthonormality
    np.testing.assert_allclose(np.array(s @ s.T), np.eye(d), atol=1e-5)
    rx = wf.transmit(g, gains, s, energy=2.0, noise_std=0.0,
                     key=jax.random.fold_in(key, 2))
    v = wf.edge_estimate(rx, s, N, 2.0)
    expected = np.einsum("n,nd->d", np.array(gains), np.array(g)) / N
    np.testing.assert_allclose(np.array(v), expected, atol=1e-4)


def test_noise_statistics_after_matched_filter():
    """Projected noise must be N(0, sigma_w^2 I_d) (Eq. 7)."""
    d, T = 8, 32
    s = wf.shaping_waveforms(d, T)
    keys = jax.random.split(jax.random.key(0), 2000)
    sigma = 0.7

    def one(k):
        noise = sigma * jax.random.normal(k, (T,))
        return wf.matched_filter(noise, s)

    w = jax.vmap(one)(keys)  # (2000, d)
    np.testing.assert_allclose(float(w.mean()), 0.0, atol=0.02)
    np.testing.assert_allclose(np.array(w.var(axis=0)),
                               sigma**2 * np.ones(d), rtol=0.2)
