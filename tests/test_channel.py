"""Channel model unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.channel import (ChannelConfig, edge_noise_std,
                                sample_complex_gains, sample_gains)


@pytest.mark.parametrize("fading,scale", [
    ("equal", 1.0), ("equal", 2.5), ("rayleigh", 1.0), ("rayleigh", 0.5),
    ("rician", 1.0), ("lognormal", 0.5),
])
def test_sample_moments_match_analytic(fading, scale):
    cfg = ChannelConfig(fading=fading, scale=scale)
    h = sample_gains(jax.random.key(0), cfg, (400_000,))
    assert float(h.min()) >= 0.0 or fading == "lognormal"
    np.testing.assert_allclose(float(h.mean()), cfg.mu_h, rtol=0.02)
    np.testing.assert_allclose(float(h.var()), cfg.sigma_h2,
                               rtol=0.05, atol=5e-3)


@pytest.mark.parametrize("fading,scale", [
    ("equal", 1.3), ("rayleigh", 0.8), ("rician", 1.0), ("lognormal", 0.5),
])
def test_complex_gain_moments(fading, scale):
    """Blind-channel draws: uniform phase makes both parts zero-mean, and
    E[a² + b²] = E[h²] = `magnitude_m2` (the blind-MRC normalizer)."""
    cfg = ChannelConfig(fading=fading, scale=scale)
    a, b = sample_complex_gains(jax.random.key(0), cfg, (400_000,))
    m2 = float((a**2 + b**2).mean())
    np.testing.assert_allclose(float(a.mean()), 0.0, atol=3e-2 * scale)
    np.testing.assert_allclose(float(b.mean()), 0.0, atol=3e-2 * scale)
    np.testing.assert_allclose(m2, cfg.magnitude_m2,
                               rtol=0.05 if fading != "lognormal" else 0.2)


def test_phase_error_reduces_mean_gain():
    base = ChannelConfig(fading="rayleigh")
    err = ChannelConfig(fading="rayleigh", phase_error_max=np.pi / 4)
    assert err.mu_h < base.mu_h
    assert err.mu_h > 0.0  # paper §III: error < pi/4 keeps nonzero mean
    h = sample_gains(jax.random.key(1), err, (400_000,))
    np.testing.assert_allclose(float(h.mean()), err.mu_h, rtol=0.02)


@given(n=st.integers(min_value=1, max_value=10_000),
       e=st.floats(min_value=1e-6, max_value=1e3))
@settings(max_examples=50, deadline=None)
def test_edge_noise_scaling_law(n, e):
    """Noise std must scale as sigma_w / (N sqrt(E_N)) (Eq. 8)."""
    cfg = ChannelConfig(noise_std=2.0, energy=e)
    assert np.isclose(edge_noise_std(cfg, n), 2.0 / (n * np.sqrt(e)))


@given(eps=st.floats(min_value=0.05, max_value=0.9))
@settings(max_examples=20, deadline=None)
def test_energy_scaling_vanishing_total_energy(eps):
    """With E_N = N^{eps-2}, total energy N*E_N -> 0 while the noise term
    d sigma_w^2/(E_N N^2) = d sigma_w^2 N^{-eps} -> 0 as well (§V-C.2)."""
    from repro.core.theory import energy_for_scaling

    n1, n2 = 100, 10_000
    e1, e2 = energy_for_scaling(n1, eps), energy_for_scaling(n2, eps)
    assert n2 * e2 < n1 * e1  # total energy decreasing
    noise1 = 1.0 / (e1 * n1**2)
    noise2 = 1.0 / (e2 * n2**2)
    assert noise2 < noise1  # noise term decreasing too
