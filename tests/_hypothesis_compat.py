"""Offline stand-in for the tiny slice of the `hypothesis` API these tests use.

The test container has no network access and `hypothesis` is not baked into
the image, so the property tests import `given` / `settings` / `strategies`
from here. When the real library is installed it is preferred (full shrinking
and example databases); otherwise a deterministic, seeded sampler with the
same decorator surface runs each property on `max_examples` pseudo-random
draws. Supported strategies: `integers`, `floats`, `sampled_from`,
`booleans` — exactly what the suite needs; extend `_Strategy` factories if
a test needs more.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is available
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: "np.random.Generator"):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**31) if min_value is None else int(min_value)
            hi = 2**31 - 1 if max_value is None else int(max_value)
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(min_value=None, max_value=None, **_):
            lo = -1e9 if min_value is None else float(min_value)
            hi = 1e9 if max_value is None else float(max_value)
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples: int = 10, **_):
        """Records `max_examples`; `deadline` etc. are accepted and ignored."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Runs the test on seeded draws; the seed derives from the test name
        so every run (and every CI machine) sees the same examples."""

        def deco(fn):
            n_examples = getattr(fn, "_compat_max_examples", 10)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = np.frombuffer(
                    f"{fn.__module__}.{fn.__qualname__}".encode(), np.uint8
                ).sum()
                rng = np.random.default_rng(int(seed))
                for i in range(n_examples):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # attach the failing example
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n_examples}): {drawn}"
                        ) from e

            # pytest resolves fixtures through __wrapped__; the strategy
            # params are filled here, not by fixtures, so hide the original
            # signature.
            del wrapper.__wrapped__
            return wrapper

        return deco


__all__ = ["given", "settings", "strategies", "HAVE_HYPOTHESIS"]
