"""Paper Fig. 3: same as Fig. 2 under i.i.d. Rayleigh fading — the gradient
is now distorted (sigma_h^2 > 0) as well as noisy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.theory import stepsize_theorem1, theorem1_bound

STEPS = 300
SEEDS = 4


def run(verbose: bool = True) -> list[str]:
    rows = []
    ks = np.arange(1, STEPS + 2)
    for n in (50, 160, 500):
        prob = MSDProblem.make(n)
        ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=1.0)
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        sim = GBMASimulator(prob.grad_fn(), ch, beta)

        def one(key, sim=sim, prob=prob):
            import jax.numpy as jnp
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            return prob.excess_risk(traj)

        emp = average_runs(one, SEEDS)
        bound = theorem1_bound(ks, beta, prob.pc, ch, n)
        rows.append(f"fig3a,N={n},final_emp,{emp[-1]:.6e}")
        rows.append(f"fig3a,N={n},final_bound,{bound[-1]:.6e}")
        rows.append(f"fig3a,N={n},bound_holds,{int(np.all(emp <= bound * 1.05))}")
    n = 500
    prob = MSDProblem.make(n)
    for eps in (0.5, 1.0, 1.5):
        ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=float(n) ** (eps - 2.0))
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        sim = GBMASimulator(prob.grad_fn(), ch, beta)

        def one(key, sim=sim, prob=prob):
            import jax.numpy as jnp
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            return prob.excess_risk(traj)

        emp = average_runs(one, SEEDS)
        bound = theorem1_bound(ks, beta, prob.pc, ch, n)
        rows.append(f"fig3b,eps={eps},final_emp,{emp[-1]:.6e}")
        rows.append(f"fig3b,eps={eps},final_bound,{bound[-1]:.6e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
