"""Paper Fig. 3: same as Fig. 2 under i.i.d. Rayleigh fading — the gradient
is now distorted (sigma_h^2 > 0) as well as noisy. Runs on the batched Monte
Carlo engine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

STEPS = 300
SEEDS = 4


def run(verbose: bool = True) -> list[str]:
    rows = []
    for n in (50, 160, 500):
        prob = MSDProblem.make(n)
        ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=1.0)
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        res = run_mc(prob.to_mc(), [ch], "gbma", [beta], STEPS, SEEDS,
                     pc=prob.pc)
        emp, bound = res.mean[0], res.bounds[0]
        rows.append(f"fig3a,N={n},final_emp,{emp[-1]:.6e}")
        rows.append(f"fig3a,N={n},final_bound,{bound[-1]:.6e}")
        rows.append(f"fig3a,N={n},bound_holds,{int(np.all(emp <= bound * 1.05))}")
    n = 500
    prob = MSDProblem.make(n)
    eps_grid = (0.5, 1.0, 1.5)
    chs = [ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (eps - 2.0)) for eps in eps_grid]
    betas = [stepsize_theorem1(prob.pc, ch, n, safety=0.9) for ch in chs]
    res = run_mc(prob.to_mc(), chs, "gbma", betas, STEPS, SEEDS, pc=prob.pc)
    for i, eps in enumerate(eps_grid):
        rows.append(f"fig3b,eps={eps},final_emp,{res.mean[i][-1]:.6e}")
        rows.append(f"fig3b,eps={eps},final_bound,{res.bounds[i][-1]:.6e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
