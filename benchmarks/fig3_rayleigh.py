"""Paper Fig. 3: same as Fig. 2 under i.i.d. Rayleigh fading — the gradient
is now distorted (sigma_h^2 > 0) as well as noisy. The node-count sweep of
(a) runs in ONE padded/masked engine compile; shared body in
`benchmarks.common.run_msd_figure` (Fig. 2 is the equal-gains twin)."""
from __future__ import annotations

from benchmarks.common import run_msd_figure

N_GRID = (50, 160, 500)
EPS_GRID = (0.5, 1.0, 1.5)
STEPS = 300
SEEDS = 4
SMOKE_COMPILES = 2  # engine compiles per run(), asserted by the smoke test


def run(verbose: bool = True, plan=None) -> list[str]:
    rows = run_msd_figure("rayleigh", "fig3", N_GRID, EPS_GRID, STEPS,
                          SEEDS, plan=plan)
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
