"""Shared benchmark scaffolding for the paper-experiment reproductions."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.montecarlo import MCProblem, quadratic_mc_problem
from repro.core.theory import ProblemConstants
from repro.data.synthetic import msd_like_regression

LAMBDA = 0.5  # paper §VI-A: regularizer of Eq. (27)


@dataclasses.dataclass
class MSDProblem:
    """Regularized linear least squares on the MSD-like dataset; one sample
    per node (paper §VI-A)."""

    X: np.ndarray
    y: np.ndarray
    theta_star: np.ndarray
    pc: ProblemConstants

    @classmethod
    def make(cls, n_nodes: int, dim: int = 90, seed: int = 0,
             delta: float = 10.0) -> "MSDProblem":
        X, y, _ = msd_like_regression(n_nodes, dim=dim, seed=seed)
        A = X.T @ X / n_nodes
        theta_star = np.linalg.solve(A + LAMBDA * np.eye(dim),
                                     X.T @ y / n_nodes)
        eig = np.linalg.eigvalsh(A)
        pc = ProblemConstants(
            mu=float(eig[0] + LAMBDA), L=float(eig[-1] + LAMBDA),
            L_bar=float(np.max(np.sum(X**2, axis=1)) + LAMBDA),
            delta=delta, r0_sq=float(np.sum(theta_star**2)), dim=dim)
        return cls(X, y, theta_star, pc)

    def grad_fn(self):
        Xj, yj = jnp.array(self.X), jnp.array(self.y)

        def g(theta):
            return (Xj @ theta - yj)[:, None] * Xj + LAMBDA * theta[None, :]

        return g

    def objective(self, theta) -> float:
        t = np.asarray(theta, np.float64)
        return float(0.5 * np.mean((self.X @ t - self.y) ** 2)
                     + LAMBDA / 2 * np.sum(t * t))

    def excess_risk(self, traj) -> np.ndarray:
        f_star = self.objective(self.theta_star)
        return np.array([self.objective(t) - f_star for t in np.asarray(traj)])

    def to_mc(self) -> MCProblem:
        """On-device problem for `repro.core.montecarlo.run_mc` (closed-form
        quadratic excess risk; numerically equivalent to `excess_risk`)."""
        return quadratic_mc_problem(self.X, self.y, LAMBDA, self.theta_star)


def average_runs(run_fn, seeds: int) -> np.ndarray:
    """Averages excess-risk curves over seeds (the expectation in Eq. 14).

    Legacy sequential path: Python loop over seeds, per-step host-side
    objective evaluation. The figures now run through
    `repro.core.montecarlo.run_mc`; this stays as the timing baseline for
    `benchmarks/bench_montecarlo.py` and as an independent oracle in tests.
    """
    curves = [run_fn(jax.random.key(s)) for s in range(seeds)]
    return np.mean(np.stack(curves), axis=0)


def timed(fn, *args, reps: int = 3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run_msd_figure(fading: str, prefix: str, n_grid, eps_grid,
                   steps: int, seeds: int, plan=None) -> list[str]:
    """Shared body of paper Figs. 2 (equal gains) and 3 (Rayleigh):
    (a) a node-count sweep at E_N = 1 — ONE padded/masked engine compile,
    one (problem, channel, stepsize) row per N — and (b) an energy sweep
    E_N = N^{eps-2} at the largest N, one vmapped call over energies.
    Both overlay the Theorem-1 bound and emit mean ± ci95 curve rows.
    `plan` passes through to `run_mc(plan=...)` (an ExecPlan or "auto");
    None keeps the figure-scale defaults."""
    from repro.core.channel import ChannelConfig
    from repro.core.montecarlo import run_mc
    from repro.core.theory import stepsize_theorem1

    rows = []
    probs = [MSDProblem.make(n) for n in n_grid]
    chs = [ChannelConfig(fading=fading, scale=1.0, noise_std=1.0,
                         energy=1.0) for _ in n_grid]
    betas = [stepsize_theorem1(p.pc, ch, n, safety=0.9)
             for p, ch, n in zip(probs, chs, n_grid)]
    res = run_mc([p.to_mc() for p in probs], chs, "gbma", betas, steps,
                 seeds, pc=[p.pc for p in probs], plan=plan)
    ks = np.arange(steps + 1)
    for i, n in enumerate(n_grid):
        emp, bound = res.mean[i], res.bounds[i]
        rows.append(f"{prefix}a,N={n},final_emp,{emp[-1]:.6e}")
        rows.append(f"{prefix}a,N={n},final_bound,{bound[-1]:.6e}")
        rows.append(f"{prefix}a,N={n},bound_holds,"
                    f"{int(np.all(emp <= bound * 1.05))}")
        rows += fmt_curve(f"{prefix}a_curve,N={n}", ks, emp, every=100,
                          ci95=res.ci95[i])
    n = n_grid[-1]
    prob = probs[-1]
    chs = [ChannelConfig(fading=fading, scale=1.0, noise_std=1.0,
                         energy=float(n) ** (eps - 2.0))
           for eps in eps_grid]
    betas = [stepsize_theorem1(prob.pc, ch, n, safety=0.9) for ch in chs]
    res = run_mc(prob.to_mc(), chs, "gbma", betas, steps, seeds,
                 pc=prob.pc, plan=plan)
    for i, eps in enumerate(eps_grid):
        rows.append(f"{prefix}b,eps={eps},final_emp,{res.mean[i][-1]:.6e}")
        rows.append(f"{prefix}b,eps={eps},final_bound,"
                    f"{res.bounds[i][-1]:.6e}")
        rows += fmt_curve(f"{prefix}b_curve,eps={eps}", ks, res.mean[i],
                          every=100, ci95=res.ci95[i])
    return rows


def fmt_curve(name: str, ks: np.ndarray, values: np.ndarray,
              every: int = 50, ci95: np.ndarray | None = None) -> list[str]:
    """CSV rows `name,k=K,value[,±ci95]`, subsampled every `every` points.

    `ci95` (same length as `values`, e.g. `MCResult.ci95[row]`) appends the
    seed-averaging 95% confidence half-width as a `±x` column."""
    idx = list(range(0, len(ks), every))
    if idx[-1] != len(ks) - 1:
        idx.append(len(ks) - 1)
    rows = []
    for i in idx:
        row = f"{name},k={int(ks[i])},{values[i]:.6e}"
        if ci95 is not None:
            row += f",±{ci95[i]:.2e}"
        rows.append(row)
    return rows
