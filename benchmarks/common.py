"""Shared benchmark scaffolding for the paper-experiment reproductions."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.montecarlo import MCProblem, quadratic_mc_problem
from repro.core.theory import ProblemConstants
from repro.data.synthetic import msd_like_regression

LAMBDA = 0.5  # paper §VI-A: regularizer of Eq. (27)


@dataclasses.dataclass
class MSDProblem:
    """Regularized linear least squares on the MSD-like dataset; one sample
    per node (paper §VI-A)."""

    X: np.ndarray
    y: np.ndarray
    theta_star: np.ndarray
    pc: ProblemConstants

    @classmethod
    def make(cls, n_nodes: int, dim: int = 90, seed: int = 0,
             delta: float = 10.0) -> "MSDProblem":
        X, y, _ = msd_like_regression(n_nodes, dim=dim, seed=seed)
        A = X.T @ X / n_nodes
        theta_star = np.linalg.solve(A + LAMBDA * np.eye(dim),
                                     X.T @ y / n_nodes)
        eig = np.linalg.eigvalsh(A)
        pc = ProblemConstants(
            mu=float(eig[0] + LAMBDA), L=float(eig[-1] + LAMBDA),
            L_bar=float(np.max(np.sum(X**2, axis=1)) + LAMBDA),
            delta=delta, r0_sq=float(np.sum(theta_star**2)), dim=dim)
        return cls(X, y, theta_star, pc)

    def grad_fn(self):
        Xj, yj = jnp.array(self.X), jnp.array(self.y)

        def g(theta):
            return (Xj @ theta - yj)[:, None] * Xj + LAMBDA * theta[None, :]

        return g

    def objective(self, theta) -> float:
        t = np.asarray(theta, np.float64)
        return float(0.5 * np.mean((self.X @ t - self.y) ** 2)
                     + LAMBDA / 2 * np.sum(t * t))

    def excess_risk(self, traj) -> np.ndarray:
        f_star = self.objective(self.theta_star)
        return np.array([self.objective(t) - f_star for t in np.asarray(traj)])

    def to_mc(self) -> MCProblem:
        """On-device problem for `repro.core.montecarlo.run_mc` (closed-form
        quadratic excess risk; numerically equivalent to `excess_risk`)."""
        return quadratic_mc_problem(self.X, self.y, LAMBDA, self.theta_star)


def average_runs(run_fn, seeds: int) -> np.ndarray:
    """Averages excess-risk curves over seeds (the expectation in Eq. 14).

    Legacy sequential path: Python loop over seeds, per-step host-side
    objective evaluation. The figures now run through
    `repro.core.montecarlo.run_mc`; this stays as the timing baseline for
    `benchmarks/bench_montecarlo.py` and as an independent oracle in tests.
    """
    curves = [run_fn(jax.random.key(s)) for s in range(seeds)]
    return np.mean(np.stack(curves), axis=0)


def timed(fn, *args, reps: int = 3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def fmt_curve(name: str, ks: np.ndarray, values: np.ndarray,
              every: int = 50) -> list[str]:
    rows = []
    for i in range(0, len(ks), every):
        rows.append(f"{name},k={int(ks[i])},{values[i]:.6e}")
    rows.append(f"{name},k={int(ks[-1])},{values[-1]:.6e}")
    return rows
