"""Timed comparison: batched Monte Carlo engine vs the seed per-seed Python
loop (`average_runs` + host-side `MSDProblem.excess_risk`), emitted to
`benchmarks/BENCH_montecarlo.json` so the speedup is tracked across PRs.

Workload: the paper's Fig. 3 operating point — MSD regression, N=500 nodes,
Rayleigh fading, 300 GBMA steps, SEEDS=4 (the figure scripts' setting). Both
paths get one untimed warm-up call (the engine compiles once; the legacy
path re-traces its scan every call, which is part of what it costs and is
measured)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

N = 500
STEPS = 300
SEEDS = 4
OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_montecarlo.json")


def _time(fn, reps: int = 3) -> tuple[float, np.ndarray]:
    fn()  # warm-up (engine: compile; legacy: first trace)
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(verbose: bool = True) -> list[str]:
    prob = MSDProblem.make(N)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.9)

    sim = GBMASimulator(prob.grad_fn(), ch, beta)

    def seed_loop():
        def one(key):
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            return prob.excess_risk(traj)

        return average_runs(one, SEEDS)

    mc = prob.to_mc()

    def engine():
        return run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS).mean[0]

    t_seed, curve_seed = _time(seed_loop)
    t_engine, curve_engine = _time(engine)
    rel = float(np.max(np.abs(curve_engine - curve_seed)
                       / np.maximum(np.abs(curve_seed), 1e-12)))
    record = {
        "workload": {"problem": "msd_regression", "n_nodes": N,
                     "steps": STEPS, "seeds": SEEDS, "fading": "rayleigh"},
        "seed_loop_s": round(t_seed, 4),
        "engine_s": round(t_engine, 4),
        "speedup": round(t_seed / t_engine, 2),
        "max_rel_curve_diff": rel,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    rows = [
        f"bench_montecarlo,seed_loop_s,{t_seed:.4f}",
        f"bench_montecarlo,engine_s,{t_engine:.4f}",
        f"bench_montecarlo,speedup,{t_seed / t_engine:.2f}",
        f"bench_montecarlo,max_rel_curve_diff,{rel:.2e}",
        f"bench_montecarlo,json,{OUT_PATH}",
    ]
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
