"""Timed comparisons for the Monte Carlo engine, emitted to
`benchmarks/BENCH_montecarlo.json` so the speedups are tracked across PRs.

1. engine vs the seed per-seed Python loop (`average_runs` + host-side
   `MSDProblem.excess_risk`) at the paper's Fig. 3 operating point — MSD
   regression, N=500 nodes, Rayleigh fading, 300 GBMA steps, SEEDS=4. Both
   paths get one untimed warm-up call (the engine compiles once; the legacy
   path re-traces its scan every call, which is part of what it costs and is
   measured).

2. node-count sweep: ONE padded/masked engine call over all N (a single
   `_mc_core` compile) vs the pre-PR-2 path of one engine call — hence one
   XLA compile — per N. Both are timed cold (the jit cache is cleared
   first): compile time is precisely what the padded N axis removes, so it
   belongs in the measurement.

3. fig7 antenna sweep: ONE per-row-`n_antennas` engine call (antenna counts
   as data, a single compile) vs one engine call — one compile — per
   antenna count M. Timed cold, like 2.: the antenna count is a draw-shape
   choice, so without the counts-as-data key split every M costs a compile.

4. fig8 batch-fraction sweep (stochastic federated logistic): ONE per-row
   `batch_frac` engine call (the minibatch lane count is traced data) vs
   one engine call — one compile — per fraction (each fraction changes the
   static minibatch width `b_max`). Timed cold.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.montecarlo import clear_cache, run_mc, trace_count
from repro.core.theory import stepsize_theorem1

N = 500
STEPS = 300
SEEDS = 4
SWEEP_N_GRID = (100, 200, 400)
SWEEP_M_GRID = (2, 8, 32)
# fractions < 1.0 only: a scalar batch_frac=1.0 takes the static
# no-sampling path (a different, cheaper program than a sweep row), so
# including it would time non-equivalent computations
SWEEP_FRAC_GRID = (0.75, 0.5, 0.25)
OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_montecarlo.json")


def _time(fn, reps: int = 3) -> tuple[float, np.ndarray]:
    fn()  # warm-up (engine: compile; legacy: first trace)
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _time_cold(fn) -> tuple[float, object, int]:
    """One cold wall-clock measurement, XLA compiles included."""
    clear_cache()
    c0 = trace_count()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out, trace_count() - c0


def bench_single_config() -> dict:
    prob = MSDProblem.make(N)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.9)

    sim = GBMASimulator(prob.grad_fn(), ch, beta)

    def seed_loop():
        def one(key):
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            return prob.excess_risk(traj)

        return average_runs(one, SEEDS)

    mc = prob.to_mc()

    def engine():
        return run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS).mean[0]

    t_seed, curve_seed = _time(seed_loop)
    t_engine, curve_engine = _time(engine)
    rel = float(np.max(np.abs(curve_engine - curve_seed)
                       / np.maximum(np.abs(curve_seed), 1e-12)))
    return {
        "workload": {"problem": "msd_regression", "n_nodes": N,
                     "steps": STEPS, "seeds": SEEDS, "fading": "rayleigh"},
        "seed_loop_s": round(t_seed, 4),
        "engine_s": round(t_engine, 4),
        "speedup": round(t_seed / t_engine, 2),
        "max_rel_curve_diff": rel,
    }


def bench_n_sweep() -> dict:
    probs = [MSDProblem.make(n) for n in SWEEP_N_GRID]
    chs = [ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (-1.5)) for n in SWEEP_N_GRID]
    betas = [stepsize_theorem1(p.pc, ch, n, safety=0.9)
             for p, ch, n in zip(probs, chs, SWEEP_N_GRID)]
    mcs = [p.to_mc() for p in probs]

    def per_n():
        return [run_mc(mc, [ch], "gbma", [b], STEPS, SEEDS).mean[0]
                for mc, ch, b in zip(mcs, chs, betas)]

    def one_compile():
        return run_mc(mcs, chs, "gbma", betas, STEPS, SEEDS).mean

    t_per_n, curves_per_n, compiles_per_n = _time_cold(per_n)
    t_padded, curves_padded, compiles_padded = _time_cold(one_compile)
    rel = float(max(
        np.max(np.abs(cp - cs) / np.maximum(np.abs(cs), 1e-12))
        for cp, cs in zip(curves_padded, curves_per_n)))
    return {
        "workload": {"problem": "msd_regression",
                     "n_grid": list(SWEEP_N_GRID), "steps": STEPS,
                     "seeds": SEEDS, "fading": "rayleigh",
                     "timing": "cold, compiles included"},
        "per_n_compile_s": round(t_per_n, 4),
        "per_n_compiles": compiles_per_n,
        "one_compile_s": round(t_padded, 4),
        "one_compile_compiles": compiles_padded,
        "speedup": round(t_per_n / t_padded, 2),
        "max_rel_curve_diff": rel,
    }


def bench_m_sweep() -> dict:
    """fig7's antenna sweep (blind transmitters): per-row antenna counts
    batch every M into one compile vs one compile per static M."""
    n = 100
    prob = MSDProblem.make(n)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0 / n)
    beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9) * ch.mu_h
    mc = prob.to_mc()

    def per_m():
        return [run_mc(mc, [ch], "blind", [beta], STEPS, SEEDS,
                       n_antennas=m).mean[0] for m in SWEEP_M_GRID]

    def one_compile():
        return list(run_mc(mc, [ch] * len(SWEEP_M_GRID), "blind",
                           [beta] * len(SWEEP_M_GRID), STEPS, SEEDS,
                           n_antennas=SWEEP_M_GRID).mean)

    t_per_m, curves_per_m, compiles_per_m = _time_cold(per_m)
    t_one, curves_one, compiles_one = _time_cold(one_compile)
    rel = float(max(
        np.max(np.abs(cp - cs) / np.maximum(np.abs(cs), 1e-12))
        for cp, cs in zip(curves_one, curves_per_m)))
    return {
        "workload": {"problem": "msd_regression", "n_nodes": n,
                     "m_grid": list(SWEEP_M_GRID), "algo": "blind",
                     "steps": STEPS, "seeds": SEEDS, "fading": "rayleigh",
                     "timing": "cold, compiles included"},
        "per_m_compile_s": round(t_per_m, 4),
        "per_m_compiles": compiles_per_m,
        "one_compile_s": round(t_one, 4),
        "one_compile_compiles": compiles_one,
        "speedup": round(t_per_m / t_one, 2),
        "max_rel_curve_diff": rel,
    }


def bench_frac_sweep() -> dict:
    """fig8's batch-fraction sweep (stochastic logistic): per-row traced
    minibatch lane counts batch every fraction into one compile vs one
    compile per static fraction."""
    from repro.core.montecarlo import logistic_mc_problem
    from repro.data.synthetic import logistic_classification

    n, k, dim = 40, 6, 16
    X, y, _ = logistic_classification(n * k, dim=dim, seed=0)
    prob = logistic_mc_problem(X, y, n, lam=0.1)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.5,
                       energy=1.0 / n)
    beta = 0.3

    def per_frac():
        return [run_mc(prob, [ch], "gbma", [beta], STEPS, SEEDS,
                       batch_frac=f).mean[0] for f in SWEEP_FRAC_GRID]

    def one_compile():
        return list(run_mc(prob, [ch] * len(SWEEP_FRAC_GRID), "gbma",
                           [beta] * len(SWEEP_FRAC_GRID), STEPS, SEEDS,
                           batch_frac=SWEEP_FRAC_GRID).mean)

    t_per, curves_per, compiles_per = _time_cold(per_frac)
    t_one, curves_one, compiles_one = _time_cold(one_compile)
    rel = float(max(
        np.max(np.abs(cp - cs) / np.maximum(np.abs(cs), 1e-12))
        for cp, cs in zip(curves_one, curves_per)))
    return {
        "workload": {"problem": "federated_logistic", "n_nodes": n,
                     "samples_per_node": k,
                     "frac_grid": list(SWEEP_FRAC_GRID), "steps": STEPS,
                     "seeds": SEEDS, "fading": "rayleigh",
                     "timing": "cold, compiles included"},
        "per_frac_compile_s": round(t_per, 4),
        "per_frac_compiles": compiles_per,
        "one_compile_s": round(t_one, 4),
        "one_compile_compiles": compiles_one,
        "speedup": round(t_per / t_one, 2),
        "max_rel_curve_diff": rel,
    }


def run(verbose: bool = True) -> list[str]:
    single = bench_single_config()
    sweep = bench_n_sweep()
    m_sweep = bench_m_sweep()
    frac_sweep = bench_frac_sweep()
    record = {
        **single,
        "n_sweep": sweep,
        "fig7_m_sweep": m_sweep,
        "fig8_frac_sweep": frac_sweep,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    rows = [
        f"bench_montecarlo,seed_loop_s,{single['seed_loop_s']:.4f}",
        f"bench_montecarlo,engine_s,{single['engine_s']:.4f}",
        f"bench_montecarlo,speedup,{single['speedup']:.2f}",
        f"bench_montecarlo,max_rel_curve_diff,{single['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,n_sweep_per_n_s,{sweep['per_n_compile_s']:.4f}"
        f",compiles={sweep['per_n_compiles']}",
        f"bench_montecarlo,n_sweep_one_compile_s,{sweep['one_compile_s']:.4f}"
        f",compiles={sweep['one_compile_compiles']}",
        f"bench_montecarlo,n_sweep_speedup,{sweep['speedup']:.2f}",
        f"bench_montecarlo,n_sweep_max_rel_curve_diff,"
        f"{sweep['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,fig7_m_sweep_per_m_s,"
        f"{m_sweep['per_m_compile_s']:.4f}"
        f",compiles={m_sweep['per_m_compiles']}",
        f"bench_montecarlo,fig7_m_sweep_one_compile_s,"
        f"{m_sweep['one_compile_s']:.4f}"
        f",compiles={m_sweep['one_compile_compiles']}",
        f"bench_montecarlo,fig7_m_sweep_speedup,{m_sweep['speedup']:.2f}",
        f"bench_montecarlo,fig7_m_sweep_max_rel_curve_diff,"
        f"{m_sweep['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,fig8_frac_sweep_per_frac_s,"
        f"{frac_sweep['per_frac_compile_s']:.4f}"
        f",compiles={frac_sweep['per_frac_compiles']}",
        f"bench_montecarlo,fig8_frac_sweep_one_compile_s,"
        f"{frac_sweep['one_compile_s']:.4f}"
        f",compiles={frac_sweep['one_compile_compiles']}",
        f"bench_montecarlo,fig8_frac_sweep_speedup,{frac_sweep['speedup']:.2f}",
        f"bench_montecarlo,fig8_frac_sweep_max_rel_curve_diff,"
        f"{frac_sweep['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,json,{OUT_PATH}",
    ]
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
