"""Timed comparisons for the Monte Carlo engine, emitted to
`benchmarks/BENCH_montecarlo.json` so the speedups are tracked across PRs.

Methodology (docs/performance.md): every workload separates **cold** time
(first call, XLA compile included — what a one-shot script pays) from
**warm steady-state** time (best of `WARM_REPS` calls after a warm-up —
what a sweep loop pays per call). Cold timings clear the jit cache first;
warm timings are best-of to shave scheduler noise on small shared
containers. The analytic peak-memory model (`mc.exec.estimate_peak_bytes`)
is recorded next to the timings.

Workloads:

1. engine vs the seed per-seed Python loop (`average_runs` + host-side
   `MSDProblem.excess_risk`) at the paper's Fig. 3 operating point — MSD
   regression, N=500 nodes, Rayleigh fading, 300 GBMA steps, SEEDS=4. The
   legacy path re-traces its scan every call, which is part of what it
   costs and is measured.

2. node-count sweep: ONE padded/masked engine call over all N (a single
   `_mc_core` compile) vs the pre-PR-2 path of one engine call — hence one
   XLA compile — per N. Timed cold (compile time is precisely what the
   padded N axis removes) plus the warm steady state of the one-compile
   path.

3. fig7 antenna sweep: ONE per-row-`n_antennas` engine call (antenna
   counts as data, a single compile) vs one engine call — one compile —
   per antenna count M. Cold + warm, like 2.

4. fig8 batch-fraction sweep (stochastic federated logistic): ONE per-row
   `batch_frac` engine call vs one engine call — one compile — per
   fraction. Cold + warm, like 2.

5. **large_chunked**: the execution-layer workload (seeds ≥ 256,
   N ≥ 4096). The all-live hoisted path exceeds the bench's device-memory
   budget (`MEM_BUDGET_GIB`, the CI-class container the scheduler is sized
   against), so this entry runs ONLY under `seed_chunk`; it compares the
   new path (hoisted RNG plan + seed chunking + on-device seed reduction)
   against the pre-exec-layer engine (in-scan RNG, all seeds live, host
   curves) warm-for-warm on the same workload, plus the plan-only chunked
   A/B.

6. **large_chunked_placed**: the same LARGE workload under `auto_plan` —
   placement ON (every visible device on the ("rows", "mc") mesh) vs
   forced OFF on the same plan, warm-for-warm, plus the auto plan's mean
   curve against the hand-tuned legacy-kwargs chunked path. Each entry
   records the device topology and resolved `ExecPlan`, so records from
   the 1-device bench run and the 4-forced-host-device CI job are
   directly comparable.

7. **train_100m_ota**: the channel-transport layer's exactness-vs-speed
   tradeoff on a training-shaped gradient pytree (a transformer-like leaf
   mix, multi-million-D at full scale). One `transport.aggregate('gbma')`
   slot per configuration: untiled (`FULL_CONCAT`, one (N, D) slot call —
   the reference), block-tiled (`block_d` columns per tile), and
   block-tiled with `transmit_dtype='bfloat16'`. Records warm times plus
   the max deviation of each path from the untiled f32 reference — tiled
   must sit at f32-ulp scale (≤ 1e-6), bf16-transmit at quantization
   scale.

8. **serve_coalesce**: the sweep server's routing win (docs/serving.md)
   on a heterogeneous-N request mix (`SERVE_N_GRID`: clusters of small
   and large node counts, signature-compatible). Three servings of the
   same K requests: per-request (one dedicated `run_mc` call each),
   monolithic coalescing (`bucket_base=0` — every request padded to one
   batch N_max, the pre-cost-model router), and bucketed coalescing
   (the pad-waste-aware router on a persistent server, so its
   shape-class registry is warm and the cost model splits whales from
   minnows). Cold records the first-sight compile counts (a fresh
   bucketed server merges monolithically — compiles dominate — so
   `coalesced_compiles` stays 1); warm records the steady-state
   tradeoff the cost model navigates: pad waste (monolithic) vs
   dispatch count (per-request). Per-batch `pad_flops_ratio`, the
   bucket occupancy and the demux pin (`max_rel_curve_diff` vs the
   dedicated calls, ≤ 1e-6 — counter-based RNG) ride along.

`--smoke` shrinks every workload to CI size, writes
`BENCH_montecarlo.smoke.json` (never the tracked full-scale record),
asserts the warm timings are finite and the curve agreements hold, and
exits nonzero on violation — the CI bench job runs exactly that and
uploads the JSON artifact. Direct invocation
(`python -m benchmarks.bench_montecarlo`, no --smoke) rewrites the
tracked record; through `benchmarks.run` the tracked record is only
written when the explicit `--write-bench` flag is passed (the
bench-clobber footgun: an unfiltered figure run must not silently
rewrite tracked numbers with contended-container timings).
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.mc.exec import estimate_peak_bytes
from repro.core.mc.plan import ExecPlan, auto_plan, resolve_seed_shards
from repro.core.montecarlo import clear_cache, run_mc, trace_count
from repro.core.theory import stepsize_theorem1

N = 500
STEPS = 300
SEEDS = 4
SWEEP_N_GRID = (100, 200, 400)
SWEEP_M_GRID = (2, 8, 32)
# the serving mix: heterogeneous node counts that cluster into two
# geometric N-buckets (×2 base: {96,100,120} -> 128, {384,400} -> 512) —
# minnows and whales the pad-waste-aware router should NOT pad together
# warm, yet must merge cold (compiles dominate)
SERVE_N_GRID = (96, 100, 120, 384, 400)
# fractions < 1.0 only: a scalar batch_frac=1.0 takes the static
# no-sampling path (a different, cheaper program than a sweep row), so
# including it would time non-equivalent computations
SWEEP_FRAC_GRID = (0.75, 0.5, 0.25)
# the execution-layer workload: all-live exceeds MEM_BUDGET_GIB, so it
# runs only under seed_chunk (the point of the chunked scheduler). dim=24
# keeps the slot channel-dominated — the regime the RNG plan targets
LARGE = {"n": 4096, "dim": 24, "steps": 150, "seeds": 1024, "chunk": 32}
# the transport workload: N nodes x D total parameters, tiled at block_d
TRAIN_OTA = {"n": 8, "d": 2 * 1024 * 1024, "block_d": 256 * 1024}
MEM_BUDGET_GIB = 2.0
# auto_plan's per-device chunk-sizing target for the placed entry: None =
# the planner's 128 MiB default (reproduces LARGE's hand-tuned chunk=32
# at full scale); --smoke shrinks it so chunking is still exercised at
# CI-size seed counts
AUTO_TARGET_CHUNK_BYTES = None
WARM_REPS = 3
OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_montecarlo.json")
# --smoke writes here instead: CI-size numbers must never clobber the
# tracked full-scale record
SMOKE_OUT_PATH = os.path.join(os.path.dirname(__file__),
                              "BENCH_montecarlo.smoke.json")


def _warm(fn, reps: int = None) -> tuple[float, object]:
    """Warm steady-state: one untimed warm-up call (compile), then best of
    `reps` timed calls."""
    reps = WARM_REPS if reps is None else reps
    fn()
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _cold(fn) -> tuple[float, object, int]:
    """One cold wall-clock measurement, XLA compiles included (the jit
    cache is cleared first)."""
    clear_cache()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out, trace_count()


def _rel(a, b) -> float:
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-12)))


def _warm_step_us(warm_s: float, rows: int, steps: int, seeds: int) -> float:
    """Warm time per (row, seed, step) trajectory step, in microseconds."""
    return warm_s / (rows * steps * seeds) * 1e6


def _topology(plan: ExecPlan = None, seeds: int = None) -> dict:
    """Device-topology stamp for a BENCH entry: records are compared
    across machines and placements, so each entry carries the device
    count and platform it ran on — plus, for engine entries, the
    resolved ExecPlan and its concrete 'mc' mesh size."""
    t = {"device_count": jax.device_count(),
         "platform": jax.default_backend()}
    if plan is not None:
        t["n_shards"] = resolve_seed_shards(plan, seeds)
        t["plan"] = plan.asdict()
    return t


def bench_single_config() -> dict:
    prob = MSDProblem.make(N)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.9)

    sim = GBMASimulator(prob.grad_fn(), ch, beta)

    def seed_loop():
        def one(key):
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            return prob.excess_risk(traj)

        return average_runs(one, SEEDS)

    mc = prob.to_mc()

    def engine():
        return run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS).mean[0]

    t_cold, _, _ = _cold(engine)
    t_seed, curve_seed = _warm(seed_loop)
    t_engine, curve_engine = _warm(engine)
    return {
        "workload": {"problem": "msd_regression", "n_nodes": N,
                     "dim": prob.pc.dim, "steps": STEPS, "seeds": SEEDS,
                     "fading": "rayleigh"},
        "seed_loop_s": round(t_seed, 4),
        "engine_s": round(t_engine, 4),
        "engine_cold_s": round(t_cold, 4),
        "engine_warm_step_us": round(
            _warm_step_us(t_engine, 1, STEPS, SEEDS), 3),
        "speedup": round(t_seed / t_engine, 2),
        "max_rel_curve_diff": _rel(curve_engine, curve_seed),
    }


def _sweep_record(workload: dict, per_key: str, t_per: float,
                  compiles_per: int, t_one_cold: float, compiles_one: int,
                  t_one_warm: float, rows: int, steps: int, seeds: int,
                  rel: float) -> dict:
    return {
        "workload": {**workload, "timing": "cold compiles included; "
                     "one_compile_warm_s is steady-state"},
        f"per_{per_key}_compile_s": round(t_per, 4),
        f"per_{per_key}_compiles": compiles_per,
        "one_compile_s": round(t_one_cold, 4),
        "one_compile_compiles": compiles_one,
        "one_compile_warm_s": round(t_one_warm, 4),
        "one_compile_warm_step_us": round(
            _warm_step_us(t_one_warm, rows, steps, seeds), 3),
        "speedup": round(t_per / t_one_cold, 2),
        "max_rel_curve_diff": rel,
    }


def bench_n_sweep() -> dict:
    probs = [MSDProblem.make(n) for n in SWEEP_N_GRID]
    chs = [ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (-1.5)) for n in SWEEP_N_GRID]
    betas = [stepsize_theorem1(p.pc, ch, n, safety=0.9)
             for p, ch, n in zip(probs, chs, SWEEP_N_GRID)]
    mcs = [p.to_mc() for p in probs]

    def per_n():
        return [run_mc(mc, [ch], "gbma", [b], STEPS, SEEDS).mean[0]
                for mc, ch, b in zip(mcs, chs, betas)]

    def one_compile():
        return run_mc(mcs, chs, "gbma", betas, STEPS, SEEDS).mean

    t_per_n, curves_per_n, compiles_per_n = _cold(per_n)
    t_padded, curves_padded, compiles_padded = _cold(one_compile)
    t_warm, _ = _warm(one_compile)
    rel = float(max(
        _rel(cp, cs) for cp, cs in zip(curves_padded, curves_per_n)))
    return _sweep_record(
        {"problem": "msd_regression", "n_grid": list(SWEEP_N_GRID),
         "steps": STEPS, "seeds": SEEDS, "fading": "rayleigh"},
        "n", t_per_n, compiles_per_n, t_padded, compiles_padded, t_warm,
        len(SWEEP_N_GRID), STEPS, SEEDS, rel)


def bench_m_sweep() -> dict:
    """fig7's antenna sweep (blind transmitters): per-row antenna counts
    batch every M into one compile vs one compile per static M."""
    n = 100
    prob = MSDProblem.make(n)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0 / n)
    beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9) * ch.mu_h
    mc = prob.to_mc()

    def per_m():
        return [run_mc(mc, [ch], "blind", [beta], STEPS, SEEDS,
                       n_antennas=m).mean[0] for m in SWEEP_M_GRID]

    def one_compile():
        return list(run_mc(mc, [ch] * len(SWEEP_M_GRID), "blind",
                           [beta] * len(SWEEP_M_GRID), STEPS, SEEDS,
                           n_antennas=SWEEP_M_GRID).mean)

    t_per_m, curves_per_m, compiles_per_m = _cold(per_m)
    t_one, curves_one, compiles_one = _cold(one_compile)
    t_warm, _ = _warm(one_compile)
    rel = float(max(
        _rel(cp, cs) for cp, cs in zip(curves_one, curves_per_m)))
    return _sweep_record(
        {"problem": "msd_regression", "n_nodes": n, "dim": prob.pc.dim,
         "m_grid": list(SWEEP_M_GRID), "algo": "blind", "steps": STEPS,
         "seeds": SEEDS, "fading": "rayleigh"},
        "m", t_per_m, compiles_per_m, t_one, compiles_one, t_warm,
        len(SWEEP_M_GRID), STEPS, SEEDS, rel)


def bench_frac_sweep() -> dict:
    """fig8's batch-fraction sweep (stochastic logistic): per-row traced
    minibatch lane counts batch every fraction into one compile vs one
    compile per static fraction."""
    from repro.core.montecarlo import logistic_mc_problem
    from repro.data.synthetic import logistic_classification

    n, k, dim = 40, 6, 16
    X, y, _ = logistic_classification(n * k, dim=dim, seed=0)
    prob = logistic_mc_problem(X, y, n, lam=0.1)
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.5,
                       energy=1.0 / n)
    beta = 0.3

    def per_frac():
        return [run_mc(prob, [ch], "gbma", [beta], STEPS, SEEDS,
                       batch_frac=f).mean[0] for f in SWEEP_FRAC_GRID]

    def one_compile():
        return list(run_mc(prob, [ch] * len(SWEEP_FRAC_GRID), "gbma",
                           [beta] * len(SWEEP_FRAC_GRID), STEPS, SEEDS,
                           batch_frac=SWEEP_FRAC_GRID).mean)

    t_per, curves_per, compiles_per = _cold(per_frac)
    t_one, curves_one, compiles_one = _cold(one_compile)
    t_warm, _ = _warm(one_compile)
    rel = float(max(
        _rel(cp, cs) for cp, cs in zip(curves_one, curves_per)))
    return _sweep_record(
        {"problem": "federated_logistic", "n_nodes": n,
         "samples_per_node": k, "frac_grid": list(SWEEP_FRAC_GRID),
         "steps": STEPS, "seeds": SEEDS, "fading": "rayleigh"},
        "frac", t_per, compiles_per, t_one, compiles_one, t_warm,
        len(SWEEP_FRAC_GRID), STEPS, SEEDS, rel)


def bench_large_chunked(warm_reps: int = 2) -> dict:
    """The execution-layer entry: seeds ≥ 256 at N ≥ 4096, runnable only
    under `seed_chunk` within the bench's device-memory budget.

    Three measurements on the SAME workload:
      * `current_engine_warm_s` — the pre-exec-layer engine: in-scan RNG,
        all seeds live in one call, per-seed curves to host;
      * `new_path_warm_s` — hoisted RNG plan + seed_chunk + on-device
        seed reduction (the execution layer's throughput configuration);
      * `inscan_chunked_warm_s` — the chunked scheduler with the legacy
        RNG plan, isolating how much of the win is the RNG plan vs the
        scheduler.
    """
    n, dim = LARGE["n"], LARGE["dim"]
    steps, seeds, chunk = LARGE["steps"], LARGE["seeds"], LARGE["chunk"]
    prob = MSDProblem.make(n, dim=dim)
    mc = prob.to_mc()
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0 / n)
    beta = 0.01

    mem_all_live = estimate_peak_bytes(
        n_rows=1, seeds=seeds, steps=steps, n_max=n, dim=dim,
        algo_set=("gbma",), seed_chunk=None)
    mem_chunked = estimate_peak_bytes(
        n_rows=1, seeds=seeds, steps=steps, n_max=n, dim=dim,
        algo_set=("gbma",), seed_chunk=chunk, keep_seed_curves=False)
    budget = MEM_BUDGET_GIB * 2**30
    fits_all_live = mem_all_live["device_peak_bytes"] <= budget

    def current_engine():
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      rng_plan="inscan").mean

    def new_path():
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      rng_plan="hoisted", seed_chunk=chunk,
                      keep_seed_curves=False).mean

    def inscan_chunked():
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      rng_plan="inscan", seed_chunk=chunk,
                      keep_seed_curves=False).mean

    # warm both compiles first, then INTERLEAVE the timed reps: on small
    # shared containers the machine's throughput drifts between runs, and
    # back-to-back blocks would charge that drift to whichever path ran
    # second — alternating reps pairs the noise instead
    mean_new = new_path()
    mean_cur = current_engine()
    t_new = t_cur = float("inf")
    for _ in range(warm_reps):
        t0 = time.perf_counter()
        current_engine()
        t_cur = min(t_cur, time.perf_counter() - t0)
        t0 = time.perf_counter()
        new_path()
        t_new = min(t_new, time.perf_counter() - t0)
    t_insc, _ = _warm(inscan_chunked, reps=1)
    return {
        "workload": {"problem": "msd_regression", "n_nodes": n, "dim": dim,
                     "steps": steps, "seeds": seeds, "seed_chunk": chunk,
                     "fading": "rayleigh",
                     "timing": "warm steady-state, best-of reps"},
        "current_engine_warm_s": round(t_cur, 3),
        "new_path_warm_s": round(t_new, 3),
        "inscan_chunked_warm_s": round(t_insc, 3),
        "warm_speedup": round(t_cur / t_new, 2),
        "new_path_warm_step_us": round(
            _warm_step_us(t_new, 1, steps, seeds), 3),
        "max_rel_curve_diff": _rel(mean_new, mean_cur),
        "memory_budget_gib": MEM_BUDGET_GIB,
        "fits_all_live": bool(fits_all_live),
        "all_live_est_bytes": int(mem_all_live["device_peak_bytes"]),
        "chunked_est_bytes": int(mem_chunked["device_peak_bytes"]),
        "runs_only_under_seed_chunk": bool(not fits_all_live),
    }


def bench_large_chunked_placed(warm_reps: int = 2) -> dict:
    """The placed execution-plan entry: the LARGE workload under
    `auto_plan` with placement ON (every visible device) vs forced OFF
    (`n_shards=0, row_shards=1` on the same plan), interleaved
    warm-for-warm, plus the auto plan's mean curve against the
    hand-tuned legacy-kwargs path (`seed_chunk=LARGE['chunk']`,
    `keep_seed_curves=False`).

    One process sees one device topology (XLA fixes it at startup), so
    the 1-device column comes from the default bench run and the
    4-device column from the CI multi-device smoke job
    (`XLA_FLAGS=--xla_force_host_platform_device_count=4`) — the
    `topology` field is what makes the two JSON artifacts comparable.
    On a single device the placed and unplaced plans coincide and their
    diff column is exactly 0.
    """
    n, dim = LARGE["n"], LARGE["dim"]
    steps, seeds = LARGE["steps"], LARGE["seeds"]
    prob = MSDProblem.make(n, dim=dim)
    mc = prob.to_mc()
    ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                       energy=1.0 / n)
    beta = 0.01
    plan = auto_plan(
        n_rows=1, seeds=seeds, steps=steps, n_max=n, dim=dim,
        keep_seed_curves=False,
        memory_budget_bytes=int(MEM_BUDGET_GIB * 2**30),
        target_chunk_bytes=AUTO_TARGET_CHUNK_BYTES)
    unplaced = plan.replace(n_shards=0, row_shards=1)

    def run_placed():
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      plan=plan).mean

    def run_unplaced():
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      plan=unplaced).mean

    def default_kwargs():
        # the behavior-pinned legacy path on the same workload (the
        # hand-tuned chunk from the large_chunked entry)
        return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                      seed_chunk=LARGE["chunk"],
                      keep_seed_curves=False, shard_seeds=False).mean

    # interleaved reps, same rationale as bench_large_chunked
    mean_placed = run_placed()
    mean_unplaced = run_unplaced()
    t_placed = t_unplaced = float("inf")
    for _ in range(warm_reps):
        t0 = time.perf_counter()
        run_unplaced()
        t_unplaced = min(t_unplaced, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_placed()
        t_placed = min(t_placed, time.perf_counter() - t0)
    mean_default = default_kwargs()

    # the measured cost model's plan for the same workload: with a
    # calibration artifact present it may re-chunk by predicted
    # wall-clock; absent one it must equal the analytic plan exactly
    # (behavior-pinned in tests/test_costmodel.py). When the plans
    # differ, time both interleaved so the record shows whether the
    # measured choice actually paid off.
    from repro.core.mc.costmodel import load_cost_model

    plan_measured = auto_plan(
        n_rows=1, seeds=seeds, steps=steps, n_max=n, dim=dim,
        keep_seed_curves=False,
        memory_budget_bytes=int(MEM_BUDGET_GIB * 2**30),
        target_chunk_bytes=AUTO_TARGET_CHUNK_BYTES,
        cost_model="measured")
    measured = {
        "calibration_found": load_cost_model() is not None,
        "plan": plan_measured.asdict(),
        "same_as_analytic": plan_measured == plan,
    }
    if plan_measured == plan:
        measured["measured_warm_s"] = round(t_placed, 3)
    else:
        def run_measured():
            return run_mc(mc, [ch], "gbma", [beta], steps, seeds,
                          plan=plan_measured).mean

        mean_measured = run_measured()
        t_meas = t_analytic = float("inf")
        for _ in range(warm_reps):
            t0 = time.perf_counter()
            run_placed()
            t_analytic = min(t_analytic, time.perf_counter() - t0)
            t0 = time.perf_counter()
            run_measured()
            t_meas = min(t_meas, time.perf_counter() - t0)
        measured["measured_warm_s"] = round(t_meas, 3)
        measured["analytic_warm_s"] = round(t_analytic, 3)
        measured["measured_vs_analytic_max_rel_diff"] = _rel(
            mean_measured, mean_placed)

    return {
        "workload": {"problem": "msd_regression", "n_nodes": n, "dim": dim,
                     "steps": steps, "seeds": seeds, "fading": "rayleigh",
                     "timing": "warm steady-state, best-of reps, "
                               "interleaved placed/unplaced"},
        "topology": _topology(plan, seeds),
        "placed_warm_s": round(t_placed, 3),
        "unplaced_warm_s": round(t_unplaced, 3),
        "placed_warm_step_us": round(
            _warm_step_us(t_placed, 1, steps, seeds), 3),
        "placed_vs_unplaced_max_rel_diff": _rel(mean_placed, mean_unplaced),
        "auto_vs_default_max_rel_diff": _rel(mean_placed, mean_default),
        "measured_plan": measured,
    }


def bench_train_100m_ota() -> dict:
    """Transport-layer exactness-vs-speed: one gbma slot on a
    training-shaped gradient pytree, untiled vs block-tiled vs
    bf16-transmit (see module docstring, workload 6). The tiled and bf16
    paths are compared value-wise against the untiled f32 reference —
    the columns the bench smoke asserts on."""
    from repro.core import transport

    n, d, block_d = TRAIN_OTA["n"], TRAIN_OTA["d"], TRAIN_OTA["block_d"]
    # transformer-ish leaf mix: one dominant embedding panel, two
    # projection-sized leaves, one tiny vector leaf (exercises blocks that
    # span a leaf, tile inside a leaf, and degenerate single-tile leaves)
    sizes = {"embed": d // 2, "attn": d // 4, "ffn": d // 4 - 128,
             "bias": 128}
    ks = jax.random.split(jax.random.key(0), len(sizes))
    grads = {name: jax.random.normal(k, (n, sz), jnp.float32)
             for (name, sz), k in zip(sizes.items(), ks)}
    ch = ChannelConfig(fading="rayleigh", noise_std=0.05, energy=1.0,
                       phase_error_max=0.3)
    slot_key = jax.random.key(1)

    def make(block, tx_dtype=None):
        cfg = transport.TransportConfig(n_nodes=n, channel=ch,
                                        block_d=block,
                                        transmit_dtype=tx_dtype)
        fn = jax.jit(
            lambda g, k: transport.aggregate("gbma", g, k, cfg)[0])
        return lambda: jax.block_until_ready(fn(grads, slot_key))

    t_untiled, v_untiled = _warm(make(transport.FULL_CONCAT))
    t_tiled, v_tiled = _warm(make(block_d))
    t_bf16, v_bf16 = _warm(make(block_d, "bfloat16"))

    def max_abs(a, b):
        return float(max(
            jnp.max(jnp.abs(x - y))
            for x, y in zip(jax.tree_util.tree_leaves(a),
                            jax.tree_util.tree_leaves(b))))

    return {
        "workload": {"aggregator": "gbma", "n_nodes": n, "total_d": d,
                     "block_d": block_d, "leaf_sizes": sizes,
                     "fading": "rayleigh",
                     "timing": "warm steady-state, best-of reps; one "
                               "aggregate() slot per call"},
        "untiled_warm_s": round(t_untiled, 4),
        "tiled_warm_s": round(t_tiled, 4),
        "bf16_tiled_warm_s": round(t_bf16, 4),
        "tiled_speedup_vs_untiled": round(t_untiled / t_tiled, 2),
        "bf16_speedup_vs_tiled": round(t_tiled / t_bf16, 2),
        "tiled_max_abs_diff": max_abs(v_tiled, v_untiled),
        "bf16_max_abs_diff": max_abs(v_bf16, v_untiled),
    }


def bench_serve_coalesce() -> dict:
    """The serving entry: the heterogeneous-N mix served per-request vs
    monolithically coalesced vs bucketed through the pad-waste-aware
    router. See module docstring, workload 8."""
    from repro.core.mc import MCProblemBatch
    from repro.serving.mc_server import (InlineExecutor, McSweepServer,
                                         McServeConfig, SweepRequest,
                                         serve_sync)

    probs = [MSDProblem.make(n) for n in SERVE_N_GRID]
    chs = [ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (-1.5)) for n in SERVE_N_GRID]
    betas = [stepsize_theorem1(p.pc, ch, n, safety=0.9)
             for p, ch, n in zip(probs, chs, SERVE_N_GRID)]
    mcs = [p.to_mc() for p in probs]
    reqs = [SweepRequest(problem=mc, channels=[ch], algo="gbma",
                         betas=[b], steps=STEPS, seeds=SEEDS)
            for mc, ch, b in zip(mcs, chs, betas)]
    cfg = McServeConfig(quantum_seeds=SEEDS)
    cfg_mono = McServeConfig(quantum_seeds=SEEDS, bucket_base=0)

    def per_request():
        # one dedicated call per client, same row-based path the server
        # uses — what K clients pay without a coalescing front-end
        return [run_mc(MCProblemBatch.stack([mc]), [ch], "gbma", [b],
                       STEPS, SEEDS, shard_seeds=False).mean[0]
                for mc, ch, b in zip(mcs, chs, betas)]

    def serve_on(server):
        return [r.mean[0] for r in serve_sync(reqs, server=server)]

    # cold: a FRESH bucketed server has seen no shape class, so the cost
    # model merges the whole signature group (compiles dominate) — the
    # one-compile coalescing story the cold column has always told
    t_per_cold, curves_per, compiles_per = _cold(per_request)
    t_co_cold, _, compiles_co = _cold(
        lambda: [r.mean[0] for r in serve_sync(reqs, cfg)])

    # warm: persistent servers. The bucketed router needs a few rounds
    # to reach steady state — first sight merges, then the measured
    # layout loop compiles + times the `merged` and `exact` layouts of
    # each bucket group once — so run untimed convergence passes until
    # its routing exploits the observations, and time THAT state (the
    # steady state a long-lived server actually serves)
    srv_bucketed = McSweepServer(cfg, executor=InlineExecutor())
    srv_mono = McSweepServer(cfg_mono, executor=InlineExecutor())
    for _ in range(5):
        serve_on(srv_bucketed)
    t_per_warm, _ = _warm(per_request)
    t_mono_warm, _ = _warm(lambda: serve_on(srv_mono))
    t_buck_warm, curves_buck = _warm(lambda: serve_on(srv_bucketed))

    # one extra (untimed) pass per server to capture its steady-state
    # batch layout and pad ratios
    n0 = len(srv_bucketed.stats.batches)
    serve_on(srv_bucketed)
    batches_warm = srv_bucketed.stats.batches[n0:]
    n0 = len(srv_mono.stats.batches)
    serve_on(srv_mono)
    mono_warm = srv_mono.stats.batches[n0:]

    rel = float(max(_rel(cb, cp)
                    for cb, cp in zip(curves_buck, curves_per)))
    return {
        "workload": {"problem": "msd_regression",
                     "n_grid": list(SERVE_N_GRID), "steps": STEPS,
                     "seeds": SEEDS, "fading": "rayleigh",
                     "requests": len(reqs),
                     "timing": "cold compiles included; warm is "
                               "steady-state best-of on persistent "
                               "servers (bucketed registry warm)"},
        "per_request_cold_s": round(t_per_cold, 4),
        "per_request_compiles": compiles_per,
        "coalesced_cold_s": round(t_co_cold, 4),
        "coalesced_compiles": compiles_co,
        "per_request_warm_s": round(t_per_warm, 4),
        "coalesced_warm_s": round(t_buck_warm, 4),
        "monolithic_warm_s": round(t_mono_warm, 4),
        "cold_speedup": round(t_per_cold / t_co_cold, 2),
        "warm_speedup": round(t_per_warm / t_buck_warm, 2),
        "monolithic_warm_speedup": round(t_per_warm / t_mono_warm, 2),
        "batches_warm": [
            {k: b[k] for k in ("rows", "n_max", "bucket", "layout",
                               "pad_flops_ratio")} for b in batches_warm],
        "layouts": dict(srv_bucketed.stats.layouts),
        "bucket_occupancy": {
            str(k): v for k, v
            in sorted(srv_bucketed.stats.bucket_occupancy.items())},
        "pad_flops_ratio": {
            "monolithic": max(b["pad_flops_ratio"] for b in mono_warm),
            "bucketed_max": max(b["pad_flops_ratio"]
                                for b in batches_warm),
        },
        "max_rel_curve_diff": rel,
    }


def _smoke_shrink():
    """CI-size constants: every path exercised, nothing slow."""
    global N, STEPS, SEEDS, SWEEP_N_GRID, SWEEP_M_GRID, SERVE_N_GRID, \
        LARGE, WARM_REPS, TRAIN_OTA, AUTO_TARGET_CHUNK_BYTES
    N, STEPS, SEEDS = 48, 40, 2
    SWEEP_N_GRID = (16, 25)
    SWEEP_M_GRID = (1, 3)
    # same two-bucket clustering as the full grid (×2 base: {6,8,7} -> 8,
    # {24,28,26} -> 32), CI-sized
    SERVE_N_GRID = (6, 8, 7, 24, 28, 26)
    LARGE = {"n": 256, "dim": 16, "steps": 30, "seeds": 16, "chunk": 4}
    TRAIN_OTA = {"n": 4, "d": 8192, "block_d": 2048}
    WARM_REPS = 2
    # CI-size seed counts fit the planner's 128 MiB default all-live;
    # shrink the target so the placed entry still exercises chunking
    AUTO_TARGET_CHUNK_BYTES = 256 * 1024


def run(verbose: bool = True, smoke: bool = False,
        write_bench: bool = True) -> list[str]:
    if smoke:
        _smoke_shrink()
    single = bench_single_config()
    sweep = bench_n_sweep()
    m_sweep = bench_m_sweep()
    frac_sweep = bench_frac_sweep()
    large = bench_large_chunked(warm_reps=1 if smoke else 3)
    placed = bench_large_chunked_placed(warm_reps=1 if smoke else 3)
    train_ota = bench_train_100m_ota()
    serve = bench_serve_coalesce()
    # every entry carries the topology it ran on; engine entries also
    # record the ExecPlan they resolved to (the kwargs entries ran under
    # the shim's behavior-pinned plans)
    single["topology"] = _topology(ExecPlan(), SEEDS)
    for entry in (sweep, m_sweep, frac_sweep):
        entry["topology"] = _topology(ExecPlan(), SEEDS)
    large["topology"] = _topology(
        ExecPlan(seed_chunk=LARGE["chunk"], keep_seed_curves=False),
        LARGE["seeds"])
    train_ota["topology"] = _topology()
    serve["topology"] = _topology(ExecPlan(), SEEDS)
    record = {
        **single,
        "n_sweep": sweep,
        "fig7_m_sweep": m_sweep,
        "fig8_frac_sweep": frac_sweep,
        "large_chunked": large,
        "large_chunked_placed": placed,
        "train_100m_ota": train_ota,
        "serve_coalesce": serve,
        "timing_methodology": {
            "cold": "jit cache cleared, one call, compiles included",
            "warm": f"best of {WARM_REPS} after one untimed warm-up",
        },
        "smoke": smoke,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
    }
    # the tracked full-scale record is only rewritten by an explicit
    # request (direct module invocation, or `benchmarks.run
    # --write-bench`); everything else — smoke AND unflagged figure-
    # driving runs through `benchmarks.run` — lands on the smoke path
    out_path = OUT_PATH if (write_bench and not smoke) else SMOKE_OUT_PATH
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    rows = [
        f"bench_montecarlo,seed_loop_s,{single['seed_loop_s']:.4f}",
        f"bench_montecarlo,engine_s,{single['engine_s']:.4f}",
        f"bench_montecarlo,engine_cold_s,{single['engine_cold_s']:.4f}",
        f"bench_montecarlo,speedup,{single['speedup']:.2f}",
        f"bench_montecarlo,max_rel_curve_diff,"
        f"{single['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,n_sweep_per_n_s,{sweep['per_n_compile_s']:.4f}"
        f",compiles={sweep['per_n_compiles']}",
        f"bench_montecarlo,n_sweep_one_compile_s,{sweep['one_compile_s']:.4f}"
        f",compiles={sweep['one_compile_compiles']}",
        f"bench_montecarlo,n_sweep_warm_s,{sweep['one_compile_warm_s']:.4f}",
        f"bench_montecarlo,n_sweep_speedup,{sweep['speedup']:.2f}",
        f"bench_montecarlo,fig7_m_sweep_speedup,{m_sweep['speedup']:.2f}",
        f"bench_montecarlo,fig7_m_sweep_warm_s,"
        f"{m_sweep['one_compile_warm_s']:.4f}",
        f"bench_montecarlo,fig8_frac_sweep_speedup,"
        f"{frac_sweep['speedup']:.2f}",
        f"bench_montecarlo,fig8_frac_sweep_warm_s,"
        f"{frac_sweep['one_compile_warm_s']:.4f}",
        f"bench_montecarlo,large_current_engine_warm_s,"
        f"{large['current_engine_warm_s']:.3f}",
        f"bench_montecarlo,large_new_path_warm_s,"
        f"{large['new_path_warm_s']:.3f}",
        f"bench_montecarlo,large_warm_speedup,{large['warm_speedup']:.2f}",
        f"bench_montecarlo,large_max_rel_curve_diff,"
        f"{large['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,large_runs_only_under_seed_chunk,"
        f"{int(large['runs_only_under_seed_chunk'])}",
        f"bench_montecarlo,large_placed_warm_s,"
        f"{placed['placed_warm_s']:.3f}",
        f"bench_montecarlo,large_unplaced_warm_s,"
        f"{placed['unplaced_warm_s']:.3f}",
        f"bench_montecarlo,large_placed_n_shards,"
        f"{placed['topology']['n_shards']}",
        f"bench_montecarlo,large_placed_vs_unplaced_max_rel_diff,"
        f"{placed['placed_vs_unplaced_max_rel_diff']:.2e}",
        f"bench_montecarlo,large_auto_vs_default_max_rel_diff,"
        f"{placed['auto_vs_default_max_rel_diff']:.2e}",
        f"bench_montecarlo,train_ota_untiled_warm_s,"
        f"{train_ota['untiled_warm_s']:.4f}",
        f"bench_montecarlo,train_ota_tiled_warm_s,"
        f"{train_ota['tiled_warm_s']:.4f}",
        f"bench_montecarlo,train_ota_bf16_warm_s,"
        f"{train_ota['bf16_tiled_warm_s']:.4f}",
        f"bench_montecarlo,train_ota_tiled_max_abs_diff,"
        f"{train_ota['tiled_max_abs_diff']:.2e}",
        f"bench_montecarlo,train_ota_bf16_max_abs_diff,"
        f"{train_ota['bf16_max_abs_diff']:.2e}",
        f"bench_montecarlo,serve_per_request_cold_s,"
        f"{serve['per_request_cold_s']:.4f}"
        f",compiles={serve['per_request_compiles']}",
        f"bench_montecarlo,serve_coalesced_cold_s,"
        f"{serve['coalesced_cold_s']:.4f}"
        f",compiles={serve['coalesced_compiles']}",
        f"bench_montecarlo,serve_per_request_warm_s,"
        f"{serve['per_request_warm_s']:.4f}",
        f"bench_montecarlo,serve_coalesced_warm_s,"
        f"{serve['coalesced_warm_s']:.4f}",
        f"bench_montecarlo,serve_monolithic_warm_s,"
        f"{serve['monolithic_warm_s']:.4f}",
        f"bench_montecarlo,serve_warm_speedup,{serve['warm_speedup']:.2f}",
        f"bench_montecarlo,serve_monolithic_warm_speedup,"
        f"{serve['monolithic_warm_speedup']:.2f}",
        f"bench_montecarlo,serve_pad_flops_ratio,"
        f"monolithic={serve['pad_flops_ratio']['monolithic']},"
        f"bucketed_max={serve['pad_flops_ratio']['bucketed_max']}",
        f"bench_montecarlo,serve_max_rel_curve_diff,"
        f"{serve['max_rel_curve_diff']:.2e}",
        f"bench_montecarlo,measured_plan_same_as_analytic,"
        f"{int(placed['measured_plan']['same_as_analytic'])}"
        f",calibration_found="
        f"{int(placed['measured_plan']['calibration_found'])}",
        f"bench_montecarlo,json,{out_path}",
    ]
    if verbose:
        print("\n".join(rows))
    if smoke:
        _smoke_assert(record)
    return rows


def _smoke_assert(record: dict) -> None:
    """The CI contract: warm step time is finite and the one-compile /
    chunked curves match their references."""
    problems = []
    for key, warm in (
        ("single", record["engine_s"]),
        ("n_sweep", record["n_sweep"]["one_compile_warm_s"]),
        ("fig7_m_sweep", record["fig7_m_sweep"]["one_compile_warm_s"]),
        ("fig8_frac_sweep", record["fig8_frac_sweep"]["one_compile_warm_s"]),
        ("large_chunked", record["large_chunked"]["new_path_warm_s"]),
        ("large_chunked_placed",
         record["large_chunked_placed"]["placed_warm_s"]),
        ("large_chunked_placed_unplaced",
         record["large_chunked_placed"]["unplaced_warm_s"]),
        ("train_100m_ota", record["train_100m_ota"]["tiled_warm_s"]),
        ("train_100m_ota_bf16",
         record["train_100m_ota"]["bf16_tiled_warm_s"]),
        ("serve_coalesce", record["serve_coalesce"]["coalesced_warm_s"]),
        ("serve_coalesce_per_request",
         record["serve_coalesce"]["per_request_warm_s"]),
    ):
        if not (np.isfinite(warm) and warm > 0):
            problems.append(f"{key}: warm time {warm!r} not finite/positive")
    ota = record["train_100m_ota"]
    if not ota["tiled_max_abs_diff"] <= 1e-6:
        problems.append(
            f"train_100m_ota: tiled deviates from untiled by "
            f"{ota['tiled_max_abs_diff']:.2e} > 1e-6 (must be f32-ulp)")
    if not 0 < ota["bf16_max_abs_diff"] <= 0.05:
        problems.append(
            f"train_100m_ota: bf16-transmit deviation "
            f"{ota['bf16_max_abs_diff']:.2e} outside (0, 0.05] — expected "
            "quantization-sized, nonzero")
    for key, rel, tol in (
        ("single", record["max_rel_curve_diff"], 1e-4),
        ("n_sweep", record["n_sweep"]["max_rel_curve_diff"], 1e-5),
        ("fig7_m_sweep", record["fig7_m_sweep"]["max_rel_curve_diff"], 1e-5),
        ("fig8_frac_sweep",
         record["fig8_frac_sweep"]["max_rel_curve_diff"], 1e-4),
        ("large_chunked",
         record["large_chunked"]["max_rel_curve_diff"], 1e-5),
        ("large_chunked_placed (placement invariance)",
         record["large_chunked_placed"]["placed_vs_unplaced_max_rel_diff"],
         1e-6),
        ("large_chunked_placed (auto vs default kwargs)",
         record["large_chunked_placed"]["auto_vs_default_max_rel_diff"],
         1e-6),
    ):
        if not rel <= tol:
            problems.append(f"{key}: max_rel_curve_diff {rel:.2e} > {tol}")
    serve = record["serve_coalesce"]
    if serve["coalesced_compiles"] != 1:
        problems.append(
            f"serve_coalesce: {serve['coalesced_compiles']} compiles for "
            "one signature-compatible request set — first-sight "
            "coalescing must pay exactly one compile")
    if not serve["max_rel_curve_diff"] <= 1e-6:
        problems.append(
            f"serve_coalesce: demuxed curves deviate from dedicated calls "
            f"by {serve['max_rel_curve_diff']:.2e} > 1e-6")
    if not serve["warm_speedup"] >= 1.0:
        problems.append(
            f"serve_coalesce: bucketed warm {serve['warm_speedup']}x < "
            "1.0x vs per-request — the pad-waste-aware router must not "
            "regress below dedicated calls")
    if not serve["pad_flops_ratio"]["bucketed_max"] \
            <= serve["pad_flops_ratio"]["monolithic"] + 1e-9:
        problems.append(
            f"serve_coalesce: bucketed pad ratio "
            f"{serve['pad_flops_ratio']['bucketed_max']} exceeds the "
            f"monolithic one {serve['pad_flops_ratio']['monolithic']}")
    measured = record["large_chunked_placed"]["measured_plan"]
    if not measured["calibration_found"] and \
            not measured["same_as_analytic"]:
        problems.append(
            "large_chunked_placed: cost_model='measured' deviated from "
            "the analytic plan with NO calibration artifact present — "
            "the behavior pin requires exact fallback")
    if not (np.isfinite(measured["measured_warm_s"])
            and measured["measured_warm_s"] > 0):
        problems.append(
            f"large_chunked_placed: measured-plan warm time "
            f"{measured['measured_warm_s']!r} not finite/positive")
    if problems:
        print("SMOKE FAILURES:\n  " + "\n  ".join(problems),
              file=sys.stderr)
        raise SystemExit(1)
    print("bench smoke: all warm timings finite, curves within tolerance")


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
