"""Paper Fig. 4: GBMA vs FDM-GD vs centralized GD, N=800, Rayleigh fading.

Operating points follow the paper: GBMA at E_N = N^{-1.5} (the paper's
-50 dB regime), FDM-GD over dedicated fading channels at E_N = 1 (the -6 dB
regime). Claim reproduced: GBMA reaches an error comparable to (or better
than) FDM-GD while its TOTAL transmitted energy is N^{1.5} ~ 4.5 orders of
magnitude smaller. All three algorithms run as one engine call (per-row
`algo`), i.e. a single `_mc_core` compile."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

N = 800
STEPS = 300
SEEDS = 4
SMOKE_COMPILES = 1  # engine compiles per run(), asserted by the smoke test


def run(verbose: bool = True) -> list[str]:
    rows = []
    prob = MSDProblem.make(N)
    mc = prob.to_mc()
    ch_gbma = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                            energy=float(N) ** (-1.5))
    # FDM: dedicated fading channel per node (no inversion, as described in
    # the paper's comparison), unit energy
    ch_fdm = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=1.0)
    beta = stepsize_theorem1(prob.pc, ch_gbma, N, safety=0.9)

    res = run_mc(mc, [ch_gbma, ch_fdm, ch_gbma],
                 ("gbma", "fdm", "centralized"),
                 [beta, beta, beta * ch_gbma.mu_h], STEPS, SEEDS,
                 invert_channel=False)
    emp_g, emp_f, emp_c = res.mean

    # total per-slot transmitted energy at theta_0: sum_n E_N ||g_n||^2
    g0 = np.asarray(mc.grad_fn(jnp.zeros(prob.pc.dim)))
    e_gbma = ch_gbma.energy * float(np.sum(g0**2))
    e_fdm = ch_fdm.energy * float(np.sum(g0**2))
    rows.append(f"fig4,energy_per_slot,gbma,{e_gbma:.4e}")
    rows.append(f"fig4,energy_per_slot,fdm,{e_fdm:.4e}")
    rows.append(f"fig4,energy_ratio_fdm_over_gbma,{e_fdm / e_gbma:.4e}")
    rows.append(f"fig4,final_excess,gbma,{emp_g[-1]:.6e}")
    rows.append(f"fig4,final_excess,fdm,{emp_f[-1]:.6e}")
    rows.append(f"fig4,final_excess,centralized,{emp_c[-1]:.6e}")
    rows.append(f"fig4,gbma_comparable_or_better,"
                f"{int(emp_g[-1] <= 1.5 * emp_f[-1])}")
    rows.append(f"fig4,gbma_energy_saving_over_1e4,"
                f"{int(e_fdm / e_gbma > 1e4)}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
