"""Paper Fig. 2: federated MSD-like regression, EQUAL channel gains.
(a) error vs iterations for N in logspace; (b) error for E_N = N^{eps-2}.
Empirical curves are overlaid with the Theorem 1 bound. All Monte Carlo
trajectories run through the batched engine (`repro.core.montecarlo`)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

STEPS = 300
SEEDS = 4


def run(verbose: bool = True) -> list[str]:
    rows = []
    # ---- (a) varying N at E_N = 1: one compile per N (shapes differ) ------
    for n in (50, 160, 500):
        prob = MSDProblem.make(n)
        ch = ChannelConfig(fading="equal", scale=1.0, noise_std=1.0,
                           energy=1.0)
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        res = run_mc(prob.to_mc(), [ch], "gbma", [beta], STEPS, SEEDS,
                     pc=prob.pc)
        emp, bound = res.mean[0], res.bounds[0]
        rows.append(f"fig2a,N={n},final_emp,{emp[-1]:.6e}")
        rows.append(f"fig2a,N={n},final_bound,{bound[-1]:.6e}")
        rows.append(f"fig2a,N={n},bound_holds,{int(np.all(emp <= bound * 1.05))}")
    # ---- (b) E_N = N^{eps-2} at N = 500: one vmapped call over energies ---
    n = 500
    prob = MSDProblem.make(n)
    eps_grid = (0.5, 1.0, 1.5)
    chs = [ChannelConfig(fading="equal", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (eps - 2.0)) for eps in eps_grid]
    betas = [stepsize_theorem1(prob.pc, ch, n, safety=0.9) for ch in chs]
    res = run_mc(prob.to_mc(), chs, "gbma", betas, STEPS, SEEDS, pc=prob.pc)
    for i, eps in enumerate(eps_grid):
        rows.append(f"fig2b,eps={eps},final_emp,{res.mean[i][-1]:.6e}")
        rows.append(f"fig2b,eps={eps},final_bound,{res.bounds[i][-1]:.6e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
