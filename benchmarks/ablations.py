"""Beyond-paper ablations of the GBMA channel model:

  (a) residual phase-error sweep — paper §III claims correction error < π/4
      keeps a positive-mean effective gain; we sweep φ_max through and past
      π/4 and measure convergence.
  (b) fading-family sweep — the theory only needs (μ_h, σ_h²); Rician and
      lognormal channels should behave per their dispersion index D=σ²/μ.
  (c) power-control OTA (CA-DSGD-style truncated channel inversion, related
      work [11]) vs GBMA at the same per-node energy — what the paper's
      "no power control" choice costs/gains.
  (d) multi-antenna edge receiver (related work [12]): the fading-distortion
      floor should fall as 1/M with M receive antennas.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.baselines import PowerControlOTA
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.theory import stepsize_theorem1

N = 200
STEPS = 300
SEEDS = 3


def _excess(prob, runner):
    def one(key):
        traj = runner.run(jnp.zeros(prob.pc.dim), STEPS, key)
        return prob.excess_risk(traj)

    return average_runs(one, SEEDS)


def run(verbose: bool = True) -> list[str]:
    rows = []
    prob = MSDProblem.make(N)

    # ---- (a) phase-error sweep ------------------------------------------
    for frac in (0.0, 0.125, 0.25, 0.4, 0.49):
        
        phi = frac * np.pi  # phi_max up to ~pi/2
        ch = ChannelConfig(fading="rayleigh", noise_std=0.5,
                           phase_error_max=max(phi, 1e-9))
        beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
        emp = _excess(prob, GBMASimulator(prob.grad_fn(), ch, beta))
        rows.append(f"ablation_phase,phi_max={phi:.3f}rad,mu_h={ch.mu_h:.3f},"
                    f"final={emp[-1]:.4e}")

    # ---- (b) fading families ---------------------------------------------
    for fading, kw in (("equal", {}), ("rayleigh", {}),
                       ("rician", {"rician_k": 4.0}),
                       ("lognormal", {"scale": 0.5})):
        ch = ChannelConfig(fading=fading, noise_std=0.5, **kw)
        beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
        emp = _excess(prob, GBMASimulator(prob.grad_fn(), ch, beta))
        rows.append(f"ablation_fading,{fading},D={ch.dispersion:.3f},"
                    f"final={emp[-1]:.4e}")

    # ---- (c) power-control OTA vs GBMA at equal energy --------------------
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5,
                       energy=float(N) ** (-1.0))
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    emp_g = _excess(prob, GBMASimulator(prob.grad_fn(), ch, beta))
    emp_p = _excess(prob, PowerControlOTA(prob.grad_fn(), ch,
                                          beta * ch.mu_h, h_min=0.3))
    rows.append(f"ablation_powerctl,gbma,final={emp_g[-1]:.4e}")
    rows.append(f"ablation_powerctl,truncated_inversion,final={emp_p[-1]:.4e}")

    # ---- (d) multi-antenna edge --------------------------------------------
    import dataclasses as _dc
    import jax as _jax
    from repro.core.gbma import ota_aggregate_multiantenna

    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    gfn = prob.grad_fn()
    pc = prob.pc
    for m_ant in (1, 4, 16):
        # fair comparison: each M uses the Theorem-1 stepsize designed for
        # its effective distortion sigma_h^2 / M (larger M -> larger beta)
        sh2 = ch.sigma_h2 / m_ant
        b1 = 2.0 / (ch.mu_h * (pc.mu + pc.L))
        b2 = (2.0 * ch.mu_h * pc.mu * pc.L * N
              / (sh2 * pc.L_bar**2 * (1.0 + 2.0 * pc.delta)
                 * (pc.mu + pc.L)))
        beta = 0.8 * min(b1, b2)

        def run_one(key, m_ant=m_ant, beta=beta):
            def body(theta, k):
                v = ota_aggregate_multiantenna(gfn(theta), k, ch, m_ant)
                return theta - beta * v, theta

            keys = _jax.random.split(key, 2 * STEPS)
            theta_fin, traj = _jax.lax.scan(body, jnp.zeros(prob.pc.dim),
                                            keys)
            import numpy as _np
            return prob.excess_risk(_np.concatenate(
                [_np.asarray(traj), _np.asarray(theta_fin)[None]]))

        emp = average_runs(run_one, SEEDS)
        rows.append(f"ablation_antennas,M={m_ant},final={emp[-1]:.4e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
