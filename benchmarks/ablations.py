"""Beyond-paper ablations of the GBMA channel model:

  (a) residual phase-error sweep — paper §III claims correction error < π/4
      keeps a positive-mean effective gain; we sweep φ_max through and past
      π/4 and measure convergence.
  (b) fading-family sweep — the theory only needs (μ_h, σ_h²); Rician and
      lognormal channels should behave per their dispersion index D=σ²/μ.
  (c) power-control OTA (CA-DSGD-style truncated channel inversion, related
      work [11]) vs GBMA at the same per-node energy — what the paper's
      "no power control" choice costs/gains.
  (d) multi-antenna edge receiver (related work [12]): the fading-distortion
      floor should fall as 1/M with M receive antennas.
  (e) accelerated GD over the MAC (Paul, Friedman & Cohen 2021): heavy-ball
      and Nesterov momentum on the same OTA superposition, vs vanilla GBMA
      at the same stepsize — the engine's `algo="momentum"/"nesterov"`
      scan-carry variants, swept over the momentum coefficient γ.
  (f) blind transmitters (Amiri, Duman & Gündüz): sweep the `blind_ec`
      per-node power budget through binding territory — the local error
      accumulation carries the truncated mass forward, so convergence
      degrades gracefully instead of stalling.
  (g) partial participation — each slot every node independently
      transmits with probability p (the unreliable-node setting of the
      federated OTA literature); the OTA sum loses mass but also noise
      averaging, so convergence degrades smoothly with p.

Every sweep runs through the Monte Carlo engine. (a) is a single vmapped
call over the five phase configs — a one-config-list change, no new loop
code; (b) needs one call per fading family (the family is a static compile
choice); (d) uses the engine's `n_antennas`; (e) batches the three
algorithms per-row in one compile; (f) batches the budgets per-row (the
budget is data) in one compile; (g) batches the participation
probabilities per-row (p is data behind one static mask flag) in one
compile.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

N = 200
STEPS = 300
SEEDS = 3


def run(verbose: bool = True) -> list[str]:
    rows = []
    prob = MSDProblem.make(N)
    mc = prob.to_mc()

    # ---- (a) phase-error sweep: one batched engine call -------------------
    phis = [max(frac * np.pi, 1e-9)
            for frac in (0.0, 0.125, 0.25, 0.4, 0.49)]
    chs = [ChannelConfig(fading="rayleigh", noise_std=0.5,
                         phase_error_max=phi) for phi in phis]
    betas = [stepsize_theorem1(prob.pc, ch, N, safety=0.8) for ch in chs]
    res = run_mc(mc, chs, "gbma", betas, STEPS, SEEDS)
    for ch, phi, emp in zip(chs, phis, res.mean):
        rows.append(f"ablation_phase,phi_max={phi:.3f}rad,mu_h={ch.mu_h:.3f},"
                    f"final={emp[-1]:.4e}")

    # ---- (b) fading families (one compile per family) ---------------------
    for fading, kw in (("equal", {}), ("rayleigh", {}),
                       ("rician", {"rician_k": 4.0}),
                       ("lognormal", {"scale": 0.5})):
        ch = ChannelConfig(fading=fading, noise_std=0.5, **kw)
        beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
        emp = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS).mean[0]
        rows.append(f"ablation_fading,{fading},D={ch.dispersion:.3f},"
                    f"final={emp[-1]:.4e}")

    # ---- (c) power-control OTA vs GBMA at equal energy --------------------
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5,
                       energy=float(N) ** (-1.0))
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    emp_g = run_mc(mc, [ch], "gbma", [beta], STEPS, SEEDS).mean[0]
    emp_p = run_mc(mc, [ch], "power_control", [beta * ch.mu_h], STEPS, SEEDS,
                   h_min=0.3).mean[0]
    rows.append(f"ablation_powerctl,gbma,final={emp_g[-1]:.4e}")
    rows.append(f"ablation_powerctl,truncated_inversion,final={emp_p[-1]:.4e}")

    # ---- (d) multi-antenna edge -------------------------------------------
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    pc = prob.pc
    for m_ant in (1, 4, 16):
        # fair comparison: each M uses the Theorem-1 stepsize designed for
        # its effective distortion sigma_h^2 / M (larger M -> larger beta)
        sh2 = ch.sigma_h2 / m_ant
        b1 = 2.0 / (ch.mu_h * (pc.mu + pc.L))
        b2 = (2.0 * ch.mu_h * pc.mu * pc.L * N
              / (sh2 * pc.L_bar**2 * (1.0 + 2.0 * pc.delta)
                 * (pc.mu + pc.L)))
        beta = 0.8 * min(b1, b2)
        emp = run_mc(mc, [ch], "gbma", [beta], 2 * STEPS, SEEDS,
                     n_antennas=m_ant).mean[0]
        rows.append(f"ablation_antennas,M={m_ant},final={emp[-1]:.4e}")

    # ---- (e) accelerated GD over the MAC (momentum / Nesterov) ------------
    # one engine call per γ: vanilla + heavy-ball + Nesterov batched per-row
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    for gamma in (0.5, 0.9):
        res = run_mc(mc, [ch, ch, ch], ("gbma", "momentum", "nesterov"),
                     # heavy-ball/Nesterov apply β to the momentum sum
                     # Σ γ^j v: rescale by (1-γ) to match vanilla's
                     # effective per-step magnitude
                     [beta, beta * (1 - gamma), beta * (1 - gamma)],
                     STEPS, SEEDS, momentum=gamma)
        for a, emp in zip(("gbma", "momentum", "nesterov"), res.mean):
            rows.append(f"ablation_accel,gamma={gamma},{a},"
                        f"final={emp[-1]:.4e}")

    # ---- (f) blind transmitters: power budget vs error accumulation -------
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5, energy=1.0 / N)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8) * ch.mu_h
    ref_sq = float(np.mean(np.sum(
        np.asarray(mc.grad_fn(jnp.zeros(mc.dim, jnp.float32))) ** 2,
        axis=1)))
    fracs = (np.inf, 1.0, 0.25, 0.05)  # budget / initial mean ||g_n||²
    algos = tuple("blind" if not np.isfinite(f) else "blind_ec"
                  for f in fracs)
    budgets = [float(f) * ref_sq if np.isfinite(f) else float("inf")
               for f in fracs]
    res = run_mc(mc, [ch] * len(fracs), algos, [beta] * len(fracs), STEPS,
                 SEEDS, n_antennas=16, power_budget=budgets)
    for f, emp in zip(fracs, res.mean):
        label = "inf(blind)" if not np.isfinite(f) else f"{f:g}"
        rows.append(f"ablation_blind_budget,frac={label},"
                    f"final={emp[-1]:.4e}")
    # ---- (g) partial participation: per-row p sweep, one compile ----------
    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.8)
    ps = (1.0, 0.9, 0.7, 0.5, 0.3)
    res = run_mc(mc, [ch] * len(ps), "gbma", [beta] * len(ps), STEPS,
                 SEEDS, participation=list(ps))
    for p, emp in zip(ps, res.mean):
        rows.append(f"ablation_participation,p={p:g},"
                    f"final={emp[-1]:.4e}")

    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
