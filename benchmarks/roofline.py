"""Roofline tables.

1. Training-substrate roofline: reads the dry-run JSON records and renders
   the per-(arch x shape x mesh) three-term roofline with dominant
   bottleneck and useful-compute ratio (EXPERIMENTS.md §Roofline).

2. Monte Carlo slot roofline (`--mc`, also appended to `run()` when
   `BENCH_montecarlo.json` exists): an analytic bytes/FLOPs-per-slot model
   of the gbma and blind slot paths, printed next to the MEASURED warm
   step times from `benchmarks/BENCH_montecarlo.json`, with machine peaks
   microbenchmarked in-process (a big f32 matmul for FLOP/s, a big copy
   for bandwidth) — so the bench output shows distance-from-roofline.
   Methodology notes in docs/performance.md.
"""
from __future__ import annotations

import json
import os
import sys

BENCH_JSON = os.path.join(os.path.dirname(__file__),
                          "BENCH_montecarlo.json")


def render(path: str) -> list[str]:
    with open(path) as f:
        records = json.load(f)
    rows = ["arch,shape,mesh,status,compute_s,memory_s,collective_s,"
            "dominant,model_flops,hlo_flops,useful_ratio,args_GiB,temp_GiB"]
    for r in records:
        if r["status"] != "ok":
            rows.append(f"{r['arch']},{r['shape']},{r['mesh']},"
                        f"{r['status']}:{r.get('reason', r.get('error', ''))[:60]}"
                        ",,,,,,,,")
            continue
        t = r["roofline"]
        m = r["memory"]
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
            f"{t['collective_s']:.3e},{t['dominant']},"
            f"{t['model_flops']:.3e},{t['hlo_flops']:.3e},"
            f"{t['useful_ratio']:.2f},"
            f"{m.get('argument_bytes', 0)/2**30:.2f},"
            f"{m.get('temp_bytes', 0)/2**30:.2f}")
    return rows


# --------------------------------------------------------------------------
# Monte Carlo slot roofline
# --------------------------------------------------------------------------
def mc_slot_model(algo: str, n: int, d: int, m: int = 1) -> dict:
    """The analytic per-slot cost model — now owned by
    `repro.core.mc.costmodel` (the calibration suite fits measured step
    times against its FLOP counts); this delegate keeps the roofline's
    public surface."""
    from repro.core.mc.costmodel import mc_slot_model as _model

    return _model(algo, n, d, m)


def machine_peaks(dim: int = 1536, reps: int = 3) -> dict:
    """Microbenchmarked machine peaks (f32 matmul GFLOP/s + big-copy
    GiB/s), served through the calibration artifact: a platform/device-
    count entry that already holds peaks is reused instead of
    re-measuring on every roofline/bench invocation
    (`costmodel.cached_machine_peaks`)."""
    from repro.core.mc.costmodel import cached_machine_peaks

    return cached_machine_peaks(dim=dim, reps=reps)


def _mc_entry_rows(label: str, algo: str, n: int, d: int, m: int,
                   warm_step_us: float, peaks: dict) -> list[str]:
    model = mc_slot_model(algo, n, d, m)
    step_s = warm_step_us * 1e-6
    achieved_gflops = model["flops"] / step_s / 1e9
    achieved_gibs = model["bytes"] / step_s / 2**30
    # the memory-side roofline bound at this intensity; the chunked
    # execution layer keeps per-step working sets near cache, so running
    # ABOVE the big-copy (DRAM-ish) roofline is the expected signature of
    # a cache-resident slot — report the regime instead of a >100% figure
    mem_bound = model["intensity"] * peaks["peak_gibs"] * 2**30 / 1e9
    bound_gflops = min(peaks["peak_gflops"], mem_bound)
    ratio = achieved_gflops / bound_gflops
    if ratio > 1.0:
        regime = "cache-resident (above the copy roofline)"
    elif mem_bound < peaks["peak_gflops"]:
        regime = f"memory-bound, {100 * ratio:.1f}% of roofline"
    else:
        regime = f"compute-bound, {100 * ratio:.1f}% of roofline"
    return [
        f"roofline_mc,{label},algo={algo},N={n},d={d},M={m},"
        f"flops_per_slot={model['flops']},bytes_per_slot={model['bytes']},"
        f"intensity={model['intensity']:.2f}",
        f"roofline_mc,{label},warm_step_us={warm_step_us:.2f},"
        f"achieved_gflops={achieved_gflops:.2f},"
        f"achieved_gibs={achieved_gibs:.2f},"
        f"roofline_bound_gflops={bound_gflops:.2f},"
        f"vs_roofline={ratio:.2f}x,regime={regime}",
    ]


def mc_run(verbose: bool = True) -> list[str]:
    """The MC slot roofline: model + measured warm step time per bench
    workload with a warm entry, against microbenchmarked peaks."""
    if not os.path.exists(BENCH_JSON):
        rows = [f"# {BENCH_JSON} missing - run "
                "`python -m benchmarks.bench_montecarlo` first"]
        if verbose:
            print("\n".join(rows))
        return rows
    with open(BENCH_JSON) as f:
        rec = json.load(f)
    peaks = machine_peaks()
    rows = [f"roofline_mc,machine,peak_gflops={peaks['peak_gflops']:.2f},"
            f"peak_gibs={peaks['peak_gibs']:.2f}"]
    wl = rec.get("workload", {})
    if "engine_warm_step_us" in rec and "dim" in wl:
        rows += _mc_entry_rows(
            "single_config", "gbma", wl["n_nodes"], wl["dim"], 1,
            rec["engine_warm_step_us"], peaks)
    large = rec.get("large_chunked")
    if large and "new_path_warm_step_us" in large:
        lwl = large["workload"]
        rows += _mc_entry_rows(
            "large_chunked", "gbma", lwl["n_nodes"], lwl["dim"], 1,
            large["new_path_warm_step_us"], peaks)
    placed = rec.get("large_chunked_placed")
    if placed and "placed_warm_step_us" in placed:
        pwl = placed["workload"]
        rows += _mc_entry_rows(
            "large_chunked_placed", "gbma", pwl["n_nodes"], pwl["dim"], 1,
            placed["placed_warm_step_us"], peaks)
        topo = placed.get("topology", {})
        rows.append(
            f"roofline_mc,large_chunked_placed,"
            f"devices={topo.get('device_count', 1)},"
            f"n_shards={topo.get('n_shards', 0)},"
            f"placed_warm_s={placed.get('placed_warm_s')},"
            f"unplaced_warm_s={placed.get('unplaced_warm_s')}")
    m_sweep = rec.get("fig7_m_sweep")
    if m_sweep and "one_compile_warm_step_us" in m_sweep \
            and "dim" in m_sweep["workload"]:
        mwl = m_sweep["workload"]
        m_mean = round(sum(mwl["m_grid"]) / len(mwl["m_grid"]))
        rows += _mc_entry_rows(
            "fig7_m_sweep", "blind", mwl["n_nodes"], mwl["dim"], m_mean,
            m_sweep["one_compile_warm_step_us"], peaks)
    if verbose:
        print("\n".join(rows))
    return rows


def run(verbose: bool = True) -> list[str]:
    rows = []
    for path in ("results/dryrun_pod.json", "results/dryrun_multipod.json",
                 "results/dryrun_pod_v2.json",
                 "results/dryrun_multipod_v2.json",
                 "results/opt_minitron.json", "results/opt_llama4.json",
                 "results/opt_deepseek.json"):
        if os.path.exists(path):
            rows.append(f"# {path}")
            rows.extend(render(path))
        elif "v2" not in path and "opt_" not in path:
            rows.append(f"# {path} missing - run "
                        f"`python -m repro.launch.dryrun --all --out {path}`")
    if os.path.exists(BENCH_JSON):
        rows.extend(mc_run(verbose=False))
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    if "--mc" in sys.argv[1:]:
        mc_run()
    else:
        run()
