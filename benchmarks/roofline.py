"""Roofline table builder: reads the dry-run JSON records and renders the
per-(arch x shape x mesh) three-term roofline with dominant bottleneck and
useful-compute ratio (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import sys


def render(path: str) -> list[str]:
    with open(path) as f:
        records = json.load(f)
    rows = ["arch,shape,mesh,status,compute_s,memory_s,collective_s,"
            "dominant,model_flops,hlo_flops,useful_ratio,args_GiB,temp_GiB"]
    for r in records:
        if r["status"] != "ok":
            rows.append(f"{r['arch']},{r['shape']},{r['mesh']},"
                        f"{r['status']}:{r.get('reason', r.get('error', ''))[:60]}"
                        ",,,,,,,,")
            continue
        t = r["roofline"]
        m = r["memory"]
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
            f"{t['collective_s']:.3e},{t['dominant']},"
            f"{t['model_flops']:.3e},{t['hlo_flops']:.3e},"
            f"{t['useful_ratio']:.2f},"
            f"{m.get('argument_bytes', 0)/2**30:.2f},"
            f"{m.get('temp_bytes', 0)/2**30:.2f}")
    return rows


def run(verbose: bool = True) -> list[str]:
    import os

    rows = []
    for path in ("results/dryrun_pod.json", "results/dryrun_multipod.json",
                 "results/dryrun_pod_v2.json",
                 "results/dryrun_multipod_v2.json",
                 "results/opt_minitron.json", "results/opt_llama4.json",
                 "results/opt_deepseek.json"):
        if os.path.exists(path):
            rows.append(f"# {path}")
            rows.extend(render(path))
        elif "v2" not in path and "opt_" not in path:
            rows.append(f"# {path} missing - run "
                        f"`python -m repro.launch.dryrun --all --out {path}`")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run(*sys.argv[1:])
