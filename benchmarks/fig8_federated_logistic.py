"""Beyond-paper Fig. 8: federated logistic regression over the fading MAC.

The paper's experiments (§VI) are deterministic full-gradient problems.
The related federated-SGD line (Amiri & Gündüz, arXiv:1907.09769; the
accelerated follow-up Paul, Friedman & Cohen, arXiv:2107.12452) runs
*stochastic* local gradients over the same channel — each node holds a
shard of a global dataset and transmits a minibatch gradient per slot.
This figure exercises the engine's stochastic-problem support: the
`logistic` problem kind (non-iid label-sorted shards via
`repro.data.federated`) draws per-slot local minibatches INSIDE the scan,
sized by the `run_mc(batch_frac=...)` knob.

(a) node-count sweep at minibatch fraction 1/2: precoded GBMA vs blind
    transmitters (M antennas, no CSI) vs centralized SGD, i.i.d. Rayleigh,
    E_N = 1/N. Non-convexity is absent (regularized logistic is strongly
    convex) but no closed-form risk exists — the excess objective
    F(θ) − F* is evaluated on-device against a host-side f64 Newton F*.
(b) batch-fraction sweep at fixed N: the SGD gradient-noise floor rises as
    the minibatch shrinks while per-slot energy falls; fractions batch
    per-row, so the whole sweep is one compile.

Each sweep runs as ONE engine call — a single `_mc_core` compile —
(asserted via SMOKE_COMPILES): node counts pad/mask, antenna counts
replay their key splits with the count as data, and the batch fraction is
a traced per-row lane count.
"""
from __future__ import annotations

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.montecarlo import logistic_mc_problem, run_mc
from repro.data.synthetic import logistic_classification

N_GRID = (20, 40, 80)
N = 40              # fixed node count for the batch-fraction sweep
M = 16              # edge antennas for the blind rows
SAMPLES_PER_NODE = 6
DIM = 16
LAMBDA = 0.1
STEPS = 300
SEEDS = 4
BATCH_FRAC = 0.5    # minibatch fraction for the N-sweep
FRAC_GRID = (1.0, 0.5, 0.25)
SMOKE_COMPILES = 2  # one compile per sweep, asserted by the smoke test

_ALGOS = ("gbma", "blind", "centralized")


def _make(n: int):
    X, y, _ = logistic_classification(n * SAMPLES_PER_NODE, dim=DIM, seed=0)
    prob = logistic_mc_problem(X, y, n, lam=LAMBDA)
    # logistic smoothness: L <= 0.25 λ_max(XᵀX/n) + λ
    L = 0.25 * float(np.linalg.eigvalsh(X.T @ X / X.shape[0])[-1]) + LAMBDA
    return prob, 1.0 / L


def _channel(n: int) -> ChannelConfig:
    return ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.5,
                         energy=1.0 / float(n))


def run(verbose: bool = True) -> list[str]:
    rows = []

    # ---- (a) node-count sweep at fixed minibatch fraction ----------------
    probs, chs, algos, betas, ants = [], [], [], [], []
    for n in N_GRID:
        prob, beta = _make(n)
        ch = _channel(n)
        for a in _ALGOS:
            probs.append(prob)
            chs.append(ch)
            algos.append(a)
            # gbma's superposition carries the mean channel gain μ_h;
            # blind (MRC-normalized) and centralized see gain ≈ 1
            betas.append(beta / ch.mu_h if a == "gbma" else beta)
            ants.append(M if a == "blind" else 1)
    res = run_mc(probs, chs, tuple(algos), betas, STEPS, SEEDS,
                 n_antennas=tuple(ants), batch_frac=BATCH_FRAC)
    for i, n in enumerate(N_GRID):
        init = res.mean[len(_ALGOS) * i][0]
        fin = {a: res.mean[len(_ALGOS) * i + j][-1]
               for j, a in enumerate(_ALGOS)}
        for a in _ALGOS:
            rows.append(f"fig8a,N={n},frac={BATCH_FRAC},final_excess,{a},"
                        f"{fin[a]:.6e}")
        rows.append(f"fig8a,N={n},gbma_converges,"
                    f"{int(fin['gbma'] < 0.5 * init)}")
        rows.append(f"fig8a,N={n},blind_within_10x_gbma,"
                    f"{int(fin['blind'] <= 10.0 * max(fin['gbma'], 1e-12))}")

    # ---- (b) batch-fraction sweep at fixed N: one engine call ------------
    prob, beta = _make(N)
    ch = _channel(N)
    res = run_mc(prob, [ch] * len(FRAC_GRID), "gbma",
                 [beta / ch.mu_h] * len(FRAC_GRID), STEPS, SEEDS,
                 batch_frac=FRAC_GRID)
    init = res.mean[0][0]
    for i, f in enumerate(FRAC_GRID):
        fin = res.mean[i][-1]
        rows.append(f"fig8b,N={N},frac={f},final_excess,{fin:.6e}")
        rows.append(f"fig8b,N={N},frac={f},converges,"
                    f"{int(fin < 0.5 * init)}")
    # energy falls with the fraction (smaller minibatch -> smaller ||g||
    # is NOT guaranteed, but fewer effective samples leave the gradient
    # scale ~constant; report the measured totals instead of asserting)
    for i, f in enumerate(FRAC_GRID):
        tot = float(np.mean(res.cum_energy[i, :, -1]))
        rows.append(f"fig8b,N={N},frac={f},total_energy,{tot:.6e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
