"""Benchmark runner — one module per paper table/figure plus the roofline
table. Prints `name,label,value` CSV rows; `python -m benchmarks.run`.

`--plan-auto` routes figure scripts whose `run()` takes a `plan` kwarg
through `run_mc(plan="auto")` — the self-planned execution strategy
(chunking/placement derived from the memory model and device topology,
docs/performance.md) instead of the figure-scale defaults.

`--write-bench` lets modules whose `run()` takes a `write_bench` kwarg
(the tracked-record benches) rewrite their tracked JSON. Without it an
unfiltered `python -m benchmarks.run` routes those records to the
`.smoke.json` path — a figure-driving run on a contended container must
never silently clobber `benchmarks/BENCH_montecarlo.json`."""
from __future__ import annotations

import inspect
import sys
import time


def main() -> None:
    from benchmarks import (ablations, bench_montecarlo, fig2_equal_gains,
                            fig3_rayleigh, fig4_fdm_comparison,
                            fig5_localization, fig6_energy_scaling,
                            fig7_blind_transmitters,
                            fig8_federated_logistic, roofline)

    modules = [
        ("fig2_equal_gains (paper Fig. 2)", fig2_equal_gains),
        ("fig3_rayleigh (paper Fig. 3)", fig3_rayleigh),
        ("fig4_fdm_comparison (paper Fig. 4)", fig4_fdm_comparison),
        ("fig5_localization (paper Fig. 5)", fig5_localization),
        ("fig6_energy_scaling (paper Fig. 6)", fig6_energy_scaling),
        ("fig7_blind_transmitters (beyond-paper: Amiri/Duman/Gündüz "
         "no-CSI baseline)", fig7_blind_transmitters),
        ("fig8_federated_logistic (beyond-paper: stochastic federated "
         "logistic regression over the MAC)", fig8_federated_logistic),
        ("ablations (beyond-paper: phase error / fading / power control)",
         ablations),
        ("bench_montecarlo (engine vs seed per-seed loop)", bench_montecarlo),
        ("roofline (EXPERIMENTS §Roofline)", roofline),
    ]
    flags = set(a for a in sys.argv[1:] if a.startswith("--"))
    argv = [a for a in sys.argv[1:] if not a.startswith("--")]
    plan_auto = "--plan-auto" in flags
    write_bench = "--write-bench" in flags
    only = argv[0] if argv else None
    for name, mod in modules:
        if only and only not in name:
            continue
        print(f"==== {name} ====", flush=True)
        t0 = time.time()
        kw = {}
        params = inspect.signature(mod.run).parameters
        if plan_auto and "plan" in params:
            kw["plan"] = "auto"
        if "write_bench" in params:
            kw["write_bench"] = write_bench
        mod.run(verbose=True, **kw)
        print(f"---- {name}: {time.time() - t0:.1f}s ----", flush=True)


if __name__ == "__main__":
    main()
