"""Paper Fig. 6: with E_N = N^{-1.5} the TOTAL transmission energy needed to
reach a fixed error (1e-2-scale) decreases to zero as N grows. The engine
accumulates the per-slot transmitted energy on-device inside the scan; the
time-to-target bookkeeping happens on the returned per-seed curves."""
from __future__ import annotations

import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import energy_to_target, run_mc
from repro.core.theory import stepsize_theorem1

STEPS = 400
SEEDS = 3
TARGET = 1e-2


def run(verbose: bool = True) -> list[str]:
    rows = []
    totals = []
    for n in (100, 200, 400, 800):
        prob = MSDProblem.make(n)
        ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=float(n) ** (-1.5))
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        res = run_mc(prob.to_mc(), [ch], "gbma", [beta], STEPS, SEEDS)
        tot = float(energy_to_target(res, TARGET)[0])
        totals.append(tot)
        rows.append(f"fig6,N={n},total_energy_to_err_{TARGET},{tot:.4e}")
    rows.append(f"fig6,energy_decreases_with_N,"
                f"{int(all(a > b for a, b in zip(totals, totals[1:])))}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
