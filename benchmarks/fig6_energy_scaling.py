"""Paper Fig. 6: with E_N = N^{-1.5} the TOTAL transmission energy needed to
reach a fixed error (1e-2-scale) decreases to zero as N grows."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem, average_runs
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator
from repro.core.theory import stepsize_theorem1

STEPS = 400
SEEDS = 3
TARGET = 1e-2


def run(verbose: bool = True) -> list[str]:
    rows = []
    totals = []
    for n in (100, 200, 400, 800):
        prob = MSDProblem.make(n)
        ch = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                           energy=float(n) ** (-1.5))
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        sim = GBMASimulator(prob.grad_fn(), ch, beta)
        g = prob.grad_fn()

        def one(key, sim=sim, prob=prob, g=g, ch=ch):
            traj = sim.run(jnp.zeros(prob.pc.dim), STEPS, key)
            risks = prob.excess_risk(traj)
            # energy spent until first hitting TARGET
            grads = np.asarray([np.sum(np.asarray(g(jnp.array(t)))**2)
                                for t in np.asarray(traj[:-1])])
            hit = np.argmax(risks <= TARGET) if np.any(risks <= TARGET) \
                else len(risks) - 1
            return np.array([np.sum(ch.energy * grads[:hit + 1])])

        tot = float(average_runs(one, SEEDS)[0])
        totals.append(tot)
        rows.append(f"fig6,N={n},total_energy_to_err_{TARGET},{tot:.4e}")
    rows.append(f"fig6,energy_decreases_with_N,"
                f"{int(all(a > b for a, b in zip(totals, totals[1:])))}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
