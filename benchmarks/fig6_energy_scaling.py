"""Paper Fig. 6: with E_N = N^{-1.5} the TOTAL transmission energy needed to
reach a fixed error (1e-2-scale) decreases to zero as N grows. The whole
node-count sweep runs as ONE padded/masked engine call (a single `_mc_core`
compile); the engine accumulates the per-slot transmitted energy on-device
inside the scan, and `energy_to_target` charges exactly the slots up to the
first target hit (a hit at initialization costs nothing)."""
from __future__ import annotations

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import energy_to_target, run_mc
from repro.core.theory import stepsize_theorem1

N_GRID = (100, 200, 400, 800)
STEPS = 400
SEEDS = 3
SMOKE_COMPILES = 1  # engine compiles per run(), asserted by the smoke test
TARGET = 1e-2


def run(verbose: bool = True) -> list[str]:
    rows = []
    probs = [MSDProblem.make(n) for n in N_GRID]
    chs = [ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=float(n) ** (-1.5)) for n in N_GRID]
    betas = [stepsize_theorem1(p.pc, ch, n, safety=0.9)
             for p, ch, n in zip(probs, chs, N_GRID)]
    res = run_mc([p.to_mc() for p in probs], chs, "gbma", betas, STEPS,
                 SEEDS)
    totals = [float(t) for t in energy_to_target(res, TARGET)]
    for n, tot in zip(N_GRID, totals):
        rows.append(f"fig6,N={n},total_energy_to_err_{TARGET},{tot:.4e}")
    rows.append(f"fig6,energy_decreases_with_N,"
                f"{int(all(a > b for a, b in zip(totals, totals[1:])))}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
