"""Paper Fig. 5: acoustic source localization with N=200 sensors, -10 dB,
GBMA vs FDM-GD vs centralized GD. The local losses are non-convex and
non-Lipschitz — Theorems 1/2 do not apply — yet GBMA converges from a good
initialization (paper §VI-B). All three algorithms run as ONE engine call
(per-row `algo` batching) — a single `_mc_core` compile — with the
on-device squared-position-error metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.gbma import slot_energy
from repro.core.montecarlo import localization_mc_problem, run_mc

N = 200
STEPS = 3000
SEEDS = 3
SMOKE_COMPILES = 1  # engine compiles per run(), asserted by the smoke test
A = 100.0


def make_problem(seed=0):
    from repro.data.synthetic import localization_field

    r, x, src, noise_std = localization_field(N, signal_a=A, snr_db=-10.0,
                                              seed=seed)
    return localization_mc_problem(r, x, src, A), src


def run(verbose: bool = True) -> list[str]:
    rows = []
    mc, src = make_problem()
    theta0 = np.array([45.0, 45.0])
    beta = 1.0
    ch_gbma = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.3,
                            energy=float(N) ** (-1.5))
    ch_fdm = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.3,
                           energy=1.0)

    res = run_mc(mc, [ch_gbma, ch_fdm, ch_gbma],
                 ("gbma", "fdm", "centralized"),
                 [beta / ch_gbma.mu_h, beta / ch_gbma.mu_h, beta],
                 STEPS, SEEDS, theta0=theta0, invert_channel=False)
    e_g, e_f, e_c = res.mean
    g0 = mc.grad_fn(jnp.asarray(theta0, jnp.float32))
    rows.append(f"fig5,final_sq_err,gbma,{e_g[-1]:.4e}")
    rows.append(f"fig5,final_sq_err,fdm,{e_f[-1]:.4e}")
    rows.append(f"fig5,final_sq_err,centralized,{e_c[-1]:.4e}")
    rows.append(f"fig5,gbma_converges,{int(e_g[-1] < 0.1 * e_g[0])}")
    # (b) total transmission energy per slot at theta0
    rows.append(f"fig5,slot_energy,gbma,{float(slot_energy(g0, ch_gbma)):.4e}")
    rows.append(f"fig5,slot_energy,fdm,{float(slot_energy(g0, ch_fdm)):.4e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
