"""Paper Fig. 5: acoustic source localization with N=200 sensors, -10 dB,
GBMA vs FDM-GD vs centralized GD. The local losses are non-convex and
non-Lipschitz — Theorems 1/2 do not apply — yet GBMA converges from a good
initialization (paper §VI-B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import average_runs
from repro.core.baselines import CentralizedGD, FDMGD
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMASimulator, slot_energy

N = 200
STEPS = 3000
SEEDS = 3
A = 100.0


def make_problem(seed=0):
    from repro.data.synthetic import localization_field

    r, x, src, noise_std = localization_field(N, signal_a=A, snr_db=-10.0,
                                              seed=seed)
    rj, xj = jnp.array(r), jnp.array(x)

    def grad_fn(theta):
        diff = theta[None, :] - rj  # (N, 2)
        d2 = jnp.sum(diff**2, axis=1)
        s = A / d2
        resid = xj - s  # (N,)
        # d/dtheta (x_n - A/d2)^2 = 2 resid * (A * 2 diff / d2^2)
        return (4.0 * A * resid / d2**2)[:, None] * diff

    def err(theta):
        return float(np.sum((np.asarray(theta) - src) ** 2))

    return grad_fn, err, src


def run(verbose: bool = True) -> list[str]:
    rows = []
    grad_fn, err, src = make_problem()
    theta0 = jnp.array([45.0, 45.0])
    beta = 1.0
    ch_gbma = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.3,
                            energy=float(N) ** (-1.5))
    ch_fdm = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=0.3,
                           energy=1.0)

    def curve(runner):
        def one(key):
            traj = runner.run(theta0, STEPS, key)
            return np.array([err(t) for t in np.asarray(traj)])

        return average_runs(one, SEEDS)

    e_g = curve(GBMASimulator(grad_fn, ch_gbma, beta / ch_gbma.mu_h))
    e_f = curve(FDMGD(grad_fn, ch_fdm, beta / ch_gbma.mu_h, invert_channel=False))
    e_c = curve(CentralizedGD(grad_fn, beta))
    g0 = grad_fn(theta0)
    rows.append(f"fig5,final_sq_err,gbma,{e_g[-1]:.4e}")
    rows.append(f"fig5,final_sq_err,fdm,{e_f[-1]:.4e}")
    rows.append(f"fig5,final_sq_err,centralized,{e_c[-1]:.4e}")
    rows.append(f"fig5,gbma_converges,{int(e_g[-1] < 0.1 * e_g[0])}")
    # (b) total transmission energy per slot at theta0
    rows.append(f"fig5,slot_energy,gbma,{float(slot_energy(g0, ch_gbma)):.4e}")
    rows.append(f"fig5,slot_energy,fdm,{float(slot_energy(g0, ch_fdm)):.4e}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
