"""Beyond-paper Fig. 7: blind transmitters (no CSI) on the fading MAC.

The paper's GBMA precodes with full CSI at the transmitters (phase
correction, Eq. 8). The strongest related baseline drops that assumption:
Amiri, Duman & Gündüz (arXiv:1907.03909, journal version 1907.09769) let
nodes transmit the raw analog gradient — no precoding at all — and recover
the sum at an M-antenna edge via channel hardening / MRC combining, plus a
local error-accumulation variant under a per-slot transmit power budget.

(a) node-count sweep at a fixed antenna count M: GBMA vs blind vs
    blind+error-accumulation vs centralized GD, i.i.d. Rayleigh.
(b) antenna sweep at a fixed N: the blind distortion floor falls as 1/M,
    closing the gap to (equal-gain) centralized performance without any
    transmitter CSI.

Each sweep runs as ONE engine call — a single `_mc_core` compile — using
the padded/masked N axis of PR 2 and the per-row `n_antennas` batch axis
(each row's antenna key split replays `split(key, m)` for its true m with
the count as data).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import MSDProblem
from repro.core.channel import ChannelConfig
from repro.core.montecarlo import run_mc
from repro.core.theory import stepsize_theorem1

N_GRID = (50, 160, 500)
M = 32            # edge antennas for the N-sweep
M_GRID = (1, 4, 16, 64)
N = 160           # fixed node count for the M-sweep
STEPS = 600
SEEDS = 4
# blind_ec per-node, per-slot budget: fraction of the initial mean
# squared gradient norm — binds early (large gradients get truncated and
# carried in the residual), relaxes as the iterates converge
BUDGET_FRAC = 0.25
SMOKE_COMPILES = 2  # one compile per sweep, asserted by the smoke test

_ALGOS = ("gbma", "blind", "blind_ec", "centralized")


def _budget(mc) -> float:
    g0 = np.asarray(mc.grad_fn(jnp.zeros(mc.dim, jnp.float32)))
    return BUDGET_FRAC * float(np.mean(np.sum(g0**2, axis=1)))


def _channel(n: int) -> ChannelConfig:
    # E_N = 1/N: the additive-noise floors become visible and the blind
    # penalty sigma_w^2/(E_N N M E[h^2]) vs GBMA's sigma_w^2/(E_N N^2)
    # separates cleanly by M
    return ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                         energy=1.0 / float(n))


def run(verbose: bool = True) -> list[str]:
    rows = []

    # ---- (a) node-count sweep at fixed M: one engine call ----------------
    probs = {n: MSDProblem.make(n) for n in N_GRID}
    mcs, chs, algos, betas, ants, budgets = [], [], [], [], [], []
    for n in N_GRID:
        prob = probs[n]
        mc = prob.to_mc()
        ch = _channel(n)
        beta = stepsize_theorem1(prob.pc, ch, n, safety=0.9)
        b_unbiased = beta * ch.mu_h  # blind/centralized see gain ≈ 1
        for a in _ALGOS:
            mcs.append(mc)
            chs.append(ch)
            algos.append(a)
            betas.append(beta if a == "gbma" else b_unbiased)
            ants.append(M if a.startswith("blind") else 1)
            budgets.append(_budget(mc) if a == "blind_ec" else float("inf"))
    res = run_mc(mcs, chs, tuple(algos), betas, STEPS, SEEDS,
                 n_antennas=tuple(ants), power_budget=budgets)
    for i, n in enumerate(N_GRID):
        fin = {a: res.mean[len(_ALGOS) * i + j][-1]
               for j, a in enumerate(_ALGOS)}
        for a in _ALGOS:
            rows.append(f"fig7a,N={n},M={M},final_excess,{a},{fin[a]:.6e}")
        rows.append(f"fig7a,N={n},blind_within_10x_gbma,"
                    f"{int(fin['blind'] <= 10.0 * fin['gbma'])}")

    # ---- (b) antenna sweep at fixed N: one engine call -------------------
    prob = probs.get(N) or MSDProblem.make(N)
    mc = prob.to_mc()
    ch = _channel(N)
    beta = stepsize_theorem1(prob.pc, ch, N, safety=0.9)
    b_unbiased = beta * ch.mu_h
    bud = _budget(mc)
    algos = ["gbma", "centralized"]
    betas = [beta, b_unbiased]
    ants = [1, 1]
    budgets = [float("inf")] * 2
    for m in M_GRID:
        algos += ["blind", "blind_ec"]
        betas += [b_unbiased, b_unbiased]
        ants += [m, m]
        budgets += [float("inf"), bud]
    res = run_mc(mc, [ch] * len(algos), tuple(algos), betas, STEPS, SEEDS,
                 n_antennas=tuple(ants), power_budget=budgets)
    fin_gbma, fin_cent = res.mean[0][-1], res.mean[1][-1]
    rows.append(f"fig7b,N={N},final_excess,gbma,{fin_gbma:.6e}")
    rows.append(f"fig7b,N={N},final_excess,centralized,{fin_cent:.6e}")
    fin_blind = []
    for i, m in enumerate(M_GRID):
        fb, fe = res.mean[2 + 2 * i][-1], res.mean[3 + 2 * i][-1]
        fin_blind.append(fb)
        rows.append(f"fig7b,N={N},M={m},final_excess,blind,{fb:.6e}")
        rows.append(f"fig7b,N={N},M={m},final_excess,blind_ec,{fe:.6e}")
    init = float(np.mean(res.risks[3::2, :, 0]))
    fin_ec = float(np.mean(res.risks[3::2, :, -1]))
    rows.append(f"fig7b,blind_improves_with_M,"
                f"{int(fin_blind[-1] < fin_blind[0])}")
    rows.append(f"fig7b,blind_maxM_within_2x_gbma,"
                f"{int(fin_blind[-1] <= 2.0 * fin_gbma)}")
    rows.append(f"fig7b,blind_ec_converges,{int(fin_ec < 0.5 * init)}")
    if verbose:
        print("\n".join(rows))
    return rows


if __name__ == "__main__":
    run()
