"""Quickstart: GBMA in 60 lines — distributed linear regression over a noisy
Rayleigh-fading MAC, compared with centralized GD and the Theorem-1 bound.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CentralizedGD, ChannelConfig, GBMASimulator)
from repro.core.theory import (ProblemConstants, stepsize_theorem1,
                               theorem1_bound)
from repro.data.synthetic import msd_like_regression

N, DIM, LAM, STEPS = 500, 90, 0.5, 300

# --- federated problem: one (x_n, y_n) sample per node (paper Eq. 27) ----
X, y, _ = msd_like_regression(N, dim=DIM, seed=0)
Xj, yj = jnp.array(X), jnp.array(y)
theta_star = np.linalg.solve(X.T @ X / N + LAM * np.eye(DIM), X.T @ y / N)


def local_gradients(theta):  # (N, DIM): every node's local gradient
    return (Xj @ theta - yj)[:, None] * Xj + LAM * theta[None, :]


def objective(theta):
    t = np.asarray(theta)
    return float(0.5 * np.mean((X @ t - y) ** 2) + LAM / 2 * np.sum(t * t))


# --- channel: Rayleigh fading, per-node energy E_N = N^{-1.5} --------------
channel = ChannelConfig(fading="rayleigh", scale=1.0, noise_std=1.0,
                        energy=float(N) ** (-1.5))

eig = np.linalg.eigvalsh(X.T @ X / N)
pc = ProblemConstants(mu=eig[0] + LAM, L=eig[-1] + LAM,
                      L_bar=float((X**2).sum(1).max() + LAM), delta=10.0,
                      r0_sq=float(np.sum(theta_star**2)), dim=DIM)
beta = stepsize_theorem1(pc, channel, N)  # provably convergent (Eq. 15)

gbma = GBMASimulator(local_gradients, channel, beta)
traj = gbma.run(jnp.zeros(DIM), STEPS, jax.random.key(0))
cen = CentralizedGD(local_gradients, beta * channel.mu_h)
traj_c = cen.run(jnp.zeros(DIM), STEPS)

f_star = objective(theta_star)
print(f"excess risk  GBMA        : {objective(traj[-1]) - f_star:.3e}")
print(f"excess risk  centralized : {objective(traj_c[-1]) - f_star:.3e}")
print(f"Theorem-1 bound at k={STEPS}: "
      f"{theorem1_bound(np.array([STEPS]), beta, pc, channel, N)[0]:.3e}")
print(f"total per-slot energy ~ N*E_N = {N * channel.energy:.2e} "
      f"(vanishes as N grows)")
