"""Paper §VI-B end-to-end: acoustic source localization with a 200-sensor
network over a fading MAC (non-convex losses — outside Theorems 1/2, still
converges).

    PYTHONPATH=src python examples/source_localization.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks import fig5_localization, fig6_energy_scaling

print("== localization error + energy (paper Fig. 5) ==")
fig5_localization.run()
print("== energy scaling law (paper Fig. 6) ==")
fig6_energy_scaling.run()
