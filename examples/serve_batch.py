"""Batched serving demo: prefill + 32-token greedy decode on any assigned
architecture (reduced config by default so it runs on CPU).

    PYTHONPATH=src python examples/serve_batch.py --arch gemma2-9b
    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
