"""End-to-end driver (deliverable b): train the ~110M-parameter repro-100m
transformer for a few hundred steps with GBMA over-the-air gradient
aggregation, on synthetic token data.

Defaults are sized for this CPU container (~15 min); pass --steps/--seq/
--batch to scale up. `--aggregator` takes ANY registered MAC algorithm:
`centralized` is the noiseless benchmark, `fdm` the orthogonal-channel
baseline, and the transport-routed family trains over the simulated MAC
slot-for-slot with the Monte Carlo engine —

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py \
        --aggregator blind --antennas 8
    PYTHONPATH=src python examples/train_100m.py \
        --aggregator blind_ec --antennas 8 --power-budget 50
    PYTHONPATH=src python examples/train_100m.py \
        --aggregator nesterov --gamma 0.9 --optimizer gd

See docs/training.md for the routing rules, block tiling (`--block-d`)
and the bf16-transmit path (`--transmit-dtype bfloat16`).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if not any(a.startswith("--arch") for a in sys.argv[1:]):
        sys.argv.extend(["--arch", "repro-100m"])
    if not any(a.startswith("--steps") for a in sys.argv[1:]):
        sys.argv.extend(["--steps", "300"])
    if not any(a.startswith("--seq") for a in sys.argv[1:]):
        sys.argv.extend(["--seq", "128"])
    main()
