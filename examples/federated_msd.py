"""Paper §VI-A end-to-end: federated year-prediction (MSD-like data), GBMA
vs FDM-GD vs centralized, with the Fig. 2/3 sweeps reduced to one page.

    PYTHONPATH=src python examples/federated_msd.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks import fig2_equal_gains, fig3_rayleigh, fig4_fdm_comparison

print("== equal gains (paper Fig. 2) ==")
fig2_equal_gains.run()
print("== Rayleigh fading (paper Fig. 3) ==")
fig3_rayleigh.run()
print("== GBMA vs FDM-GD vs centralized (paper Fig. 4) ==")
fig4_fdm_comparison.run()
