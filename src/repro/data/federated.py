"""Federated partitioning: map a global batch onto GBMA nodes.

The paper's setting assigns each sample (or local dataset) to one node; the
node computes its local gradient g_n and transmits over the MAC. In the
framework tier the global batch is partitioned into `n_nodes` contiguous
example groups, each group belonging to one node, aligned with the
('pod','data') device sharding so a node's examples never straddle devices
unless n_nodes < n_devices.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FederatedSpec:
    n_nodes: int
    global_batch: int

    def __post_init__(self):
        if self.global_batch % self.n_nodes:
            raise ValueError(
                f"global_batch {self.global_batch} must divide into "
                f"{self.n_nodes} nodes")

    @property
    def examples_per_node(self) -> int:
        return self.global_batch // self.n_nodes

    def node_of_example(self) -> np.ndarray:
        return np.repeat(np.arange(self.n_nodes), self.examples_per_node)


def partition_rows(X: np.ndarray, y: np.ndarray, n_nodes: int):
    """Row-partition a dataset across nodes (paper §VI-A: one sample per
    device). Returns list of (X_n, y_n)."""
    idx = np.array_split(np.arange(X.shape[0]), n_nodes)
    return [(X[i], y[i]) for i in idx]


def partition_noniid(X: np.ndarray, y: np.ndarray, n_nodes: int):
    """Label-skewed (non-iid) partition: sort the examples by target value
    (stable, so ties keep dataset order) and hand out contiguous shards.

    This is the classic pathological federated split — each node sees a
    narrow slice of the label distribution, so local gradients disagree and
    the aggregation over the MAC actually matters (federated SGD over
    wireless channels, Amiri & Gündüz arXiv:1907.09769). Returns list of
    (X_n, y_n)."""
    order = np.argsort(y, kind="stable")
    return partition_rows(X[order], y[order], n_nodes)
