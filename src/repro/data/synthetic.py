"""Synthetic data generators.

* Token streams with power-law unigram statistics and Markov structure for
  language-model training (offline container: no corpora available).
* An MSD-like regression set matching the paper's federated experiment: 90
  audio-feature covariates, a "release year" linear target + noise, one
  sample per node (paper §VI-A). Statistics (feature scale, year range) match
  the UCI YearPredictionMSD layout so the regularized least-squares objective
  (27) has comparable conditioning.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDatasetConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    """Deterministic, seekable synthetic token batches (B, S+1)."""

    def __init__(self, cfg: TokenDatasetConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # power-law unigram distribution over a shuffled vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        probs /= probs.sum()
        self._probs = probs[rng.permutation(v)]
        # cheap Markov structure: each token biases the next toward t+1 mod v
        self._carry = 0.3

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len + 1
        iid = rng.choice(cfg.vocab_size, size=(b, s), p=self._probs)
        out = iid.copy()
        stay = rng.random((b, s)) < self._carry
        for t in range(1, s):
            out[:, t] = np.where(stay[:, t],
                                 (out[:, t - 1] + 1) % cfg.vocab_size,
                                 iid[:, t])
        return out.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def msd_like_regression(n_samples: int, dim: int = 90, seed: int = 0,
                        noise_std: float = 0.1):
    """(X, y, theta_true): standardized features, linear target like the
    Million-Song year-prediction task of paper §VI-A."""
    rng = np.random.default_rng(seed)
    # anisotropic covariance: audio features are correlated
    q, _ = np.linalg.qr(rng.standard_normal((dim, dim)))
    scales = np.exp(rng.uniform(-1.0, 1.0, size=dim))
    X = rng.standard_normal((n_samples, dim)) * scales[None]
    X = X @ q.T
    X /= X.std(axis=0, keepdims=True)
    theta = rng.standard_normal(dim) / np.sqrt(dim)
    y = X @ theta + noise_std * rng.standard_normal(n_samples)
    return X.astype(np.float64), y.astype(np.float64), theta


def logistic_classification(n_samples: int, dim: int = 16, seed: int = 0,
                            margin: float = 1.0, flip_frac: float = 0.05):
    """(X, y ∈ {−1, +1}, theta_true): linearly separable-ish binary
    classification for the federated logistic-regression experiment
    (beyond-paper Fig. 8). Features share the anisotropic/correlated
    covariance of `msd_like_regression`; labels follow a ground-truth
    halfspace with `margin` controlling the logit scale and a small
    label-flip fraction keeping the Bayes risk nonzero (so the regularized
    optimum is finite and the excess risk well-conditioned)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((dim, dim)))
    scales = np.exp(rng.uniform(-1.0, 1.0, size=dim))
    X = rng.standard_normal((n_samples, dim)) * scales[None]
    X = X @ q.T
    X /= X.std(axis=0, keepdims=True)
    # margin scales the ground-truth vector itself (labels are invariant
    # to a positive rescale, so scaling theta — not the logits — is what
    # makes the returned optimum reflect the logit scale)
    theta = rng.standard_normal(dim) / np.sqrt(dim) * margin
    y = np.sign(X @ theta + 1e-12)
    flip = rng.random(n_samples) < flip_frac
    y = np.where(flip, -y, y)
    return X.astype(np.float64), y.astype(np.float64), theta


def localization_field(n_sensors: int, field: float = 100.0,
                       source=(60.0, 60.0), signal_a: float = 100.0,
                       snr_db: float = -10.0, min_radius: float = 8.0,
                       seed: int = 0):
    """Source-localization sensing setup of paper §VI-B: N sensors at known
    positions on a field x field m^2 area (>= min_radius from the source),
    far-field magnitude measurements x_n = A/||theta-r_n||^2 + v_n."""
    rng = np.random.default_rng(seed)
    src = np.asarray(source, np.float64)
    pts = []
    while len(pts) < n_sensors:
        cand = rng.uniform(0.0, field, size=(n_sensors, 2))
        keep = np.linalg.norm(cand - src[None], axis=1) >= min_radius
        pts.extend(cand[keep].tolist())
    r = np.asarray(pts[:n_sensors], np.float64)
    s = signal_a / np.sum((src[None] - r) ** 2, axis=1)
    sig_pow = np.mean(s**2)
    noise_std = np.sqrt(sig_pow / (10.0 ** (snr_db / 10.0)))
    x = s + noise_std * rng.standard_normal(n_sensors)
    return r, x, src, noise_std
