"""Serving engine: batched prefill + greedy/temperature decode.

Decode shapes of the assignment lower `serve_step` — ONE token against a
seq_len-deep cache — which is exactly `Model.decode_step`; this engine wraps
it for the runnable examples (generation loops on real arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = serve_cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill, static_argnums=(2,))

    def generate(self, batch: dict) -> Array:
        """batch: prompt inputs (model.input_specs 'prefill' layout with real
        arrays). Returns (B, max_new_tokens) generated ids."""
        cfg, m = self.cfg, self.model
        max_len = batch["tokens"].shape[1] + cfg.max_new_tokens
        logits, cache = self._prefill(self.params, batch, max_len)
        b = logits.shape[0]
        prompt_len = batch["tokens"].shape[1]
        pos0 = prompt_len + (m.cfg.n_patches or 0) + (m.cfg.meta_tokens or 0)
        key = jax.random.key(cfg.seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(cfg.max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(
                self.params, cache, tok, jnp.asarray(pos0 + i, jnp.int32))
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
        return jnp.stack(out, axis=1)

    def _sample(self, logits: Array, key) -> Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.cfg.temperature,
                                      axis=-1)
