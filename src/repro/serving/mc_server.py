"""MC-as-a-service: a coalescing sweep server over the Monte Carlo engine.

The expensive artifact of a Monte Carlo sweep is the compiled executable:
`_mc_core`'s jit cache keys on static shapes and flags, and everything
else — channel parameters, stepsizes, problem data, node counts, antenna
counts, minibatch fractions — is row *data* the padded batch axis already
fuses. Serving many clients is therefore a request-*coalescing* problem,
not a request-queueing one: requests whose static facets agree can be
packed into ONE engine call and pay one compile between them, exactly the
way the one-compile N/M/frac sweep benchmarks do, just across strangers.

The server is three small pieces:

* **Signature router.** Each `SweepRequest` maps to a compile-cache
  signature (`exec.static_signature` — the same hashing machinery the
  resume fingerprint uses, restricted to static facets: problem kind and
  registry row fns, dim, fading family, steps, the (seeds, seed0) axis,
  the algorithm, stochastic/antenna modes). Signature-equal requests
  coalesce into one padded `run_mc` batch — their node counts, channel
  params, stepsizes, antenna counts, minibatch fractions and power
  budgets concatenate as row data; signature-distinct requests never
  share a batch. K concurrent requests compile exactly once per distinct
  signature (asserted by `trace_count()` in the tests and the
  `serve_mc --selftest` CI job).

* **Admission control.** `exec.estimate_peak_bytes` prices each request
  (and each growing batch) against `McServeConfig.memory_budget_bytes`.
  A request whose own single-quantum working set exceeds the budget is
  rejected at `submit` with a typed `AdmissionError`; an affordable
  request that would push a batch over the budget (or past
  `max_batch_rows`) closes the batch and starts the next one — same
  signature, but scheduled separately.

* **Fairness-preserving preemption.** A batch does not run its whole
  seed axis in one blocking call: the scheduler round-robins *seed
  quanta* of `quantum_seeds` across all live batches — the same
  seeds-are-data slicing `run_mc(seed_chunk=)` uses internally, driven
  here from the event loop so a 1024-seed whale cannot starve 4-seed
  minnows. Quantum k runs `run_mc(..., seeds=q, seed0=seed0 + off)`,
  which replays exactly the seed streams `seed0 + off .. seed0 + off + q`
  of the uninterrupted call (counter-based RNG), so sliced results are
  identical to single-shot ones. Seed counts that are multiples of the
  quantum share one compiled slice shape; a ragged final quantum costs
  one extra compile.

Results demux back per request with `mc.slice_result` row views of the
batch `MCResult`. Clients cancelling mid-batch detach their future; the
batch still completes for its other requests (and a batch whose every
request cancelled is dropped without running its remaining quanta).

Determinism knobs — the test harness (`tests/_serving_harness.py`) and
the bench inject both: `clock` (only used for the coalesce window;
`ManualClock` advances virtual time without wall-clock sleeps) and
`executor` (`InlineExecutor` runs engine calls synchronously on the loop
thread in deterministic order; the default `LoopExecutor` uses a thread
so the event loop stays responsive under real traffic).

See docs/serving.md for the request schema and semantics;
`repro.launch.serve_mc` is the CLI front-end.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import time
from collections import deque
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.mc import exec as exec_mod
from repro.core.mc.engine import MCResult, run_mc, slice_result
from repro.core.mc.exec import estimate_peak_bytes, host_seed_stats
from repro.core.mc.problems import PROBLEMS, MCProblem, MCProblemBatch
from repro.core.mc.slots import ALGO_REGISTRY


# --------------------------------------------------------------------------
# errors
# --------------------------------------------------------------------------
class ServeError(Exception):
    """Base class of the server's typed failures."""


class RequestError(ServeError):
    """Malformed request payload — raised at `submit`, before the request
    ever reaches the router queue (fail fast, nothing to poison)."""


class AdmissionError(ServeError):
    """Request rejected by admission control: its own single-quantum
    working set (analytic `estimate_peak_bytes`) exceeds the server's
    memory budget."""


# --------------------------------------------------------------------------
# request schema
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One client's sweep: rows (channel × stepsize, sharing one problem
    kind and one algorithm) × a private seed axis.

    problem:     a library-built `MCProblem` shared by every row, or one
                 per row (node counts may differ — rows pad to the batch
                 N_max like any engine sweep).
    channels:    one `ChannelConfig` per row (one fading family per
                 request; the family is static and part of the
                 signature).
    algo:        `ALGO_REGISTRY` name; static (part of the signature).
    betas:       one stepsize per row (row data).
    steps:       slot count (static).
    seeds:       Monte Carlo seed count — the seed-axis *shape* is static,
                 so it is part of the signature; the seed ints are data.
    seed0:       first seed; seed s uses `jax.random.key(seed0 + s)`,
                 the same stream a dedicated `run_mc` call would use.
    batch_frac:  minibatch fraction (scalar or per row) for stochastic
                 problem kinds; 1.0 = exact full-batch gradients.
                 Full-batch and minibatch requests never coalesce (the
                 no-sampling path is a different, cheaper program).
    n_antennas:  edge antenna count M (scalar broadcast or per row;
                 required for blind algorithms). Normalized to per-row
                 data so M-heterogeneous requests coalesce.
    power_budget: per-slot per-node transmit budget (scalar or per row;
                 row data, only `blind_ec` rows enforce it).
    momentum:    γ for momentum/nesterov rows (whole-call scalar, so it
                 is part of the signature).
    theta0:      shared starting iterate (whole-call data: requests must
                 agree on it to coalesce, so its bytes fold into the
                 signature); None = zeros.
    """

    problem: Union[MCProblem, Sequence[MCProblem]]
    channels: Sequence[ChannelConfig]
    algo: str
    betas: Sequence[float]
    steps: int
    seeds: int
    seed0: int = 0
    batch_frac: Union[float, Sequence[float]] = 1.0
    n_antennas: Optional[Union[int, Sequence[int]]] = None
    power_budget: Optional[Union[float, Sequence[float]]] = None
    momentum: float = 0.9
    theta0: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class McServeConfig:
    """Server policy knobs (all documented in docs/serving.md).

    memory_budget_bytes: admission budget the analytic
        `estimate_peak_bytes` working sets are priced against.
    quantum_seeds: seeds per scheduling quantum — the preemption grain.
        Requests whose seed count is a multiple of it share one compiled
        slice shape.
    max_batch_rows: hard cap on rows per coalesced engine call.
    coalesce_window: seconds `serve_forever` waits after a wakeup for
        straggler requests before draining (0 = drain immediately).
    """

    memory_budget_bytes: int = 2 * 2**30
    quantum_seeds: int = 64
    max_batch_rows: int = 256
    coalesce_window: float = 0.0


# --------------------------------------------------------------------------
# injectable clock / executor
# --------------------------------------------------------------------------
class WallClock:
    """Real time: `serve_forever`'s coalesce window sleeps on the loop."""

    def time(self) -> float:
        return time.monotonic()

    async def sleep(self, dt: float) -> None:
        await asyncio.sleep(dt)


class LoopExecutor:
    """Default executor: engine calls run in the loop's default thread
    pool so the event loop keeps accepting submissions mid-quantum."""

    async def run(self, fn, info: Optional[dict] = None):
        return await asyncio.get_running_loop().run_in_executor(None, fn)


class InlineExecutor:
    """Deterministic executor: the engine call runs synchronously on the
    loop thread — quanta execute in exactly the order the scheduler
    issues them. One cooperative yield per quantum lets submissions that
    arrive mid-drain enqueue (and be served in the same drain pass)
    without introducing any thread or timing nondeterminism. Used by the
    tests, the bench and `serve_sync`."""

    async def run(self, fn, info: Optional[dict] = None):
        await asyncio.sleep(0)
        return fn()


# --------------------------------------------------------------------------
# internal records
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Pending:
    req: "_NormRequest"
    future: asyncio.Future


@dataclasses.dataclass(frozen=True)
class _NormRequest:
    """Validated, normalized request: per-row tuples throughout."""

    problems: tuple  # one MCProblem per row
    channels: tuple
    algo: str
    betas: tuple
    steps: int
    seeds: int
    seed0: int
    fracs: Optional[tuple]  # None = exact full-batch (no sampling path)
    m_per_row: Optional[tuple]
    budgets: Optional[tuple]
    momentum: float
    theta0: Optional[np.ndarray]
    signature: str
    b_max: int

    @property
    def n_rows(self) -> int:
        return len(self.channels)


@dataclasses.dataclass
class ServeStats:
    """Router observability, asserted on by the deterministic tests."""

    admitted: int = 0
    rejected: int = 0
    cancelled: int = 0
    failed_batches: int = 0
    batches: list = dataclasses.field(default_factory=list)


class _Job:
    """One coalesced batch in flight: merged rows + a seed cursor."""

    def __init__(self, pending: Sequence[_Pending], cfg: McServeConfig):
        self.pending = list(pending)
        self.cfg = cfg
        first = pending[0].req
        self.signature = first.signature
        self.algo = first.algo
        self.steps, self.seeds = first.steps, first.seeds
        self.seed0 = first.seed0
        self.momentum, self.theta0 = first.momentum, first.theta0
        self.problems, self.channels, self.betas = [], [], []
        self.spans = []
        fracs, m_rows, budgets = [], [], []
        off = 0
        for p in pending:
            r = p.req
            self.problems += list(r.problems)
            self.channels += list(r.channels)
            self.betas += list(r.betas)
            fracs += list(r.fracs) if r.fracs is not None else []
            m_rows += list(r.m_per_row) if r.m_per_row is not None else []
            budgets += list(r.budgets if r.budgets is not None
                            else (float("inf"),) * r.n_rows)
            self.spans.append((off, off + r.n_rows))
            off += r.n_rows
        self.n_rows = off
        self.fracs = tuple(fracs) if first.fracs is not None else None
        self.m_per_row = tuple(m_rows) if first.m_per_row is not None \
            else None
        self.budgets = (tuple(budgets)
                        if any(np.isfinite(b) for b in budgets) else None)
        self.off = 0  # seed cursor
        self.quanta_run = 0
        self.risks = np.empty((off, self.seeds, self.steps + 1), np.float32)
        self.cum_e = np.empty((off, self.seeds, self.steps), np.float32)

    @property
    def done(self) -> bool:
        return self.off >= self.seeds

    @property
    def abandoned(self) -> bool:
        """Every client detached (cancelled) — remaining quanta are
        freed instead of computing results nobody will read."""
        return all(p.future.done() for p in self.pending)


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------
class McSweepServer:
    """Asyncio front-end: `await submit(request)` -> per-request
    `MCResult`. Drive it either with `start()`/`stop()` (the
    `serve_forever` router task) or by calling `drain()` explicitly
    after a round of submissions (tests, `serve_sync`)."""

    def __init__(self, cfg: McServeConfig = McServeConfig(), *,
                 clock=None, executor=None):
        self.cfg = cfg
        self.clock = clock if clock is not None else WallClock()
        self.executor = executor if executor is not None else LoopExecutor()
        self.stats = ServeStats()
        self._queue: list[_Pending] = []
        self._wakeup: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False

    # ---- client surface -------------------------------------------------
    async def submit(self, request: SweepRequest) -> MCResult:
        """Validate, admit and enqueue a request; resolves with this
        request's own `MCResult` slice once its batch completes. Raises
        `RequestError`/`AdmissionError` before enqueueing — a bad request
        never reaches the router queue."""
        norm = self._normalize(request)
        self._admit(norm)
        self.stats.admitted += 1
        fut = asyncio.get_running_loop().create_future()
        self._queue.append(_Pending(req=norm, future=fut))
        if self._wakeup is not None:
            self._wakeup.set()
        return await fut

    def start(self) -> asyncio.Task:
        """Start the router (`serve_forever`) on the running loop."""
        self._wakeup = asyncio.Event()
        self._running = True
        self._task = asyncio.ensure_future(self.serve_forever())
        return self._task

    async def stop(self) -> None:
        self._running = False
        if self._wakeup is not None:
            self._wakeup.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def serve_forever(self) -> None:
        """Router loop: wake on submission, optionally hold the coalesce
        window open for stragglers, then drain the queue."""
        while self._running:
            await self._wakeup.wait()
            self._wakeup.clear()
            if not self._running:
                break
            if self.cfg.coalesce_window > 0:
                await self.clock.sleep(self.cfg.coalesce_window)
            await self.drain()

    async def drain(self) -> None:
        """Process everything queued now (and anything that arrives while
        draining): coalesce by signature, then round-robin one seed
        quantum per job until every job finishes."""
        while self._queue:
            pending, self._queue = self._queue, []
            ready = deque(_Job(group, self.cfg)
                          for group in self._coalesce(pending))
            while ready:
                job = ready.popleft()
                if job.abandoned:
                    self.stats.cancelled += len(job.pending)
                    continue
                if not await self._run_quantum(job):
                    continue  # batch failed; futures already resolved
                if job.done:
                    self._finish(job)
                else:
                    ready.append(job)

    # ---- validation / signature / admission -----------------------------
    def _normalize(self, req: SweepRequest) -> _NormRequest:
        if not isinstance(req, SweepRequest):
            raise RequestError(
                f"expected a SweepRequest, got {type(req).__name__}")
        channels = tuple(req.channels)
        n_rows = len(channels)
        if n_rows == 0:
            raise RequestError("request has no rows (empty channels)")
        if not all(isinstance(c, ChannelConfig) for c in channels):
            raise RequestError("channels must be ChannelConfig instances")
        if len({c.fading for c in channels}) != 1:
            raise RequestError(
                "one request = one fading family; split per family")
        probs = [req.problem] if isinstance(req.problem, MCProblem) \
            else list(req.problem)
        if not probs or not all(isinstance(p, MCProblem) for p in probs):
            raise RequestError("problem must be MCProblem(s)")
        if len(probs) == 1:
            probs = probs * n_rows
        if len(probs) != n_rows:
            raise RequestError(
                f"need one problem per row: {len(probs)} vs C={n_rows}")
        kind = probs[0].kind
        if any(p.kind != kind for p in probs):
            raise RequestError("rows must share one problem kind")
        if kind not in PROBLEMS or any(p.data is None for p in probs):
            raise RequestError(
                f"problem kind {kind!r} is not a registered library kind "
                "— the server batches strangers' rows, which needs the "
                "row-based PROBLEMS registry path")
        if len({p.dim for p in probs}) != 1:
            raise RequestError("rows must share the problem dim")
        shapes0 = {k: np.shape(v)[1:] for k, v in probs[0].data.items()}
        for p in probs[1:]:
            if {k: np.shape(v)[1:] for k, v in p.data.items()} != shapes0:
                raise RequestError(
                    "rows must agree on every non-node data shape "
                    "(only the node axis pads)")
        betas = tuple(float(b) for b in np.atleast_1d(
            np.asarray(req.betas, dtype=np.float64)))
        if len(betas) != n_rows:
            raise RequestError(
                f"need one stepsize per row: {len(betas)} vs C={n_rows}")
        if req.algo not in ALGO_REGISTRY:
            raise RequestError(
                f"unknown algo {req.algo!r}; expected one of "
                f"{tuple(ALGO_REGISTRY)}")
        if not (isinstance(req.steps, int) and req.steps > 0):
            raise RequestError(f"steps must be a positive int, "
                               f"got {req.steps!r}")
        if not (isinstance(req.seeds, int) and req.seeds > 0):
            raise RequestError(f"seeds must be a positive int, "
                               f"got {req.seeds!r}")
        # minibatch fractions -> per-row tuple, or None for full batch
        fr = req.batch_frac
        fracs = tuple(float(f) for f in (
            (fr,) * n_rows if isinstance(fr, (int, float)) else fr))
        if len(fracs) != n_rows:
            raise RequestError(
                f"need one batch_frac per row: {len(fracs)} vs C={n_rows}")
        if any(not (0.0 < f <= 1.0) for f in fracs):
            raise RequestError(f"batch_frac must be in (0, 1], got {fracs}")
        b_max = 0
        if all(f == 1.0 for f in fracs):
            fracs = None
        else:
            spec = PROBLEMS[kind]
            if spec.stochastic_grad_row is None:
                raise RequestError(
                    f"batch_frac < 1 needs a stochastic problem kind, "
                    f"got {kind!r}")
            k = probs[0].data[spec.sample_axis_field].shape[-2]
            b_max = max(max(1, int(round(f * k))) for f in fracs)
        # antennas -> per-row tuple (merged as data), or None
        m = req.n_antennas
        if m is None:
            m_per_row = None
            if ALGO_REGISTRY[req.algo].blind:
                raise RequestError(
                    f"algo {req.algo!r} is blind and needs n_antennas")
        else:
            m_per_row = tuple(int(x) for x in (
                (m,) * n_rows if isinstance(m, (int, np.integer)) else m))
            if len(m_per_row) != n_rows:
                raise RequestError(f"need one antenna count per row: "
                                   f"{len(m_per_row)} vs C={n_rows}")
            if any(x < 1 for x in m_per_row):
                raise RequestError(f"antenna counts must be >= 1: "
                                   f"{m_per_row}")
        pb = req.power_budget
        if pb is None:
            budgets = None
        else:
            budgets = tuple(float(b) for b in (
                (pb,) * n_rows if isinstance(pb, (int, float)) else pb))
            if len(budgets) != n_rows:
                raise RequestError(f"need one power budget per row: "
                                   f"{len(budgets)} vs C={n_rows}")
        theta0 = None if req.theta0 is None \
            else np.asarray(req.theta0, np.float32)
        if theta0 is not None and theta0.shape != (probs[0].dim,):
            raise RequestError(
                f"theta0 shape {theta0.shape} != (dim,) = "
                f"({probs[0].dim},)")
        sig = self._signature(kind, probs[0], req.algo, req.steps,
                              req.seeds, req.seed0, channels[0].fading,
                              fracs is not None, m_per_row is not None,
                              req.momentum, theta0)
        return _NormRequest(
            problems=tuple(probs), channels=channels, algo=req.algo,
            betas=betas, steps=int(req.steps), seeds=int(req.seeds),
            seed0=int(req.seed0), fracs=fracs, m_per_row=m_per_row,
            budgets=budgets, momentum=float(req.momentum), theta0=theta0,
            signature=sig, b_max=b_max)

    @staticmethod
    def _signature(kind, prob, algo, steps, seeds, seed0, fading,
                   stochastic, antennas, momentum, theta0) -> str:
        """The request's compile-cache signature (module docstring):
        static facets only, via `exec.static_signature`. Node counts,
        channel params, stepsizes, antenna counts, fractions and budgets
        are deliberately absent — they are row data the padded batch
        fuses. Non-node data shapes (e.g. the per-node sample count of a
        stochastic kind) are static, so they are in."""
        spec = PROBLEMS[kind]
        data_shapes = tuple(sorted(
            (name, tuple(np.shape(v)[1:]))
            for name, v in prob.data.items()))
        th = None if theta0 is None else hashlib.sha256(
            np.ascontiguousarray(theta0).tobytes()).hexdigest()
        return exec_mod.static_signature({
            "kind": kind, "grad_fn": spec.grad_row,
            "risk_fn": spec.risk_row, "dim": prob.dim,
            "data_shapes": data_shapes, "fading": fading,
            "steps": steps, "seeds": seeds, "seed0": seed0, "algo": algo,
            "stochastic": stochastic, "antennas": antennas,
            "momentum": momentum, "theta0": th,
        })

    def _estimate(self, reqs: Sequence[_NormRequest]) -> int:
        """Analytic single-quantum working set of one coalesced batch."""
        n_rows = sum(r.n_rows for r in reqs)
        n_max = max(p.n_nodes for r in reqs for p in r.problems)
        m_sizes = tuple(sorted({m for r in reqs
                                for m in (r.m_per_row or ())}))
        first = reqs[0]
        est = estimate_peak_bytes(
            n_rows=n_rows, seeds=first.seeds, steps=first.steps,
            n_max=n_max, dim=first.problems[0].dim,
            algo_set=(first.algo,),
            seed_chunk=min(self.cfg.quantum_seeds, first.seeds),
            m_sizes=m_sizes, b_max=first.b_max, keep_seed_curves=True)
        return est["device_peak_bytes"]

    def _admit(self, norm: _NormRequest) -> None:
        est = self._estimate([norm])
        if est > self.cfg.memory_budget_bytes:
            self.stats.rejected += 1
            raise AdmissionError(
                f"request needs ~{est} bytes per seed quantum "
                f"(analytic estimate_peak_bytes at quantum_seeds="
                f"{self.cfg.quantum_seeds}) > budget "
                f"{self.cfg.memory_budget_bytes} — shrink the request "
                "(rows / nodes / dim) or raise the server budget")

    # ---- coalescing -----------------------------------------------------
    def _coalesce(self, pending: Sequence[_Pending]) -> list:
        """Group signature-equal requests (submission order preserved),
        then pack each group into batches under the admission budget and
        the row cap. Returns a list of pending-lists, one per batch."""
        groups: dict[str, list[_Pending]] = {}
        for p in pending:
            groups.setdefault(p.req.signature, []).append(p)
        batches = []
        for group in groups.values():
            cur: list[_Pending] = []
            for p in group:
                trial = [q.req for q in cur] + [p.req]
                rows = sum(r.n_rows for r in trial)
                if cur and (rows > self.cfg.max_batch_rows
                            or self._estimate(trial)
                            > self.cfg.memory_budget_bytes):
                    batches.append(cur)
                    cur = [p]
                else:
                    cur.append(p)
            batches.append(cur)
        return batches

    # ---- execution ------------------------------------------------------
    def _engine_call(self, job: _Job, off: int, q: int):
        res = run_mc(
            MCProblemBatch.stack(job.problems), job.channels, job.algo,
            job.betas, job.steps, q, seed0=job.seed0 + off,
            theta0=job.theta0, n_antennas=job.m_per_row,
            power_budget=job.budgets,
            batch_frac=job.fracs if job.fracs is not None else 1.0,
            momentum=job.momentum, shard_seeds=False)
        return res.risks, res.cum_energy

    async def _run_quantum(self, job: _Job) -> bool:
        """One scheduling quantum of `job`; False when the batch failed
        (its futures carry the exception) and must leave the ring."""
        off = job.off
        q = min(self.cfg.quantum_seeds, job.seeds - off)
        info = {"signature": job.signature[:12], "off": off, "quantum": q,
                "rows": job.n_rows}
        try:
            risks, cum_e = await self.executor.run(
                lambda: self._engine_call(job, off, q), info=info)
        except Exception as e:  # noqa: BLE001 — routed to the clients
            self.stats.failed_batches += 1
            for p in job.pending:
                if not p.future.done():
                    p.future.set_exception(
                        ServeError(f"batch {job.signature[:12]} failed "
                                   f"at seed offset {off}: {e!r}"))
            return False
        job.risks[:, off:off + q] = risks
        job.cum_e[:, off:off + q] = cum_e
        job.off = off + q
        job.quanta_run += 1
        return True

    def _finish(self, job: _Job) -> None:
        mean, ci95 = host_seed_stats(job.risks)
        full = MCResult(risks=job.risks, mean=mean.astype(np.float32),
                        ci95=ci95.astype(np.float32), cum_energy=job.cum_e,
                        bounds=None, plan=None)
        cancelled = 0
        for p, (lo, hi) in zip(job.pending, job.spans):
            if p.future.done():  # client cancelled mid-batch
                cancelled += 1
                continue
            p.future.set_result(slice_result(full, slice(lo, hi)))
        self.stats.cancelled += cancelled
        self.stats.batches.append({
            "signature": job.signature[:12],
            "requests": len(job.pending),
            "rows": job.n_rows,
            "seeds": job.seeds,
            "quanta": job.quanta_run,
            "cancelled": cancelled,
        })


# --------------------------------------------------------------------------
# synchronous convenience front-end
# --------------------------------------------------------------------------
def serve_sync(requests: Sequence[SweepRequest],
               cfg: McServeConfig = None,
               server: McSweepServer = None) -> list:
    """One-shot synchronous façade: submit every request, coalesce, run
    to completion on a private event loop with the deterministic inline
    executor, return per-request `MCResult`s in submission order. The
    entry point the bench (`serve_coalesce`) and `serve_mc` CLI use."""

    async def go():
        srv = server if server is not None else McSweepServer(
            cfg if cfg is not None else McServeConfig(),
            executor=InlineExecutor())
        tasks = [asyncio.ensure_future(srv.submit(r)) for r in requests]
        await asyncio.sleep(0)  # run each submit up to its future await
        await srv.drain()
        return await asyncio.gather(*tasks), srv

    results, srv = asyncio.run(go())
    serve_sync.last_stats = srv.stats  # introspection for bench/selftest
    return results


serve_sync.last_stats = None
