"""MC-as-a-service: a coalescing sweep server over the Monte Carlo engine.

The expensive artifact of a Monte Carlo sweep is the compiled executable:
`_mc_core`'s jit cache keys on static shapes and flags, and everything
else — channel parameters, stepsizes, problem data, node counts, antenna
counts, minibatch fractions — is row *data* the padded batch axis already
fuses. Serving many clients is therefore a request-*coalescing* problem,
not a request-queueing one: requests whose static facets agree can be
packed into ONE engine call and pay one compile between them, exactly the
way the one-compile N/M/frac sweep benchmarks do, just across strangers.

The server is three small pieces:

* **Signature router.** Each `SweepRequest` maps to a compile-cache
  signature (`exec.static_signature` — the same hashing machinery the
  resume fingerprint uses, restricted to static facets: problem kind and
  registry row fns, dim, fading family, steps, the (seeds, seed0) axis,
  the algorithm, stochastic/antenna modes). Signature-equal requests
  coalesce into one padded `run_mc` batch — their node counts, channel
  params, stepsizes, antenna counts, minibatch fractions and power
  budgets concatenate as row data; signature-distinct requests never
  share a batch. K concurrent requests compile exactly once per distinct
  signature (asserted by `trace_count()` in the tests and the
  `serve_mc --selftest` CI job).

* **Admission control.** `exec.estimate_peak_bytes` prices each request
  (and each growing batch) against `McServeConfig.memory_budget_bytes`.
  A request whose own single-quantum working set exceeds the budget is
  rejected at `submit` with a typed `AdmissionError`; an affordable
  request that would push a batch over the budget (or past
  `max_batch_rows`) closes the batch and starts the next one — same
  signature, but scheduled separately.

* **Pad-waste-aware bucketing.** Coalescing pads every row to the batch
  N_max, so a N=32 minnow merged with a N=4096 whale pays N=4096 FLOPs
  per slot — cheap cold (one compile amortized across strangers), a pure
  tax warm. The router therefore quantizes each request into a geometric
  **N-bucket shape class** (`bucket_base`, ×2 by default) and prices
  merged-vs-separate with the measured cost model
  (`repro.core.mc.costmodel`): a signature group that spans buckets
  merges only when `predicted(merged) ≤ predicted(separate) +
  compile_amortization`, where each side charges `CostModel.compile_s`
  for every shape class this server instance has not executed yet (a
  per-instance registry, invalidated when `mc.clear_cache()` bumps
  `exec.cache_epoch()`). On top of the static prediction the router
  closes the loop with **measured layout feedback** (`measure_layouts`):
  once a (signature, bucket) group's shapes are compiled, it times its
  own warm batches (observations polluted by a recompile are discarded
  via `trace_count()`), tries the group's two layouts — `merged` (one
  padded batch) and `exact` (one batch per distinct N, zero pad) — once
  each, then routes to the measured-cheaper one (µs per padded node).
  Net effect: the first sight of a cross-bucket group merges (compiles
  dominate), and steady-state traffic settles into whatever mix of
  padded and exact batches this machine actually runs fastest — the
  `serve_coalesce` bench entry records the warm win. Counter-based RNG
  keeps every routing choice invisible in the numbers: bucketed demux ==
  solo `run_mc` ≤ 1e-6 (property-tested). `ServeStats.bucket_occupancy`,
  `ServeStats.layouts` and per-batch `pad_flops_ratio`/`layout` make the
  routing observable. (Observations are µs per *demanded* node, so for
  a stationary mix comparing rates compares round totals exactly.)

* **Fairness-preserving preemption.** A batch does not run its whole
  seed axis in one blocking call: the scheduler round-robins *seed
  quanta* of `quantum_seeds` across all live batches — the same
  seeds-are-data slicing `run_mc(seed_chunk=)` uses internally, driven
  here from the event loop so a 1024-seed whale cannot starve 4-seed
  minnows. Quantum k runs `run_mc(..., seeds=q, seed0=seed0 + off)`,
  which replays exactly the seed streams `seed0 + off .. seed0 + off + q`
  of the uninterrupted call (counter-based RNG), so sliced results are
  identical to single-shot ones. Seed counts that are multiples of the
  quantum share one compiled slice shape; a ragged final quantum costs
  one extra compile.

* **Fault tolerance.** Deadlines: a request still running when its
  (relative) `deadline_s` expires resolves with a typed `PartialResult`
  over the seeds its batch completed — the quantum scheduler's stitched
  per-quantum results make the partial statistics exactly what a
  dedicated `run_mc` over those seeds returns, and batchmates keep
  running. Retry: `McServeConfig.retry` re-attempts a failed engine
  quantum under capped exponential backoff before the failure reaches
  any client. Watchdog: `hang_threshold_s` quarantines a signature whose
  engine call ran too long (post-hoc on the injectable clock — fully
  deterministic under the test harness) so one poison request cannot
  starve the queue; later same-signature submits fail fast with
  `QuarantinedError` carrying the original cause.

Results demux back per request with `mc.slice_result` row views of the
batch `MCResult`. Clients cancelling mid-batch detach their future; the
batch still completes for its other requests (and a batch whose every
request cancelled is dropped without running its remaining quanta).

Determinism knobs — the test harness (`tests/_serving_harness.py`) and
the bench inject both: `clock` (only used for the coalesce window;
`ManualClock` advances virtual time without wall-clock sleeps) and
`executor` (`InlineExecutor` runs engine calls synchronously on the loop
thread in deterministic order; the default `LoopExecutor` uses a thread
so the event loop stays responsive under real traffic).

See docs/serving.md for the request schema and semantics;
`repro.launch.serve_mc` is the CLI front-end.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import math
import time
from collections import deque
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.mc import exec as exec_mod
from repro.core.mc.engine import MCResult, run_mc, slice_result
from repro.core.mc.exec import estimate_peak_bytes, host_seed_stats
from repro.core.mc.plan import RetryPolicy
from repro.core.mc.problems import PROBLEMS, MCProblem, MCProblemBatch
from repro.core.mc.slots import ALGO_REGISTRY


# --------------------------------------------------------------------------
# errors
# --------------------------------------------------------------------------
class ServeError(Exception):
    """Base class of the server's typed failures."""


class RequestError(ServeError):
    """Malformed request payload — raised at `submit`, before the request
    ever reaches the router queue (fail fast, nothing to poison)."""


class AdmissionError(ServeError):
    """Request rejected by admission control: its own single-quantum
    working set (analytic `estimate_peak_bytes`) exceeds the server's
    memory budget."""


class QuarantinedError(ServeError):
    """The request's signature is quarantined: an earlier engine call for
    it exceeded the hang threshold (`McServeConfig.hang_threshold_s`), so
    the watchdog fenced the signature off rather than let one poison
    request starve the queue. Carries the original cause; raised both on
    the hung batch's own futures and on every subsequent same-signature
    `submit`."""


@dataclasses.dataclass(frozen=True)
class PartialResult:
    """What a deadline-expired request resolves with (docs/serving.md):
    the statistics of the seeds its batch HAD completed when the deadline
    passed, instead of an error or an unbounded wait.

    result:          an `MCResult` over the completed seed prefix —
                     risks/cum_energy sliced to `seeds_completed`,
                     mean/ci95 computed over exactly those seeds (the
                     quantum scheduler replays per-seed streams, so these
                     match a dedicated `run_mc` over the same seeds).
                     None when the deadline passed before any quantum
                     finished (`seeds_completed == 0`).
    seeds_completed: seeds actually run when the deadline expired.
    seeds_requested: the request's full seed count.
    """

    result: Optional[MCResult]
    seeds_completed: int
    seeds_requested: int


# --------------------------------------------------------------------------
# request schema
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One client's sweep: rows (channel × stepsize, sharing one problem
    kind and one algorithm) × a private seed axis.

    problem:     a library-built `MCProblem` shared by every row, or one
                 per row (node counts may differ — rows pad to the batch
                 N_max like any engine sweep).
    channels:    one `ChannelConfig` per row (one fading family per
                 request; the family is static and part of the
                 signature).
    algo:        `ALGO_REGISTRY` name; static (part of the signature).
    betas:       one stepsize per row (row data).
    steps:       slot count (static).
    seeds:       Monte Carlo seed count — the seed-axis *shape* is static,
                 so it is part of the signature; the seed ints are data.
    seed0:       first seed; seed s uses `jax.random.key(seed0 + s)`,
                 the same stream a dedicated `run_mc` call would use.
    batch_frac:  minibatch fraction (scalar or per row) for stochastic
                 problem kinds; 1.0 = exact full-batch gradients.
                 Full-batch and minibatch requests never coalesce (the
                 no-sampling path is a different, cheaper program).
    n_antennas:  edge antenna count M (scalar broadcast or per row;
                 required for blind algorithms). Normalized to per-row
                 data so M-heterogeneous requests coalesce.
    power_budget: per-slot per-node transmit budget (scalar or per row;
                 row data, only `blind_ec` rows enforce it).
    momentum:    γ for momentum/nesterov rows (whole-call scalar, so it
                 is part of the signature).
    theta0:      shared starting iterate (whole-call data: requests must
                 agree on it to coalesce, so its bytes fold into the
                 signature); None = zeros.
    deadline_s:  relative deadline in seconds (measured on the server's
                 clock from admission). A request still running when it
                 expires resolves with a typed `PartialResult` over the
                 seeds its batch completed — batchmates are unaffected.
                 None falls back to `McServeConfig.default_deadline_s`
                 (None = no deadline). NOT a signature facet: requests
                 differing only in deadline still coalesce.
    """

    problem: Union[MCProblem, Sequence[MCProblem]]
    channels: Sequence[ChannelConfig]
    algo: str
    betas: Sequence[float]
    steps: int
    seeds: int
    seed0: int = 0
    batch_frac: Union[float, Sequence[float]] = 1.0
    n_antennas: Optional[Union[int, Sequence[int]]] = None
    power_budget: Optional[Union[float, Sequence[float]]] = None
    momentum: float = 0.9
    theta0: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class McServeConfig:
    """Server policy knobs (all documented in docs/serving.md).

    memory_budget_bytes: admission budget the analytic
        `estimate_peak_bytes` working sets are priced against.
    quantum_seeds: seeds per scheduling quantum — the preemption grain.
        Requests whose seed count is a multiple of it share one compiled
        slice shape.
    max_batch_rows: hard cap on rows per coalesced engine call.
    coalesce_window: seconds `serve_forever` waits after a wakeup for
        straggler requests before draining (0 = drain immediately).
    bucket_base: geometric base of the N-bucket shape classes the
        pad-waste-aware coalescer quantizes requests into (a request
        whose largest row has N nodes lands in class base^ceil(log_base
        N)). Values <= 1 (or 0/None) disable bucketing: every
        signature group merges monolithically, the pre-cost-model
        behavior.
    compile_amortization_s: extra predicted seconds a merged batch may
        cost over separate ones and still merge — slack biasing the
        merge decision toward fewer compiles/dispatches. Unseen shape
        classes already charge `CostModel.compile_s` inside the
        prediction; this knob is on top (default 0 = decide purely on
        predicted wall-clock).
    measure_layouts: close the loop on the cost model: once a
        (signature, bucket) group's shapes are compiled, time its warm
        batches, try the `merged` and `exact` layouts once each, and
        route steady-state traffic to the measured-cheaper one. False
        restores the purely predicted (always-merged-within-bucket)
        routing.
    default_deadline_s: deadline applied to requests that set none
        (None = unbounded). Per-request `SweepRequest.deadline_s` wins.
    hang_threshold_s: per-batch watchdog (None = off): an engine call
        whose elapsed time on the server clock exceeds this quarantines
        the batch's signature — its unresolved futures fail with
        `QuarantinedError`, and every later same-signature submit is
        rejected with the original cause, so one poison request cannot
        starve the queue.
    retry: a `RetryPolicy` re-attempting a failed engine quantum with
        capped exponential backoff (backoff waits on the server clock —
        virtual under the test harness). None (default) keeps the legacy
        fail-fast containment: the batch's futures carry the error.
    """

    memory_budget_bytes: int = 2 * 2**30
    quantum_seeds: int = 64
    max_batch_rows: int = 256
    coalesce_window: float = 0.0
    bucket_base: float = 2.0
    compile_amortization_s: float = 0.0
    measure_layouts: bool = True
    default_deadline_s: Optional[float] = None
    hang_threshold_s: Optional[float] = None
    retry: Optional[RetryPolicy] = None


# --------------------------------------------------------------------------
# injectable clock / executor
# --------------------------------------------------------------------------
class WallClock:
    """Real time: `serve_forever`'s coalesce window sleeps on the loop."""

    def time(self) -> float:
        return time.monotonic()

    async def sleep(self, dt: float) -> None:
        await asyncio.sleep(dt)


class LoopExecutor:
    """Default executor: engine calls run in the loop's default thread
    pool so the event loop keeps accepting submissions mid-quantum."""

    async def run(self, fn, info: Optional[dict] = None):
        return await asyncio.get_running_loop().run_in_executor(None, fn)


class InlineExecutor:
    """Deterministic executor: the engine call runs synchronously on the
    loop thread — quanta execute in exactly the order the scheduler
    issues them. One cooperative yield per quantum lets submissions that
    arrive mid-drain enqueue (and be served in the same drain pass)
    without introducing any thread or timing nondeterminism. Used by the
    tests, the bench and `serve_sync`."""

    async def run(self, fn, info: Optional[dict] = None):
        await asyncio.sleep(0)
        return fn()


# --------------------------------------------------------------------------
# internal records
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Pending:
    req: "_NormRequest"
    future: asyncio.Future
    # absolute deadline on the server clock (None = unbounded), and
    # whether this request already resolved with a PartialResult — which
    # is NOT a cancellation for the stats
    deadline: Optional[float] = None
    expired: bool = False


@dataclasses.dataclass(frozen=True)
class _NormRequest:
    """Validated, normalized request: per-row tuples throughout."""

    problems: tuple  # one MCProblem per row
    channels: tuple
    algo: str
    betas: tuple
    steps: int
    seeds: int
    seed0: int
    fracs: Optional[tuple]  # None = exact full-batch (no sampling path)
    m_per_row: Optional[tuple]
    budgets: Optional[tuple]
    momentum: float
    theta0: Optional[np.ndarray]
    signature: str
    b_max: int
    deadline_s: Optional[float]  # effective (request or config default)

    @property
    def n_rows(self) -> int:
        return len(self.channels)


@dataclasses.dataclass
class ServeStats:
    """Router observability, asserted on by the deterministic tests.

    `bucket_occupancy` counts admitted-and-routed requests per N-bucket
    shape class (empty while bucketing is disabled); each entry of
    `batches` records its batch's `n_max`, `bucket`, `layout` (the
    measured-feedback routing that produced it — None outside the
    layout loop) and `pad_flops_ratio` = rows·N_max / Σ N_i — the
    padded-FLOPs multiplier the batch actually paid (1.0 = no pad
    waste). `layouts` snapshots the router's measured layout
    observations: "sig12/bucket" -> {layout: µs per demanded node}."""

    admitted: int = 0
    rejected: int = 0
    cancelled: int = 0
    failed_batches: int = 0
    retries: int = 0
    deadline_expired: int = 0
    quarantined: int = 0
    batches: list = dataclasses.field(default_factory=list)
    bucket_occupancy: dict = dataclasses.field(default_factory=dict)
    layouts: dict = dataclasses.field(default_factory=dict)


class _Job:
    """One coalesced batch in flight: merged rows + a seed cursor."""

    def __init__(self, pending: Sequence[_Pending], cfg: McServeConfig,
                 layout=None):
        self.pending = list(pending)
        self.cfg = cfg
        # measured-layout bookkeeping: ((signature, bucket), layout name)
        # tag from the router, wall-µs of warm quanta, and whether any
        # quantum recompiled (which disqualifies the observation)
        self.layout = layout
        self.obs_us = 0.0
        self.recompiled = False
        first = pending[0].req
        self.signature = first.signature
        self.algo = first.algo
        self.steps, self.seeds = first.steps, first.seeds
        self.seed0 = first.seed0
        self.momentum, self.theta0 = first.momentum, first.theta0
        self.problems, self.channels, self.betas = [], [], []
        self.spans = []
        fracs, m_rows, budgets = [], [], []
        off = 0
        for p in pending:
            r = p.req
            self.problems += list(r.problems)
            self.channels += list(r.channels)
            self.betas += list(r.betas)
            fracs += list(r.fracs) if r.fracs is not None else []
            m_rows += list(r.m_per_row) if r.m_per_row is not None else []
            budgets += list(r.budgets if r.budgets is not None
                            else (float("inf"),) * r.n_rows)
            self.spans.append((off, off + r.n_rows))
            off += r.n_rows
        self.n_rows = off
        self.row_nodes = tuple(p.n_nodes for p in self.problems)
        self.fracs = tuple(fracs) if first.fracs is not None else None
        self.m_per_row = tuple(m_rows) if first.m_per_row is not None \
            else None
        self.budgets = (tuple(budgets)
                        if any(np.isfinite(b) for b in budgets) else None)
        self.off = 0  # seed cursor
        self.quanta_run = 0
        self.risks = np.empty((off, self.seeds, self.steps + 1), np.float32)
        self.cum_e = np.empty((off, self.seeds, self.steps), np.float32)

    @property
    def done(self) -> bool:
        return self.off >= self.seeds

    @property
    def abandoned(self) -> bool:
        """Every client detached (cancelled) — remaining quanta are
        freed instead of computing results nobody will read."""
        return all(p.future.done() for p in self.pending)


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------
class McSweepServer:
    """Asyncio front-end: `await submit(request)` -> per-request
    `MCResult`. Drive it either with `start()`/`stop()` (the
    `serve_forever` router task) or by calling `drain()` explicitly
    after a round of submissions (tests, `serve_sync`)."""

    def __init__(self, cfg: McServeConfig = McServeConfig(), *,
                 clock=None, executor=None, cost_model=None):
        self.cfg = cfg
        self.clock = clock if clock is not None else WallClock()
        self.executor = executor if executor is not None else LoopExecutor()
        self.stats = ServeStats()
        self._queue: list[_Pending] = []
        self._wakeup: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False
        # pad-waste-aware routing state: the injected (or lazily loaded)
        # CostModel, the per-instance registry of (signature, bucket)
        # shape classes this server has already executed, the measured
        # layout observations ((signature, bucket) -> {layout: [µs,
        # padded nodes]}) and the padded problem-pack cache — all
        # mirrored on `exec.cache_epoch()` so `mc.clear_cache()` forgets
        # them too
        self._cost_model = cost_model
        self._seen: set = set()
        self._layout_obs: dict = {}
        self._stack_cache: dict = {}
        self._seen_epoch = exec_mod.cache_epoch()
        # watchdog fence: signature -> original cause string; same-
        # signature submits are rejected with QuarantinedError(cause)
        self._quarantined: dict = {}

    # ---- client surface -------------------------------------------------
    async def submit(self, request: SweepRequest) -> MCResult:
        """Validate, admit and enqueue a request; resolves with this
        request's own `MCResult` slice once its batch completes. Raises
        `RequestError`/`AdmissionError` before enqueueing — a bad request
        never reaches the router queue. A signature the watchdog fenced
        off raises `QuarantinedError` with the original cause."""
        norm = self._normalize(request)
        cause = self._quarantined.get(norm.signature)
        if cause is not None:
            self.stats.rejected += 1
            raise QuarantinedError(
                f"signature {norm.signature[:12]} is quarantined: {cause}")
        self._admit(norm)
        self.stats.admitted += 1
        fut = asyncio.get_running_loop().create_future()
        deadline = None if norm.deadline_s is None \
            else self.clock.time() + norm.deadline_s
        self._queue.append(_Pending(req=norm, future=fut,
                                    deadline=deadline))
        if self._wakeup is not None:
            self._wakeup.set()
        return await fut

    def start(self) -> asyncio.Task:
        """Start the router (`serve_forever`) on the running loop."""
        self._wakeup = asyncio.Event()
        self._running = True
        self._task = asyncio.ensure_future(self.serve_forever())
        return self._task

    async def stop(self) -> None:
        self._running = False
        if self._wakeup is not None:
            self._wakeup.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def serve_forever(self) -> None:
        """Router loop: wake on submission, optionally hold the coalesce
        window open for stragglers, then drain the queue."""
        while self._running:
            await self._wakeup.wait()
            self._wakeup.clear()
            if not self._running:
                break
            if self.cfg.coalesce_window > 0:
                await self.clock.sleep(self.cfg.coalesce_window)
            await self.drain()

    async def drain(self) -> None:
        """Process everything queued now (and anything that arrives while
        draining): coalesce by signature, then round-robin one seed
        quantum per job until every job finishes."""
        while self._queue:
            pending, self._queue = self._queue, []
            ready = deque(_Job(group, self.cfg, layout=tag)
                          for group, tag in self._coalesce(pending))
            while ready:
                job = ready.popleft()
                self._expire_deadlines(job)
                if job.abandoned:
                    # futures all resolved — only true cancellations (not
                    # deadline expiries) count as cancelled; either way
                    # the remaining quanta are dropped, so an expired
                    # request never blocks the ring
                    self.stats.cancelled += sum(
                        1 for p in job.pending if not p.expired)
                    continue
                if not await self._run_quantum(job):
                    continue  # batch failed; futures already resolved
                self._expire_deadlines(job)
                if job.done:
                    self._finish(job)
                else:
                    ready.append(job)

    # ---- deadlines ------------------------------------------------------
    def _expire_deadlines(self, job: _Job) -> None:
        """Resolve every pending request whose deadline has passed with a
        `PartialResult` over the seeds the batch completed so far. Runs
        before and after every quantum: graceful degradation costs at
        most one quantum of latency, batchmates keep running, and a job
        whose every client expired becomes `abandoned` (its remaining
        quanta are dropped)."""
        now = self.clock.time()
        off = job.off
        for p, (lo, hi) in zip(job.pending, job.spans):
            if p.future.done() or p.deadline is None or now < p.deadline:
                continue
            if off > 0:
                risks = job.risks[lo:hi, :off].copy()
                cum_e = job.cum_e[lo:hi, :off].copy()
                mean, ci95 = host_seed_stats(risks)
                res = MCResult(risks=risks,
                               mean=mean.astype(np.float32),
                               ci95=ci95.astype(np.float32),
                               cum_energy=cum_e, bounds=None, plan=None)
            else:
                res = None
            p.expired = True
            self.stats.deadline_expired += 1
            p.future.set_result(PartialResult(
                result=res, seeds_completed=off,
                seeds_requested=job.seeds))

    # ---- validation / signature / admission -----------------------------
    def _normalize(self, req: SweepRequest) -> _NormRequest:
        if not isinstance(req, SweepRequest):
            raise RequestError(
                f"expected a SweepRequest, got {type(req).__name__}")
        channels = tuple(req.channels)
        n_rows = len(channels)
        if n_rows == 0:
            raise RequestError("request has no rows (empty channels)")
        if not all(isinstance(c, ChannelConfig) for c in channels):
            raise RequestError("channels must be ChannelConfig instances")
        if len({c.fading for c in channels}) != 1:
            raise RequestError(
                "one request = one fading family; split per family")
        probs = [req.problem] if isinstance(req.problem, MCProblem) \
            else list(req.problem)
        if not probs or not all(isinstance(p, MCProblem) for p in probs):
            raise RequestError("problem must be MCProblem(s)")
        if len(probs) == 1:
            probs = probs * n_rows
        if len(probs) != n_rows:
            raise RequestError(
                f"need one problem per row: {len(probs)} vs C={n_rows}")
        kind = probs[0].kind
        if any(p.kind != kind for p in probs):
            raise RequestError("rows must share one problem kind")
        if kind not in PROBLEMS or any(p.data is None for p in probs):
            raise RequestError(
                f"problem kind {kind!r} is not a registered library kind "
                "— the server batches strangers' rows, which needs the "
                "row-based PROBLEMS registry path")
        if len({p.dim for p in probs}) != 1:
            raise RequestError("rows must share the problem dim")
        shapes0 = {k: np.shape(v)[1:] for k, v in probs[0].data.items()}
        for p in probs[1:]:
            if {k: np.shape(v)[1:] for k, v in p.data.items()} != shapes0:
                raise RequestError(
                    "rows must agree on every non-node data shape "
                    "(only the node axis pads)")
        betas = tuple(float(b) for b in np.atleast_1d(
            np.asarray(req.betas, dtype=np.float64)))
        if len(betas) != n_rows:
            raise RequestError(
                f"need one stepsize per row: {len(betas)} vs C={n_rows}")
        if req.algo not in ALGO_REGISTRY:
            raise RequestError(
                f"unknown algo {req.algo!r}; expected one of "
                f"{tuple(ALGO_REGISTRY)}")
        if not (isinstance(req.steps, int) and req.steps > 0):
            raise RequestError(f"steps must be a positive int, "
                               f"got {req.steps!r}")
        if not (isinstance(req.seeds, int) and req.seeds > 0):
            raise RequestError(f"seeds must be a positive int, "
                               f"got {req.seeds!r}")
        # minibatch fractions -> per-row tuple, or None for full batch
        fr = req.batch_frac
        fracs = tuple(float(f) for f in (
            (fr,) * n_rows if isinstance(fr, (int, float)) else fr))
        if len(fracs) != n_rows:
            raise RequestError(
                f"need one batch_frac per row: {len(fracs)} vs C={n_rows}")
        if any(not (0.0 < f <= 1.0) for f in fracs):
            raise RequestError(f"batch_frac must be in (0, 1], got {fracs}")
        b_max = 0
        if all(f == 1.0 for f in fracs):
            fracs = None
        else:
            spec = PROBLEMS[kind]
            if spec.stochastic_grad_row is None:
                raise RequestError(
                    f"batch_frac < 1 needs a stochastic problem kind, "
                    f"got {kind!r}")
            k = probs[0].data[spec.sample_axis_field].shape[-2]
            b_max = max(max(1, int(round(f * k))) for f in fracs)
        # antennas -> per-row tuple (merged as data), or None
        m = req.n_antennas
        if m is None:
            m_per_row = None
            if ALGO_REGISTRY[req.algo].blind:
                raise RequestError(
                    f"algo {req.algo!r} is blind and needs n_antennas")
        else:
            m_per_row = tuple(int(x) for x in (
                (m,) * n_rows if isinstance(m, (int, np.integer)) else m))
            if len(m_per_row) != n_rows:
                raise RequestError(f"need one antenna count per row: "
                                   f"{len(m_per_row)} vs C={n_rows}")
            if any(x < 1 for x in m_per_row):
                raise RequestError(f"antenna counts must be >= 1: "
                                   f"{m_per_row}")
        pb = req.power_budget
        if pb is None:
            budgets = None
        else:
            budgets = tuple(float(b) for b in (
                (pb,) * n_rows if isinstance(pb, (int, float)) else pb))
            if len(budgets) != n_rows:
                raise RequestError(f"need one power budget per row: "
                                   f"{len(budgets)} vs C={n_rows}")
        theta0 = None if req.theta0 is None \
            else np.asarray(req.theta0, np.float32)
        if theta0 is not None and theta0.shape != (probs[0].dim,):
            raise RequestError(
                f"theta0 shape {theta0.shape} != (dim,) = "
                f"({probs[0].dim},)")
        deadline_s = req.deadline_s if req.deadline_s is not None \
            else self.cfg.default_deadline_s
        if deadline_s is not None and not deadline_s > 0:
            raise RequestError(
                f"deadline_s must be positive, got {deadline_s!r}")
        sig = self._signature(kind, probs[0], req.algo, req.steps,
                              req.seeds, req.seed0, channels[0].fading,
                              fracs is not None, m_per_row is not None,
                              req.momentum, theta0)
        return _NormRequest(
            problems=tuple(probs), channels=channels, algo=req.algo,
            betas=betas, steps=int(req.steps), seeds=int(req.seeds),
            seed0=int(req.seed0), fracs=fracs, m_per_row=m_per_row,
            budgets=budgets, momentum=float(req.momentum), theta0=theta0,
            signature=sig, b_max=b_max, deadline_s=deadline_s)

    @staticmethod
    def _signature(kind, prob, algo, steps, seeds, seed0, fading,
                   stochastic, antennas, momentum, theta0) -> str:
        """The request's compile-cache signature (module docstring):
        static facets only, via `exec.static_signature`. Node counts,
        channel params, stepsizes, antenna counts, fractions and budgets
        are deliberately absent — they are row data the padded batch
        fuses. Non-node data shapes (e.g. the per-node sample count of a
        stochastic kind) are static, so they are in."""
        spec = PROBLEMS[kind]
        data_shapes = tuple(sorted(
            (name, tuple(np.shape(v)[1:]))
            for name, v in prob.data.items()))
        th = None if theta0 is None else hashlib.sha256(
            np.ascontiguousarray(theta0).tobytes()).hexdigest()
        return exec_mod.static_signature({
            "kind": kind, "grad_fn": spec.grad_row,
            "risk_fn": spec.risk_row, "dim": prob.dim,
            "data_shapes": data_shapes, "fading": fading,
            "steps": steps, "seeds": seeds, "seed0": seed0, "algo": algo,
            "stochastic": stochastic, "antennas": antennas,
            "momentum": momentum, "theta0": th,
        })

    def _estimate(self, reqs: Sequence[_NormRequest]) -> int:
        """Analytic single-quantum working set of one coalesced batch."""
        n_rows = sum(r.n_rows for r in reqs)
        n_max = max(p.n_nodes for r in reqs for p in r.problems)
        m_sizes = tuple(sorted({m for r in reqs
                                for m in (r.m_per_row or ())}))
        first = reqs[0]
        est = estimate_peak_bytes(
            n_rows=n_rows, seeds=first.seeds, steps=first.steps,
            n_max=n_max, dim=first.problems[0].dim,
            algo_set=(first.algo,),
            seed_chunk=min(self.cfg.quantum_seeds, first.seeds),
            m_sizes=m_sizes, b_max=first.b_max, keep_seed_curves=True)
        return est["device_peak_bytes"]

    def _admit(self, norm: _NormRequest) -> None:
        est = self._estimate([norm])
        if est > self.cfg.memory_budget_bytes:
            self.stats.rejected += 1
            raise AdmissionError(
                f"request needs ~{est} bytes per seed quantum "
                f"(analytic estimate_peak_bytes at quantum_seeds="
                f"{self.cfg.quantum_seeds}) > budget "
                f"{self.cfg.memory_budget_bytes} — shrink the request "
                "(rows / nodes / dim) or raise the server budget")

    # ---- coalescing -----------------------------------------------------
    def _coalesce(self, pending: Sequence[_Pending]) -> list:
        """Group signature-equal requests (submission order preserved),
        partition each group by the pad-waste-aware bucket rule
        (`_partition`), then pack every partition into batches under the
        admission budget and the row cap. Returns a list of
        (pending-list, layout-tag) pairs, one per batch. Every routed
        request's shape class is recorded in the seen-registry
        afterwards — the next drain prices those classes as already
        compiled."""
        self._sync_seen_epoch()
        groups: dict[str, list[_Pending]] = {}
        for p in pending:
            groups.setdefault(p.req.signature, []).append(p)
        batches = []
        for sig, group in groups.items():
            for part, tag in self._partition(sig, group):
                batches.extend((b, tag) for b in self._pack(part))
        if self._bucketing:
            occ = self.stats.bucket_occupancy
            for batch, _ in batches:
                for p in batch:
                    b = self._bucket(max(pr.n_nodes
                                         for pr in p.req.problems))
                    self._seen.add((p.req.signature, b))
                    occ[b] = occ.get(b, 0) + 1
        return batches

    @property
    def _bucketing(self) -> bool:
        base = self.cfg.bucket_base
        return bool(base) and base > 1.0

    def _bucket(self, n: int) -> int:
        """The geometric shape class of node count `n`: the smallest
        base^k >= n (integer-rounded so fractional bases stay exact)."""
        b = 1
        while b < n:
            b = max(b + 1, int(math.ceil(b * self.cfg.bucket_base)))
        return b

    def _sync_seen_epoch(self) -> None:
        epoch = exec_mod.cache_epoch()
        if epoch != self._seen_epoch:
            self._seen.clear()
            self._layout_obs.clear()
            self._stack_cache.clear()
            self._seen_epoch = epoch

    def cost_model(self):
        """The routing `CostModel`: injected at construction, else the
        calibration artifact for this platform/device-count, else the
        analytic fallback (lazy — servers that never see cross-bucket
        traffic never load it)."""
        if self._cost_model is None:
            from repro.core.mc import costmodel as costmodel_mod

            self._cost_model = (costmodel_mod.load_cost_model()
                                or costmodel_mod.analytic_cost_model())
        return self._cost_model

    def _predict_batch_us(self, reqs: Sequence[_NormRequest]) -> float:
        """Predicted wall-clock of serving `reqs` as ONE padded batch,
        priced the way the scheduler will actually run it: every row at
        the merged N_max, seed quanta as the chunk grain, single device
        (`shard_seeds=False` in `_engine_call`)."""
        from repro.core.mc.costmodel import Workload
        from repro.core.mc.plan import ExecPlan

        first = reqs[0]
        wl = Workload(
            n_rows=sum(r.n_rows for r in reqs), seeds=first.seeds,
            steps=first.steps,
            n_max=max(p.n_nodes for r in reqs for p in r.problems),
            dim=first.problems[0].dim, algo_set=(first.algo,),
            m_sizes=tuple(sorted({m for r in reqs
                                  for m in (r.m_per_row or ())})),
            b_max=max(r.b_max for r in reqs))
        plan = ExecPlan(seed_chunk=min(self.cfg.quantum_seeds,
                                       first.seeds),
                        n_shards=0, row_shards=1, keep_seed_curves=True)
        return self.cost_model().predict_run_us(plan, wl, device_count=1)

    def _partition(self, sig: str, group: list) -> list:
        """The merge decision (docs/serving.md), two levels, returning
        (part, layout-tag) pairs.

        Cross-bucket (predicted): a signature group that spans several
        N-buckets merges only when the cost model prices the merged
        padded batch at or below the per-bucket batches — each side
        charged `compile_s` per shape class this server has not executed
        yet, plus the `compile_amortization_s` slack on the separate
        side.

        Within-bucket (measured): each per-bucket group with more than
        one distinct N then picks its layout — `merged` (one padded
        batch) or `exact` (one zero-pad batch per distinct N) — from the
        router's own warm-batch timings: unseen shapes merge (compile
        amortization), each layout is explored once, then traffic
        exploits the measured-cheaper µs per demanded node (ties
        merge). Bucketing disabled = everything merges, untagged."""
        if not self._bucketing:
            return [(group, None)]
        sub: dict[int, list] = {}
        for p in group:
            b = self._bucket(max(pr.n_nodes for pr in p.req.problems))
            sub.setdefault(b, []).append(p)
        if len(sub) > 1:
            compile_us = self.cost_model().compile_s * 1e6
            t_merged = self._predict_batch_us([p.req for p in group])
            if (sig, max(sub)) not in self._seen:
                t_merged += compile_us  # merged batch compiles at max-N
            t_sep = 0.0
            for b, ps in sub.items():
                t_sep += self._predict_batch_us([p.req for p in ps])
                if (sig, b) not in self._seen:
                    t_sep += compile_us
            slack = self.cfg.compile_amortization_s * 1e6
            if t_merged <= t_sep + slack:
                return [(group, None)]
        parts = []
        for b in sorted(sub):
            parts.extend(self._layout(sig, b, sub[b]))
        return parts

    def _layout(self, sig: str, bucket: int, ps: list) -> list:
        """Route one (signature, bucket) group by measured layout
        feedback; returns (part, tag) pairs. Groups with a single
        distinct N have nothing to decide (merged == exact)."""
        by_n: dict[int, list] = {}
        for p in ps:
            n = max(pr.n_nodes for pr in p.req.problems)
            by_n.setdefault(n, []).append(p)
        if len(by_n) <= 1:
            return [(ps, None)]
        if not self.cfg.measure_layouts:
            return [(ps, None)]  # purely predicted routing: merge
        key = (sig, bucket)
        obs = self._layout_obs.get(key, {})
        if key not in self._seen:
            choice = "merged"  # first sight: compile amortization wins
        elif "merged" not in obs:
            choice = "merged"  # explore the padded layout first
        elif "exact" not in obs:
            choice = "exact"
        else:
            per_node = {k: v[0] / max(v[1], 1) for k, v in obs.items()}
            choice = ("merged" if per_node["merged"] <= per_node["exact"]
                      else "exact")
        if choice == "merged":
            return [(ps, (key, "merged"))]
        return [(by_n[n], (key, "exact")) for n in sorted(by_n)]

    def _pack(self, group: list) -> list:
        """Greedy-pack one mergeable run of requests into batches under
        the admission budget and the row cap."""
        batches = []
        cur: list[_Pending] = []
        for p in group:
            trial = [q.req for q in cur] + [p.req]
            rows = sum(r.n_rows for r in trial)
            if cur and (rows > self.cfg.max_batch_rows
                        or self._estimate(trial)
                        > self.cfg.memory_budget_bytes):
                batches.append(cur)
                cur = [p]
            else:
                cur.append(p)
        batches.append(cur)
        return batches

    # ---- execution ------------------------------------------------------
    def _stacked(self, problems: Sequence[MCProblem]) -> MCProblemBatch:
        """The padded problem pack for `problems`, cached per identity
        tuple: persistent servers re-serving the same library-built
        problems skip the numpy re-pad every round (problem data is
        treated as immutable after submit). The cache holds strong
        references, so the id-keys cannot alias, and is bounded."""
        key = tuple(map(id, problems))
        hit = self._stack_cache.get(key)
        if hit is None:
            hit = (MCProblemBatch.stack(problems), tuple(problems))
            while len(self._stack_cache) >= 64:
                self._stack_cache.pop(next(iter(self._stack_cache)))
            self._stack_cache[key] = hit
        return hit[0]

    def _engine_call(self, job: _Job, off: int, q: int):
        res = run_mc(
            self._stacked(job.problems), job.channels, job.algo,
            job.betas, job.steps, q, seed0=job.seed0 + off,
            theta0=job.theta0, n_antennas=job.m_per_row,
            power_budget=job.budgets,
            batch_frac=job.fracs if job.fracs is not None else 1.0,
            momentum=job.momentum, shard_seeds=False)
        return res.risks, res.cum_energy

    async def _run_quantum(self, job: _Job) -> bool:
        """One scheduling quantum of `job`; False when the batch failed
        (its futures carry the exception) and must leave the ring.

        With `cfg.retry` set, a failed engine call re-attempts under the
        policy's capped backoff (waited on the server clock) before the
        failure is routed to the clients — counter-based RNG replays the
        quantum's exact seed streams, so a retried quantum is
        indistinguishable from a first-try one. With
        `cfg.hang_threshold_s` set, an engine call whose elapsed server-
        clock time exceeds the threshold quarantines the signature
        (post-hoc watchdog: deterministic under an injected clock, no
        racing timers)."""
        off = job.off
        q = min(self.cfg.quantum_seeds, job.seeds - off)
        info = {"signature": job.signature[:12], "off": off, "quantum": q,
                "rows": job.n_rows}
        attempt = 1
        while True:
            tc0 = exec_mod.trace_count()
            t0 = time.perf_counter()
            w0 = self.clock.time()
            try:
                risks, cum_e = await self.executor.run(
                    lambda: self._engine_call(job, off, q), info=info)
                break
            except Exception as e:  # noqa: BLE001 — routed to the clients
                policy = self.cfg.retry
                if policy is not None and attempt < policy.max_attempts:
                    self.stats.retries += 1
                    await self.clock.sleep(policy.delay_s(attempt))
                    attempt += 1
                    continue
                self.stats.failed_batches += 1
                for p in job.pending:
                    if not p.future.done():
                        p.future.set_exception(
                            ServeError(f"batch {job.signature[:12]} failed "
                                       f"at seed offset {off}: {e!r}"))
                return False
        elapsed = self.clock.time() - w0
        if self.cfg.hang_threshold_s is not None \
                and elapsed > self.cfg.hang_threshold_s:
            cause = (f"engine call at seed offset {off} took "
                     f"{elapsed:.3f}s > hang_threshold_s="
                     f"{self.cfg.hang_threshold_s}")
            self._quarantined[job.signature] = cause
            self.stats.quarantined += 1
            for p in job.pending:
                if not p.future.done():
                    p.future.set_exception(QuarantinedError(
                        f"signature {job.signature[:12]} quarantined: "
                        f"{cause}"))
            return False
        job.obs_us += (time.perf_counter() - t0) * 1e6
        if exec_mod.trace_count() != tc0:
            job.recompiled = True  # compile pollutes the warm timing
        job.risks[:, off:off + q] = risks
        job.cum_e[:, off:off + q] = cum_e
        job.off = off + q
        job.quanta_run += 1
        return True

    def _finish(self, job: _Job) -> None:
        mean, ci95 = host_seed_stats(job.risks)
        full = MCResult(risks=job.risks, mean=mean.astype(np.float32),
                        ci95=ci95.astype(np.float32), cum_energy=job.cum_e,
                        bounds=None, plan=None)
        cancelled = expired = 0
        for p, (lo, hi) in zip(job.pending, job.spans):
            if p.future.done():  # cancelled mid-batch, or deadline fired
                if p.expired:
                    expired += 1
                else:
                    cancelled += 1
                continue
            p.future.set_result(slice_result(full, slice(lo, hi)))
        self.stats.cancelled += cancelled
        n_max = max(job.row_nodes)
        if job.layout is not None and not job.recompiled:
            key, choice = job.layout
            ent = self._layout_obs.setdefault(key, {}) \
                .setdefault(choice, [0.0, 0])
            ent[0] += job.obs_us
            # normalize by the *demanded* (unpadded) nodes: both layouts
            # serve the same traffic, so µs per demanded node compares
            # totals exactly — the merged layout's pad tax shows up as a
            # worse rate, not a bigger denominator
            ent[1] += sum(job.row_nodes)
            self.stats.layouts[f"{key[0][:12]}/{key[1]}"] = {
                k: round(v[0] / max(v[1], 1), 2)
                for k, v in self._layout_obs[key].items()}
        self.stats.batches.append({
            "signature": job.signature[:12],
            "requests": len(job.pending),
            "rows": job.n_rows,
            "seeds": job.seeds,
            "quanta": job.quanta_run,
            "cancelled": cancelled,
            "expired": expired,
            "n_max": n_max,
            "bucket": self._bucket(n_max) if self._bucketing else 0,
            "layout": job.layout[1] if job.layout is not None else None,
            "pad_flops_ratio": round(
                job.n_rows * n_max / sum(job.row_nodes), 4),
        })


# --------------------------------------------------------------------------
# synchronous convenience front-end
# --------------------------------------------------------------------------
def serve_sync(requests: Sequence[SweepRequest],
               cfg: McServeConfig = None,
               server: McSweepServer = None) -> list:
    """One-shot synchronous façade: submit every request, coalesce, run
    to completion on a private event loop with the deterministic inline
    executor, return per-request `MCResult`s in submission order. The
    entry point the bench (`serve_coalesce`) and `serve_mc` CLI use."""

    async def go():
        srv = server if server is not None else McSweepServer(
            cfg if cfg is not None else McServeConfig(),
            executor=InlineExecutor())
        tasks = [asyncio.ensure_future(srv.submit(r)) for r in requests]
        await asyncio.sleep(0)  # run each submit up to its future await
        await srv.drain()
        return await asyncio.gather(*tasks), srv

    results, srv = asyncio.run(go())
    serve_sync.last_stats = srv.stats  # introspection for bench/selftest
    return results


serve_sync.last_stats = None
