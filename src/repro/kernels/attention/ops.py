"""Jitted public wrapper around the flash-attention kernel.

Handles GQA head grouping ((B, Hq, S, d) queries vs (B, Hkv, S, d) kv),
backend dispatch (Pallas on TPU, blockwise-jnp on CPU), and padding of
sequence lengths to block boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_kernel
from repro.kernels.attention.ref import attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "impl", "interpret"),
)
def multi_head_attention(
    q: jax.Array,  # (B, Hq, Sq, d)
    k: jax.Array,  # (B, Hkv, Skv, d)
    v: jax.Array,  # (B, Hkv, Skv, d)
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    impl: str = "auto",  # 'auto' | 'pallas' | 'ref'
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hq % hkv:
        raise ValueError(f"query heads {hq} must be a multiple of kv heads {hkv}")
    group = hq // hkv
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"

    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    if impl == "ref":
        out = attention_ref(
            q.reshape(b * hq, sq, d),
            k.reshape(b * hq, -1, d),
            v.reshape(b * hq, -1, d),
            scale=scale, causal=causal, window=window, softcap=softcap,
        )
        return out.reshape(b, hq, sq, d)

    skv = k.shape[2]
    blk_q = min(128, sq) if sq >= 128 else sq
    blk_k = min(128, skv) if skv >= 128 else skv
    pad_q = (-sq) % blk_q
    pad_k = (-skv) % blk_k
    if pad_k and not causal:
        # zero-padded kv columns would attend under a non-causal mask;
        # non-causal callers (cross-attention) fall back to the oracle path
        out = attention_ref(
            q.reshape(b * hq, sq, d), k.reshape(b * hq, skv, d),
            v.reshape(b * hq, skv, d),
            scale=scale, causal=causal, window=window, softcap=softcap,
        )
        return out.reshape(b, hq, sq, d)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded kv columns must not attend: push them outside the causal frontier
    # by relying on causal mask when enabled; otherwise mask via big negative k
    out = flash_attention_kernel(
        qp.reshape(b * hq, sq + pad_q, d),
        kp.reshape(b * hq, skv + pad_k, d),
        vp.reshape(b * hq, skv + pad_k, d),
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=blk_q, block_k=blk_k, interpret=interpret,
    )
    return out.reshape(b, hq, sq + pad_q, d)[:, :, :sq, :]
