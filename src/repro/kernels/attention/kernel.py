"""Pallas TPU flash-attention kernel (online-softmax, VMEM-tiled).

Supports the attention variants required by the assigned architectures:
  * causal masking                       (all decoder stacks)
  * sliding-window masking               (gemma2 local layers, hymba, llama4-chunked)
  * logit soft-capping cap*tanh(x/cap)   (gemma2)
  * GQA via head-group reshape in ops.py (all GQA/MQA archs)

TPU adaptation: the (Sq, Skv) score matrix is never materialized in HBM —
the grid walks (batch*heads, q_blocks, kv_blocks) with the kv dimension
innermost/sequential; running max/denominator and the output accumulator live
in VMEM scratch. Block shapes are (128, head_dim) / (128, head_dim), keeping
the MXU matmul dims at the native 128 alignment. Accumulation is fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 softcap: float | None, block_q: int, block_k: int,
                 n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)  # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal or window is not None:
        # skip fully-masked kv blocks entirely (their columns can't contribute)
        first_q = qi * block_q
        last_q = first_q + block_q - 1
        first_k = kj * block_k
        last_k = first_k + block_k - 1
        live = jnp.bool_(True)
        if causal:
            live &= last_q >= first_k
        if window is not None:
            live &= (first_q - last_k) < window

        @pl.when(live)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "block_q",
                     "block_k", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BH, Skv, d)
    v: jax.Array,  # (BH, Skv, d)
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq lens ({sq},{skv}) must tile by ({block_q},{block_k})")
    n_kv_blocks = skv // block_k
    grid = (bh, sq // block_q, n_kv_blocks)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
