"""Pure-jnp oracle for the flash-attention kernel: full-softmax attention
with identical masking/softcap semantics, materializing the score matrix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BH, Skv, d)
    v: jax.Array,  # (BH, Skv, d)
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    sq, skv = q.shape[1], k.shape[1]
    q_idx = q_offset + jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
