"""Pallas TPU kernel for the RWKV6 (Finch) WKV recurrence with
data-dependent decay [arXiv:2404.05892]:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: (D_k, D_v) per head)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU adaptation: the recurrence is inherently sequential in t, so the kernel
keeps the per-(batch, head) state S resident in VMEM across *time-chunk* grid
steps — HBM traffic is one read of (r,k,v,w) per chunk and one write of o,
instead of per-step state round-trips (the naive scan's 2*T*D*D state
traffic). The grid is (B*H, T/C) with the time dimension sequential; inside a
chunk a fori_loop performs C rank-1 updates on the VMEM-resident S with VPU
outer products. D=64 lanes align with the VPU registers. A fully parallel
chunked-matmul formulation (q̃(KᵀV) style) is a further §Perf step; it trades
the sequential VPU work for MXU matmuls but needs per-channel log-space
rescaling to stay stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
                s_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)  # (1, D)

    def step(t, _):
        r = r_ref[0, t, :].astype(jnp.float32)[None, :]  # (1, D)
        k = k_ref[0, t, :].astype(jnp.float32)[None, :]
        v = v_ref[0, t, :].astype(jnp.float32)[None, :]
        w = w_ref[0, t, :].astype(jnp.float32)[None, :]
        s = s_ref[...]  # (D, D): rows = k-channels, cols = v-channels
        kv = k.T @ v  # rank-1 outer product (D, D)
        o = r @ (s + u.T * kv)  # (1, D)
        o_ref[0, t, :] = o[0].astype(o_ref.dtype)
        s_ref[...] = w.T * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        sf_ref[0] = s_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_kernel(
    r: jax.Array,  # (BH, T, D) receptance
    k: jax.Array,  # (BH, T, D) key
    v: jax.Array,  # (BH, T, D) value
    w: jax.Array,  # (BH, T, D) decay in (0,1): exp(-exp(w_raw))
    u: jax.Array,  # (BH, D)    per-channel bonus
    s0: jax.Array,  # (BH, D, D) initial state
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bh, t, d = r.shape
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"T={t} must tile by chunk={chunk}")
    n_chunks = t // chunk
    grid = (bh, n_chunks)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    out, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),  # r
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),  # k
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),  # v
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),  # w
            pl.BlockSpec((1, d), lambda b, c: (b, 0)),  # u
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),  # s0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), r.dtype),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_fin
