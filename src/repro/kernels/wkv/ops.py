"""Jitted public wrapper for the WKV6 recurrence kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv.kernel import wkv6_kernel
from repro.kernels.wkv.ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "interpret"))
def wkv6(
    r: jax.Array,  # (B, H, T, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, D)
    s0: jax.Array | None = None,  # (B, H, D, D)
    *,
    impl: str = "auto",
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, h, t, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    flat = lambda x: x.reshape(b * h, *x.shape[2:])
    u_b = jnp.broadcast_to(u[None], (b, h, d))
    if impl == "ref":
        out, s_fin = wkv6_ref(flat(r), flat(k), flat(v), flat(w), flat(u_b), flat(s0))
    else:
        pad = (-t) % chunk
        pads = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        out, s_fin = wkv6_kernel(
            pads(flat(r)), pads(flat(k)), pads(flat(v)),
            # pad decay with ones so the padded tail leaves the state intact
            jnp.pad(flat(w), ((0, 0), (0, pad), (0, 0)), constant_values=1.0),
            flat(u_b), flat(s0), chunk=min(chunk, t + pad), interpret=interpret,
        )
        out = out[:, :t]
    return out.reshape(b, h, t, d), s_fin.reshape(b, h, d, d)
