"""Pure-jnp oracle for the WKV6 recurrence.

`wkv6_ref` scans chunk-by-chunk with a rematerialized (checkpointed) chunk
body: the backward pass stores only chunk-boundary states (T/C x (D,D) per
head) and recomputes the in-chunk steps — without this, training a 32-layer
RWKV at 4k context stores a (B,H,D,D) state per *timestep* (hundreds of GiB).
The per-step reference `wkv6_ref_naive` is kept as the test oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

REF_CHUNK = 64


def _step(s, inp, u):
    rt, kt, vt, wt = inp  # each (BH, D)
    kv = kt[:, :, None] * vt[:, None, :]  # (BH, D, D)
    ot = jnp.einsum("bi,bij->bj", rt, s + u[:, :, None] * kv)
    s_new = wt[:, :, None] * s + kv
    return s_new, ot


def wkv6_ref_naive(r, k, v, w, u, s0):
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf, s0f = u.astype(jnp.float32), s0.astype(jnp.float32)
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    s_fin, out = jax.lax.scan(functools.partial(_step, u=uf), s0f, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), s_fin


def wkv6_ref(
    r: jax.Array,  # (BH, T, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0,1)
    u: jax.Array,  # (BH, D)
    s0: jax.Array,  # (BH, D, D)
) -> tuple[jax.Array, jax.Array]:
    bh, t, d = r.shape
    chunk = min(REF_CHUNK, t)
    if t % chunk:
        return wkv6_ref_naive(r, k, v, w, u, s0)
    nc = t // chunk
    rf, kf, vf, wf = (x.astype(jnp.float32).reshape(bh, nc, chunk, d)
                      for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def chunk_body(s, inp):
        rc, kc, vc, wc = inp  # (BH, chunk, D)
        xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rc, kc, vc, wc))
        s_new, out = jax.lax.scan(functools.partial(_step, u=uf), s, xs)
        return s_new, jnp.moveaxis(out, 0, 1)

    chunk_body = jax.checkpoint(
        chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    s_fin, out = jax.lax.scan(chunk_body, s0.astype(jnp.float32), xs)
    out = jnp.moveaxis(out, 0, 1).reshape(bh, t, d)
    return out.astype(r.dtype), s_fin
