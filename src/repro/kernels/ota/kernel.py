"""Pallas TPU kernel for the fused OTA-MAC edge aggregation (paper Eq. 8).

Computes  v = (1/N) * sum_n h[n] * g[n, :] + noise_scale * w  over a tile grid,
fusing the per-node gain scaling, the MAC superposition (the reduction), the
1/N matched-filter normalization and the edge-noise add. The (N, d) matrix of
*scaled* gradients is never materialized in HBM: node blocks stream through
VMEM and accumulate into a d-tile resident accumulator.

TPU adaptation notes (vs the radio physical layer / a GPU port):
  * the "superposition" is a VMEM-resident accumulation over node blocks —
    the reduction dimension (nodes) is tiled innermost so each d-tile of the
    output is produced once (one HBM write per output tile);
  * tiles are (NODE_BLK, LANE_BLK) with LANE_BLK a multiple of 128 to align
    with the VPU lane width; the gain vector block is broadcast across lanes;
  * accumulation is fp32 regardless of input dtype (bf16 gradients are
    upcast on load), matching the MXU/VPU-native mixed-precision idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_NODE_BLK = 128
DEFAULT_LANE_BLK = 512


def _ota_kernel(g_ref, h_ref, w_ref, o_ref, acc_ref, *, n_nodes: int,
                noise_scale: float, n_node_blocks: int):
    """Grid: (d_blocks, node_blocks); node dim innermost (sequential)."""
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)  # (NODE_BLK, LANE_BLK)
    h = h_ref[...].astype(jnp.float32)  # (NODE_BLK, 1)
    acc_ref[...] += jnp.sum(h * g, axis=0, keepdims=True)  # (1, LANE_BLK)

    @pl.when(nb == n_node_blocks - 1)
    def _finalize():
        v = acc_ref[...] / n_nodes
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] = (v + noise_scale * w).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("noise_scale", "n_nodes", "node_blk", "lane_blk",
                     "interpret", "out_dtype"),
)
def ota_edge_aggregate_kernel(
    grads: jax.Array,  # (N, d)
    gains: jax.Array,  # (N,)
    noise: jax.Array,  # (d,) standard-normal draws (edge noise, pre-scaled by 1)
    *,
    noise_scale: float,
    n_nodes: int | None = None,
    node_blk: int = DEFAULT_NODE_BLK,
    lane_blk: int = DEFAULT_LANE_BLK,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """`n_nodes` is the matched-filter normalization N (Eq. 8). Callers that
    zero-pad the node dimension pass the TRUE node count here: padded rows
    have zero gain and add nothing to the superposition, so normalizing by
    the true N inside the kernel is exact — no host-side rescaling (which
    would double-round the noise term through the output dtype).

    `out_dtype` (default: grads.dtype) is the emission dtype of the f32
    VMEM accumulator — the bf16-transmit/f32-accumulate transport path
    streams bf16 gradient tiles but keeps the received update in f32."""
    n, d = grads.shape
    if n_nodes is None:
        n_nodes = n
    if out_dtype is None:
        out_dtype = grads.dtype
    node_blk = min(node_blk, n)
    lane_blk = min(lane_blk, d)
    if n % node_blk or d % lane_blk:
        raise ValueError(f"(N={n}, d={d}) must tile by ({node_blk}, {lane_blk})")
    n_node_blocks = n // node_blk
    grid = (d // lane_blk, n_node_blocks)

    kernel = functools.partial(
        _ota_kernel,
        n_nodes=n_nodes,
        noise_scale=noise_scale,
        n_node_blocks=n_node_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((node_blk, lane_blk), lambda i, j: (j, i)),  # grads
            pl.BlockSpec((node_blk, 1), lambda i, j: (j, 0)),  # gains
            pl.BlockSpec((1, lane_blk), lambda i, j: (0, i)),  # noise
        ],
        out_specs=pl.BlockSpec((1, lane_blk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((1, lane_blk), jnp.float32)],
        interpret=interpret,
    )(grads, gains.reshape(n, 1), noise.reshape(1, d))
    return out.reshape(d)
