"""Jitted public wrapper for the OTA edge-aggregation kernel.

Dispatches to the Pallas TPU kernel on TPU backends (interpret mode for CPU
testing) and to the jnp oracle otherwise; pads N and d to tile boundaries.

`noise_scale` is a TRACED scalar operand: sweeping noise levels (or N,
whose edge-noise std depends on it) reuses one compiled program per
(shape, impl) pair instead of recompiling per float value. Only `impl`,
`interpret` and `out_dtype` remain static. `trace_count()` /
`clear_cache()` mirror `repro.core.montecarlo`'s compile-counting surface
so tests can assert the wrapper's compile behaviour.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ota.kernel import ota_edge_aggregate_kernel
from repro.kernels.ota.ref import ota_edge_aggregate_ref

_TRACE_COUNT = 0


def trace_count(reset: bool = False) -> int:
    """Times the jitted wrapper body has been traced (== XLA compiles)
    since import or the last reset; `clear_cache()` also zeroes it."""
    global _TRACE_COUNT
    count = _TRACE_COUNT
    if reset:
        _TRACE_COUNT = 0
    return count


def clear_cache() -> bool:
    """Drop the wrapper's compiled cache and reset the trace counter.
    Returns False on JAX versions without jit clear_cache support."""
    global _TRACE_COUNT
    _TRACE_COUNT = 0
    if hasattr(_ota_edge_aggregate, "clear_cache"):
        _ota_edge_aggregate.clear_cache()
        return True
    return False


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "out_dtype"))
def _ota_edge_aggregate(grads, gains, noise, noise_scale, *, impl, interpret,
                        out_dtype):
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # python side effect: runs once per trace/compile
    if impl == "ref":
        return ota_edge_aggregate_ref(grads, gains, noise,
                                      noise_scale=noise_scale,
                                      out_dtype=out_dtype)

    n, d = grads.shape
    node_blk = 128 if n >= 128 else max(8, 1 << (n - 1).bit_length())
    lane_blk = 512 if d >= 512 else 128
    pad_n = (-n) % node_blk
    pad_d = (-d) % lane_blk
    g = jnp.pad(grads, ((0, pad_n), (0, pad_d)))
    h = jnp.pad(gains, (0, pad_n))
    # the traced noise_scale folds into the noise operand in f32 — the
    # kernel's static scale stays 1.0 (bit-identical: the kernel upcast the
    # noise to f32 before its own multiply anyway, so the product is the
    # same f32 op either way, and 1.0*w is exact)
    w = jnp.pad(noise_scale * noise.astype(jnp.float32), (0, pad_d))
    # padded rows have zero gain -> contribute nothing to the superposition;
    # the kernel normalizes by the TRUE n (not n + pad_n), so no host-side
    # un-scaling of the noise term is needed (the old rescale-then-subtract
    # double-rounded the noise through the output dtype — lossy for bf16).
    out = ota_edge_aggregate_kernel(
        g, h, w,
        noise_scale=1.0,
        n_nodes=n,
        node_blk=node_blk,
        lane_blk=lane_blk,
        interpret=interpret,
        out_dtype=out_dtype,
    )
    return out[:d]


def ota_edge_aggregate(
    grads: jax.Array,
    gains: jax.Array,
    noise: jax.Array,
    *,
    noise_scale,
    impl: str = "auto",  # 'auto' | 'pallas' | 'ref'
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """One OTA edge aggregation v = (1/N) Σ h_n g_n + noise_scale·w.

    `noise_scale` may be a python float or a traced f32 scalar — it is a
    traced operand either way (one compile covers every value).
    `out_dtype` (static; default grads.dtype) picks the emission dtype of
    the f32 accumulation — f32 out for bf16 grads is the mixed-precision
    transmit path."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if out_dtype is None:
        out_dtype = grads.dtype
    return _ota_edge_aggregate(
        grads, gains, noise, jnp.asarray(noise_scale, jnp.float32),
        impl=impl, interpret=interpret, out_dtype=jnp.dtype(out_dtype))
