"""Jitted public wrapper for the OTA edge-aggregation kernel.

Dispatches to the Pallas TPU kernel on TPU backends (interpret mode for CPU
testing) and to the jnp oracle otherwise; pads N and d to tile boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ota.kernel import ota_edge_aggregate_kernel
from repro.kernels.ota.ref import ota_edge_aggregate_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("noise_scale", "impl", "interpret"))
def ota_edge_aggregate(
    grads: jax.Array,
    gains: jax.Array,
    noise: jax.Array,
    *,
    noise_scale: float,
    impl: str = "auto",  # 'auto' | 'pallas' | 'ref'
    interpret: bool = False,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ota_edge_aggregate_ref(grads, gains, noise, noise_scale=noise_scale)

    n, d = grads.shape
    node_blk = 128 if n >= 128 else max(8, 1 << (n - 1).bit_length())
    lane_blk = 512 if d >= 512 else 128
    pad_n = (-n) % node_blk
    pad_d = (-d) % lane_blk
    g = jnp.pad(grads, ((0, pad_n), (0, pad_d)))
    h = jnp.pad(gains, (0, pad_n))
    w = jnp.pad(noise, (0, pad_d))
    # padded rows have zero gain -> contribute nothing to the superposition;
    # the kernel normalizes by the TRUE n (not n + pad_n), so no host-side
    # un-scaling of the noise term is needed (the old rescale-then-subtract
    # double-rounded the noise through the output dtype — lossy for bf16).
    out = ota_edge_aggregate_kernel(
        g, h, w,
        noise_scale=noise_scale,
        n_nodes=n,
        node_blk=node_blk,
        lane_blk=lane_blk,
        interpret=interpret,
    )
    return out[:d]
