"""Pure-jnp oracle for the OTA edge aggregation kernel (paper Eq. 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ota_edge_aggregate_ref(
    grads: jax.Array,  # (N, d)
    gains: jax.Array,  # (N,)
    noise: jax.Array,  # (d,)
    *,
    noise_scale: float,
) -> jax.Array:
    n = grads.shape[0]
    v = jnp.einsum(
        "n,nd->d", gains.astype(jnp.float32), grads.astype(jnp.float32)
    ) / n
    return (v + noise_scale * noise.astype(jnp.float32)).astype(grads.dtype)
