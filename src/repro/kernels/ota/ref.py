"""Pure-jnp oracle for the OTA edge aggregation kernel (paper Eq. 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ota_edge_aggregate_ref(
    grads: jax.Array,  # (N, d)
    gains: jax.Array,  # (N,)
    noise: jax.Array,  # (d,)
    *,
    noise_scale,
    out_dtype=None,
) -> jax.Array:
    """`noise_scale` may be a python float or a traced f32 scalar; the
    arithmetic is identical either way. `out_dtype` (default: grads.dtype)
    selects the emission dtype AFTER the f32 accumulation — the
    bf16-transmit/f32-accumulate path requests f32 out for bf16 grads."""
    if out_dtype is None:
        out_dtype = grads.dtype
    n = grads.shape[0]
    v = jnp.einsum(
        "n,nd->d", gains.astype(jnp.float32), grads.astype(jnp.float32)
    ) / n
    return (v + noise_scale * noise.astype(jnp.float32)).astype(out_dtype)
