"""Sharding rules: logical roles -> PartitionSpec, with divisibility fallback.

The production mesh is ('pod', 'data', 'model') (multi-pod) or
('data', 'model') (single pod). Parameters are tensor-parallel over 'model'
(heads / ffn / vocab / experts) and optionally FSDP over 'data' (the reduction
dim of big matrices). Activations shard batch over ('pod','data').

Several assigned architectures have head counts that do not divide the
16-way model axis (hymba 25H, whisper 12H, llama4 40H, minitron 24H, kv=8
archs): `fit_spec` drops an axis from any dimension it does not divide, so
those tensors fall back to replication on that dim (GSPMD then row-shards the
contraction via the remaining dims). This is the documented baseline; head
padding is a §Perf item.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def data_axes(mesh: Optional[Mesh] = None) -> tuple:
    """The batch-sharding axes: ('pod','data'), or ('pod','data','model')
    under the pure-DP §Perf mode (use_dp_over_model) where small dense models
    trade tensor parallelism for full data parallelism."""
    mesh = mesh or current_mesh()
    if getattr(_state, "dp_over_model", False):
        if mesh is None:
            return ("data", "model")
        return tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    if mesh is None:
        return ("data",)
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def tp_axis() -> Optional[str]:
    """The tensor-parallel axis ('model'), or None under pure-DP mode."""
    return None if getattr(_state, "dp_over_model", False) else "model"


@contextlib.contextmanager
def use_dp_over_model(enabled: bool = True):
    prev = getattr(_state, "dp_over_model", False)
    _state.dp_over_model = enabled
    try:
        yield
    finally:
        _state.dp_over_model = prev


def axis_size(axis, mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


def fit_spec(shape: Sequence[int], spec: P, mesh: Optional[Mesh] = None) -> P:
    """Drop axis names from dims they do not evenly divide."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is not None and isinstance(axis, (tuple, list)):
            axis = tuple(a for a in axis if a in names) or None
        elif axis is not None and axis not in names:
            axis = None
        if axis is None:
            out.append(None)
            continue
        if dim % axis_size(axis, mesh) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def shard(x: jax.Array, *spec, mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint with divisibility fallback; no-op without mesh."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return x
    p = fit_spec(x.shape, P(*spec), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, p))


def named_sharding(spec: P, mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# parameter partition rules, by leaf path substring
# ---------------------------------------------------------------------------
def param_spec(path: str, shape: Sequence[int], fsdp: bool,
               mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a parameter leaf, identified by its tree path.

    Conventions (trailing dims; any leading layer-stack dims are unsharded):
      embed / lm_head      : vocab -> 'model'
      attn wq/wk/wv        : (.., D, H*hd)    -> D: fsdp, H*hd: 'model'
      attn wo              : (.., H*hd, D)    -> H*hd: 'model', D: fsdp
      mlp wi/wg            : (.., D, F)       -> D: fsdp, F: 'model'
      mlp wo               : (.., F, D)       -> F: 'model', D: fsdp
      moe experts wi/wg    : (.., E, D, F)    -> E: None, D: fsdp, F: 'model'
      moe experts wo       : (.., E, F, D)    -> E: None, F: 'model', D: fsdp
      router / norms / biases / scalars: replicated
    """
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    dp = getattr(_state, "dp_over_model", False)
    tp = None if dp else "model"
    # FSDP spans every batch axis (incl. 'pod'): with params sharded over
    # 'data' only, the multi-pod gradient reduction over ('pod','data') is
    # misaligned and GSPMD gathers the global batch (320 GiB/device/layer)
    f = (("pod", "data", "model") if dp else ("pod", "data")) if fsdp else None
    nd = len(shape)

    def tail(*tspec):
        return P(*([None] * (nd - len(tspec)) + list(tspec)))

    if "embed" in path and nd >= 2:
        return fit_spec(shape, tail(tp if tp else f, None), mesh)
    if "lm_head" in path or "head_out" in path:
        return fit_spec(shape, tail(None, tp if tp else f), mesh)  # (D, V)
    if any(s in path for s in ("router", "norm", "ln", "bias", "scale",
                               "meta", "bonus", "decay", "mix", "a_log",
                               "d_skip", "dt", "pos_embed")):
        return P(*([None] * nd))
    if "experts" in path and nd >= 3:
        # (E, D, F) / (E, F, D): experts over 'model' (aligns the dispatch
        # all-to-all), reduction dim FSDP-sharded over 'data', last dim whole
        return fit_spec(shape, tail(tp, f, None), mesh)
    if "kv_b" in path and nd >= 3:
        return fit_spec(shape, tail(tp, f, None), mesh)
    if any(s in path for s in ("wq", "wk", "wv", "wi", "wg", "in_proj",
                               "w_up", "q_a", "q_b", "kv_a")):
        return fit_spec(shape, tail(f, tp), mesh)
    if any(s in path for s in ("wo", "out_proj", "w_down")):
        return fit_spec(shape, tail(tp, f), mesh)
    if nd >= 2:
        return fit_spec(shape, tail(f, tp), mesh)
    return P(*([None] * nd))


def cache_spec(path: str, shape: Sequence[int], mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a KV/state cache leaf (leading dim = layer stack).

    kv caches (L, B, H, S, hd): batch over ('pod','data'), heads over 'model'
    when divisible. MLA latent caches (L, B, S, r) and SSM/shift states:
    batch over ('pod','data'). pos_ids replicated.
    """
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    da = data_axes(mesh)
    nd = len(shape)
    if "pos_ids" in path:
        return P(*([None] * nd))
    msize = axis_size("model", mesh)
    if nd >= 5 and any(s in path for s in ("/k", "/v", "xk", "xv", "wkv")):
        spec = [None] * nd
        spec[-4] = da  # batch
        if shape[-3] % msize == 0:
            spec[-3] = "model"  # heads
        else:
            # non-dividing head counts (llama4 8kv, hymba 5kv, whisper 12H):
            # shard head_dim instead — decode scores contract it with a psum
            spec[-1] = "model"
        return fit_spec(shape, P(*spec), mesh)
    if nd >= 4 and ("/c" in path or "k_rope" in path):
        # MLA latent cache (L, B, S, rank): shard the latent rank — the
        # absorbed-decode einsums contract it (psum), the seq-dim stays whole
        # so the per-token cache write is a local dynamic-update-slice
        return fit_spec(shape, P(None, da, None, "model"), mesh)
    # (L, B, ...) states: batch on dim 1 (or 0 when no layer dim)
    spec = [None] * nd
    spec[1 if nd >= 3 else 0] = da
    return fit_spec(shape, P(*spec), mesh)


def cache_shardings(cache_shape, mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()

    def one(path, leaf):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "name", k))) for k in path
        ).lower()
        return NamedSharding(mesh, cache_spec("/" + name, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_shardings(batch_shape, mesh: Optional[Mesh] = None):
    """Inputs: shard leading (batch) dim over ('pod','data'); scalars whole."""
    mesh = mesh or current_mesh()
    da = data_axes(mesh)

    def one(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        spec = [None] * len(leaf.shape)
        spec[0] = da
        return NamedSharding(mesh, fit_spec(leaf.shape, P(*spec), mesh))

    return jax.tree_util.tree_map(one, batch_shape)


def constrain_like_params(tree, fsdp: bool, mesh: Optional[Mesh] = None):
    """Apply param-rule sharding constraints to a tree of traced arrays.

    Used (a) on gradient trees, and (b) on the per-layer param slices INSIDE
    scan bodies: with_sharding_constraint transposes to itself, so the
    constraint pins the per-step cotangent shardings and the scan-transpose
    accumulates gradients sharded instead of replicated (the difference
    between 3 GiB and 64 GiB per device on the 400B config)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return tree
    shardings = params_shardings(tree, fsdp, mesh)
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings)


def params_shardings(params_shape, fsdp: bool, mesh: Optional[Mesh] = None):
    """Tree of NamedShardings for a params ShapeDtypeStruct tree."""
    mesh = mesh or current_mesh()

    def one(path, leaf):
        name = "/".join(
            getattr(k, "key", getattr(k, "name", str(k))) for k in path
        ).lower()
        return NamedSharding(mesh, param_spec(name, leaf.shape, fsdp, mesh))

    return jax.tree_util.tree_map_with_path(one, params_shape)
