"""Optimizers. The paper's algorithm is constant-stepsize GD (Eq. 9) — no
state — which is also what keeps the 400B/671B configs inside v5e HBM during
the dry-run. Momentum-GD and Adam are provided for the beyond-paper
experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    # update(grads, state, params) -> (new_params, new_state)


def gd(stepsize: float) -> Optimizer:
    """theta <- theta - beta v (paper Eq. 9), stateless."""

    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - stepsize * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(stepsize: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        new_m = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - stepsize * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adam(stepsize: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)
        new_p = jax.tree_util.tree_map(
            lambda p, m_, v_: (p.astype(jnp.float32) - stepsize * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def global_norm(grads: PyTree) -> jax.Array:
    """f32 global L2 norm of a gradient tree."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))


def clip_by_global_norm(grads: PyTree, max_norm: float,
                        norm: Optional[jax.Array] = None) -> PyTree:
    """Scale `grads` so the global norm is at most `max_norm`. Pass a
    pre-computed `global_norm(grads)` as `norm` to avoid recomputing the
    reduction when the caller also reports it as a metric."""
    if norm is None:
        norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads)


def get_optimizer(name: str, stepsize: float) -> Optimizer:
    if name == "gd":
        return gd(stepsize)
    if name == "momentum":
        return momentum(stepsize)
    if name == "adam":
        return adam(stepsize)
    raise ValueError(name)
