"""Mixture-of-Experts layer (llama4-style top-1 and deepseek-v3-style
shared+routed top-8) with GShard-style grouped capacity dispatch.

Distribution strategy (baseline): tokens are viewed as G groups (G = a
config-chosen grouping, set to the mesh size by the launcher) sharded over
all mesh axes; the dispatch one-hot is built per group (local cumsum, no
cross-group communication); the (G, E, C, D) expert-input tensor is resharded
from group-sharded to expert-sharded — GSPMD lowers that reshard to an
all-to-all, reproducing the GShard schedule. Expert weights are sharded
(E:'model', F:'data'-when-fsdp). Over-capacity tokens are dropped (standard
GShard semantics) and counted in aux stats.

The router aux (load-balance) loss follows Switch/GShard: E * Σ_e f_e·p_e.
DeepSeek-v3's sigmoid scoring + per-expert bias is supported via
`router_scoring='sigmoid'` [arXiv:2412.19437].
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import activation, dense_init
from repro.sharding.specs import (axis_size, current_mesh, data_axes, shard,
                                  tp_axis)

Array = jax.Array


def _a2a_reshard(x: Array, *, invert: bool) -> Array:
    """Explicit GShard dispatch all-to-all over the TP axis via shard_map.

    forward (invert=False): (g:(pod,data,model), e, c, d)
                          -> (g:(pod,data), e:'model', c, d)
    On the 2x16x16 mesh GSPMD lowers the equivalent with_sharding_constraint
    reshard through its replicate-then-repartition fallback (a full
    all-gather of the expert-input tensor, ~320 GiB/device/step for the 400B
    config); the explicit tiled all_to_all is exact and local.
    """
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    tp = tp_axis()
    if mesh is None or tp is None or axis_size(tp) == 1:
        return x
    da = data_axes()
    g, e = x.shape[0], x.shape[1]
    if g % axis_size(tuple(da) + (tp,)) or e % axis_size(tp):
        return x  # small-group regimes: leave the reshard to GSPMD

    if not invert:
        in_spec = P((*da, tp), None, None, None)
        out_spec = P(da, tp, None, None)

        def body(xl):  # (g_loc, e, c, d) -> (g_loc*m, e/m, c, d)
            return jax.lax.all_to_all(xl, tp, split_axis=1, concat_axis=0,
                                      tiled=True)
    else:
        in_spec = P(da, tp, None, None)
        out_spec = P((*da, tp), None, None, None)

        def body(xl):  # (g_loc, e_loc, c, d) -> (g_loc/m, e_loc*m, c, d)
            return jax.lax.all_to_all(xl, tp, split_axis=0, concat_axis=1,
                                      tiled=True)

    return shard_map(body, mesh=mesh, in_specs=(in_spec,),
                     out_specs=out_spec)(x)


def moe_params(key: Array, cfg: ModelConfig, lead=()) -> dict:
    dt = jnp.dtype(cfg.dtype)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_ff
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d, (*lead, d, e), jnp.dtype("float32")),
        "experts_wi": dense_init(ks[1], d, (*lead, e, d, f), dt),
        "experts_wg": dense_init(ks[2], d, (*lead, e, d, f), dt),
        "experts_wo": dense_init(ks[3], f, (*lead, e, f, d), dt),
    }
    if cfg.router_scoring == "sigmoid":
        p["router_bias"] = jnp.zeros((*lead, e), jnp.float32)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_wi"] = dense_init(ks[4], d, (*lead, d, fs), dt)
        p["shared_wg"] = dense_init(ks[5], d, (*lead, d, fs), dt)
        p["shared_wo"] = dense_init(ks[6], fs, (*lead, fs, d), dt)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(min(tokens_per_group, max(c, 4)), 1)


GROUP_SIZE = 256  # tokens per routing group; dispatch-einsum cost is
# O(tg * E * C * d) = O(k * tg^2 * d) per group — quadratic in group size, so
# groups are kept small (GShard-style) and their count is a multiple of the
# mesh size so the group dim shards over every axis.


def moe_apply(
    x: Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    n_groups: int = 1,  # minimum group count (mesh size), from the caller
) -> tuple[Array, Array]:
    """Returns (output (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    # groups = (example, seq-chunk) pairs: the (b, s, d) -> (g, tg, d)
    # reshape then merges a batch-sharded dim with a seq-chunk dim whose
    # sharding ('model', via sequence parallelism) is minor-most — tile-order
    # aligned, so GSPMD reshards it locally. A flat t//GROUP_SIZE grouping
    # forces a 3-axis reshard that hits the replicate-then-repartition
    # fallback on the (pod, data, model) mesh (~320 GiB/device of gathers).
    tg = min(GROUP_SIZE, s)
    while s % tg:
        tg //= 2
    g = t // tg
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(tg, cfg)
    tp = tp_axis()
    da = data_axes()
    xg = x.reshape(b, s // tg, tg, d)
    xg = shard(xg, da, tp, None, None)
    xg = xg.reshape(g, tg, d)
    xg = shard(xg, (*da, *((tp,) if tp else ())))

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    if cfg.router_scoring == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + p["router_bias"][None, None]
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel_scores = scores

    # ---- iterative top-k with capacity assignment ---------------------------
    # §Perf: each (token, expert, slot) cell is written at most once across
    # the k rounds, so bf16 combine weights lose no accumulation precision
    comb_dt = jnp.bfloat16 if cfg.opt_bf16_dispatch else jnp.float32
    dispatch = jnp.zeros((g, tg, e, cap), jnp.bool_)
    combine = jnp.zeros((g, tg, e, cap), comb_dt)
    counts = jnp.zeros((g, e), jnp.int32)  # slots already used per expert
    remaining = sel_scores
    gate_sum = jnp.zeros((g, tg), jnp.float32)
    frac_routed = jnp.zeros((g, e), jnp.float32)
    for _ in range(k):
        eid = jnp.argmax(remaining, axis=-1)  # (g, tg)
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.float32)  # (g, tg, e)
        frac_routed += jnp.mean(onehot, axis=1)
        # position of each token within its expert's slots this round
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        slot = jnp.einsum("gte,gte->gt", pos_in_e, onehot).astype(jnp.int32)
        keep = slot < cap
        gate = jnp.take_along_axis(scores, eid[..., None], axis=-1)[..., 0]
        gate = jnp.where(keep, gate, 0.0)
        slot_oh = jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                                 dtype=comb_dt)[..., :cap]  # (g,tg,cap)
        d_k = onehot.astype(comb_dt)[..., None] * slot_oh[:, :, None, :]
        dispatch |= d_k.astype(jnp.bool_)
        combine += gate.astype(comb_dt)[..., None, None] * d_k
        gate_sum += gate
        counts += jnp.sum(onehot, axis=1).astype(jnp.int32)
        remaining = remaining - onehot * 1e9  # mask chosen expert
    if cfg.top_k > 1:  # renormalize combined gates over selected experts
        denom = jnp.maximum(gate_sum, 1e-9)[..., None, None]
        combine = (combine / denom.astype(comb_dt)).astype(comb_dt)

    # ---- aux load-balance loss (Switch-style) --------------------------------
    mean_prob = jnp.mean(scores, axis=1)  # (g, e)
    aux = e * jnp.mean(jnp.sum(frac_routed / k * mean_prob, axis=-1))

    # ---- dispatch -> expert compute -> combine --------------------------------
    disp = dispatch.astype(xg.dtype)
    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xg)
    # reshard (g:(pod,data,model), e:None) -> (g:(pod,data), e:'model'):
    # the GShard dispatch all-to-all over 'model'
    if cfg.opt_shardmap_moe:
        expert_in = _a2a_reshard(expert_in, invert=False)
    expert_in = shard(expert_in, data_axes(), tp_axis())
    h = jnp.einsum("gecd,edf->gecf", expert_in, p["experts_wi"])
    hg = jnp.einsum("gecd,edf->gecf", expert_in, p["experts_wg"])
    h = activation(hg, cfg.act) * h
    h = shard(h, data_axes(), tp_axis())
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["experts_wo"])
    if cfg.opt_shardmap_moe:
        expert_out = _a2a_reshard(expert_out, invert=True)
    tp = tp_axis()
    expert_out = shard(expert_out,
                       (*data_axes(), *((tp,) if tp else ())))  # a2a back
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(xg.dtype), expert_out)

    # ---- shared experts (deepseek-v3) ------------------------------------------
    if cfg.n_shared_experts:
        hs = jnp.einsum("gtd,df->gtf", xg, p["shared_wi"])
        hsg = jnp.einsum("gtd,df->gtf", xg, p["shared_wg"])
        out = out + jnp.einsum(
            "gtf,fd->gtd", activation(hsg, cfg.act) * hs, p["shared_wo"])

    out = out.reshape(b, s, d)
    out = shard(out, data_axes(), None, None)
    return out, aux.astype(jnp.float32)
