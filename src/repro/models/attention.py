"""Attention for the model zoo: GQA/MQA, sliding-window, logit softcap,
blockwise (flash-style) jnp implementation for memory-sane lowering on any
backend, Pallas TPU kernel dispatch, and ring-buffer KV caches for decode.

The blockwise path is the production CPU-lowering implementation: the
(Sq, Skv) score matrix is never materialized — nested lax.scan over q/kv
blocks with online-softmax accumulators, so compiled peak memory stays
O(block^2) per head. The Pallas kernel (kernels/attention) is selected on
TPU backends.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import dense_init, rope
from repro.sharding.specs import axis_size, data_axes, shard

Array = jax.Array
NEG_INF = -1e30


def head_axis_for(n_heads: int) -> str | None:
    """Shard attention over the TP axis on the q-head dim when divisible; the
    non-dividing archs (llama4 40H, minitron 24H, whisper 12H, hymba 25H)
    run attention head-replicated over 'model' at baseline (DESIGN.md §6;
    head-padding is a §Perf item). Under pure-DP mode there is no TP axis —
    heads stay whole and the batch covers every device."""
    from repro.sharding.specs import tp_axis

    tp = tp_axis()
    if tp is None:
        return None
    return tp if n_heads % max(axis_size(tp), 1) == 0 else None


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------
def attention_params(key: Array, cfg: ModelConfig, lead=()) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, (*lead, cfg.d_model, cfg.q_dim), dt),
        "wk": dense_init(ks[1], cfg.d_model, (*lead, cfg.d_model, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], cfg.d_model, (*lead, cfg.d_model, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], cfg.q_dim, (*lead, cfg.q_dim, cfg.d_model), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*lead, cfg.head_dim), dt)
        p["k_norm"] = jnp.ones((*lead, cfg.head_dim), dt)
    return p


# --------------------------------------------------------------------------
# blockwise (flash-style) jnp attention
# --------------------------------------------------------------------------
def _block_mask(q_idx: Array, k_idx: Array, *, causal: bool,
                window: Optional[int], is_global: Optional[Array]) -> Array:
    """(bq, bk) boolean mask from absolute indices; `is_global` (traced bool)
    disables the window at runtime (hymba's few full-attention layers inside a
    scanned homogeneous stack)."""
    mask = jnp.ones(q_idx.shape[:1] + k_idx.shape[-1:], dtype=jnp.bool_)
    if causal:
        mask &= q_idx[:, None] >= k_idx[None, :]
    if window is not None:
        wmask = (q_idx[:, None] - k_idx[None, :]) < window
        if is_global is not None:
            wmask = jnp.logical_or(wmask, is_global)
        mask &= wmask
    return mask


def blockwise_attention(
    q: Array,  # (B, Hq, Sq, d)
    k: Array,  # (B, Hkv, Skv, d)
    v: Array,
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    is_global: Optional[Array] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_kv: int = 1024,
    head_axis: Optional[str] = None,
) -> Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_kv, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = (sq + pad_q) // bq, (skv + pad_k) // bk
    qg = q.reshape(b, hkv, g, sq + pad_q, d)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=3)
        q_idx = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * bk, bk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * bk, bk, axis=2)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                q_blk.astype(jnp.float32), k_blk.astype(jnp.float32),
            ) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            k_idx = kj * bk + jnp.arange(bk)
            mask = _block_mask(q_idx, k_idx, causal=causal, window=window,
                               is_global=is_global)
            mask &= (k_idx < skv)[None, :]  # kv padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        da = data_axes()
        init = (
            shard(jnp.full((b, hkv, g, bq, 1), NEG_INF, jnp.float32),
                  da, head_axis),
            shard(jnp.zeros((b, hkv, g, bq, 1), jnp.float32), da, head_axis),
            shard(jnp.zeros((b, hkv, g, bq, dv), jnp.float32), da, head_axis),
        )
        # remat: the backward pass recomputes each block's (bq, bk) scores
        # instead of storing them — otherwise training stores the full S^2
        # probability matrix across scan steps (flash-attention invariant).
        kv_body = jax.checkpoint(
            kv_step, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(kv_body, init, jnp.arange(nk))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / l).astype(q.dtype)

    q_body = jax.checkpoint(
        q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(q_body, None, jnp.arange(nq))  # (nq, b, hkv, g, bq, dv)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, sq + pad_q, dv)
    return out.reshape(b, hq, sq + pad_q, dv)[:, :, :sq]


def full_attention(
    q: Array, k: Array, v: Array, *, scale: float, causal: bool = True,
    window: Optional[int] = None, softcap: Optional[float] = None,
    is_global: Optional[Array] = None, q_offset: int = 0,
) -> Array:
    """Materializing oracle — used for small shapes and as the test reference."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = _block_mask(q_offset + jnp.arange(sq), jnp.arange(skv),
                       causal=causal, window=window, is_global=is_global)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def sdpa(q, k, v, cfg: ModelConfig, *, causal=True, window=None,
         is_global=None, q_offset=0, impl: str = "auto",
         head_axis: Optional[str] = None):
    """Dispatch: Pallas kernel on TPU, blockwise jnp elsewhere."""
    scale = cfg.head_dim**-0.5
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "blockwise"
    if impl == "pallas" and is_global is None:
        from repro.kernels.attention.ops import multi_head_attention

        return multi_head_attention(
            q, k, v, scale=scale, causal=causal, window=window,
            softcap=cfg.attn_softcap)
    if impl == "full":
        return full_attention(q, k, v, scale=scale, causal=causal,
                              window=window, softcap=cfg.attn_softcap,
                              is_global=is_global, q_offset=q_offset)
    if cfg.opt_flash_vjp and is_global is None:
        from repro.models.flash_vjp import flash_attention

        return flash_attention(
            q, k, v, scale=scale, causal=causal, window=window,
            softcap=cfg.attn_softcap, q_offset=q_offset,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    return blockwise_attention(
        q, k, v, scale=scale, causal=causal, window=window,
        softcap=cfg.attn_softcap, is_global=is_global, q_offset=q_offset,
        block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        head_axis=head_axis)


# --------------------------------------------------------------------------
# KV cache (ring buffer for windowed layers; optional int8 quantization)
# --------------------------------------------------------------------------
def init_kv_cache(batch: int, cache_len: int, cfg: ModelConfig, lead=()) -> dict:
    dt = jnp.dtype(cfg.dtype)
    shape = (*lead, batch, cfg.n_kv_heads, cache_len, cfg.head_dim)
    cache = {"pos_ids": jnp.full((*lead, cache_len), -1, jnp.int32)}
    if cfg.opt_int8_cache:
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        sshape = (*lead, batch, cfg.n_kv_heads, cache_len, 1)
        cache["k_scale"] = jnp.zeros(sshape, jnp.float32)
        cache["v_scale"] = jnp.zeros(sshape, jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, dt)
        cache["v"] = jnp.zeros(shape, dt)
    return cache


def _quantize(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8 quantization over head_dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def cache_kv(cache: dict, which: str) -> Array:
    """Read (and dequantize if int8) the cached K or V, fp32."""
    x = cache[which].astype(jnp.float32)
    if f"{which}_scale" in cache:
        x = x * cache[f"{which}_scale"]
    return x


def cache_write(cache: dict, k_new: Array, v_new: Array, pos: Array) -> dict:
    """Write one token (B, Hkv, 1, d) at absolute position `pos` (scalar)."""
    cache_len = cache["k"].shape[-2]
    slot = jnp.mod(pos, cache_len)
    out = dict(cache)
    if "k_scale" in cache:
        for name, new in (("k", k_new), ("v", v_new)):
            q, s = _quantize(new)
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], q, slot, axis=-2)
            out[f"{name}_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache[f"{name}_scale"], s, slot, axis=-2)
    else:
        out["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new,
                                                       slot, axis=-2)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new,
                                                       slot, axis=-2)
    out["pos_ids"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos_ids"], pos.reshape(1).astype(jnp.int32), slot, axis=-1)
    return out


def decode_attention(
    q: Array,  # (B, Hq, 1, d)
    cache: dict,
    pos: Array,  # scalar absolute position of the query token
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    is_global: Optional[Array] = None,
) -> Array:
    b, hq, _, d = q.shape
    hkv = cache["k"].shape[1]
    g = hq // hkv
    scale = cfg.head_dim**-0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, cache_kv(cache, "k")) * scale
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    pid = cache["pos_ids"]  # (S,)
    valid = (pid >= 0) & (pid <= pos)
    if window is not None:
        wvalid = (pos - pid) < window
        if is_global is not None:
            wvalid = jnp.logical_or(wvalid, is_global)
        valid &= wvalid
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, cache_kv(cache, "v"))
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# --------------------------------------------------------------------------
# full attention sub-layer (projections + rope + sdpa / decode)
# --------------------------------------------------------------------------
def attn_apply(
    x: Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    *,
    positions: Array,  # (S,) absolute positions
    causal: bool = True,
    window: Optional[int] = None,
    is_global: Optional[Array] = None,
    cache: Optional[dict] = None,  # decode mode when set with S==1
    decode_pos: Optional[Array] = None,
) -> tuple[Array, Optional[dict]]:
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q, k, v = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))  # (B, H, S, d)

    if cache is not None and s == 1:
        cache = cache_write(cache, k, v, decode_pos)
        out = decode_attention(q, cache, decode_pos, cfg, window=window,
                               is_global=is_global)
    else:
        # distribution: shard heads over 'model' when divisible — for GQA
        # that requires materializing kv at q-head width first (the repeat
        # is sharded 16-way, cheaper than replicating attention 16x)
        k0, v0 = k, v  # kv-head-width tensors for the cache
        head_axis = head_axis_for(cfg.n_heads)
        pad_h = 0
        if head_axis is not None and cfg.n_kv_heads < cfg.n_heads:
            rep = cfg.n_heads // cfg.n_kv_heads
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
        elif head_axis is None and cfg.opt_pad_heads:
            # §Perf: activation-level head padding — zero-pad q/k/v to the
            # next multiple of the model-axis size so attention shards
            # instead of replicating; padded heads are sliced off before wo
            msize = max(axis_size("model"), 1)
            hq_pad = -cfg.n_heads % msize
            if cfg.n_kv_heads < cfg.n_heads:
                rep = cfg.n_heads // cfg.n_kv_heads
                k = jnp.repeat(k, rep, axis=1)
                v = jnp.repeat(v, rep, axis=1)
            if hq_pad:
                zpad = ((0, 0), (0, hq_pad), (0, 0), (0, 0))
                q = jnp.pad(q, zpad)
                k = jnp.pad(k, zpad)
                v = jnp.pad(v, zpad)
                pad_h = hq_pad
            head_axis = "model"
        da = data_axes()
        q = shard(q, da, head_axis)
        k = shard(k, da, head_axis)
        v = shard(v, da, head_axis)
        out = sdpa(q, k, v, cfg, causal=causal, window=window,
                   is_global=is_global, head_axis=head_axis)
        out = shard(out, da, head_axis)
        if pad_h:
            out = out[:, : cfg.n_heads]
        k, v = k0, v0
        if cache is not None:  # prefill into cache
            cache_len = cache["k"].shape[-2]
            take = min(s, cache_len)
            new_cache = dict(cache)
            if "k_scale" in cache:
                for name, t in (("k", k), ("v", v)):
                    q8, sc = _quantize(t[:, :, -take:])
                    new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                        cache[name], q8, 0, axis=-2)
                    new_cache[f"{name}_scale"] = \
                        jax.lax.dynamic_update_slice_in_dim(
                            cache[f"{name}_scale"], sc, 0, axis=-2)
            else:
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k[:, :, -take:], 0, axis=-2)
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v[:, :, -take:], 0, axis=-2)
            new_cache["pos_ids"] = jnp.pad(
                positions[-take:].astype(jnp.int32),
                (0, cache_len - take), constant_values=-1)
            cache = new_cache
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.q_dim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), cache
