"""Whisper-style encoder–decoder backbone [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv frontend is a STUB:
`input_specs()` supplies precomputed frame embeddings (B, enc_seq, D). This
module implements the transformer encoder (non-causal) whose output feeds the
decoder's cross-attention (decoder = transformer.decoder_forward with xattn).
The decoder uses on-the-fly sinusoidal positions instead of Whisper's learned
448-position table so the assigned 32k decode shape is expressible
(documented deviation, DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import (apply_norm, mlp_apply, mlp_params,
                                 norm_param, sinusoidal_positions)
from repro.sharding.specs import constrain_like_params

Array = jax.Array


def encoder_params(key: Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, cfg.n_enc_layers + 1)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": norm_param(cfg),
            "ln2": norm_param(cfg),
            "attn": attn_mod.attention_params(k1, cfg),
            "mlp": mlp_params(k2, cfg),
        }

    blocks = [one(ks[i]) for i in range(cfg.n_enc_layers)]
    return {
        "blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": norm_param(cfg),
    }


def encoder_forward(params: dict, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder states (B, S_enc, D)."""
    s = frames.shape[1]
    pos = sinusoidal_positions(jnp.arange(s), cfg.d_model)
    x = frames.astype(jnp.dtype(cfg.dtype)) + pos[None].astype(frames.dtype)
    positions = jnp.arange(s)

    def body(xx, bp):
        bp = constrain_like_params(bp, cfg.fsdp)
        h = apply_norm(xx, bp.get("ln1"), cfg)
        a, _ = attn_mod.attn_apply(h, bp["attn"], cfg, positions=positions,
                                   causal=False)
        xx = xx + a
        h = apply_norm(xx, bp.get("ln2"), cfg)
        return xx + mlp_apply(h, bp["mlp"], cfg), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return apply_norm(x, params.get("final_norm"), cfg)
