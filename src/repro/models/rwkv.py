"""RWKV6 "Finch" — attention-free RNN with data-dependent decay
[arXiv:2404.05892]. Time-mix uses token-shift interpolation and the
LoRA-produced per-channel decay w_t = exp(-exp(w0 + tanh(x A) B)) — the
data-dependent decay that distinguishes v6 — feeding the WKV recurrence
(Pallas kernel on TPU, scan oracle elsewhere). Channel-mix is the squared-
ReLU MLP with token shift. Decode state is O(1): per-layer shift tokens plus
the (H, hd, hd) WKV state — hence this arch runs the 524k-token decode shape.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.kernels.wkv.ops import wkv6
from repro.sharding.specs import constrain_like_params, data_axes, shard, tp_axis

Array = jax.Array

W_LORA_RANK = 64


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    return cfg.n_heads, cfg.d_model // cfg.n_heads


def block_params(key: Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 12)
    rank = min(W_LORA_RANK, d // 2)
    return {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "tm": {
            "mix_r": jnp.full((d,), 0.5, dt),
            "mix_k": jnp.full((d,), 0.5, dt),
            "mix_v": jnp.full((d,), 0.5, dt),
            "mix_w": jnp.full((d,), 0.5, dt),
            "mix_g": jnp.full((d,), 0.5, dt),
            "wr": dense_init(ks[0], d, (d, d), dt),
            "wk": dense_init(ks[1], d, (d, d), dt),
            "wv": dense_init(ks[2], d, (d, d), dt),
            "wg": dense_init(ks[3], d, (d, d), dt),
            "wo": dense_init(ks[4], d, (d, d), dt),
            "decay_base": jnp.full((d,), -1.0, jnp.float32),  # w0
            "decay_lora_a": dense_init(ks[5], d, (d, rank), dt),
            "decay_lora_b": dense_init(ks[6], rank, (rank, d), dt),
            "bonus": (0.5 * jax.random.normal(ks[7], (h, hd))).astype(jnp.float32),
            "head_norm": jnp.ones((d,), dt),
        },
        "cm": {
            "mix_k": jnp.full((d,), 0.5, dt),
            "mix_r": jnp.full((d,), 0.5, dt),
            "wk": dense_init(ks[8], d, (d, f), dt),
            "wv": dense_init(ks[9], f, (f, d), dt),
            "wr": dense_init(ks[10], d, (d, d), dt),
        },
    }


def init_params(key: Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = [block_params(ks[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-1], (cfg.vocab_size, cfg.d_model), dt),
        "ln_in": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(ks[-2], cfg.d_model,
                              (cfg.d_model, cfg.vocab_size), dt),
        "blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks),
    }


def _shift(x: Array, last: Optional[Array]) -> Array:
    """Token shift: x_{t-1}; position 0 uses `last` (decode state) or zeros."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def time_mix(x: Array, p: dict, cfg: ModelConfig, state: Optional[dict]):
    """x: (B, S, D). state: {'shift': (B, D), 'wkv': (B, H, hd, hd)} or None."""
    b, s, d = x.shape
    h, hd = _heads(cfg)
    last = None if state is None else state["tm_shift"]
    xs = _shift(x, last)
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_v"]), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_g"]), p["wg"]))
    xw = _mix(x, xs, p["mix_w"])
    w_raw = p["decay_base"] + jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_lora_a"])),
        p["decay_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw))  # (B, S, D) in (0,1)

    hsplit = lambda t: jnp.swapaxes(t.reshape(b, s, h, hd), 1, 2)
    s0 = None if state is None else state["wkv"]
    o, s_fin = wkv6(hsplit(r), hsplit(k), hsplit(v),
                    hsplit(w.astype(x.dtype)), p["bonus"], s0)
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, d)
    # per-head group norm
    o = rms_norm(o.reshape(b, s, h, hd), None).reshape(b, s, d)
    o = o * p["head_norm"] * g
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    new_state = {"tm_shift": x[:, -1], "wkv": s_fin}
    return out, new_state


def channel_mix(x: Array, p: dict, state: Optional[dict]):
    last = None if state is None else state["cm_shift"]
    xs = _shift(x, last)
    k = jnp.einsum("bsd,df->bsf", _mix(x, xs, p["mix_k"]), p["wk"])
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _mix(x, xs, p["mix_r"]),
                                  p["wr"]))
    out = r * jnp.einsum("bsf,fd->bsd", k, p["wv"])
    return out, {"cm_shift": x[:, -1]}


def block_apply(x: Array, p: dict, cfg: ModelConfig, state: Optional[dict]):
    a, st_tm = time_mix(rms_norm(x, p["ln1"]), p["tm"], cfg, state)
    x = x + a
    m, st_cm = channel_mix(rms_norm(x, p["ln2"]), p["cm"], state)
    x = x + m
    return x, {**st_tm, **st_cm}


def forward(params: dict, tokens: Array, cfg: ModelConfig,
            state: Optional[dict] = None):
    """tokens: (B, S). state: per-layer stacked decode state or None.
    Returns (hidden (B,S,D), new_state)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = rms_norm(x, params["ln_in"])

    def body(xx, xs):
        bp, st = xs
        bp = constrain_like_params(bp, cfg.fsdp)
        xx, new_st = block_apply(xx, bp, cfg, st)
        if cfg.fsdp:
            xx = shard(xx, data_axes(), tp_axis(), None)
        return xx, new_st

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    h = rms_norm(x, params["final_norm"])
    return h, new_state


def init_state(batch: int, cfg: ModelConfig) -> dict:
    h, hd = _heads(cfg)
    return {
        "tm_shift": jnp.zeros((cfg.n_layers, batch, cfg.d_model),
                              jnp.dtype(cfg.dtype)),
        "cm_shift": jnp.zeros((cfg.n_layers, batch, cfg.d_model),
                              jnp.dtype(cfg.dtype)),
        "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
    }
