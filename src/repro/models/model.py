"""Unified model API over all assigned architecture families.

Every architecture exposes the same five entry points, so the training
substrate (GBMA aggregation), the serving engine, and the dry-run launcher
are family-agnostic:

    model = build_model(cfg)
    params = model.init_params(key)
    losses = model.train_loss_per_example(params, batch)   # (B,) for GBMA
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, token, pos)
    cache = model.init_cache(batch_size, cache_len)
    batch = model.input_specs(shape)                       # ShapeDtypeStructs
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, rwkv, ssm as hymba
from repro.models import transformer as tfm
from repro.models.layers import apply_norm
from repro.sharding.specs import data_axes, shard

Array = jax.Array

MTP_WEIGHT = 0.3


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


class Model:
    """Family-dispatching façade; all methods are pure and jit-friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kind = (
            "rwkv" if cfg.family == "ssm" else
            "hymba" if cfg.family == "hybrid" else
            "encdec" if cfg.family == "encdec" else
            "transformer")

    # ------------------------------------------------------------------ init
    def init_params(self, key: Array):
        cfg = self.cfg
        if self.kind == "rwkv":
            return rwkv.init_params(key, cfg)
        if self.kind == "hymba":
            return hymba.init_params(key, cfg)
        if self.kind == "encdec":
            k1, k2 = jax.random.split(key)
            p = tfm.init_decoder(k1, cfg, cross_attn=True)
            p["encoder"] = encdec.encoder_params(k2, cfg)
            return p
        return tfm.init_decoder(key, cfg)

    def params_shape(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))

    # ----------------------------------------------------------------- train
    def train_loss_per_example(self, params, batch) -> tuple[Array, dict]:
        """Per-example losses (B,) (MoE aux folded in), plus metrics."""
        cfg = self.cfg
        tokens = batch["tokens"]  # (B, S+1)
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        inputs = shard(inputs, data_axes())
        b, s = inputs.shape
        aux = jnp.zeros((), jnp.float32)

        if self.kind == "rwkv":
            h, _ = rwkv.forward(params, inputs, cfg)
            losses = tfm.chunked_xent(params, h, labels,
                                      jnp.ones_like(labels), cfg)
        elif self.kind == "hymba":
            h, _ = hymba.forward(params, inputs, cfg, prepend_meta=True)
            h = h[:, cfg.meta_tokens:]
            losses = tfm.chunked_xent(params, h, labels,
                                      jnp.ones_like(labels), cfg)
        elif self.kind == "encdec":
            enc = encdec.encoder_forward(params["encoder"], batch["frames"],
                                         cfg)
            x = tfm.embed_tokens(params, inputs, cfg)
            h, _, aux = tfm.decoder_forward(params, x, cfg,
                                            positions=jnp.arange(s),
                                            enc_out=enc)
            losses = tfm.chunked_xent(params, h, labels,
                                      jnp.ones_like(labels), cfg)
        else:
            x = tfm.embed_tokens(params, inputs, cfg)
            mask = jnp.ones_like(labels)
            if cfg.n_patches:  # VLM: patch embeddings prepended, not predicted
                patches = batch["patch_embed"].astype(x.dtype)
                x = jnp.concatenate([patches, x], axis=1)
            h, _, aux = tfm.decoder_forward(
                params, x, cfg, positions=jnp.arange(x.shape[1]))
            if cfg.n_patches:
                h = h[:, cfg.n_patches:]
            losses = tfm.chunked_xent(params, h, labels, mask, cfg)
            if cfg.mtp:  # deepseek-v3 multi-token prediction (k=1)
                losses = losses + MTP_WEIGHT * self._mtp_loss(
                    params, h, inputs, labels, cfg)

        metrics = {"loss": jnp.mean(losses), "aux_loss": aux}
        losses = losses + cfg.router_aux_weight * aux
        return losses, metrics

    def _mtp_loss(self, params, h, inputs, labels, cfg) -> Array:
        """Predict token t+2 from a one-block MTP head on (h_t, emb_{t+1}).
        Rematerialized: the unscanned MTP block otherwise keeps ~30 GiB of
        full-sequence activations alive for its backward (671B config)."""
        if cfg.remat:
            return jax.checkpoint(
                functools.partial(self._mtp_loss_inner, cfg=cfg),
                policy=jax.checkpoint_policies.nothing_saveable,
            )(params, h, inputs, labels)
        return self._mtp_loss_inner(params, h, inputs, labels, cfg=cfg)

    def _mtp_loss_inner(self, params, h, inputs, labels, cfg) -> Array:
        mp = params["mtp"]
        h_in = apply_norm(h[:, :-1], mp.get("norm_h"), cfg)
        e_in = apply_norm(tfm.embed_tokens(params, inputs[:, 1:], cfg),
                          mp.get("norm_e"), cfg)
        z = jnp.einsum("bsd,dk->bsk",
                       jnp.concatenate([h_in, e_in], axis=-1), mp["proj"])
        z, _, _ = tfm.sublayer_apply(
            z, mp["block"], tfm.SubLayer("dense", None), cfg,
            positions=jnp.arange(z.shape[1]))
        mask = jnp.ones_like(labels[:, 1:])
        return tfm.chunked_xent(params, z, labels[:, 1:], mask, cfg)

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, cache_len: int):
        cfg = self.cfg
        if self.kind == "rwkv":
            return rwkv.init_state(batch, cfg)
        if self.kind == "hymba":
            return hymba.init_cache(batch, cache_len, cfg)
        return tfm.init_decoder_cache(batch, cache_len, cfg,
                                      cross_attn=self.kind == "encdec")

    def prefill(self, params, batch, max_len: Optional[int] = None
                ) -> tuple[Array, Any]:
        """Processes the prompt; returns (last-position logits fp32, cache).
        `max_len` (static) sizes the KV cache beyond the prompt for
        subsequent decode steps."""
        cfg = self.cfg
        tokens = batch["tokens"]  # (B, S)
        b, s = tokens.shape
        if self.kind == "rwkv":
            h, state = rwkv.forward(params, tokens, cfg,
                                    state=rwkv.init_state(b, cfg))
            return tfm.logits_fn(params, h[:, -1:], cfg)[:, 0], state
        if self.kind == "hymba":
            cache = hymba.init_cache(
                b, max(max_len or 0, s) + cfg.meta_tokens, cfg)
            h, cache = hymba.forward(params, tokens, cfg, cache=cache,
                                     prepend_meta=True)
            return tfm.logits_fn(params, h[:, -1:], cfg)[:, 0], cache
        clen = max(max_len or 0, s)
        cache = tfm.init_decoder_cache(b, clen, cfg,
                                       cross_attn=self.kind == "encdec")
        enc = None
        if self.kind == "encdec":
            enc = encdec.encoder_forward(params["encoder"], batch["frames"],
                                         cfg)
        x = tfm.embed_tokens(params, tokens, cfg)
        if cfg.n_patches and "patch_embed" in batch:
            x = jnp.concatenate([batch["patch_embed"].astype(x.dtype), x],
                                axis=1)
            cache = tfm.init_decoder_cache(b, max(clen, x.shape[1]), cfg)
        h, cache, _ = tfm.decoder_forward(
            params, x, cfg, positions=jnp.arange(x.shape[1]), cache=cache,
            enc_out=enc)
        return tfm.logits_fn(params, h[:, -1:], cfg)[:, 0], cache

    def decode_step(self, params, cache, token: Array, pos: Array):
        """One-token decode: token (B,), pos scalar absolute position.
        Returns (logits (B, V) fp32, new_cache)."""
        cfg = self.cfg
        b = token.shape[0]
        if self.kind == "rwkv":
            h, state = rwkv.forward(params, token[:, None], cfg, state=cache)
            return tfm.logits_fn(params, h, cfg)[:, 0], state
        if self.kind == "hymba":
            h, cache = hymba.forward(params, token[:, None], cfg, cache=cache,
                                     decode_pos=pos)
            return tfm.logits_fn(params, h, cfg)[:, 0], cache
        x = tfm.embed_tokens(params, token[:, None], cfg)
        h, cache, _ = tfm.decoder_forward(
            params, x, cfg, positions=pos.reshape(1), cache=cache,
            decode_pos=pos)
        return tfm.logits_fn(params, h, cfg)[:, 0], cache

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: InputShape, dtype=jnp.int32) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of `shape`."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        emb = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.dtype(cfg.dtype))
        if shape.kind == "train":
            if self.kind == "encdec":
                return {"tokens": tok(b, s + 1), "frames": emb(b, cfg.enc_seq,
                                                               cfg.d_model)}
            if cfg.n_patches:
                return {"tokens": tok(b, s - cfg.n_patches + 1),
                        "patch_embed": emb(b, cfg.n_patches, cfg.d_model)}
            return {"tokens": tok(b, s + 1)}
        if shape.kind == "prefill":
            base = {"tokens": tok(b, s)}
            if self.kind == "encdec":
                base["frames"] = emb(b, cfg.enc_seq, cfg.d_model)
            if cfg.n_patches:
                base = {"tokens": tok(b, s - cfg.n_patches),
                        "patch_embed": emb(b, cfg.n_patches, cfg.d_model)}
            return base
        # decode: one token with a seq_len-deep cache
        return {"token": tok(b), "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_len_for(self, shape: InputShape) -> int:
        """Cache depth for decode shapes; windowed archs bound the 524k decode
        by their window/state (documented in DESIGN.md)."""
        cfg = self.cfg
        if self.kind in ("rwkv",):
            return 1  # O(1) state
        if shape.seq_len > 65536 and cfg.sliding_window:
            return cfg.sliding_window
        return shape.seq_len


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
