"""Shared model building blocks: norms, RoPE, MLPs, embeddings, initializers."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def dense_init(key: Array, fan_in: int, shape, dtype) -> Array:
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key: Array, shape, dtype) -> Array:
    return (0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x: Array, scale: Optional[Array], eps: float = 1e-6,
             plus_one: bool = False) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        s = scale.astype(jnp.float32)
        y = y * (1.0 + s if plus_one else s)
    return y.astype(x.dtype)


def nonparam_layer_norm(x: Array, eps: float = 1e-5) -> Array:
    """OLMo's non-parametric LayerNorm [arXiv:2402.00838]: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(x: Array, p, cfg: ModelConfig) -> Array:
    if cfg.norm == "ln_nonparam":
        return nonparam_layer_norm(x)
    # gemma-family rms norm uses the (1 + scale) parameterization
    return rms_norm(x, p, plus_one=cfg.norm_style == "sandwich" or cfg.embed_scale)


def norm_param(cfg: ModelConfig, *lead) -> Optional[Array]:
    if cfg.norm == "ln_nonparam":
        return None
    return jnp.zeros((*lead, cfg.d_model), _dt(cfg)) if (
        cfg.norm_style == "sandwich" or cfg.embed_scale
    ) else jnp.ones((*lead, cfg.d_model), _dt(cfg))


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]  # (B, S, 1, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(positions: Array, dim: int) -> Array:
    """Whisper-style sinusoidal embeddings, (len(positions), dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# mlp
# --------------------------------------------------------------------------
def activation(x: Array, act: str) -> Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu2":  # nemotron/minitron squared ReLU [arXiv:2407.14679]
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(act)


def mlp_params(key: Array, cfg: ModelConfig, d_ff: Optional[int] = None,
               lead=()) -> dict:
    d_ff = d_ff or cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], cfg.d_model, (*lead, cfg.d_model, d_ff), dt),
        "wo": dense_init(ks[1], d_ff, (*lead, d_ff, cfg.d_model), dt),
    }
    if cfg.glu:
        p["wg"] = dense_init(ks[2], cfg.d_model, (*lead, cfg.d_model, d_ff), dt)
    return p


def mlp_apply(x: Array, p: dict, cfg: ModelConfig) -> Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if cfg.glu:
        h = activation(jnp.einsum("...d,df->...f", x, p["wg"]), cfg.act) * h
    else:
        h = activation(h, cfg.act)
    return jnp.einsum("...f,fd->...d", h, p["wo"])
