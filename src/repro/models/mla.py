"""Multi-head Latent Attention (DeepSeek-V3 [arXiv:2412.19437]).

Queries and keys/values are projected through low-rank latents; only the
compressed kv latent c_kv (kv_lora_rank) plus the shared rotary key
(qk_rope_dim) are cached at decode. TPU adaptation: the decode path uses the
*absorbed-matmul* formulation — q_nope is pre-multiplied by W_ukᵀ so scores
are computed directly in the latent space and the per-head K/V are never
expanded over the 32k/500k cache (turning a memory-bound cache expansion into
two small MXU matmuls).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (blockwise_attention, full_attention,
                                    head_axis_for)
from repro.models.layers import dense_init, rms_norm, rope
from repro.sharding.specs import data_axes, shard

Array = jax.Array
NEG_INF = -1e30


def mla_params(key: Array, cfg: ModelConfig, lead=()) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank:
        p["q_a"] = dense_init(ks[0], d, (*lead, d, cfg.q_lora_rank), dt)
        p["q_a_norm"] = jnp.ones((*lead, cfg.q_lora_rank), dt)
        p["q_b"] = dense_init(ks[1], cfg.q_lora_rank,
                              (*lead, cfg.q_lora_rank, h * qd), dt)
    else:
        p["q_b"] = dense_init(ks[1], d, (*lead, d, h * qd), dt)
    p["kv_a"] = dense_init(ks[2], d,
                           (*lead, d, cfg.kv_lora_rank + cfg.qk_rope_dim), dt)
    p["kv_a_norm"] = jnp.ones((*lead, cfg.kv_lora_rank), dt)
    p["kv_b_k"] = dense_init(ks[3], cfg.kv_lora_rank,
                             (*lead, h, cfg.kv_lora_rank, cfg.qk_nope_dim), dt)
    p["kv_b_v"] = dense_init(ks[4], cfg.kv_lora_rank,
                             (*lead, h, cfg.kv_lora_rank, cfg.v_head_dim), dt)
    p["wo"] = dense_init(ks[5], h * cfg.v_head_dim,
                         (*lead, h * cfg.v_head_dim, d), dt)
    return p


def _project_q(x: Array, p: dict, cfg: ModelConfig) -> tuple[Array, Array]:
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["q_a"]), p["q_a_norm"])
        q = jnp.einsum("bsr,rh->bsh", cq, p["q_b"])
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["q_b"])
    q = q.reshape(b, s, h, qd)
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _project_kv_latent(x: Array, p: dict, cfg: ModelConfig):
    ckv = jnp.einsum("bsd,dr->bsr", x, p["kv_a"])
    c = rms_norm(ckv[..., : cfg.kv_lora_rank], p["kv_a_norm"])
    k_rope = ckv[..., cfg.kv_lora_rank:]  # (B, S, qk_rope_dim), shared heads
    return c, k_rope


def init_mla_cache(batch: int, cache_len: int, cfg: ModelConfig, lead=()) -> dict:
    dt = jnp.dtype(cfg.dtype)
    return {
        "c": jnp.zeros((*lead, batch, cache_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((*lead, batch, cache_len, cfg.qk_rope_dim), dt),
        "pos_ids": jnp.full((*lead, cache_len), -1, jnp.int32),
    }


def mla_apply(
    x: Array,  # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    *,
    positions: Array,
    cache: Optional[dict] = None,
    decode_pos: Optional[Array] = None,
) -> tuple[Array, Optional[dict]]:
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_nope, q_rope = _project_q(x, p, cfg)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c, k_rope = _project_kv_latent(x, p, cfg)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is not None and s == 1:
        # ---- absorbed decode: never expand per-head K/V over the cache ----
        slot = jnp.mod(decode_pos, cache["c"].shape[-2])
        cache = {
            "c": jax.lax.dynamic_update_slice_in_dim(cache["c"], c, slot, -2),
            "k_rope": jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope, slot, -2),
            "pos_ids": jax.lax.dynamic_update_slice_in_dim(
                cache["pos_ids"], decode_pos.reshape(1).astype(jnp.int32),
                slot, -1),
        }
        # absorb W_uk into q: (B,1,H,nope) x (H,rank,nope) -> (B,H,rank)
        q_lat = jnp.einsum("bshn,hrn->bhr", q_nope.astype(jnp.float32),
                           p["kv_b_k"].astype(jnp.float32))
        s_lat = jnp.einsum("bhr,btr->bht", q_lat,
                           cache["c"].astype(jnp.float32))
        s_rope = jnp.einsum("bshr,btr->bht", q_rope.astype(jnp.float32),
                            cache["k_rope"].astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        valid = (cache["pos_ids"] >= 0) & (cache["pos_ids"] <= decode_pos)
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bht,btr->bhr", attn, cache["c"].astype(jnp.float32))
        out = jnp.einsum("bhr,hrv->bhv", ctx, p["kv_b_v"].astype(jnp.float32))
        out = out.reshape(b, 1, h * cfg.v_head_dim).astype(x.dtype)
    else:
        # ---- train / prefill: expand K/V per head, flash attention ----
        k_nope = jnp.einsum("bsr,hrn->bshn", c, p["kv_b_k"])
        v = jnp.einsum("bsr,hrv->bshv", c, p["kv_b_v"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, s, h, cfg.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        head_axis = head_axis_for(cfg.n_heads)
        da = data_axes()
        qt = shard(qt, da, head_axis)
        kt = shard(kt, da, head_axis)
        vt = shard(vt, da, head_axis)
        if s <= 1024:
            o = full_attention(qt, kt, vt, scale=scale, causal=True)
        else:
            o = blockwise_attention(qt, kt, vt, scale=scale, causal=True,
                                    block_q=cfg.attn_block_q,
                                    block_kv=cfg.attn_block_kv,
                                    head_axis=head_axis)
        out = jnp.swapaxes(o, 1, 2).reshape(b, s, h * cfg.v_head_dim)
        if cache is not None:  # prefill
            take = min(s, cache["c"].shape[-2])
            cache = {
                "c": jax.lax.dynamic_update_slice_in_dim(
                    cache["c"], c[:, -take:], 0, -2),
                "k_rope": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope[:, -take:], 0, -2),
                "pos_ids": jnp.pad(
                    positions[-take:].astype(jnp.int32),
                    (0, cache["c"].shape[-2] - take), constant_values=-1),
            }
    return jnp.einsum("bsv,vd->bsd", out, p["wo"]), cache
