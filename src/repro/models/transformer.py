"""Generic decoder-only / encoder-decoder transformer stack, config-driven.

Covers the dense, MoE, VLM(backbone) and whisper-decoder families of the
assigned architectures. The layer stack is organized into *scan segments*:
maximal runs of structurally-identical layers whose parameters are stacked on
a leading dim and iterated with jax.lax.scan (keeps HLO size O(1) in depth —
essential for compiling 61-layer 671B configs). Alternating patterns
(gemma2 local/global, llama4 dense/MoE) become multi-sublayer scan bodies.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers, mla as mla_mod, moe as moe_mod
from repro.models.layers import apply_norm, embed_init, norm_param
from repro.sharding.specs import (constrain_like_params, current_mesh,
                                  data_axes, shard, tp_axis)

Array = jax.Array


# ---------------------------------------------------------------------------
# stack structure
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SubLayer:
    kind: str  # 'dense' | 'moe'
    window: Optional[int]  # sliding window (None = global)
    dynamic_global: bool = False  # per-step is_global flag fed via scan xs


@dataclasses.dataclass(frozen=True)
class Segment:
    n_steps: int
    subs: tuple


def build_segments(cfg: ModelConfig) -> tuple:
    if cfg.n_experts and cfg.first_dense_layers:
        # deepseek-v3: leading dense layers, then a homogeneous MoE stack
        return (
            Segment(cfg.first_dense_layers, (SubLayer("dense", None),)),
            Segment(cfg.n_layers - cfg.first_dense_layers,
                    (SubLayer("moe", None),)),
        )
    if cfg.n_experts and cfg.moe_layer_step == 2:
        # llama4: alternating (local dense, global MoE) pairs
        return (
            Segment(cfg.n_layers // 2,
                    (SubLayer("dense", cfg.sliding_window),
                     SubLayer("moe", None))),
        )
    if cfg.n_experts:
        return (Segment(cfg.n_layers, (SubLayer("moe", None),)),)
    if cfg.layer_pattern == "alt_local_global":
        # gemma2: local, global, local, ...
        return (
            Segment(cfg.n_layers // 2,
                    (SubLayer("dense", cfg.sliding_window),
                     SubLayer("dense", None))),
        )
    if cfg.layer_pattern == "hymba_global_set":
        return (Segment(cfg.n_layers,
                        (SubLayer("dense", cfg.sliding_window,
                                  dynamic_global=True),)),)
    window = cfg.sliding_window if cfg.layer_pattern == "all_local" else None
    return (Segment(cfg.n_layers, (SubLayer("dense", window),)),)


def global_flags(cfg: ModelConfig, seg: Segment) -> Optional[Array]:
    """Per-step is_global flags for dynamic_global segments (hymba)."""
    if not any(s.dynamic_global for s in seg.subs):
        return None
    ids = jnp.arange(seg.n_steps)
    flag = jnp.zeros((seg.n_steps,), jnp.bool_)
    for g in cfg.global_layer_ids:
        flag |= ids == g
    return flag


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def _stack_init(key, n, init_one):
    """Init n per-layer param trees and stack leaves on a leading dim."""
    trees = [init_one(k) for k in jax.random.split(key, n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def sublayer_params(key: Array, sub: SubLayer, cfg: ModelConfig,
                    cross_attn: bool) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": norm_param(cfg), "ln2": norm_param(cfg)}
    if cfg.use_mla:
        p["attn"] = mla_mod.mla_params(ks[0], cfg)
    else:
        p["attn"] = attn_mod.attention_params(ks[0], cfg)
    if sub.kind == "moe":
        p["moe"] = moe_mod.moe_params(ks[1], cfg)
    else:
        p["mlp"] = layers.mlp_params(ks[1], cfg)
    if cfg.norm_style == "sandwich":
        p["post_ln1"] = norm_param(cfg)
        p["post_ln2"] = norm_param(cfg)
    if cross_attn:
        p["xattn"] = attn_mod.attention_params(ks[2], cfg)
        p["ln_x"] = norm_param(cfg)
        if cfg.norm_style == "sandwich":
            p["post_ln_x"] = norm_param(cfg)
    return p


def init_decoder(key: Array, cfg: ModelConfig, cross_attn: bool = False) -> dict:
    dt = jnp.dtype(cfg.dtype)
    segs = build_segments(cfg)
    ks = jax.random.split(key, len(segs) + 4)
    params: dict = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": norm_param(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            ks[1], cfg.d_model, (cfg.d_model, cfg.vocab_size), dt)
    params["segments"] = {}
    for i, seg in enumerate(segs):
        def seg_one(k, seg=seg):
            sks = jax.random.split(k, len(seg.subs))
            return {f"sub{j}": sublayer_params(sks[j], sub, cfg, cross_attn)
                    for j, sub in enumerate(seg.subs)}
        params["segments"][f"seg{i}"] = _stack_init(ks[2 + i], seg.n_steps,
                                                    seg_one)
    if cfg.mtp:
        km = jax.random.split(ks[-1], 3)
        params["mtp"] = {
            "proj": layers.dense_init(km[0], 2 * cfg.d_model,
                                      (2 * cfg.d_model, cfg.d_model), dt),
            "block": sublayer_params(km[1], SubLayer("dense", None), cfg, False),
            "norm_h": norm_param(cfg),
            "norm_e": norm_param(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------
def embed_tokens(params: dict, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def logits_fn(params: dict, h: Array, cfg: ModelConfig) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def chunked_xent(params: dict, h: Array, labels: Array, mask: Array,
                 cfg: ModelConfig) -> Array:
    """Per-example mean cross-entropy, computed in seq chunks so the full
    (B, S, vocab) logits tensor is never materialized (202k-vocab configs)."""
    b, s, d = h.shape
    chunk = min(cfg.logit_chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (s + pad) // chunk
    hc = h.reshape(b, n, chunk, d)
    lc = labels.reshape(b, n, chunk)
    mc = mask.reshape(b, n, chunk)

    def step(carry, inp):
        hs, ls, ms = inp  # (B, chunk, D), (B, chunk), (B, chunk)
        logits = logits_fn(params, hs, cfg)  # (B, chunk, V) fp32
        logits = shard(logits, data_axes(), None, tp_axis())
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return carry + jnp.sum(nll, axis=-1), None

    # remat: backward recomputes each chunk's logits instead of storing the
    # (B, chunk, V) softmax residuals for every chunk (202k-vocab configs)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0))
    tot, _ = jax.lax.scan(step, jnp.zeros((b,), jnp.float32), xs)
    return tot / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)


# ---------------------------------------------------------------------------
# sublayer / stack forward
# ---------------------------------------------------------------------------
def sublayer_apply(x, sp, sub: SubLayer, cfg: ModelConfig, *, positions,
                   cache=None, decode_pos=None, is_global=None,
                   enc_out=None, n_groups=1):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, sp.get("ln1"), cfg)
    new_cache = {}
    if cfg.use_mla:
        a, kvc = mla_mod.mla_apply(h, sp["attn"], cfg, positions=positions,
                                   cache=None if cache is None else cache["kv"],
                                   decode_pos=decode_pos)
    else:
        a, kvc = attn_mod.attn_apply(
            h, sp["attn"], cfg, positions=positions, causal=True,
            window=sub.window, is_global=is_global,
            cache=None if cache is None else cache["kv"],
            decode_pos=decode_pos)
    if cfg.norm_style == "sandwich":
        a = apply_norm(a, sp.get("post_ln1"), cfg)
    x = x + a
    if kvc is not None:
        new_cache["kv"] = kvc

    if "xattn" in sp:  # whisper decoder cross-attention
        h = apply_norm(x, sp.get("ln_x"), cfg)
        xa, (xk, xv) = _cross_attn(h, sp["xattn"], cfg, enc_out=enc_out,
                                   cache=cache)
        if cfg.norm_style == "sandwich":
            xa = apply_norm(xa, sp.get("post_ln_x"), cfg)
        x = x + xa
        if cache is not None:
            new_cache["xk"] = xk
            new_cache["xv"] = xv

    h = apply_norm(x, sp.get("ln2"), cfg)
    if sub.kind == "moe":
        m, aux = moe_mod.moe_apply(h, sp["moe"], cfg, n_groups=n_groups)
    else:
        m = layers.mlp_apply(h, sp["mlp"], cfg)
    if cfg.norm_style == "sandwich":
        m = apply_norm(m, sp.get("post_ln2"), cfg)
    return x + m, new_cache, aux


def _cross_attn(h, p, cfg, *, enc_out=None, cache=None):
    """Non-causal attention over encoder states; k/v precomputed in cache
    at prefill (cache['xk'/'xv']: (B, Hkv, S_enc, hd))."""
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    q = jnp.swapaxes(q, 1, 2)
    if enc_out is None:  # decode: encoder K/V precomputed at prefill
        k, v = cache["xk"], cache["xv"]
    else:
        k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.head_dim)
        k, v = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
    o = attn_mod.full_attention(q, k, v, scale=cfg.head_dim**-0.5,
                                causal=False)
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, cfg.q_dim)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), (k, v)


def decoder_forward(
    params: dict,
    x: Array,  # (B, S, D) embedded inputs
    cfg: ModelConfig,
    *,
    positions: Array,
    cache: Optional[dict] = None,
    decode_pos: Optional[Array] = None,
    enc_out: Optional[Array] = None,
) -> tuple[Array, Optional[dict], Array]:
    """Runs all scan segments. Returns (hidden, new_cache, aux_loss_sum)."""
    segs = build_segments(cfg)
    mesh = current_mesh()
    n_groups = mesh.devices.size if mesh is not None else 1
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = {} if cache is not None else None

    for i, seg in enumerate(segs):
        seg_params = params["segments"][f"seg{i}"]
        seg_cache = None if cache is None else cache[f"seg{i}"]
        flags = global_flags(cfg, seg)

        def body_full(carry, xs, seg=seg):
            xx, aux = carry
            sp_all, sc_all, flag = xs
            # pin per-layer param slices (and, via the transpose of the
            # constraint, their cotangents) to the parameter shardings
            sp_all = constrain_like_params(sp_all, cfg.fsdp)
            nc_all = {}
            a_sum = jnp.zeros((), jnp.float32)
            for j, sub in enumerate(seg.subs):
                sc = None if sc_all is None else sc_all[f"sub{j}"]
                xx, nc, a = sublayer_apply(
                    xx, sp_all[f"sub{j}"], sub, cfg, positions=positions,
                    cache=sc, decode_pos=decode_pos,
                    is_global=flag if sub.dynamic_global else None,
                    enc_out=enc_out, n_groups=n_groups)
                nc_all[f"sub{j}"] = nc
                a_sum = a_sum + a
            # sequence parallelism on the residual stream: the saved scan
            # carry (one per layer, the dominant training working set) is
            # sharded over 'model' on the seq dim; GSPMD inserts the
            # all-gather at the next layer's first projection.
            if cfg.fsdp:
                xx = shard(xx, data_axes(), tp_axis(), None)
            return (xx, aux + a_sum), nc_all

        fn = jax.checkpoint(body_full,
                            policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else body_full
        flag_xs = flags if flags is not None else jnp.zeros(
            (seg.n_steps,), jnp.bool_)
        (x, aux_total), seg_new_cache = jax.lax.scan(
            fn, (x, aux_total), (seg_params, seg_cache, flag_xs))
        if new_cache is not None:
            new_cache[f"seg{i}"] = seg_new_cache

    h = apply_norm(x, params.get("final_norm"), cfg)
    return h, new_cache, aux_total


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------
def init_decoder_cache(batch: int, cache_len: int, cfg: ModelConfig,
                       cross_attn: bool = False) -> dict:
    """Cache pytree matching decoder_forward's scan structure. For windowed
    sublayers the per-layer cache is a ring buffer of min(window, cache_len).
    Encoder-decoder stacks also carry per-layer cross-attention K/V over the
    encoder states (filled during prefill).
    """
    segs = build_segments(cfg)
    cache: dict = {}
    for i, seg in enumerate(segs):
        subs_cache = {}
        for j, sub in enumerate(seg.subs):
            clen = cache_len
            if sub.window is not None and not sub.dynamic_global:
                clen = min(cache_len, sub.window)
            if cfg.use_mla:
                kvc = mla_mod.init_mla_cache(batch, clen, cfg,
                                             lead=(seg.n_steps,))
            else:
                kvc = attn_mod.init_kv_cache(batch, clen, cfg,
                                             lead=(seg.n_steps,))
            sc = {"kv": kvc}
            if cross_attn:
                dt = jnp.dtype(cfg.dtype)
                sc["xk"] = jnp.zeros((seg.n_steps, batch, cfg.n_kv_heads,
                                      cfg.enc_seq, cfg.head_dim), dt)
                sc["xv"] = jnp.zeros((seg.n_steps, batch, cfg.n_kv_heads,
                                      cfg.enc_seq, cfg.head_dim), dt)
            subs_cache[f"sub{j}"] = sc
        cache[f"seg{i}"] = subs_cache
    return cache
