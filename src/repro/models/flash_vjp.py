"""Flash-attention with a custom VJP (jnp, backend-agnostic).

The default blockwise attention relies on jax.checkpoint around its scan
bodies: correct, but the backward re-runs the whole forward (including the
O(S²·d) pv matmul and online-softmax rescaling) before transposing it. The
flash backward (Dao et al.) instead saves only (out, lse) per row and
recomputes just the score blocks, in two passes:

  pass 1 (kv-major):  dk_j = Σ_i ds_ijᵀ q_i · scale,  dv_j = Σ_i p_ijᵀ do_i
  pass 2 (q-major):   dq_i = Σ_j ds_ij k_j · scale
  with  p = exp(s_cap − lse),  ds_cap = p ⊙ (do·vᵀ − D),  D = rowsum(do ⊙ out)
  and the softcap chain rule  ds = ds_cap ⊙ (1 − (s_cap/cap)²).

Enabled per-config via `opt_flash_vjp` (§Perf); equivalence against
full-attention autodiff is tested in tests/test_flash_vjp.py.
Supports causal, sliding-window and softcap; GQA via the (b, hkv, g, s, d)
grouped layout shared with blockwise_attention. `is_global` (hymba) falls
back to the checkpointed path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_idx, k_idx, causal, window, skv):
    m = (k_idx < skv)[None, :]
    if causal:
        m = m & (q_idx[:, None] >= k_idx[None, :])
    if window is not None:
        m = m & ((q_idx[:, None] - k_idx[None, :]) < window)
    return m  # (bq, bk)


def _scores(q_blk, k_blk, scale, softcap):
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                   k_blk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s  # post-cap scores, fp32


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_mha(q, k, v, scale, causal, window, softcap, q_offset,
              block_q, block_kv):
    """q: (B,Hkv,G,Sq,d); k/v: (B,Hkv,Skv,d). Returns (B,Hkv,G,Sq,dv)."""
    out, _ = _fwd_impl(q, k, v, scale, causal, window, softcap, q_offset,
                       block_q, block_kv)
    return out


def _fwd_impl(q, k, v, scale, causal, window, softcap, q_offset,
              block_q, block_kv):
    b, h, g, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[-1]
    bq, bk = min(block_q, sq), min(block_kv, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, pad_k), (0, 0)))
    nq, nk = (sq + pad_q) // bq, (skv + pad_k) // bk

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, axis=3)
        q_idx = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kp, kj * bk, bk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, kj * bk, bk, axis=2)
            s = _scores(q_blk, k_blk, scale, softcap)
            msk = _mask(q_idx, kj * bk + jnp.arange(bk), causal, window, skv)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, h, g, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, h, g, bq, 1), jnp.float32),
                jnp.zeros((b, h, g, bq, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        l_safe = jnp.where(l == 0.0, 1.0, l)
        lse = m + jnp.log(l_safe)
        return None, ((acc / l_safe).astype(q.dtype), lse)

    _, (out, lse) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 3).reshape(b, h, g, sq + pad_q, dv)[:, :, :, :sq]
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, h, g, sq + pad_q, 1)[:, :, :, :sq]
    return out, lse


def _flash_fwd(q, k, v, scale, causal, window, softcap, q_offset,
               block_q, block_kv):
    out, lse = _fwd_impl(q, k, v, scale, causal, window, softcap, q_offset,
                         block_q, block_kv)
    return out, (q, k, v, out, lse)


def _flash_bwd(scale, causal, window, softcap, q_offset, block_q, block_kv,
               res, d_out):
    q, k, v, out, lse = res
    b, h, g, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[-1]
    bq, bk = min(block_q, sq), min(block_kv, skv)
    pad_q, pad_k = (-sq) % bq, (-skv) % bk
    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, pad_k), (0, 0)))
    do = jnp.pad(d_out.astype(jnp.float32),
                 ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    # D_i = rowsum(do ⊙ out); padded lse rows -> NEG_INF so p = 0 there
    dvec = jnp.sum(do[:, :, :, : sq] * out.astype(jnp.float32), axis=-1,
                   keepdims=True)
    dvec = jnp.pad(dvec, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0),) * 3 + ((0, pad_q), (0, 0)),
                   constant_values=-NEG_INF)
    nq, nk = (sq + pad_q) // bq, (skv + pad_k) // bk

    def block_grads(qi, kj):
        """Recompute p/ds for block (qi, kj); shared by both passes."""
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, axis=3)
        k_blk = jax.lax.dynamic_slice_in_dim(kp, kj * bk, bk, axis=2)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, kj * bk, bk, axis=2)
        do_blk = jax.lax.dynamic_slice_in_dim(do, qi * bq, bq, axis=3)
        lse_blk = jax.lax.dynamic_slice_in_dim(lsep, qi * bq, bq, axis=3)
        d_blk = jax.lax.dynamic_slice_in_dim(dvec, qi * bq, bq, axis=3)
        q_idx = q_offset + qi * bq + jnp.arange(bq)
        s_cap = _scores(q_blk, k_blk, scale, softcap)
        msk = _mask(q_idx, kj * bk + jnp.arange(bk), causal, window, skv)
        p = jnp.where(msk[None, None, None],
                      jnp.exp(s_cap - lse_blk), 0.0)  # (b,h,g,bq,bk)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_blk,
                        v_blk.astype(jnp.float32))
        ds = p * (dp - d_blk)
        if softcap is not None:
            ds = ds * (1.0 - jnp.square(s_cap / softcap))
        return q_blk, k_blk, do_blk, p, ds

    # ---- pass 1: kv-major -> dk, dv ---------------------------------------
    def kv_major(_, kj):
        def q_inner(carry, qi):
            dk_acc, dv_acc = carry
            q_blk, _, do_blk, p, ds = block_grads(qi, kj)
            dk_acc += jnp.einsum("bhgqk,bhgqd->bhkd", ds,
                                 q_blk.astype(jnp.float32)) * scale
            dv_acc += jnp.einsum("bhgqk,bhgqd->bhkd", p, do_blk)
            return (dk_acc, dv_acc), None

        init = (jnp.zeros((b, h, bk, d), jnp.float32),
                jnp.zeros((b, h, bk, dv), jnp.float32))
        (dk_b, dv_b), _ = jax.lax.scan(q_inner, init, jnp.arange(nq))
        return None, (dk_b, dv_b)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(kv_major, None, jnp.arange(nk))
    dk = jnp.moveaxis(dk_blocks, 0, 2).reshape(b, h, skv + pad_k, d)[:, :, :skv]
    dv_out = jnp.moveaxis(dv_blocks, 0, 2).reshape(b, h, skv + pad_k,
                                                   dv)[:, :, :skv]

    # ---- pass 2: q-major -> dq ---------------------------------------------
    def q_major(_, qi):
        def kv_inner(dq_acc, kj):
            _, k_blk, _, _, ds = block_grads(qi, kj)
            dq_acc += jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                                 k_blk.astype(jnp.float32)) * scale
            return dq_acc, None

        dq_b, _ = jax.lax.scan(
            kv_inner, jnp.zeros((b, h, g, bq, d), jnp.float32),
            jnp.arange(nk))
        return None, dq_b

    _, dq_blocks = jax.lax.scan(q_major, None, jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(b, h, g, sq + pad_q,
                                               d)[:, :, :, :sq]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv_out.astype(v.dtype))


flash_mha.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, d)
    k: jax.Array,  # (B, Hkv, Skv, d)
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    out = flash_mha(qg, k, v, scale, causal, window, softcap, q_offset,
                    min(block_q, sq), min(block_kv, k.shape[2]))
    return out.reshape(b, hq, sq, -1)
