"""Selective-SSM (Mamba-style) branch and the Hymba hybrid stack
[arXiv:2411.13676]: every layer runs attention heads and SSM heads *in
parallel* on the same input, averages the (per-branch-normalized) outputs,
plus 128 learned meta tokens prepended to the sequence. Most layers use
sliding-window attention; layers in `global_layer_ids` attend globally
(fed through the scanned stack as a per-step flag).

The selective scan is evaluated chunk-sequentially with an associative scan
inside each chunk: peak memory O(B * chunk * D * state) instead of
O(B * S * D * state), while keeping MXU-friendly parallelism within chunks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import (apply_norm, dense_init, embed_init, mlp_apply,
                                 mlp_params, norm_param, rms_norm)
from repro.sharding.specs import constrain_like_params

Array = jax.Array

SSM_CHUNK = 256


# ---------------------------------------------------------------------------
# mamba branch
# ---------------------------------------------------------------------------
def mamba_params(key: Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    din = cfg.d_model  # hymba: ssm head dim matches model width
    n, r = cfg.ssm_state, max(cfg.dt_rank, 1)
    ks = jax.random.split(key, 8)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, n)))
    return {
        "in_proj": dense_init(ks[0], d, (d, 2 * din), dt),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, din))
                   ).astype(dt),
        "conv_b": jnp.zeros((din,), dt),
        "bc_proj": dense_init(ks[2], din, (din, 2 * n), dt),
        "dt_lora_a": dense_init(ks[3], din, (din, r), dt),
        "dt_lora_b": dense_init(ks[4], r, (r, din), dt),
        "dt_bias": jnp.zeros((din,), jnp.float32),
        "a_log": a_init,  # (din, n)
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[5], din, (din, d), dt),
    }


def _selective_scan(a: Array, bx: Array, h0: Array) -> tuple[Array, Array]:
    """h_t = a_t * h_{t-1} + bx_t, chunked associative scan.
    a, bx: (B, S, Din, N) fp32; h0: (B, Din, N). Returns (h_all, h_final)."""
    b, s, d, n = a.shape
    chunk = min(SSM_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    a_c = a.reshape(b, nc, chunk, d, n)
    bx_c = bx.reshape(b, nc, chunk, d, n)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        ac, bc = inp  # (B, chunk, D, N)
        a_cum, b_cum = jax.lax.associative_scan(assoc, (ac, bc), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        return h_all[:, -1], h_all

    h_fin, h_all = jax.lax.scan(
        chunk_step, h0, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(bx_c, 1, 0)))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(b, nc * chunk, d, n)[:, :s]
    return h_all, h_fin


def mamba_apply(x: Array, p: dict, cfg: ModelConfig,
                state: Optional[dict] = None):
    """x: (B, S, D). state: {'conv': (B, W-1, Din), 'ssm': (B, Din, N)}."""
    b, s, d = x.shape
    n = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv
    w = p["conv_w"]  # (W, Din)
    kw = w.shape[0]
    if state is None:
        xpad = jnp.pad(xi_raw, ((0, 0), (kw - 1, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([state["conv"], xi_raw], axis=1)
    conv = sum(xpad[:, i:i + s] * w[i][None, None] for i in range(kw))
    xi = jax.nn.silu(conv + p["conv_b"])

    bc = jnp.einsum("bsd,dn->bsn", xi, p["bc_proj"])
    b_ssm, c_ssm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,S,N)
    dt = jnp.einsum("bsr,rd->bsd",
                    jnp.einsum("bsd,dr->bsr", xi, p["dt_lora_a"]),
                    p["dt_lora_b"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,S,Din)
    a = -jnp.exp(p["a_log"])  # (Din, N)
    xf = xi.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * a[None, None])  # (B,S,Din,N)
    bx = (dt * xf)[..., None] * b_ssm[:, :, None, :]  # (B,S,Din,N)
    h0 = (jnp.zeros((b, d, n), jnp.float32) if state is None
          else state["ssm"])
    h_all, h_fin = _selective_scan(decay, bx, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, c_ssm) + p["d_skip"] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    new_state = {"conv": xpad[:, -(kw - 1):], "ssm": h_fin}
    return out, new_state


# ---------------------------------------------------------------------------
# hymba hybrid stack
# ---------------------------------------------------------------------------
def hymba_block_params(key: Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": norm_param(cfg),
        "ln2": norm_param(cfg),
        "attn": attn_mod.attention_params(ks[0], cfg),
        "mamba": mamba_params(ks[1], cfg),
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "ssm_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp_params(ks[2], cfg),
    }


def init_params(key: Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, cfg.n_layers + 4)
    blocks = [hymba_block_params(ks[i], cfg) for i in range(cfg.n_layers)]
    p = {
        "embed": embed_init(ks[-1], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": norm_param(cfg),
        "lm_head": dense_init(ks[-2], cfg.d_model,
                              (cfg.d_model, cfg.vocab_size), dt),
        "blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks),
    }
    if cfg.meta_tokens:
        p["meta"] = embed_init(ks[-3], (cfg.meta_tokens, cfg.d_model), dt)
    return p


def hymba_block(x, p, cfg: ModelConfig, *, positions, is_global,
                cache=None, decode_pos=None):
    h = apply_norm(x, p.get("ln1"), cfg)
    a, kvc = attn_mod.attn_apply(
        h, p["attn"], cfg, positions=positions, causal=True,
        window=cfg.sliding_window, is_global=is_global,
        cache=None if cache is None else cache["kv"], decode_pos=decode_pos)
    m, ssm_state = mamba_apply(h, p["mamba"], cfg,
                               state=None if cache is None else cache["ssm"])
    # per-branch normalization then average (hymba fusion)
    fused = 0.5 * (rms_norm(a, p["attn_norm"]) + rms_norm(m, p["ssm_norm"]))
    x = x + fused
    h = apply_norm(x, p.get("ln2"), cfg)
    x = x + mlp_apply(h, p["mlp"], cfg)
    new_cache = None
    if cache is not None:
        new_cache = {"kv": kvc, "ssm": ssm_state}
    return x, new_cache


def forward(params: dict, tokens: Array, cfg: ModelConfig, *,
            cache: Optional[dict] = None, decode_pos=None,
            prepend_meta: bool = False):
    """Returns (hidden (B, S(+meta), D), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    offset = 0
    if prepend_meta and cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (b, cfg.meta_tokens,
                                                       cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
        offset = cfg.meta_tokens
    if decode_pos is not None:
        positions = decode_pos.reshape(1)
    else:
        positions = jnp.arange(s + offset)

    ids = jnp.arange(cfg.n_layers)
    flags = jnp.zeros((cfg.n_layers,), jnp.bool_)
    for g in cfg.global_layer_ids:
        flags = flags | (ids == g)

    def body(xx, xs):
        bp, fl, c = xs
        bp = constrain_like_params(bp, cfg.fsdp)
        xx, nc = hymba_block(xx, bp, cfg, positions=positions, is_global=fl,
                             cache=c, decode_pos=decode_pos)
        return xx, nc

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    return apply_norm(x, params.get("final_norm"), cfg), new_cache


def init_cache(batch: int, cache_len: int, cfg: ModelConfig) -> dict:
    kv = attn_mod.init_kv_cache(batch, cache_len, cfg, lead=(cfg.n_layers,))
    return {
        "kv": kv,
        "ssm": {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                               cfg.d_model), jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_model,
                              cfg.ssm_state), jnp.float32),
        },
    }
