"""Host-side training loop: data feeding, jitted step, metrics, checkpoints."""
from __future__ import annotations

import time
from typing import Callable, Iterable, Optional

import jax
import numpy as np


def run_training(
    train_step: Callable,
    params,
    opt_state,
    batches: Iterable,
    steps: int,
    *,
    log_every: int = 10,
    checkpoint_fn: Optional[Callable] = None,
    checkpoint_every: int = 0,
    donate: bool = True,
):
    """Runs `steps` iterations; returns (params, opt_state, history)."""
    step_fn = jax.jit(train_step, donate_argnums=(0, 1) if donate else ())
    history = []
    t0 = time.time()
    it = iter(batches)
    for step in range(steps):
        batch = next(it)
        batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch, step)
        if log_every and (step % log_every == 0 or step == steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            extras = ""
            if m.get("clip_frac", 0.0) > 0.0:
                extras += " clipped"
            if "tx_energy" in m:
                extras += f" tx {m['tx_energy']:.3g}"
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f}{extras} "
                  f"({m['wall_s']:.1f}s)", flush=True)
        if checkpoint_fn and checkpoint_every and step and \
                step % checkpoint_every == 0:
            checkpoint_fn(params, opt_state, step)
    return params, opt_state, history
