"""Train-step builder: model loss + gradient aggregation protocol + optimizer.

`TrainConfig.aggregator` resolves through the MAC algorithm registry
(`mc/slots.ALGO_REGISTRY`) via the channel-transport layer
(`repro.core.transport`) — every registered algorithm trains real models.
Two routes:

  * **fused** (`gbma` / `fdm` / `centralized`, the historical trio): the
    MAC is folded into the loss — GBMA's fading superposition is obtained
    exactly by h-weighting each node's local loss and letting pjit/GSPMD
    insert the all-reduce, then edge noise is added to the REDUCED
    gradient tree (`gbma.perturb_gradients`); fdm adds its per-node-
    averaged noise the same way. One gradient tree, no per-node
    materialization — this is the production path for large models, and it
    is byte-for-byte the pre-transport behaviour (pinned by the golden
    trajectory tests).
  * **transport** (everything else — `blind`, `blind_ec`, `momentum`,
    `nesterov`, `power_control` — or any aggregator when
    `route='transport'`): each node's local gradient is computed
    explicitly (vmap over the node axis of the batch; node n owns the
    n-th contiguous example group) and the per-node (N, ...) gradient tree
    goes through `transport.aggregate` — block-tiled OTA superposition
    through the same slot fns the Monte Carlo engine validates. Costs one
    (N, ...) gradient tree per step; the engine-parity tests pin the
    trajectory against `run_mc` on the same RNG stream.

Stateful aggregators (receiver momentum, blind_ec's per-node residual)
carry their transport state INSIDE the opt_state slot: `build_train_step`
attaches `train_step.init_state(params)` which returns `opt.init(params)`
for stateless runs (unchanged) and `(opt.init(params), transport_state)`
for stateful ones — `run_training` threads it opaquely either way.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import transport
from repro.core.channel import edge_noise_std
from repro.core.gbma import (GBMAConfig, gbma_value_and_grad, node_weights,
                             perturb_gradients)
from repro.models.model import Model
from repro.optim.gd import Optimizer, clip_by_global_norm, global_norm
from repro.sharding.specs import current_mesh, params_shardings

PyTree = Any

# aggregators whose MAC folds into the loss/reduced-tree (no per-node
# gradient materialization); everything else goes through the transport
_FUSED_AGGREGATORS = ("gbma", "fdm", "centralized")


def _constrain_like_params(grads: PyTree, fsdp: bool) -> PyTree:
    """Pin the gradient tree to the parameter shardings. Without this GSPMD
    materializes scan-accumulated cotangents replicated (64 GiB/device for the
    400B config) before the optimizer update re-shards them."""
    mesh = current_mesh()
    if mesh is None:
        return grads
    shardings = params_shardings(grads, fsdp, mesh)
    return jax.tree_util.tree_map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, shardings)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    aggregator: str = "gbma"  # any slots.ALGO_REGISTRY name
    gbma: GBMAConfig = dataclasses.field(default_factory=GBMAConfig)
    seed: int = 0
    clip_norm: Optional[float] = None
    # §Perf: 'rbg' generates the d-dimensional edge noise with one
    # RngBitGenerator op per leaf instead of threefry's elementwise chain —
    # at d = 671e9 the threefry pipeline materializes tens of GiB of u32
    # counter tensors per expert leaf. 'threefry2x32' is the baseline.
    rng_impl: str = "threefry2x32"
    # §Perf: gradient accumulation over microbatches. Faithful to the paper —
    # each node transmits ONE analog gradient per slot regardless of how it
    # computed it locally (f_n is the node's full local loss); only the
    # per-step activation working set shrinks by the microbatch factor.
    # Fused route only: the transport route materializes per-node gradients.
    microbatches: int = 1
    # 'auto': fused path for gbma/fdm/centralized, transport for the rest.
    # 'transport': force every aggregator through transport.aggregate —
    # the engine-parity testing mode (gbma-through-transport matches the
    # fused path to f32 ulp, not byte-for-byte).
    route: str = "auto"
    # transport knobs (antennas, power budget, receiver momentum, block
    # tiling, transmit dtype, OTA kernel impl, engine-parity key schedule).
    # None derives TransportConfig(n_nodes, channel) from `gbma`; an
    # explicit TransportConfig is used as-is (its n_nodes/channel win).
    transport: Optional[transport.TransportConfig] = None


def _fdm_noise(grads: PyTree, key, gcfg: GBMAConfig) -> PyTree:
    """FDM-GD: each node's dedicated channel adds independent noise at energy
    E_N; the edge averages N received gradients, so the per-coordinate noise
    std is sigma_w / (sqrt(E_N) * sqrt(N)) = sqrt(N) * GBMA's. The draw is
    `transport.add_tree_noise` (bit-identical to the historical inline
    loop; the std constant stays host-side f64)."""
    std = (gcfg.channel.noise_std
           / math.sqrt(gcfg.channel.energy * gcfg.n_nodes))
    return transport.add_tree_noise(grads, key, std)


def _accumulated_grads(vg, params, batch, weights, m: int, fsdp: bool):
    """Scan over m microbatches, accumulating the mean gradient in f32
    (sharded like the params). Cuts the per-step activation working set by m
    at the cost of an f32 gradient accumulator (2x param bytes)."""
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
    mb_w = weights.reshape(m, -1)
    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc0 = _constrain_like_params(acc0, fsdp)

    def body(carry, mb):
        acc, loss_sum = carry
        b, w = mb
        loss, g = vg(params, b, w)
        g = _constrain_like_params(g, fsdp)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32) / m, acc, g)
        acc = _constrain_like_params(acc, fsdp)
        return (acc, loss_sum + loss / m), None

    (grads, loss), _ = jax.lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)), (mb_batch, mb_w))
    return loss, grads


def resolve_route(tcfg: TrainConfig) -> str:
    """'fused' or 'transport' for this config; validates the aggregator
    against the registry either way."""
    transport.resolve(tcfg.aggregator)  # raises on unknown names
    if tcfg.route not in ("auto", "transport"):
        raise ValueError(
            f"route must be 'auto' or 'transport', got {tcfg.route!r}")
    if tcfg.route == "transport":
        return "transport"
    return "fused" if tcfg.aggregator in _FUSED_AGGREGATORS else "transport"


def _transport_config(tcfg: TrainConfig) -> transport.TransportConfig:
    if tcfg.transport is not None:
        return tcfg.transport
    return transport.TransportConfig(n_nodes=tcfg.gbma.n_nodes,
                                     channel=tcfg.gbma.channel)


def _node_grads_fn(model: Model, n_nodes: int) -> Callable:
    """(params, batch) -> (mean clean loss, per-node gradient tree with
    (n_nodes, ...) leaves). Node n's local objective f_n is the mean loss
    over its contiguous example group (the `node_weights` partition), so
    the transport's (1/N) Σ_n superposition estimates ∇F exactly as the
    fused h-weighted path does."""

    def fn(params, batch):
        bsz = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if bsz % n_nodes != 0:
            raise ValueError(
                f"global batch {bsz} not divisible by n_nodes {n_nodes}")
        node_batch = jax.tree_util.tree_map(
            lambda x: x.reshape(n_nodes, bsz // n_nodes, *x.shape[1:]),
            batch)

        def one(b):
            def loss(p):
                per_ex, _ = model.train_loss_per_example(p, b)
                return jnp.mean(per_ex)

            return jax.value_and_grad(loss)(params)

        losses, node_g = jax.vmap(one)(node_batch)
        return jnp.mean(losses), node_g

    return fn


def build_train_step(model: Model, tcfg: TrainConfig, opt: Optimizer
                     ) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit at the call site.

    The returned callable carries `train_step.init_state(params)` — use it
    instead of `opt.init` so stateful aggregators get their transport
    state (receiver momentum / blind_ec residual) threaded through the
    opt_state slot; for stateless runs it returns `opt.init(params)`
    unchanged. Metrics: `loss` (clean), `grad_norm` (global norm BEFORE
    clipping), `clip_frac` (1.0 on steps where clipping engaged, 0.0
    otherwise), `noise_std`, and on the transport route `tx_energy` (the
    slot's transmitted energy E_N Σ_n ‖x_n‖²)."""
    gcfg = tcfg.gbma
    route = resolve_route(tcfg)
    base_key = jax.random.key(tcfg.seed, impl=tcfg.rng_impl)

    if route == "transport":
        return _build_transport_step(model, tcfg, opt, base_key)
    if tcfg.transport is not None:
        raise ValueError(
            "TrainConfig.transport is set but the fused route ignores it; "
            "pass route='transport' to use it")

    vg = gbma_value_and_grad(
        lambda p, b: model.train_loss_per_example(p, b)[0])

    def train_step(params, opt_state, batch, step):
        k_step = jax.random.fold_in(base_key, step)
        k_h, k_w = jax.random.split(k_step)
        bsz = batch["tokens"].shape[0]

        if tcfg.aggregator == "gbma" and gcfg.enabled:
            weights = node_weights(k_h, gcfg, bsz)
        else:
            weights = jnp.ones((bsz,), jnp.float32)

        if tcfg.microbatches > 1:
            clean_loss, grads = _accumulated_grads(
                vg, params, batch, weights, tcfg.microbatches, model.cfg.fsdp)
        else:
            clean_loss, grads = vg(params, batch, weights)
            grads = _constrain_like_params(grads, model.cfg.fsdp)

        if tcfg.aggregator == "gbma" and gcfg.enabled:
            grads = perturb_gradients(grads, k_w, gcfg)
        elif tcfg.aggregator == "fdm":
            grads = _fdm_noise(grads, k_w, gcfg)

        grads, metrics = _clip_and_metrics(grads, tcfg)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics["loss"] = clean_loss
        metrics["noise_std"] = (edge_noise_std(gcfg.channel, gcfg.n_nodes)
                                if tcfg.aggregator == "gbma" else 0.0)
        return params, opt_state, metrics

    train_step.init_state = opt.init
    return train_step


def _clip_and_metrics(grads: PyTree, tcfg: TrainConfig):
    """Shared clip + metric computation: `grad_norm` is the PRE-clip global
    norm (a clipped run's reported norm is the raw gradient scale, not the
    post-clip constant `clip_norm`); `clip_frac` marks the steps where the
    clip engaged. The clip itself reuses the already-computed norm."""
    gnorm = global_norm(grads)
    if tcfg.clip_norm is not None:
        grads = clip_by_global_norm(grads, tcfg.clip_norm, norm=gnorm)
        clip_frac = (gnorm > tcfg.clip_norm).astype(jnp.float32)
    else:
        clip_frac = jnp.zeros((), jnp.float32)
    return grads, {"grad_norm": gnorm, "clip_frac": clip_frac}


def _build_transport_step(model: Model, tcfg: TrainConfig, opt: Optimizer,
                          base_key) -> Callable:
    """The transport route: explicit per-node gradients through
    `transport.aggregate`. Slot key schedule: `transport.step_key` —
    `fold_in(base, step)` normally, the engine's `split(key(seed), steps)`
    replay when `transport.mc_steps` is set (parity testing)."""
    algo = tcfg.aggregator
    tp = _transport_config(tcfg)
    spec = transport.resolve(algo)
    if tcfg.microbatches > 1:
        raise ValueError(
            "the transport route materializes per-node gradients and does "
            "not compose with microbatch accumulation; use microbatches=1")
    stateful = transport.has_state(algo)
    grads_fn = _node_grads_fn(model, tp.n_nodes)

    def train_step(params, opt_state, batch, step):
        if stateful:
            opt_state, agg_state = opt_state
        else:
            agg_state = None
        slot_key = transport.step_key(base_key, step, tp.mc_steps)

        eval_params = transport.lookahead_params(algo, params, agg_state, tp) \
            if spec.nesterov else params
        clean_loss, node_g = grads_fn(eval_params, batch)
        node_g = jax.vmap(
            lambda g: _constrain_like_params(g, model.cfg.fsdp))(node_g)

        update, agg_state, aux = transport.aggregate(
            algo, node_g, slot_key, tp, agg_state)
        update = _constrain_like_params(update, model.cfg.fsdp)

        update, metrics = _clip_and_metrics(update, tcfg)
        params, opt_state = opt.update(update, opt_state, params)
        if stateful:
            opt_state = (opt_state, agg_state)
        metrics["loss"] = clean_loss
        metrics["noise_std"] = (edge_noise_std(tp.channel, tp.n_nodes)
                                if spec.ota else 0.0)
        metrics["tx_energy"] = aux["tx_energy"]
        return params, opt_state, metrics

    def init_state(params):
        if stateful:
            return (opt.init(params), transport.init_state(algo, params, tp))
        return opt.init(params)

    train_step.init_state = init_state
    return train_step
