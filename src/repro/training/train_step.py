"""Train-step builder: model loss + gradient aggregation protocol + optimizer.

The aggregation protocol is selected per run:
  'gbma'        — the paper: fading-weighted loss (exact OTA superposition,
                  DESIGN.md §4) + edge noise on the reduced gradient tree.
  'fdm'         — FDM-GD baseline: orthogonal per-node channels, channel-
                  inverted (no fading distortion) but per-node additive noise;
                  the averaged-gradient noise std is sqrt(N) times GBMA's.
  'centralized' — noiseless exact mean (Remark 1 benchmark).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.channel import edge_noise_std
from repro.core.gbma import (GBMAConfig, gbma_value_and_grad, node_weights,
                             perturb_gradients)
from repro.models.model import Model
from repro.optim.gd import Optimizer, clip_by_global_norm
from repro.sharding.specs import current_mesh, params_shardings

PyTree = Any


def _constrain_like_params(grads: PyTree, fsdp: bool) -> PyTree:
    """Pin the gradient tree to the parameter shardings. Without this GSPMD
    materializes scan-accumulated cotangents replicated (64 GiB/device for the
    400B config) before the optimizer update re-shards them."""
    mesh = current_mesh()
    if mesh is None:
        return grads
    shardings = params_shardings(grads, fsdp, mesh)
    return jax.tree_util.tree_map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, shardings)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    aggregator: str = "gbma"  # gbma | fdm | centralized
    gbma: GBMAConfig = dataclasses.field(default_factory=GBMAConfig)
    seed: int = 0
    clip_norm: Optional[float] = None
    # §Perf: 'rbg' generates the d-dimensional edge noise with one
    # RngBitGenerator op per leaf instead of threefry's elementwise chain —
    # at d = 671e9 the threefry pipeline materializes tens of GiB of u32
    # counter tensors per expert leaf. 'threefry2x32' is the baseline.
    rng_impl: str = "threefry2x32"
    # §Perf: gradient accumulation over microbatches. Faithful to the paper —
    # each node transmits ONE analog gradient per slot regardless of how it
    # computed it locally (f_n is the node's full local loss); only the
    # per-step activation working set shrinks by the microbatch factor.
    microbatches: int = 1


def _fdm_noise(grads: PyTree, key, gcfg: GBMAConfig) -> PyTree:
    """FDM-GD: each node's dedicated channel adds independent noise at energy
    E_N; the edge averages N received gradients, so the per-coordinate noise
    std is sigma_w / (sqrt(E_N) * sqrt(N)) = sqrt(N) * GBMA's."""
    std = (gcfg.channel.noise_std
           / math.sqrt(gcfg.channel.energy * gcfg.n_nodes))
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [g + std * jax.random.normal(k, g.shape, jnp.float32).astype(g.dtype)
             for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def _accumulated_grads(vg, params, batch, weights, m: int, fsdp: bool):
    """Scan over m microbatches, accumulating the mean gradient in f32
    (sharded like the params). Cuts the per-step activation working set by m
    at the cost of an f32 gradient accumulator (2x param bytes)."""
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)
    mb_w = weights.reshape(m, -1)
    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc0 = _constrain_like_params(acc0, fsdp)

    def body(carry, mb):
        acc, loss_sum = carry
        b, w = mb
        loss, g = vg(params, b, w)
        g = _constrain_like_params(g, fsdp)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(jnp.float32) / m, acc, g)
        acc = _constrain_like_params(acc, fsdp)
        return (acc, loss_sum + loss / m), None

    (grads, loss), _ = jax.lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)), (mb_batch, mb_w))
    return loss, grads


def build_train_step(model: Model, tcfg: TrainConfig, opt: Optimizer
                     ) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit at the call site."""
    gcfg = tcfg.gbma
    base_key = jax.random.key(tcfg.seed, impl=tcfg.rng_impl)
    vg = gbma_value_and_grad(
        lambda p, b: model.train_loss_per_example(p, b)[0])

    def train_step(params, opt_state, batch, step):
        k_step = jax.random.fold_in(base_key, step)
        k_h, k_w = jax.random.split(k_step)
        bsz = batch["tokens"].shape[0]

        if tcfg.aggregator == "gbma" and gcfg.enabled:
            weights = node_weights(k_h, gcfg, bsz)
        else:
            weights = jnp.ones((bsz,), jnp.float32)

        if tcfg.microbatches > 1:
            clean_loss, grads = _accumulated_grads(
                vg, params, batch, weights, tcfg.microbatches, model.cfg.fsdp)
        else:
            clean_loss, grads = vg(params, batch, weights)
            grads = _constrain_like_params(grads, model.cfg.fsdp)

        if tcfg.aggregator == "gbma" and gcfg.enabled:
            grads = perturb_gradients(grads, k_w, gcfg)
        elif tcfg.aggregator == "fdm":
            grads = _fdm_noise(grads, k_w, gcfg)

        if tcfg.clip_norm is not None:
            grads = clip_by_global_norm(grads, tcfg.clip_norm)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {
            "loss": clean_loss,
            "grad_norm": gnorm,
            "noise_std": (edge_noise_std(gcfg.channel, gcfg.n_nodes)
                          if tcfg.aggregator == "gbma" else 0.0),
        }
        return params, opt_state, metrics

    return train_step
