"""repro: GBMA — analog over-the-air gradient descent over fading MACs,
integrated as a first-class gradient-aggregation mode of a multi-pod JAX
training/serving framework. See DESIGN.md."""

__version__ = "0.1.0"
