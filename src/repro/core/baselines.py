"""Baselines the paper compares against (§VI): centralized GD and FDM-GD,
plus a CA-DSGD-style power-control OTA baseline from the related work [11].
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig, sample_gains

Array = jax.Array


@dataclasses.dataclass
class CentralizedGD:
    """Noiseless benchmark: theta_{k+1} = theta_k - beta * (1/N) Σ_n g_n."""

    grad_fn: Callable[[Array], Array]  # theta -> (N, d)
    stepsize: float

    def run(self, theta0: Array, steps: int, key: Array | None = None) -> Array:
        def body(theta, _):
            v = jnp.mean(self.grad_fn(theta), axis=0)
            return theta - self.stepsize * v, theta

        theta_fin, traj = jax.lax.scan(body, theta0, None, length=steps)
        return jnp.concatenate([traj, theta_fin[None]], axis=0)


@dataclasses.dataclass
class FDMGD:
    """Distributed GD over orthogonal (FDM/TDM) channels.

    Each node gets its own dimension-per-node channel: the edge receives
    h_{n,k} g_n + w_n with an *independent* noise vector per node (the noise
    cost scales with N — the paper's key disadvantage of FDM, §I-A). Channel
    gains are assumed equalized per-link (coherent detection with channel
    inversion is standard on dedicated channels), so distortion comes only
    from the per-node additive noise at energy E_N per node.
    """

    grad_fn: Callable[[Array], Array]
    channel: ChannelConfig
    stepsize: float
    invert_channel: bool = True

    def run(self, theta0: Array, steps: int, key: Array) -> Array:
        import math

        def body(theta, k):
            g = self.grad_fn(theta)  # (N, d)
            n = g.shape[0]
            k_h, k_w = jax.random.split(k)
            noise = self.channel.noise_std / math.sqrt(self.channel.energy) * (
                jax.random.normal(k_w, g.shape, dtype=g.dtype)
            )
            if self.invert_channel:
                rx = g + noise  # per-link equalized
            else:
                h = sample_gains(k_h, self.channel, (n,))
                rx = h[:, None] * g + noise
            v = jnp.mean(rx, axis=0)
            return theta - self.stepsize * v, theta

        keys = jax.random.split(key, steps)
        theta_fin, traj = jax.lax.scan(body, theta0, keys)
        return jnp.concatenate([traj, theta_fin[None]], axis=0)

    def slot_energy(self, grads: Array) -> Array:
        """FDM per-slot energy: N separate transmissions at energy E_N each."""
        return self.channel.energy * jnp.sum(grads.astype(jnp.float32) ** 2)


@dataclasses.dataclass
class PowerControlOTA:
    """CA-DSGD-style truncated channel inversion (related work [11]).

    Nodes invert their channel gain so the edge sees the undistorted sum, but
    nodes in deep fade (h < h_min) stay silent to bound the inversion power.
    Included to quantify what GBMA gives up / gains by *not* using power
    control.
    """

    grad_fn: Callable[[Array], Array]
    channel: ChannelConfig
    stepsize: float
    h_min: float = 0.3

    def run(self, theta0: Array, steps: int, key: Array) -> Array:
        import math

        def body(theta, k):
            g = self.grad_fn(theta)
            n = g.shape[0]
            k_h, k_w = jax.random.split(k)
            h = sample_gains(k_h, self.channel, (n,))
            active = (h >= self.h_min).astype(g.dtype)
            n_active = jnp.maximum(jnp.sum(active), 1.0)
            # inverted channels superpose to sum of active gradients
            sup = jnp.einsum("n,nd->d", active, g)
            w = self.channel.noise_std / (
                n_active * math.sqrt(self.channel.energy)
            ) * jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype)
            v = sup / n_active + w
            return theta - self.stepsize * v, theta

        keys = jax.random.split(key, steps)
        theta_fin, traj = jax.lax.scan(body, theta0, keys)
        return jnp.concatenate([traj, theta_fin[None]], axis=0)
