"""Fading multiple-access channel models (paper §II–III).

Each node n experiences a block-fading channel ``h~_{n,k}`` at slot t_k with
magnitude gain ``h_{n,k} = |h~_{n,k}|`` and phase ``phi_{n,k}``. Gains are
i.i.d. across nodes and slots with mean ``mu_h`` and variance ``sigma_h2``.
Nodes apply phase correction ``e^{-j phi_{n,k}}``; with a residual phase error
``|phi_err| < pi/4`` the *effective real gain* at the matched-filter output is
``h_{n,k} * cos(phi_err_{n,k})`` which keeps a non-zero mean (paper §III).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Configuration of the fading MAC.

    Attributes:
      fading: one of 'equal' | 'rayleigh' | 'rician' | 'lognormal'.
      scale: distribution scale parameter. For 'rayleigh' this is the Rayleigh
        sigma; for 'equal' the constant gain; for 'rician' the scatter sigma;
        for 'lognormal' the log-std.
      rician_k: Rician K-factor (LOS power / scattered power), only for 'rician'.
      phase_error_max: residual phase-correction error bound (radians). 0 means
        perfect phase correction. Values < pi/4 preserve a positive-mean gain.
      noise_std: sigma_w — std of the additive channel noise per waveform at the
        matched-filter output (before the 1/(N sqrt(E_N)) normalization).
      energy: E_N — per-node transmission energy coefficient.
    """

    fading: str = "rayleigh"
    scale: float = 1.0
    rician_k: float = 4.0
    phase_error_max: float = 0.0
    noise_std: float = 1.0
    energy: float = 1.0

    # ---- first/second moments of the effective gain -----------------------
    @property
    def mu_h(self) -> float:
        """E[h] of the *magnitude* gain (before phase error)."""
        import math

        if self.fading == "equal":
            mu = self.scale
        elif self.fading == "rayleigh":
            mu = self.scale * math.sqrt(math.pi / 2.0)
        elif self.fading == "rician":
            # nu^2 = K * 2 sigma^2 ; E[h] = sigma*sqrt(pi/2)*L_{1/2}(-nu^2/(2sigma^2))
            nu2 = self.rician_k * 2.0 * self.scale**2
            x = nu2 / (2.0 * self.scale**2)
            # Laguerre L_{1/2}(-x) = e^{-x/2}[(1+x) I0(x/2) + x I1(x/2)]
            l_half = math.exp(-x / 2.0) * (
                (1.0 + x) * _bessel_i0(x / 2.0) + x * _bessel_i1(x / 2.0)
            )
            mu = self.scale * math.sqrt(math.pi / 2.0) * l_half
        elif self.fading == "lognormal":
            mu = math.exp(self.scale**2 / 2.0)
        else:
            raise ValueError(f"unknown fading model: {self.fading}")
        if self.phase_error_max > 0.0:
            # E[cos(U)] for U ~ Unif[-a, a] = sin(a)/a
            mu *= math.sin(self.phase_error_max) / self.phase_error_max
        return mu

    @property
    def sigma_h2(self) -> float:
        """Var[h_eff] of the effective gain (including phase error)."""
        import math

        if self.fading == "equal":
            second = self.scale**2
        elif self.fading == "rayleigh":
            second = 2.0 * self.scale**2
        elif self.fading == "rician":
            nu2 = self.rician_k * 2.0 * self.scale**2
            second = nu2 + 2.0 * self.scale**2
        elif self.fading == "lognormal":
            second = math.exp(2.0 * self.scale**2)
        else:
            raise ValueError(f"unknown fading model: {self.fading}")
        if self.phase_error_max > 0.0:
            a = self.phase_error_max
            # E[cos^2 U] = 1/2 + sin(2a)/(4a)
            second *= 0.5 + math.sin(2.0 * a) / (4.0 * a)
        return second - self.mu_h**2

    @property
    def dispersion(self) -> float:
        """Channel index of dispersion D = sigma_h^2 / mu_h (paper Eq. 24)."""
        return self.sigma_h2 / self.mu_h

    @property
    def magnitude_m2(self) -> float:
        """E[h²] of the raw *magnitude* gain — no phase-error factor.

        This is the normalizer of the blind-transmitter MRC combiner
        (Amiri-Duman-Gündüz): with h~ = h e^{jφ}, E[|h~|²] = E[h²]
        regardless of the phase distribution."""
        import math

        if self.fading == "equal":
            return self.scale**2
        if self.fading == "rayleigh":
            return 2.0 * self.scale**2
        if self.fading == "rician":
            return 2.0 * self.scale**2 * (1.0 + self.rician_k)
        if self.fading == "lognormal":
            return math.exp(2.0 * self.scale**2)
        raise ValueError(f"unknown fading model: {self.fading}")


def _bessel_i0(x: float) -> float:
    # series expansion, adequate for the moderate K factors used here
    s, term = 1.0, 1.0
    for k in range(1, 30):
        term *= (x / 2.0) ** 2 / k**2
        s += term
    return s


def _bessel_i1(x: float) -> float:
    s, term = 0.0, x / 2.0
    for k in range(0, 30):
        s += term
        term *= (x / 2.0) ** 2 / ((k + 1) * (k + 2))
    return s


def _sample_magnitude(k_mag: Array, cfg: ChannelConfig, shape: tuple) -> Array:
    """Magnitude gains h = |h~| for `shape` slots (no phase factor)."""
    if cfg.fading == "equal":
        h = jnp.full(shape, cfg.scale, dtype=jnp.float32)
    elif cfg.fading == "rayleigh":
        h = cfg.scale * jnp.sqrt(
            -2.0 * jnp.log(jax.random.uniform(k_mag, shape, minval=1e-12, maxval=1.0))
        )
    elif cfg.fading == "rician":
        import math

        nu = math.sqrt(cfg.rician_k * 2.0) * cfg.scale
        xy = jax.random.normal(k_mag, shape + (2,)) * cfg.scale
        h = jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    elif cfg.fading == "lognormal":
        h = jnp.exp(cfg.scale * jax.random.normal(k_mag, shape))
    else:
        raise ValueError(f"unknown fading model: {cfg.fading}")
    return h


def sample_gains(key: Array, cfg: ChannelConfig, shape: tuple) -> Array:
    """Sample effective real channel gains h_eff for `shape` node slots.

    Includes the residual-phase-error factor cos(phi_err). Shapes are
    typically (N,) for one slot or (steps, N).
    """
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, cfg, shape)
    if cfg.phase_error_max > 0.0:
        phi = jax.random.uniform(
            k_ph, shape, minval=-cfg.phase_error_max, maxval=cfg.phase_error_max
        )
        h = h * jnp.cos(phi)
    return h.astype(jnp.float32)


def sample_complex_gains(
    key: Array, cfg: ChannelConfig, shape: tuple
) -> tuple[Array, Array]:
    """Sample complex channel gains h~ = h e^{jφ} as (real, imag) parts.

    The blind-transmitter setting: nodes apply NO phase correction, so the
    full uniform phase φ ~ Unif[-π, π) survives (vs `sample_gains`, whose
    residual phase error is bounded by `phase_error_max` after precoding).
    The magnitude reuses the per-family sampler of `sample_gains` — same
    key split order, so the magnitude draws coincide for a fixed key.
    """
    import math

    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, cfg, shape)
    phi = jax.random.uniform(k_ph, shape, minval=-math.pi, maxval=math.pi)
    return ((h * jnp.cos(phi)).astype(jnp.float32),
            (h * jnp.sin(phi)).astype(jnp.float32))


def edge_noise_std(cfg: ChannelConfig, n_nodes: int) -> float:
    """Per-coordinate std of w_k = w~_k / (N sqrt(E_N)) (paper Eq. 8)."""
    import math

    return cfg.noise_std / (n_nodes * math.sqrt(cfg.energy))


def received_snr_db(cfg: ChannelConfig, n_nodes: int, grad_power: float = 1.0) -> float:
    """Approximate received SNR (dB) of the aggregated signal at the edge.

    Signal power ~ E_N * (N mu_h)^2 * grad_power per coordinate vs noise
    sigma_w^2; used to report the operating point as in paper Fig. 4.
    """
    import math

    sig = cfg.energy * (n_nodes * cfg.mu_h) ** 2 * grad_power
    return 10.0 * math.log10(sig / cfg.noise_std**2)
