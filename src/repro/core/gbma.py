"""GBMA — Gradient-Based Multiple Access (paper §III).

Three implementation tiers, all realizing Eq. (8)–(9):

  v_k = (1/N) sum_n h_{n,k} g_n(theta_k) + w_k,  w_k ~ N(0, sigma_w^2/(N^2 E_N) I_d)
  theta_{k+1} = theta_k - beta v_k

(i)   `ota_aggregate` / `GBMASimulator` — vectorized N-node simulation used by
      the paper-experiment benchmarks (linear regression, localization).
(ii)  `gbma_value_and_grad` + `perturb_gradients` — the *production* path: the
      fading superposition is obtained exactly by weighting each node's local
      loss with its stop-gradiented gain (∇ Σ h_n f_n /N = Σ h_n g_n /N) and
      letting pjit/GSPMD insert the all-reduce (the MAC superposition); edge
      noise is added to the reduced gradient tree afterwards. Composes with
      FSDP / tensor parallelism / remat / scan.
(iii) `shard_map_aggregate` — the explicit per-device protocol: scale the local
      gradient by the local node gain, `psum` over the node axes (= analog
      superposition over the MAC), normalize by N, add edge noise. Used for
      exposition and cross-validated against tier (ii) in tests.

Tier (i) and the tree helpers are thin veneers over the unified
channel-transport layer (`repro.core.transport`), which routes every slot
through the `mc/slots.py` algo registry — one definition of each MAC
algorithm shared by the Monte Carlo engine and real-model training. The
veneers keep this module's historical signatures and RNG streams
(split-for-split); values agree with the pre-transport implementations to
f32 ulp (<= 1e-6): the only arithmetic change is that channel constants
like the edge-noise std are now computed in traced f32 (the engine's
convention) instead of host-side f64, a one-ulp rounding difference.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compat import tree_map
from repro.core import transport
from repro.core.channel import ChannelConfig, edge_noise_std, sample_gains

Array = jax.Array
PyTree = Any


# --------------------------------------------------------------------------
# tier (i): vectorized N-node simulation (paper experiments)
# --------------------------------------------------------------------------
def ota_aggregate(
    grads: Array,  # (N, d) per-node local gradients
    key: Array,
    cfg: ChannelConfig,
    use_kernel: bool = False,
) -> Array:
    """One MAC slot: returns v_k of shape (d,) per Eq. (8).

    A veneer over `transport.aggregate('gbma', ...)` — the slot key splits
    k -> (k_h, k_w) exactly as before (gains then edge noise), so fixed
    seeds reproduce; the received update is computed in f32 and cast back
    to `grads.dtype`. `use_kernel` routes the superposition through
    `repro.kernels.ota` (pallas on TPU, jnp oracle elsewhere)."""
    impl = ("pallas" if jax.default_backend() == "tpu" else "ref") \
        if use_kernel else "inline"
    tcfg = transport.TransportConfig(
        n_nodes=grads.shape[0], channel=cfg, ota_impl=impl)
    v, _, _ = transport.aggregate("gbma", grads, key, tcfg)
    return v.astype(grads.dtype)


@dataclasses.dataclass
class GBMASimulator:
    """Iterates theta_{k+1} = theta_k - beta * v_k on an N-node problem.

    `grad_fn(theta) -> (N, d)` returns every node's local gradient (the
    simulator plays both the nodes and the edge). Matches the paper's
    experimental setup; `run` returns the trajectory of estimates.
    """

    grad_fn: Callable[[Array], Array]
    channel: ChannelConfig
    stepsize: float

    def run(self, theta0: Array, steps: int, key: Array) -> Array:
        def body(theta, k):
            g = self.grad_fn(theta)  # (N, d)
            v = ota_aggregate(g, k, self.channel)
            return theta - self.stepsize * v, theta

        keys = jax.random.split(key, steps)
        theta_fin, traj = jax.lax.scan(body, theta0, keys)
        return jnp.concatenate([traj, theta_fin[None]], axis=0)  # (steps+1, d)


# --------------------------------------------------------------------------
# tier (ii): production path — h-weighted loss under pjit
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GBMAConfig:
    """GBMA integration config for the training substrate.

    n_nodes: total number of transmitting nodes N. Each node owns a contiguous
      group of examples in the global batch (global_batch % n_nodes == 0).
    channel: the fading-MAC model.
    enabled: if False the aggregator degrades to exact (centralized) mean — the
      paper's noiseless/equal-gain special case (Remark 1).
    """

    n_nodes: int = 16
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    enabled: bool = True
    # §Perf: sample the edge noise directly in the gradient dtype (bf16) —
    # the f32 default is the faithful baseline; for bf16 gradients the noise
    # (std << 1) quantizes identically after the add
    noise_dtype: str = "float32"


def node_weights(key: Array, gcfg: GBMAConfig, global_batch: int) -> Array:
    """Per-example fading weights, shape (global_batch,).

    Example i belongs to node floor(i / (B/N)); all of a node's examples share
    its slot gain h_{n,k}. With `enabled=False` returns all-ones (equal gains,
    noiseless edge → centralized GD; Remark 1 of the paper).
    """
    if not gcfg.enabled:
        return jnp.ones((global_batch,), jnp.float32)
    n = gcfg.n_nodes
    if global_batch % n != 0:
        raise ValueError(f"global_batch {global_batch} not divisible by n_nodes {n}")
    h = sample_gains(key, gcfg.channel, (n,))  # (N,)
    return jnp.repeat(h, global_batch // n)


def gbma_value_and_grad(
    loss_fn: Callable[..., Array],
) -> Callable[..., Tuple[Array, PyTree]]:
    """Wrap a per-example loss into the h-weighted GBMA objective.

    `loss_fn(params, batch) -> (B,) per-example losses`. Returns a function
    `(params, batch, weights) -> (mean_loss, distorted_grad)` where
    `distorted_grad = (1/N) Σ_n h_n ∇f_n` exactly (f_n = mean loss of node n's
    example group, h_n folded into per-example weights that sum to B).
    """

    def weighted(params, batch, weights):
        losses = loss_fn(params, batch)  # (B,)
        w = jax.lax.stop_gradient(weights).astype(losses.dtype)
        return jnp.mean(w * losses), jnp.mean(losses)

    vg = jax.value_and_grad(weighted, has_aux=True)

    def fn(params, batch, weights):
        (_, clean_loss), grads = vg(params, batch, weights)
        return clean_loss, grads

    return fn


def perturb_gradients(
    grads: PyTree, key: Array, gcfg: GBMAConfig, dtype=None
) -> PyTree:
    """Add the edge noise w_k to the superposed gradient tree (Eq. 8).

    Per-leaf independent normals with std sigma_w/(N sqrt(E_N)); leaf keys
    come from `split(key, n_leaves)` so the tree structure, not leaf order
    in memory, defines the stream. SPMD-safe: same key on every device
    yields identical noise, consistent with any output sharding. The draw
    itself is `transport.add_tree_noise` (bit-identical to the historical
    inline loop); only the std constant stays host-side f64 here, so this
    fused path is byte-for-byte stable across the transport refactor.
    """
    if not gcfg.enabled:
        return grads
    if dtype is None:
        dtype = jnp.dtype(gcfg.noise_dtype)
    std = edge_noise_std(gcfg.channel, gcfg.n_nodes)
    return transport.add_tree_noise(grads, key, std, noise_dtype=dtype)


# --------------------------------------------------------------------------
# tier (iii): explicit shard_map protocol
# --------------------------------------------------------------------------
def shard_map_aggregate(
    local_grad: PyTree,
    local_gain: Array,  # scalar gain of this device's node
    key: Array,  # identical on all devices (edge noise)
    gcfg: GBMAConfig,
    axis_names: Sequence[str] = ("data",),
) -> PyTree:
    """Explicit OTA protocol body — call inside `repro.compat.shard_map`
    (the version-portable spelling; `jax.shard_map` does not exist on 0.4.x).

    Each device scales its local gradient by its own slot gain (the analog
    amplification sqrt(E_N) h g after phase correction and matched filtering),
    `psum`s over the node axes — the physical superposition on the MAC — then
    normalizes by N and adds the edge noise once (same key on all devices).
    """
    n = gcfg.n_nodes

    def superpose(g):
        s = g * local_gain.astype(g.dtype)
        for ax in axis_names:
            s = jax.lax.psum(s, ax)
        return s / n

    v = tree_map(superpose, local_grad)
    return perturb_gradients(v, key, gcfg)


def ota_aggregate_multiantenna(
    grads: Array,  # (N, d)
    key: Array,
    cfg: ChannelConfig,
    n_antennas: int,
) -> Array:
    """Multi-antenna edge receiver (related work [12], Amiri et al.): each of
    M antennas sees an independent fading realization of the same
    superposition; MRC-style averaging divides both the gradient-distortion
    variance (sigma_h^2 -> sigma_h^2/M) and the noise variance by M — the
    fading effect vanishes as M grows even without any phase correction at
    the transmitters.

    Veneer over `transport.aggregate('gbma', ..., n_antennas=M)`: the key
    splits `split(key, M)` into per-antenna slot chains exactly as the
    historical vmap did (M=1 included — its extra split is part of the
    stream)."""
    tcfg = transport.TransportConfig(
        n_nodes=grads.shape[0], channel=cfg, n_antennas=n_antennas)
    v, _, _ = transport.aggregate("gbma", grads, key, tcfg)
    return v.astype(grads.dtype)


def blind_ota_aggregate(
    grads: Array,  # (N, d) transmitted analog vectors (no precoding)
    key: Array,
    cfg: ChannelConfig,
    n_antennas: int,
) -> Array:
    """Blind-transmitter OTA slot (Amiri, Duman & Gündüz, arXiv:1907.03909).

    Nodes transmit sqrt(E_N) g_n with NO channel state information — no
    channel-inversion precoding, no phase correction — so antenna m of the
    edge receives the complex superposition
    ``y_m = Σ_n h~_{n,m} sqrt(E_N) g_n + z~_m`` with i.i.d. complex gains
    h~ = h e^{jφ}, φ ~ Unif[-π, π). The edge (which does know the channel —
    receiver CSI only) MRC-combines over its M antennas:

        v = 1/(N M E[h²]) Σ_m Re{ (Σ_n h~*_{n,m}) y_m } / sqrt(E_N)

    Channel hardening makes the per-node coefficient
    c_n = Σ_m(a_{n,m} A_m + b_{n,m} B_m)/(M E[h²]) concentrate on 1: the
    cross-node interference and the noise both vanish as 1/M, so v → the
    equal-gain (scale 1) GBMA update as M grows — no transmitter CSI
    needed. Effective noise variance ≈ σ_w²/(E_N N M E[h²]) per coordinate
    (vs σ_w²/(E_N N²) for precoded GBMA).

    Veneer over `transport.aggregate('blind', ...)` (the engine's
    `_blind_slot`): key chain slot -> `split(key, M)` -> per antenna
    (k_h complex gains, k_w stacked real/imag noise), split-for-split the
    historical stream.
    """
    tcfg = transport.TransportConfig(
        n_nodes=grads.shape[0], channel=cfg, n_antennas=n_antennas)
    v, _, _ = transport.aggregate("blind", grads, key, tcfg)
    return v.astype(grads.dtype)


# --------------------------------------------------------------------------
# energy accounting
# --------------------------------------------------------------------------
def slot_energy(grads: Array, cfg: ChannelConfig) -> Array:
    """Total transmitted energy of one slot: Σ_n E_N ||g_n||^2 (waveforms are
    orthonormal so the transmitted signal energy of node n is E_N ||g_n||²)."""
    return cfg.energy * jnp.sum(grads.astype(jnp.float32) ** 2)
