"""Random-draw machinery for the Monte Carlo engine.

Everything here is a *traceable twin* of a host-side reference sampler
(`repro.core.channel.sample_gains` / `sample_complex_gains`,
`jax.random.split`, `jax.random.normal`) — same key-split order, same draw
shapes — so engine trajectories reproduce the reference simulators under a
fixed seed. Three tiers per draw:

  * plain shaped draws (`_sample_gains`, `_sample_complex_gains`) for a
    single static node count;
  * padded `lax.switch` variants (`*_padded`, `_normal_padded`) that sample
    at each row's true static shape and zero-pad to N_max (threefry streams
    are shape-dependent, so padded-then-masked sampling would change every
    row's stream);
  * dynamic-count variants (`*_dynamic_n`, `_antenna_keys`) that reproduce
    the shaped draw bit-for-bit in ONE static-shape program by calling the
    raw threefry2x32 hash with counter vectors computed from the row's true
    count as *data* — no per-count branches, compile time independent of
    the sweep size. Only valid under the default threefry PRNG
    (`_dynamic_threefry_ok`); callers fall back to the switch tier.

`_row_gains` / `_row_complex_gains` pick the fastest valid tier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

Array = jax.Array


def _sample_magnitude(k_mag: Array, fading: str, p: dict,
                      shape: tuple) -> Array:
    """Traceable twin of `channel._sample_magnitude` over dynamic scalar
    params: the per-family |h~| draw, shared by the precoded sampler
    (`_sample_gains`) and the complex no-CSI one (`_sample_complex_gains`)."""
    scale = p["scale"]
    if fading == "equal":
        return jnp.broadcast_to(scale.astype(jnp.float32), shape)
    if fading == "rayleigh":
        u = jax.random.uniform(k_mag, shape, minval=1e-12, maxval=1.0)
        return scale * jnp.sqrt(-2.0 * jnp.log(u))
    if fading == "rician":
        nu = jnp.sqrt(p["rician_k"] * 2.0) * scale
        xy = jax.random.normal(k_mag, shape + (2,)) * scale
        return jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    if fading == "lognormal":
        return jnp.exp(scale * jax.random.normal(k_mag, shape))
    raise ValueError(f"unknown fading model: {fading}")


def _magnitude_m2(fading: str, p: dict) -> Array:
    """Traceable twin of `ChannelConfig.magnitude_m2`: E[h²] of the raw
    magnitude gain — the blind-MRC combiner's normalizer."""
    scale = p["scale"]
    if fading == "equal":
        return scale**2
    if fading == "rayleigh":
        return 2.0 * scale**2
    if fading == "rician":
        return 2.0 * scale**2 * (1.0 + p["rician_k"])
    if fading == "lognormal":
        return jnp.exp(2.0 * scale**2)
    raise ValueError(f"unknown fading model: {fading}")


def _sample_gains(key: Array, fading: str, p: dict, shape: tuple,
                  phase_zero: bool = False) -> Array:
    """Traceable twin of `channel.sample_gains` over dynamic scalar params.

    Split order and draw shapes match `sample_gains` exactly, so a fixed key
    yields the same random draws as the reference simulators (trajectories
    then agree to f32 rounding). The phase factor is applied
    unconditionally: with phase_error_max == 0 the uniform draw is 0 and
    cos(0) == 1, identical to the skipped branch.

    `phase_zero` (static) asserts that every row's phase_error_max is 0 and
    skips the phase draw entirely — value-identical (h · cos(0) == h
    bit-for-bit, and the phase stream hashes its own key half, so no other
    draw shifts) but half the per-gain threefry work. The execution layer's
    hoisted RNG plan sets it from the batch's configs.
    """
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, fading, p, shape)
    if phase_zero:
        return h.astype(jnp.float32)
    phi = jax.random.uniform(k_ph, shape, minval=-p["phase_error_max"],
                             maxval=p["phase_error_max"])
    return (h * jnp.cos(phi)).astype(jnp.float32)


def _sample_complex_gains(key: Array, fading: str, p: dict,
                          shape: tuple) -> tuple:
    """Traceable twin of `channel.sample_complex_gains`: (real, imag) parts
    of h~ = h e^{jφ} with the FULL uniform phase φ ~ Unif[-π, π) — no
    precoding in the blind-transmitter setting, so nothing bounds the
    phase. Same split order as the reference."""
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, fading, p, shape)
    phi = jax.random.uniform(k_ph, shape, minval=-np.pi, maxval=np.pi)
    return ((h * jnp.cos(phi)).astype(jnp.float32),
            (h * jnp.sin(phi)).astype(jnp.float32))


def _sample_gains_padded(key: Array, fading: str, p: dict,
                         n_sizes: tuple, n_max: int,
                         phase_zero: bool = False) -> Array:
    """(n_max,) gains whose first n entries equal the unpadded (n,) draw.

    Threefry streams depend on the draw shape, so sampling (n_max,) and
    masking would NOT reproduce the per-N reference draws. Instead the
    row's true node count (p['n_idx'] indexes the static `n_sizes`) selects
    a branch that samples at the true static shape and zero-pads. With a
    single full-size branch this is the plain sampler (no switch traced).
    """
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return _sample_gains(key, fading, p, (n_max,), phase_zero)
    branches = [
        (lambda k, n=n: jnp.pad(_sample_gains(k, fading, p, (n,),
                                              phase_zero),
                                (0, n_max - n)))
        for n in n_sizes
    ]
    return jax.lax.switch(p["n_idx"], branches, key)


def _sample_complex_gains_padded(key: Array, fading: str, p: dict,
                                 n_sizes: tuple, n_max: int) -> tuple:
    """(a, b) complex-gain parts, zero-padded like `_sample_gains_padded`
    (per-N branches sample at the true static shape)."""
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return _sample_complex_gains(key, fading, p, (n_max,))
    branches = [
        (lambda k, n=n: jnp.pad(
            jnp.stack(_sample_complex_gains(k, fading, p, (n,))),
            ((0, 0), (0, n_max - n))))
        for n in n_sizes
    ]
    ab = jax.lax.switch(p["n_idx"], branches, key)
    return ab[0], ab[1]


def _normal_padded(key: Array, n_idx: Array, n_sizes: tuple, n_max: int,
                   d: int, dtype) -> Array:
    """(n_max, d) normal draw matching the unpadded (n, d) draw per row
    (same shape-dependent-stream issue as `_sample_gains_padded`)."""
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return jax.random.normal(key, (n_max, d), dtype=dtype)
    branches = [
        (lambda k, n=n: jnp.pad(jax.random.normal(k, (n, d), dtype=dtype),
                                ((0, n_max - n), (0, 0))))
        for n in n_sizes
    ]
    return jax.lax.switch(n_idx, branches, key)


# --------------------------------------------------------------------------
# dynamic-length draws with static shapes (node-count sweeps, fast path)
#
# Threefry draws depend on the requested shape: `uniform(key, (n,))` hashes
# counter pairs (j, j + ceil(n/2)), so every distinct N needs its own draw
# program, and the `lax.switch` over those programs is what makes the padded
# sweep expensive to compile. But the counters are just uint32 DATA — by
# calling the raw threefry2x32 primitive on counter vectors computed from a
# *traced* n, one static-shape (n_max) program reproduces the (n,)-shaped
# draw bit-for-bit in lanes [0, n). The bits->float transforms below are
# copied from `jax._src.random._uniform` / `_normal_real` so the values
# match exactly. Only valid for the default threefry PRNG — callers must
# check `compat.threefry_is_default()` and fall back to the switch sampler.
# --------------------------------------------------------------------------
def _dynamic_bits(kd: Array, size: Array, out_max: int) -> Array:
    """uint32 bits equal to `random_bits(key, 32, (size,))` in lanes
    [0, size); `size` is traced (<= out_max), `out_max` static."""
    m_max = (out_max + 1) // 2
    m = (size + 1) // 2  # half-width of the counter vector (incl. odd pad)
    i = jnp.arange(m_max, dtype=jnp.int32)
    x0 = i.astype(jnp.uint32)
    # second counter half: j + m, with the odd-size pad slot hashed on 0
    x1 = jnp.where(i + m < size, i + m, 0).astype(jnp.uint32)
    # merge batch dims BEFORE the bind: the primitive's batching rule
    # mis-broadcasts when keys are vmapped over different axes (seeds,
    # steps) than the counts (configs). `| zero` stamps every operand with
    # the union of batch dims through ordinary elementwise batching (x1
    # carries the config dims via `m`; kd carries the seed/step dims).
    zero = (kd[0] & jnp.uint32(0)) | (x1 & jnp.uint32(0))
    o0, o1 = compat.threefry2x32(kd[0] | zero, kd[1] | zero,
                                 x0 | zero, x1 | zero)
    j = jnp.arange(out_max, dtype=jnp.int32)
    bits0 = o0[jnp.minimum(j, m_max - 1)]
    bits1 = o1[jnp.clip(j - m, 0, m_max - 1)]
    return jnp.where(j < m, bits0, bits1)


_F32_ONE_BITS = np.float32(1.0).view(np.uint32)
_NORMAL_LO = np.nextafter(np.float32(-1.0), np.float32(0.0))


def _bits_to_u01(bits: Array) -> Array:
    """uint32 bits -> uniform [0, 1) floats, as `_uniform` builds them."""
    fb = (bits >> jnp.uint32(9)) | jnp.uint32(_F32_ONE_BITS)
    return jax.lax.bitcast_convert_type(fb, jnp.float32) - jnp.float32(1.0)


def _u01_to_uniform(u01: Array, minval, maxval) -> Array:
    return jnp.maximum(minval, u01 * (maxval - minval) + minval)


def _u01_to_normal(u01: Array) -> Array:
    lo = jnp.float32(_NORMAL_LO)
    u = jnp.maximum(lo, u01 * (jnp.float32(1.0) - lo) + lo)
    return jnp.float32(np.sqrt(2.0)) * jax.lax.erf_inv(u)


def _normal_dynamic_n(key: Array, n: Array, n_max: int, d: int) -> Array:
    """Zero-padded (n_max, d) twin of `normal(key, (n, d))` for traced n
    (the fdm per-node noise on node-count sweeps) — same counts-as-data
    trick as `_sample_gains_dynamic_n`, so the scan body stays free of
    per-N `lax.switch` branches."""
    kd = jax.random.key_data(key)
    z = _u01_to_normal(_bits_to_u01(_dynamic_bits(kd, n * d, n_max * d)))
    z = jnp.where(jnp.arange(n_max * d) < n * d, z, jnp.float32(0.0))
    return z.reshape(n_max, d)


def _sample_magnitude_dynamic_n(kd_mag: Array, fading: str, p: dict,
                                n: Array, n_max: int) -> Array:
    """Dynamic-count twin of `_sample_magnitude` (traced n, static n_max);
    lanes ≥ n are garbage until the caller masks them."""
    scale = p["scale"]
    if fading == "equal":
        return jnp.broadcast_to(scale.astype(jnp.float32), (n_max,))
    if fading == "rayleigh":
        u01 = _bits_to_u01(_dynamic_bits(kd_mag, n, n_max))
        u = _u01_to_uniform(u01, jnp.float32(1e-12), jnp.float32(1.0))
        return scale * jnp.sqrt(-2.0 * jnp.log(u))
    if fading == "rician":
        nu = jnp.sqrt(p["rician_k"] * 2.0) * scale
        z = _u01_to_normal(_bits_to_u01(
            _dynamic_bits(kd_mag, 2 * n, 2 * n_max)))
        xy = z.reshape(n_max, 2) * scale
        return jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    if fading == "lognormal":
        z = _u01_to_normal(_bits_to_u01(_dynamic_bits(kd_mag, n, n_max)))
        return jnp.exp(scale * z)
    raise ValueError(f"unknown fading model: {fading}")


def _sample_gains_dynamic_n(key: Array, fading: str, p: dict,
                            n_max: int, phase_zero: bool = False) -> Array:
    """Bit-exact twin of `_sample_gains(key, fading, p, (n,))` zero-padded
    to (n_max,), with n = p['n_nodes'] traced — one static-shape program
    covers every node count in the sweep. `phase_zero` skips the phase
    stream statically (value-identical; see `_sample_gains`)."""
    n = p["n_nodes"].astype(jnp.int32)
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude_dynamic_n(jax.random.key_data(k_mag), fading, p,
                                    n, n_max)
    if not phase_zero:
        a = p["phase_error_max"]
        phi = _u01_to_uniform(
            _bits_to_u01(_dynamic_bits(jax.random.key_data(k_ph), n, n_max)),
            -a, a)
        h = h * jnp.cos(phi)
    h = h.astype(jnp.float32)
    return jnp.where(jnp.arange(n_max) < n, h, jnp.float32(0.0))


def _sample_complex_gains_dynamic_n(key: Array, fading: str, p: dict,
                                    n_max: int) -> tuple:
    """Dynamic-count twin of `_sample_complex_gains(key, fading, p, (n,))`
    zero-padded to (n_max,) — the blind family's per-antenna gain draw on
    node-count sweeps."""
    n = p["n_nodes"].astype(jnp.int32)
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude_dynamic_n(jax.random.key_data(k_mag), fading, p,
                                    n, n_max)
    phi = _u01_to_uniform(
        _bits_to_u01(_dynamic_bits(jax.random.key_data(k_ph), n, n_max)),
        jnp.float32(-np.pi), jnp.float32(np.pi))
    lane = jnp.arange(n_max) < n
    a = jnp.where(lane, (h * jnp.cos(phi)).astype(jnp.float32), 0.0)
    b = jnp.where(lane, (h * jnp.sin(phi)).astype(jnp.float32), 0.0)
    return a, b


def _dynamic_threefry_ok() -> bool:
    """Counts-as-data fast paths need the raw primitive AND the default
    threefry PRNG (the bit-level replication is only valid then)."""
    return compat.threefry2x32 is not None and compat.threefry_is_default()


def _row_gains(key: Array, fading: str, p: dict, n_sizes: tuple,
               n_max: int, phase_zero: bool = False) -> Array:
    """This row's (n_max,) zero-padded slot gains: dynamic-count program
    when available (no per-N branches), per-N `lax.switch` otherwise."""
    if len(n_sizes) > 1 and _dynamic_threefry_ok():
        return _sample_gains_dynamic_n(key, fading, p, n_max, phase_zero)
    return _sample_gains_padded(key, fading, p, n_sizes, n_max, phase_zero)


def _row_complex_gains(key: Array, fading: str, p: dict, n_sizes: tuple,
                       n_max: int) -> tuple:
    """Complex counterpart of `_row_gains` for the blind family."""
    if len(n_sizes) > 1 and _dynamic_threefry_ok():
        return _sample_complex_gains_dynamic_n(key, fading, p, n_max)
    return _sample_complex_gains_padded(key, fading, p, n_sizes, n_max)


def _antenna_keys(key: Array, m_sizes: tuple, p: dict) -> Array:
    """(m_max,) antenna keys whose first m entries (m = this row's true
    antenna count, `p['n_antennas']`) equal `jax.random.split(key, m)`.

    Antenna counts suffer the same shape-dependent-stream problem as node
    counts: `split` is itself a threefry draw over `iota(2m)` counters, so
    splitting at m_max and masking would change every row's stream. The
    fast path replays the original split layout with the row's count as
    DATA (`_dynamic_bits` over 2m counters, reshaped (m_max, 2)); its
    validity is verified empirically by `compat.threefry_split_is_original`
    (False under `jax_threefry_partitionable`). The fallback is a
    `lax.switch` over the distinct static counts. Lanes ≥ m hold
    well-formed garbage keys — callers mask the antenna axis."""
    m_max = max(m_sizes)
    if len(m_sizes) == 1:
        return jax.random.split(key, m_max)
    if compat.threefry2x32 is not None \
            and compat.threefry_split_is_original():
        m = p["n_antennas"].astype(jnp.int32)
        bits = _dynamic_bits(jax.random.key_data(key), 2 * m, 2 * m_max)
        return jax.random.wrap_key_data(bits.reshape(m_max, 2))
    branches = [
        (lambda k, m=m: jnp.pad(
            jax.random.key_data(jax.random.split(k, m)),
            ((0, m_max - m), (0, 0))))
        for m in m_sizes
    ]
    return jax.random.wrap_key_data(
        jax.lax.switch(p["m_idx"], branches, key))
