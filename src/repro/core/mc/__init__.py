"""Pluggable Monte Carlo engine for OTA gradient-descent experiments.

Layers (see each module's docstring):

  * `problems` — problem containers + the open `PROBLEMS` registry
    (`register_problem`); built-ins `quadratic`, `localization`, and the
    stochastic-capable `logistic`.
  * `sampling` — reference-twin RNG samplers (padded / dynamic-count
    threefry draws, antenna key replay).
  * `slots`    — per-slot algorithm updates behind `register_algo`
    (`ALGOS` derives from the registry) + each algorithm's `hoist_draws`
    RNG-plan twin.
  * `plan`     — `ExecPlan` (one sweep's execution strategy) +
    `auto_plan` deriving it from the analytic memory model, a memory
    budget and the device topology (or, with `cost_model="measured"`,
    from the calibration-fed cost model).
  * `costmodel` — the measured per-workload cost model: a one-time
    calibration suite persisted as a versioned JSON artifact keyed by
    platform/device-count, `CostModel.predict_step_us/predict_run_us`
    consumed by `auto_plan` and the sweep server's pad-waste-aware
    coalescer, and the cached machine-peaks microbench the roofline
    renders.
  * `exec`     — the execution layer: the compiled `_mc_core` placed on
    a ("rows", "mc") device mesh, the hoisted counter-based RNG plan,
    the seed-chunked resumable scheduler with donated Chan-merged
    moment carries, the on-device seed reduction, and the analytic
    memory model (`estimate_peak_bytes`) — see docs/performance.md.
  * `engine`   — row assembly + the public `run_mc`, `MCResult`,
    `ChannelBatch`, `energy_to_target`.

`repro.core.montecarlo` remains the back-compat import path.
"""
from repro.core.mc.engine import (
    ChannelBatch,
    MCResult,
    clear_cache,
    energy_to_target,
    run_mc,
    slice_result,
    trace_count,
)
from repro.core.mc.costmodel import (
    CalibrationConfig,
    CostModel,
    Workload,
    analytic_cost_model,
    load_cost_model,
)
from repro.core.mc.exec import cache_epoch, estimate_peak_bytes, \
    static_signature
from repro.core.mc.plan import ExecPlan, RetryPolicy, auto_plan, \
    validate_plan
from repro.core.mc.problems import (
    MCProblem,
    MCProblemBatch,
    PROBLEMS,
    ProblemSpec,
    localization_mc_problem,
    logistic_mc_problem,
    quadratic_mc_problem,
    register_problem,
)
from repro.core.mc.slots import (
    ALGO_REGISTRY,
    AlgoSpec,
    SlotCtx,
    register_algo,
)


def __getattr__(name: str):
    if name in ("ALGOS", "_OTA_ALGOS", "_BLIND_ALGOS"):
        from repro.core.mc import slots

        return getattr(slots, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ALGO_REGISTRY",
    "ALGOS",
    "AlgoSpec",
    "CalibrationConfig",
    "ChannelBatch",
    "CostModel",
    "ExecPlan",
    "Workload",
    "analytic_cost_model",
    "cache_epoch",
    "load_cost_model",
    "MCProblem",
    "MCProblemBatch",
    "MCResult",
    "PROBLEMS",
    "ProblemSpec",
    "RetryPolicy",
    "SlotCtx",
    "auto_plan",
    "clear_cache",
    "energy_to_target",
    "estimate_peak_bytes",
    "localization_mc_problem",
    "logistic_mc_problem",
    "quadratic_mc_problem",
    "register_algo",
    "register_problem",
    "run_mc",
    "slice_result",
    "static_signature",
    "trace_count",
    "validate_plan",
]
