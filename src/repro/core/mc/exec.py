"""Throughput-first execution layer for the Monte Carlo engine.

This module owns HOW a sweep executes; `engine.py` owns WHAT a sweep is
(row assembly, validation, results). Three orthogonal knobs, all surfaced
through `run_mc`:

* **RNG plan** (`rng_plan="hoisted"` default / `"inscan"`): the hoisted
  plan materializes every randomness stream — channel gains, edge noise,
  per-antenna complex fades, fdm per-node noise, minibatch indices — in
  one batched counter-based (threefry) draw per stream OUTSIDE the
  `lax.scan`, as scan inputs, instead of tracing the key-split chains into
  the scan body. The draws replay the per-slot `split` chains key-for-key
  (each algorithm registers a `hoist_draws` twin of its slot fn's draw
  code in `mc/slots.py`), so trajectories are stream-identical to the
  legacy in-scan plan; the scan body is left with pure linear algebra.
  The plan also knows one static shortcut: when every row's
  `phase_error_max` is 0 the precoded-phase draw is skipped entirely
  (cos(0) == 1 exactly, and the phase stream has its own key half, so
  skipping it cannot shift any other draw). `"inscan"` keeps the
  pre-exec-layer engine byte-for-byte — it is the benchmark baseline and
  the fallback for third-party algos registered without a `hoist_draws`.

* **Seed chunking** (`seed_chunk=`): a host-side scheduler runs the seed
  axis in blocks of `seed_chunk`, re-materializing the hoisted draws per
  chunk, so peak device memory is O(C · chunk · steps · n_max) instead of
  O(C · seeds · steps · n_max). One compile covers every chunk (the seed
  ints are data). With `keep_seed_curves=False` the running curve
  statistics are carried between chunks in donated device buffers
  (`jax.jit(..., donate_argnums=...)` — XLA reuses the accumulator
  allocation in place).

* **On-device reduction** (`keep_seed_curves=False`): when the caller
  only needs the seed-mean and ci95 (most figures), the (C, S, steps+1)
  per-seed curves never leave the device — only (C, steps+1) statistics
  transfer to host. Chunked sweeps carry exact per-chunk two-pass moments
  and merge them with Chan's parallel algorithm (`chan_merge`) in donated
  device buffers; under placement the per-shard moments tree-reduce
  across the 'mc' mesh axis (`lax.psum`) before they ever leave the
  mapped region. `energy_to_target` needs per-seed curves and raises if
  they were reduced away.

* **Placement** (`n_shards` / `row_shards`, via `plan.ExecPlan`): the
  live seed axis and the sweep-row axis lay out over a real 2-D
  `("rows", "mc")` device mesh (`compat.shard_map`). The hoisted
  counter-based RNG plan materializes each trajectory's streams inside
  the mapped region — a device draws exactly the streams of the seeds it
  owns, so chunk streams are location-independent by construction and
  curves do not depend on placement.

* **Resume** (`run_chunked(..., resume_dir=)`): the chunked moments path
  persists (chunk cursor, running Chan moments) through
  `repro.checkpoint.ckpt` after every chunk, keyed by a workload
  fingerprint. Counter-based RNG makes an interrupted-then-resumed sweep
  bit-identical to an uninterrupted one.

`estimate_peak_bytes` is the analytic memory model behind the knobs
(documented in docs/performance.md); `benchmarks/bench_montecarlo.py`
records it next to warm/cold timings. `plan.auto_plan` derives a full
`ExecPlan` from it plus the device topology.
"""
from __future__ import annotations

import functools
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.checkpoint import ckpt
from repro.core.mc.slots import ALGO_REGISTRY, SlotCtx

Array = jax.Array

# fold_in constant deriving the per-trajectory minibatch key stream from
# the trajectory key — disjoint from the `split(key, steps)` slot keys
_DATA_STREAM = 0x64617461  # b"data"
# fold_in constant for the per-step node-participation mask stream —
# disjoint from both the slot keys and the minibatch stream, so enabling
# dropout cannot shift any other draw
_PART_STREAM = 0x70617274  # b"part"

_TRACE_COUNT = 0
_CACHE_EPOCH = 0


def cache_epoch() -> int:
    """Monotone counter bumped by every `clear_cache()`. Consumers that
    key decisions on "has this program shape compiled before" (the sweep
    server's shape-class registry) compare epochs to invalidate their
    seen-sets exactly when the jit caches they mirror are dropped."""
    return _CACHE_EPOCH


def trace_count(reset: bool = False) -> int:
    """Number of times the engine core has been traced (== XLA compiles,
    since the python body runs once per jit cache miss) since import or
    the last reset. `reset=True` returns the current count and zeroes it;
    `clear_cache()` also zeroes it, so compile-count tests can write
    `clear_cache(); ...; assert trace_count() == 1`."""
    global _TRACE_COUNT
    count = _TRACE_COUNT
    if reset:
        _TRACE_COUNT = 0
    return count


def clear_cache() -> bool:
    """Drop the engine's compiled-program caches (compile-count tests,
    cold benchmark timings) and reset the trace counter. Returns False on
    JAX versions without jit clear_cache support — callers should then
    skip compile-count asserts."""
    global _TRACE_COUNT, _CACHE_EPOCH
    _TRACE_COUNT = 0
    _CACHE_EPOCH += 1
    cleared = False
    for fn in (_mc_core, _mc_stats, _mc_moments_merge):
        if hasattr(fn, "clear_cache"):
            fn.clear_cache()
            cleared = True
    return cleared


# --------------------------------------------------------------------------
# compiled core
# --------------------------------------------------------------------------
_STATIC_ARGNAMES = (
    "grad_fn", "risk_fn", "row_based", "algo_set", "fading", "steps",
    "n_sizes", "n_antennas", "m_sizes", "invert_channel", "h_min",
    "n_shards", "row_shards", "sgrad_fn", "b_max", "ota_impl", "rng_plan",
    "phase_zero", "sample_idx_fn", "sgrad_idx_fn", "participation_on",
)


def _mc_core_impl(params, betas, theta0, seeds, data, *, grad_fn, risk_fn,
                  row_based, algo_set, fading, steps, n_sizes, n_antennas,
                  m_sizes, invert_channel, h_min, n_shards, row_shards=1,
                  sgrad_fn=None, b_max=0, ota_impl="inline",
                  rng_plan="hoisted", phase_zero=False, sample_idx_fn=None,
                  sgrad_idx_fn=None, participation_on=False,
                  reduce_moments=False):
    """(C,)-batched rows × (S,) seeds × scan(steps), placed on a 2-D
    ("rows", "mc") device mesh when `n_shards > 0` or `row_shards > 1`.

    `algo_set` is the deduped algorithm tuple; the row-to-algorithm
    assignment is traced data (params['algo_idx']), so re-assigning rows
    among the same algorithms reuses the compiled program. Rows sharing one
    algorithm skip the dispatch switch. The momentum carry unifies all step
    rules: m_{k+1} = γ m_k + v_k and θ_{k+1} = θ_k − β m_{k+1} reduce
    bit-exactly to vanilla GD at γ = 0 (0·m = 0, 0 + v = v), and the
    Nesterov lookahead θ − nest·βγ·m is exactly θ when the row's nest flag
    is 0.

    When `algo_set` contains an error-feedback algorithm (`blind_ec`) the
    scan carry additionally holds the per-node residual e (n_max, d): rows
    flagged p['ec']=1 transmit x = α(g + e) with the power-budget scaling
    α = min(1, √(B/‖g+e‖²)) per node and carry e ← (g+e) − x forward
    (error accumulation of 1907.09769); all other rows select α = 1 and
    reduce bit-exactly to x = g — even when their own α expression is NaN
    (an overflowing row under the default unbounded budget hits inf/inf).
    The transmitted energy is always computed from x — identical to the
    g-based accounting whenever no truncation happened.

    `sgrad_fn` (static; a registered `stochastic_grad_row`) switches the
    gradient to a per-slot minibatch: each step consumes one key of the
    dedicated data-key stream and the row's traced params['b_count'] (an
    int32 lane count) picks how many of the static `b_max` index lanes
    count. Under the hoisted plan the index draws move out of the scan via
    the registered `sample_idx_fn` / `sgrad_idx_fn` split, when available.

    `rng_plan` selects the execution strategy (see the module docstring):
    'hoisted' feeds the algorithm's pre-materialized draw streams to the
    scan as inputs — homogeneous (single-algorithm) calls only, since a
    mixed batch would materialize every algorithm's streams per
    trajectory; mixed calls and 'inscan' run the legacy body (including
    PR 2's N-sweep-only gain hoisting), kept as the benchmark baseline.

    `reduce_moments` (python-level, not a jit argname: the jitted
    wrappers pin it at their call sites) switches the return value from
    per-seed (risks, cum_energy) to exact two-pass block moments
    (mean, M2) of shape (C, steps+1), reduced INSIDE the mapped region —
    per-shard moments tree-reduce across the 'mc' axis with Chan's
    multi-group merge under `lax.psum`, so only (C, steps+1) statistics
    cross device boundaries regardless of placement.
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # python side effect: runs once per trace/compile

    # gains-consuming slot types, single-antenna: eligible for the legacy
    # (inscan-plan) hoisting of the per-N sampling switch out of the scan
    hoistable = n_antennas is None and not m_sizes and any(
        ALGO_REGISTRY[a].hoist_gains(invert_channel) for a in algo_set)
    use_ec = any(ALGO_REGISTRY[a].error_feedback for a in algo_set)
    # The hoisted plan applies to HOMOGENEOUS calls only: with several
    # algorithms dispatched per row by the traced algo_idx switch, every
    # trajectory would have to materialize every algorithm's streams (the
    # switch branch is data, unknowable at trace time) — multiplying draw
    # memory and threefry work by |algo_set| where the in-scan switch
    # executes only the selected branch. Mixed-algo calls (the fig4/fig5/
    # fig7/fig8 comparison shape) therefore keep the legacy in-scan body
    # byte-for-byte; single-algo calls — the large-throughput regime the
    # execution layer targets — hoist everything.
    hoist = rng_plan == "hoisted" and len(algo_set) == 1
    hoist_idx = (hoist and sgrad_fn is not None
                 and sample_idx_fn is not None and sgrad_idx_fn is not None)

    def trajectory(p, beta, row, seed, t0):
        key = jax.random.key(seed)
        n_max_ = row["mask"].shape[0]
        dim = t0.shape[0]

        def make_ctx(h_slot, draws=None):
            return SlotCtx(fading=fading, p=p, mask=row["mask"],
                           n_sizes=n_sizes, n_antennas=n_antennas,
                           m_sizes=m_sizes, invert_channel=invert_channel,
                           h_min=h_min, h_slot=h_slot, ota_impl=ota_impl,
                           phase_zero=phase_zero, draws=draws)

        def slot(g, k, h_slot, dr_all):
            def ctx_for(a):
                dr = dr_all.get(a) if dr_all is not None else None
                return make_ctx(h_slot, dr)

            if len(algo_set) == 1:
                return ALGO_REGISTRY[algo_set[0]].slot_fn(
                    g, k, ctx_for(algo_set[0]))
            branches = [
                (lambda kk, a=a: ALGO_REGISTRY[a].slot_fn(g, kk, ctx_for(a)))
                for a in algo_set
            ]
            return jax.lax.switch(p["algo_idx"], branches, k)

        def body(carry, x):
            k, h_slot, dk, dr_all, idx, pu = x
            if use_ec:
                theta, m, e_res, cum_e = carry
            else:
                theta, m, cum_e = carry
            theta_eval = theta - p["nest"] * beta * p["gamma"] * m
            if sgrad_fn is not None:
                if idx is not None:
                    g = sgrad_idx_fn(row, theta_eval, idx, p["b_count"])
                else:
                    g = sgrad_fn(row, theta_eval, dk, p["b_count"], b_max)
            else:
                g = (grad_fn(row, theta_eval) if row_based
                     else grad_fn(theta_eval))
            risk = risk_fn(row, theta) if row_based else risk_fn(theta)
            if use_ec:
                u = g + p["ec"] * e_res
                sq = jnp.sum(u * u, axis=1)
                alpha = jnp.minimum(1.0, jnp.sqrt(
                    p["tx_budget"] / jnp.maximum(sq, 1e-30)))
                # select, don't blend: inf/inf above is NaN (e.g. an
                # overflowing row with the default unbounded budget) and
                # 0*NaN would leak it into ec=0 rows
                alpha = jnp.where(p["ec"] > 0, alpha, 1.0)
                x_tx = alpha[:, None] * u
            else:
                x_tx = g
            if participation_on:
                # per-step Bernoulli node mask: a dropped node transmits
                # nothing this slot (and spends no energy); the edge still
                # normalizes by the full N — graceful degradation, not
                # participant-aware rescaling
                x_tx = (pu < p["participation"]).astype(
                    jnp.float32)[:, None] * x_tx
            if use_ec:
                # residual sees the MASKED transmission: a dropped node
                # carries its whole update forward as error feedback
                e_res = p["ec"] * (u - x_tx)
            cum_e = cum_e + p["energy"] * jnp.sum(
                x_tx.astype(jnp.float32) ** 2)
            v = slot(x_tx, k, h_slot, dr_all)
            m = p["gamma"] * m + v
            theta = theta - beta * m
            carry = (theta, m, e_res, cum_e) if use_ec \
                else (theta, m, cum_e)
            return carry, (risk, cum_e)

        step_keys = jax.random.split(key, steps)
        data_keys = None
        if sgrad_fn is not None:
            data_keys = jax.random.split(
                jax.random.fold_in(key, _DATA_STREAM), steps)
        h_all = None
        draws_all = None
        idx_all = None
        if hoist:
            # The universal RNG plan: every registered stream materializes
            # as one batched (steps, ...) draw outside the scan, via each
            # algorithm's hoist_draws twin. Streams replay the in-scan
            # key-split chains exactly, so the plans are interchangeable.
            ctx0 = make_ctx(None)
            draws_all = {}
            for a in algo_set:
                hd = ALGO_REGISTRY[a].hoist_draws
                if hd is not None:
                    draws_all[a] = hd(step_keys, ctx0, n_max_, dim)
            if not draws_all:
                # algorithm registered without a hoist twin: nothing was
                # hoisted — fall through to the legacy in-scan body
                # (including its N-sweep gain hoist below) instead of
                # running a strictly worse plan
                draws_all = None
            if hoist_idx:
                idx_all = jax.vmap(
                    lambda dk: sample_idx_fn(row, dk, b_max))(data_keys)
        if draws_all is None and len(n_sizes) > 1 and hoistable:
            # Legacy inscan-plan hoisting, node-count sweeps only: sample
            # every slot's gains up front instead of tracing the per-N
            # `lax.switch` branches into the scan body (which multiplies
            # the XLA program and its compile time — the very cost the
            # padded N axis exists to remove). Stream-identical: each step
            # key is split exactly as the slot fns would split it, and the
            # k_h half feeds the same padded sampler. The dynamic-count
            # sampler (one static-shape threefry program for all N) is
            # preferred; the per-N `lax.switch` sampler is the fallback
            # when the raw primitive is unavailable or a non-threefry PRNG
            # is active.
            from repro.core.mc import sampling

            k_hs = jax.vmap(lambda k: jax.random.split(k)[0])(step_keys)
            if sampling._dynamic_threefry_ok():
                sample = lambda kh: sampling._sample_gains_dynamic_n(
                    kh, fading, p, n_max_)
            else:
                sample = lambda kh: sampling._sample_gains_padded(
                    kh, fading, p, n_sizes, n_max_)
            h_all = jax.vmap(sample)(k_hs)
        carry0 = (t0, jnp.zeros_like(t0), jnp.float32(0.0))
        if use_ec:
            carry0 = (t0, jnp.zeros_like(t0),
                      jnp.zeros((row["mask"].shape[0], t0.shape[0]),
                                jnp.float32), jnp.float32(0.0))
        part_u = None
        if participation_on:
            # the mask stream is hoisted under EVERY rng plan (one code
            # path): a batched uniform over `split(fold_in(key, part), steps)`
            # is stream-identical to per-step in-scan draws over the same
            # keys, and the body stays pure linear algebra
            part_keys = jax.random.split(
                jax.random.fold_in(key, _PART_STREAM), steps)
            part_u = jax.vmap(
                lambda pk: jax.random.uniform(pk, (n_max_,), jnp.float32))(
                    part_keys)
        carry_fin, (risks, cum_e) = jax.lax.scan(
            body, carry0,
            (step_keys, h_all, data_keys, draws_all, idx_all, part_u))
        theta_fin = carry_fin[0]
        fin = risk_fn(row, theta_fin) if row_based else risk_fn(theta_fin)
        risks = jnp.concatenate([risks, fin[None]])
        return risks, cum_e  # (steps+1,), (steps,)

    placed = n_shards > 0 or row_shards > 1
    mc_size = max(n_shards, 1)

    def seed_block(seeds_blk, params, betas, theta0, data):
        per_config = jax.vmap(
            lambda p, b, row: jax.vmap(
                lambda s: trajectory(p, b, row, s, theta0))(seeds_blk))
        risks, cum_e = per_config(params, betas, data)
        if not reduce_moments:
            return risks, cum_e
        # exact two-pass moments of this device's seed block, then Chan's
        # multi-group merge across the 'mc' axis: the psum'd correction
        # s_loc·(local_mean − global_mean)² turns per-shard M2 into the
        # global M2 without any per-seed value crossing devices
        s_loc = risks.shape[1]
        lsum = jnp.sum(risks, axis=1)
        lmean = lsum / s_loc
        lm2 = jnp.sum(jnp.square(risks - lmean[:, None, :]), axis=1)
        if placed:
            gmean = jax.lax.psum(lsum, "mc") / (s_loc * mc_size)
            gm2 = jax.lax.psum(
                lm2 + s_loc * jnp.square(lmean - gmean), "mc")
            return gmean, gm2
        return lmean, lm2

    if placed:
        mesh = compat.make_mesh((row_shards, mc_size), ("rows", "mc"))
        if reduce_moments:  # moments leave the region 'mc'-replicated
            out_specs = (P("rows"), P("rows"))
        else:
            out_specs = (P("rows", "mc"), P("rows", "mc"))
        seed_block = compat.shard_map(
            seed_block, mesh=mesh,
            in_specs=(P("mc"), P("rows"), P("rows"), P(), P("rows")),
            out_specs=out_specs)
    return seed_block(seeds, params, betas, theta0, data)


_mc_core = jax.jit(_mc_core_impl, static_argnames=_STATIC_ARGNAMES)


@functools.partial(jax.jit, static_argnames=_STATIC_ARGNAMES)
def _mc_stats(params, betas, theta0, seeds, data, **kw):
    """Single-shot on-device seed reduction (`keep_seed_curves=False`,
    `seed_chunk=None`): the (C, S, steps+1) curves stay device-side; only
    the (C, steps+1) mean and ci95 transfer. Exact two-pass moments —
    the same formula the host path applies to materialized curves."""
    risks, _ = _mc_core_impl(params, betas, theta0, seeds, data, **kw)
    n = risks.shape[1]
    mean = jnp.mean(risks, axis=1)
    if n > 1:
        ci95 = 1.96 * jnp.std(risks, axis=1, ddof=1) / np.sqrt(n)
    else:
        ci95 = jnp.zeros_like(mean)
    return mean, ci95


def chan_merge(mean_a, m2_a, n_a, mean_b, m2_b, n_b):
    """Chan's parallel-variance merge of two (mean, M2, n) moment groups.

    M2 is the centered sum of squares Σ(x − mean)²; the merge is exact in
    exact arithmetic and numerically stable where the one-pass
    (Σx, Σx²) accumulator catastrophically cancels (variance far below
    the squared mean). With n_a = 0 the result is group b exactly:
    delta·n_b/n = mean_b and the cross term vanishes, so the first chunk
    of a sweep is bit-identical to its own two-pass moments.

    Works elementwise on arrays and under jit/np alike; `n_a`/`n_b` may
    be traced scalars (chunk counts are data, not compile-time shape).
    """
    n = n_a + n_b
    delta = mean_b - mean_a
    mean = mean_a + delta * (n_b / n)
    m2 = m2_a + m2_b + jnp.square(delta) * (n_a * n_b / n)
    return mean, m2


@functools.partial(jax.jit, static_argnames=_STATIC_ARGNAMES,
                   donate_argnums=(0, 1))
def _mc_moments_merge(acc_mean, acc_m2, n_prev, params, betas, theta0,
                      seeds, data, **kw):
    """One seed chunk's exact two-pass block moments Chan-merged into the
    running (mean, M2) curve statistics. The accumulators are DONATED:
    XLA reuses their buffers in place, so the chunked stats path carries
    O(C · steps) state between chunks and nothing else survives a chunk.
    `n_prev` is traced data (float32) — the chunk cursor never recompiles.
    """
    bmean, bm2 = _mc_core_impl(params, betas, theta0, seeds, data,
                               reduce_moments=True, **kw)
    n_b = jnp.float32(seeds.shape[0])
    return chan_merge(acc_mean, acc_m2, n_prev, bmean, bm2, n_b)


def host_seed_stats(risks: np.ndarray) -> tuple:
    """(C, S, steps+1) curves -> (mean, ci95), the host-side seed
    reduction — the single definition the unchunked, chunked and
    on-device paths all agree with."""
    seeds = risks.shape[1]
    mean = np.mean(risks, axis=1)
    if seeds > 1:
        ci95 = 1.96 * np.std(risks, axis=1, ddof=1) / np.sqrt(seeds)
    else:
        ci95 = np.zeros_like(mean)
    return mean, ci95


def finalize_merged_stats(mean: np.ndarray, m2: np.ndarray,
                          n_seeds: int) -> tuple:
    """Chan-merged (mean, M2, n) -> (mean, ci95), ddof=1 sample variance.

    M2 = Σ(x − mean)² is nonnegative by construction (up to rounding in
    the merge's cross terms, hence the max with 0) — unlike the retired
    one-pass (Σx, Σx²) accumulator, whose difference of large squares
    collapsed ci95 to 0 on near-deterministic rows.
    """
    if n_seeds > 1:
        var = np.maximum(0.0, np.asarray(m2)) / (n_seeds - 1)
        ci95 = 1.96 * np.sqrt(var / n_seeds)
    else:
        ci95 = np.zeros_like(mean)
    return np.asarray(mean), ci95


# --------------------------------------------------------------------------
# seed-chunked scheduler (+ resume + chunk-level fault isolation)
# --------------------------------------------------------------------------
_RESUME_FILE = "mc_chunked_resume.npz"

# Fault-injection seam: hooks fire at the START of every chunk attempt
# with {"off": int, "attempt": int, "stage": "moments"|"curves"}; a hook
# that raises simulates that chunk failing (tests/_fault_harness.py
# schedules deterministic fault patterns through this).
_CHUNK_FAULT_HOOKS = []


def install_chunk_fault_hook(hook):
    """Register a chunk-attempt hook (fault injection); returns a
    remover callable. Hooks see every attempt of every chunk and may
    raise to make that attempt fail."""
    _CHUNK_FAULT_HOOKS.append(hook)

    def remove():
        try:
            _CHUNK_FAULT_HOOKS.remove(hook)
        except ValueError:
            pass
    return remove


def _attempt_chunk(retry, off, stage, attempt_fn, reset_fn=None):
    """Run one chunk with the plan's `RetryPolicy`: on an exception the
    accumulator state is rolled back (`reset_fn`), the policy's capped
    exponential backoff waits, and the chunk re-runs — replaying its
    exact counter-based streams, so a retried chunk is indistinguishable
    from a first-try one. `retry=None` (or an exhausted budget)
    re-raises: fail-fast is the legacy behavior and the checkpoint on
    disk stays at the last completed chunk."""
    attempt = 1
    while True:
        try:
            for hook in list(_CHUNK_FAULT_HOOKS):
                hook({"off": int(off), "attempt": attempt, "stage": stage})
            return attempt_fn()
        except Exception:
            if retry is None or attempt >= retry.max_attempts:
                raise
            if reset_fn is not None:
                reset_fn()
            retry.wait(attempt)
            attempt += 1


def _hash_array_leaf(h, name, value) -> None:
    arr = np.asarray(value)
    h.update(f"{name}:{arr.dtype.str}:{arr.shape};".encode())
    h.update(np.ascontiguousarray(arr).tobytes())


def _hash_static_kwargs(h, statics: dict) -> None:
    """Feed STATIC facets (jit static_argnames material) into a hash:
    callables by qualname — stable across processes, unlike their reprs —
    everything else by repr. Shared by the resume fingerprint and the
    serving compile signature."""
    for name in sorted(statics):
        v = statics[name]
        if callable(v):
            v = getattr(v, "__qualname__", repr(v))
        h.update(f"{name}={v!r};".encode())


def static_signature(statics: dict) -> str:
    """Compile-cache signature of one engine call: a sha256 hex digest
    over static facets only — shapes, flags, registry callables — the
    things `_mc_core`'s jit cache keys on. Values may be numbers,
    strings, bools, tuples or callables; array-valued workload data does
    NOT belong here (rows that differ only in data share a signature —
    that is the whole point). Two calls with equal signatures trace the
    same compiled program, so a serving router
    (`repro.serving.mc_server`) can coalesce them into one padded batch
    and pay exactly one compile."""
    h = hashlib.sha256()
    _hash_static_kwargs(h, statics)
    return h.hexdigest()


def _workload_fingerprint(params, betas, theta0, seed_ints, data,
                          seed_chunk, n_rows, n_shards, row_shards,
                          core_kwargs) -> np.ndarray:
    """sha256 identity of a chunked sweep, as a (32,) uint8 leaf.

    Covers the static core kwargs (callables by qualname — stable across
    processes, unlike their reprs), the numeric workload (channel/algo
    params, stepsizes, theta0, problem data — a different noise_std or
    stepsize is a different sweep even though every static matches), the
    full seed-int sequence, the chunk size, the row count and the mesh
    shape. Two sweeps with equal fingerprints replay identical chunk
    streams in identical order, so a checkpoint from one resumes the
    other bit-identically; placement is included because the
    cross-device moment reduction order is part of the accumulators'
    bit pattern.
    """
    h = hashlib.sha256()
    _hash_static_kwargs(h, core_kwargs)
    for name in sorted(params):
        _hash_array_leaf(h, f"params.{name}", params[name])
    for name in sorted(data):
        _hash_array_leaf(h, f"data.{name}", data[name])
    _hash_array_leaf(h, "betas", betas)
    _hash_array_leaf(h, "theta0", theta0)
    h.update(np.ascontiguousarray(
        np.asarray(seed_ints, np.int64)).tobytes())
    h.update(f"chunk={seed_chunk};rows={n_rows};"
             f"mesh={row_shards}x{n_shards};".encode())
    return np.frombuffer(h.digest(), np.uint8)


def run_chunked(params, betas, theta0, seed_ints, data, *, seed_chunk,
                keep_seed_curves, n_shards, row_shards=1, core_kwargs,
                resume_dir=None, retry=None):
    """Drive the seed axis in blocks of `seed_chunk` through one compiled
    program (chunk seed ints are data). Returns the same
    (risks, cum_energy, mean, ci95) quadruple as the single-shot paths,
    with the first two None when `keep_seed_curves=False`.

    Per-chunk peak memory is O(C · seed_chunk · steps · n_max): the
    hoisted RNG streams re-materialize per chunk, per-seed curves either
    stream to preallocated host arrays (`keep_seed_curves=True`) or
    Chan-merge into donated (C, steps+1) moment accumulators.
    `n_shards`/`row_shards` place each chunk on the ("rows", "mc") mesh.

    `resume_dir` (moments path only) persists (fingerprint, chunk
    cursor, acc_mean, acc_m2) to `<resume_dir>/mc_chunked_resume.npz`
    after every chunk, and restores from it when present: the sweep
    restarts at the first unfinished chunk with the saved accumulators.
    Counter-based RNG replays each chunk's streams exactly and the f32
    host round-trip is value-preserving, so interrupted-then-resumed
    equals uninterrupted bit-for-bit. A checkpoint written by a
    different workload (fingerprint mismatch) raises instead of
    silently corrupting the sweep; a finished sweep's checkpoint
    short-circuits straight to finalization. A CORRUPT checkpoint
    (truncated, bit-flipped — `ckpt.CheckpointCorrupt`) falls back to
    the rotated `.prev` artifact, and when both are bad the sweep
    restarts from scratch with a warning — never a crash, never a
    silent resume from garbage.

    `retry` (a `plan.RetryPolicy`) adds chunk-level fault isolation: a
    chunk that raises is rolled back and re-attempted with capped
    exponential backoff; counter-based RNG replays its exact streams, so
    a sweep surviving k faults within budget is bit-identical to the
    fault-free run.
    """
    seeds = len(seed_ints)
    if seed_chunk <= 0:
        raise ValueError(f"seed_chunk must be positive, got {seed_chunk}")
    if seeds % seed_chunk != 0:
        raise ValueError(
            f"seeds ({seeds}) must divide into seed_chunk ({seed_chunk}) "
            "blocks — pad the seed count or pick a chunk that divides it")
    steps = core_kwargs["steps"]
    n_rows = len(betas)
    if keep_seed_curves:
        if resume_dir is not None:
            raise ValueError(
                "resume_dir requires the reduced-moments path "
                "(keep_seed_curves=False): per-seed curves are not "
                "checkpointed between chunks")
        risks = np.empty((n_rows, seeds, steps + 1), np.float32)
        cum_e = np.empty((n_rows, seeds, steps), np.float32)
        for off in range(0, seeds, seed_chunk):
            blk = jnp.asarray(seed_ints[off:off + seed_chunk])

            def _run(blk=blk):
                return _mc_core(params, betas, theta0, blk, data,
                                n_shards=n_shards, row_shards=row_shards,
                                **core_kwargs)

            r, ce = _attempt_chunk(retry, off, "curves", _run)
            risks[:, off:off + seed_chunk] = np.asarray(r)
            cum_e[:, off:off + seed_chunk] = np.asarray(ce)
        return (risks, cum_e) + host_seed_stats(risks)
    fp = _workload_fingerprint(params, betas, theta0, seed_ints, data,
                               seed_chunk, n_rows, n_shards, row_shards,
                               core_kwargs)
    start = 0
    acc_mean = jnp.zeros((n_rows, steps + 1), jnp.float32)
    acc_m2 = jnp.zeros((n_rows, steps + 1), jnp.float32)
    ckpt_path = None
    if resume_dir is not None:
        ckpt_path = os.path.join(resume_dir, _RESUME_FILE)
        candidates = [p for p in (ckpt_path, ckpt_path + ckpt.PREV_SUFFIX)
                      if os.path.exists(p)]
        raw = None
        for cand in candidates:
            try:
                raw = ckpt.peek(cand)
                break
            except ckpt.CheckpointCorrupt as e:
                # fall back to the rotated artifact; a torn newest write
                # costs at most one chunk of progress
                import warnings
                warnings.warn(f"ignoring corrupt resume checkpoint: {e}")
        if raw is not None:
            if not np.array_equal(raw.get("fingerprint"), fp):
                raise ValueError(
                    f"checkpoint at {ckpt_path} belongs to a different "
                    "workload (fingerprint mismatch) — point resume_dir "
                    "at this sweep's own directory or remove the stale "
                    "checkpoint")
            start = int(raw["next_off"])
            acc_mean = jnp.asarray(raw["acc_mean"])
            acc_m2 = jnp.asarray(raw["acc_m2"])
        elif candidates:
            import warnings
            warnings.warn(
                f"no intact resume checkpoint under {resume_dir} — "
                "restarting the sweep from the first chunk")
    for off in range(start, seeds, seed_chunk):
        blk = jnp.asarray(seed_ints[off:off + seed_chunk])
        # the merge DONATES the accumulators: for retry, snapshot them to
        # host first so a failed attempt can roll back (the f32 round-trip
        # is value-preserving — bit-identity holds)
        snap = (np.asarray(acc_mean), np.asarray(acc_m2)) \
            if retry is not None else None

        def _merge(blk=blk, off=off):
            return _mc_moments_merge(
                acc_mean, acc_m2, np.float32(off), params, betas, theta0,
                blk, data, n_shards=n_shards, row_shards=row_shards,
                **core_kwargs)

        def _reset(snap=snap):
            nonlocal acc_mean, acc_m2
            acc_mean = jnp.asarray(snap[0])
            acc_m2 = jnp.asarray(snap[1])

        acc_mean, acc_m2 = _attempt_chunk(
            retry, off, "moments", _merge,
            _reset if retry is not None else None)
        if ckpt_path is not None:
            # np.asarray copies to host BEFORE the next merge donates the
            # accumulator buffers back to XLA
            ckpt.save(ckpt_path, {
                "fingerprint": fp,
                "next_off": np.int64(off + seed_chunk),
                "acc_mean": np.asarray(acc_mean),
                "acc_m2": np.asarray(acc_m2)})
    mean, ci95 = finalize_merged_stats(
        np.asarray(acc_mean), np.asarray(acc_m2), seeds)
    return None, None, mean, ci95


# --------------------------------------------------------------------------
# analytic memory model
# --------------------------------------------------------------------------
_F32 = 4  # bytes


def estimate_peak_bytes(*, n_rows: int, seeds: int, steps: int, n_max: int,
                        dim: int, algo_set=("gbma",), seed_chunk=None,
                        n_antennas=None, m_sizes=(), b_max: int = 0,
                        keep_seed_curves: bool = True,
                        rng_plan: str = "hoisted",
                        invert_channel: bool = False,
                        participation_on: bool = False,
                        n_shards: int = 1, row_shards: int = 1) -> dict:
    """Analytic peak-memory estimate (bytes) of one engine call, per the
    execution-layer memory model (docs/performance.md).

    Counts the O(C · S_live · steps)-scaling buffers that dominate at
    scale — the hoisted per-stream RNG draws (per-algorithm widths from
    `slots.hoist_draw_elems`, next to the registry), the scanned per-seed
    curve outputs, and the per-step gradient temporaries — for S_live =
    seed_chunk (when chunking) or the full seed count. Deliberately an
    estimate: XLA fusion removes some temporaries and adds others, so
    treat it as the scaling model the knobs are chosen against, not an
    allocator ground truth.

    Under placement every counted buffer is sharded over the
    (row_shards × n_shards) mesh — each device materializes only its own
    seeds' streams — so `per_device_peak_bytes` is the whole-call total
    divided by the mesh size; it is the figure `plan.auto_plan` sizes
    chunks against.
    """
    from repro.core.mc import slots

    s_live = seeds if seed_chunk is None else min(seed_chunk, seeds)
    m_live = max(m_sizes) if m_sizes else (n_antennas or 1)
    per_traj_draws = 0
    # draws hoist only on homogeneous calls (see _mc_core_impl)
    if rng_plan == "hoisted" and len(algo_set) == 1:
        for a in algo_set:
            per_traj_draws += slots.hoist_draw_elems(
                a, steps=steps, n_max=n_max, dim=dim, m_live=m_live,
                invert_channel=invert_channel)
        if b_max > 0:
            per_traj_draws += steps * n_max * b_max  # minibatch indices
    if participation_on:
        # the node-dropout mask stream hoists under EVERY rng plan
        per_traj_draws += steps * n_max
    draw_bytes = n_rows * s_live * per_traj_draws * _F32
    # scanned outputs: risks (steps+1) + cum_energy (steps) per trajectory
    curve_bytes = n_rows * s_live * (2 * steps + 1) * _F32
    # per-step live temporaries: transmitted g + one working copy
    temp_bytes = 2 * n_rows * s_live * n_max * dim * _F32
    host_bytes = (n_rows * seeds * (2 * steps + 1) * _F32
                  if keep_seed_curves else 0)
    device_total = draw_bytes + curve_bytes + temp_bytes
    mesh_size = max(n_shards, 1) * max(row_shards, 1)
    return {
        "device_peak_bytes": device_total,
        "per_device_peak_bytes": -(-device_total // mesh_size),
        "rng_draw_bytes": draw_bytes,
        "curve_bytes": curve_bytes,
        "grad_temp_bytes": temp_bytes,
        "host_curve_bytes": host_bytes,
        "s_live": s_live,
    }
