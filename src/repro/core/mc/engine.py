"""Batched, jitted Monte Carlo engine: row assembly + public entry point.

The paper's figures reproduce the expectation in Eq. (14) by averaging
excess-risk curves over seeds; the engine runs a whole sweep as one
compiled call:

    shard_map(seeds over 'mc' devices) ∘ vmap(rows) ∘ vmap(seeds) ∘ scan(steps)

with the excess-risk curve computed **on-device inside the scan**. A batch
row is a (problem, channel params, algo, stepsize) tuple; problems come
from the `PROBLEMS` registry (`mc/problems.py`), per-slot algorithm updates
from the `ALGO_REGISTRY` (`mc/slots.py`), and every RNG draw from the
reference-twin samplers (`mc/sampling.py`). HOW a call executes — the
hoisted counter-based RNG plan, the seed-chunked scheduler with donated
carries, the on-device seed reduction — lives in the execution layer
(`mc/exec.py`, knobs `rng_plan` / `seed_chunk` / `keep_seed_curves`, see
docs/performance.md). `repro.core.montecarlo` is the back-compat façade
re-exporting this package's public surface.

Stochastic problems (a registered `stochastic_grad_row`, e.g. `logistic`)
draw per-slot minibatch indices from a dedicated data-key stream
(`fold_in(trajectory key, _DATA_STREAM)` — disjoint from the slot keys, so
channel/noise draws are unchanged by the minibatching). The minibatch size
is the `run_mc(batch_frac=...)` knob — scalar or per-row, so a
batch-fraction sweep is ONE compile; `batch_frac=1.0` (the default)
statically disables sampling and is bit-identical to running the same
problem registered without a stochastic gradient.

`run_mc(ota_impl=)` routes the single-antenna OTA superposition through
`repro.kernels.ota.ota_edge_aggregate` ('pallas' on TPU / 'ref' jnp
oracle); 'auto' picks pallas on TPU when eligible and the inline einsum
otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.mc import exec as exec_mod
from repro.core.mc.exec import (  # noqa: F401  (re-exported surface)
    _DATA_STREAM,
    _mc_core,
    clear_cache,
    trace_count,
)
from repro.core.mc.plan import (
    ExecPlan,
    auto_plan,
    resolve_seed_shards,
    validate_plan,
)
from repro.core.mc.problems import MCProblem, MCProblemBatch, PROBLEMS
from repro.core.mc.slots import ALGO_REGISTRY
from repro.core.theory import ProblemConstants, theorem1_bound

Array = jax.Array


# --------------------------------------------------------------------------
# batched channel parameters
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChannelBatch:
    """Stack of C `ChannelConfig`s sharing one fading family.

    The family string is static (it selects the gain-sampling code path);
    everything else is a (C,) f32 array and vmaps in a single compile.
    """

    fading: str
    params: dict  # {'scale','noise_std','energy','phase_error_max','rician_k'}
    configs: tuple  # the original ChannelConfigs (host side, for bounds)

    @classmethod
    def stack(cls, cfgs: Sequence[ChannelConfig]) -> "ChannelBatch":
        fams = {c.fading for c in cfgs}
        if len(fams) != 1:
            raise ValueError(
                f"one ChannelBatch = one fading family, got {sorted(fams)}; "
                "issue one run_mc call per family")
        arr = lambda name: jnp.asarray(
            [getattr(c, name) for c in cfgs], jnp.float32)
        return cls(
            fading=cfgs[0].fading,
            params={
                "scale": arr("scale"),
                "noise_std": arr("noise_std"),
                "energy": arr("energy"),
                "phase_error_max": arr("phase_error_max"),
                "rician_k": arr("rician_k"),
            },
            configs=tuple(cfgs),
        )

    def __len__(self) -> int:
        return len(self.configs)


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------
@dataclasses.dataclass
class MCResult:
    """Host-side result of one engine call.

    risks:      (C, S, steps+1) per-row per-seed excess-risk curves, or
                None under `keep_seed_curves=False` (the curves were
                seed-reduced on device and never transferred).
    mean:       (C, steps+1) seed average (the Eq. 14 expectation estimate).
    ci95:       (C, steps+1) 1.96 * standard error over seeds (0 if S == 1).
    cum_energy: (C, S, steps) cumulative transmitted energy Σ E_N ||x_k||²
                of the actually-transmitted vectors — x_k = g_k for every
                algorithm except `blind_ec`, whose power budget truncates
                x_k = α(g_k + e_k). None under `keep_seed_curves=False`.
    bounds:     (C, steps+1) Theorem-1 bound per row (None unless problem
                constants were supplied AND every row is single-antenna
                'gbma' — the setting Theorem 1 covers).
    """

    risks: Optional[np.ndarray]
    mean: np.ndarray
    ci95: np.ndarray
    cum_energy: Optional[np.ndarray]
    bounds: Optional[np.ndarray]
    plan: Optional[ExecPlan] = None  # the resolved ExecPlan this ran under


def _resolve_n_shards(n_seeds: int, shard_seeds: Optional[bool]) -> int:
    """0 = plain path; k > 0 = shard_map over a ('mc',) mesh of k devices."""
    if shard_seeds is False:
        return 0
    ndev = jax.device_count()
    if shard_seeds is None:
        return ndev if (ndev > 1 and n_seeds % ndev == 0) else 0
    if n_seeds % ndev != 0:
        raise ValueError(
            f"shard_seeds=True needs seeds ({n_seeds}) divisible by the "
            f"device count ({ndev})")
    return ndev


def _resolve_ota_impl(ota_impl: str, n_sizes: tuple) -> str:
    """'auto' → 'pallas' on TPU when the kernel applies, 'inline' else.

    The OTA kernel normalizes by a STATIC node count, so it only applies
    when every row transmits at the same (full, unpadded) N — explicit
    'pallas'/'ref' on a padded node-count sweep is an error rather than a
    silent wrong normalization.
    """
    if ota_impl not in ("auto", "pallas", "ref"):
        raise ValueError(
            f"ota_impl must be 'auto', 'pallas' or 'ref', got {ota_impl!r}")
    eligible = len(n_sizes) == 1
    if ota_impl == "auto":
        return "pallas" if (eligible and jax.default_backend() == "tpu") \
            else "inline"
    if not eligible:
        raise ValueError(
            f"ota_impl={ota_impl!r} needs a single node count per call "
            f"(got n_sizes={n_sizes}): the OTA kernel normalizes by the "
            "static N, which a padded node-count sweep does not have")
    return ota_impl


def _resolve_batch_frac(batch_frac, n_rows: int, batch_prob, problem):
    """-> (spec, b_max, b_counts) for the stochastic path, or
    (None, 0, None) for the static full-batch path."""
    if isinstance(batch_frac, (int, float, np.integer, np.floating)):
        fracs = (float(batch_frac),) * n_rows
    else:
        fracs = tuple(float(f) for f in batch_frac)
        if len(fracs) != n_rows:
            raise ValueError(f"need one batch_frac per row: "
                             f"{len(fracs)} vs C={n_rows}")
    if any(not (0.0 < f <= 1.0) for f in fracs):
        raise ValueError(f"batch_frac must be in (0, 1], got {fracs}")
    if all(f == 1.0 for f in fracs):
        return None, 0, None  # exact full-batch gradients, no sampling
    stochastic = batch_prob.stochastic if batch_prob is not None \
        else getattr(problem, "stochastic", False)
    kind = batch_prob.kind if batch_prob is not None \
        else getattr(problem, "kind", "")
    spec = PROBLEMS.get(kind)
    if not stochastic or spec is None or spec.stochastic_grad_row is None:
        raise ValueError(
            f"batch_frac={fracs} needs a stochastic problem kind (a "
            "registered stochastic_grad_row); "
            f"got kind={kind!r}")
    data = batch_prob.data if batch_prob is not None else problem.data
    k = data[spec.sample_axis_field].shape[-2]
    b_counts = tuple(max(1, int(round(f * k))) for f in fracs)
    return spec, max(b_counts), b_counts


# --------------------------------------------------------------------------
# public entry point
# --------------------------------------------------------------------------
def run_mc(
    problem: Union[MCProblem, MCProblemBatch, Sequence[MCProblem]],
    channels: Sequence[ChannelConfig] | ChannelBatch,
    algo: str | Sequence[str],
    betas: Sequence[float] | np.ndarray,
    steps: int,
    seeds: int,
    *,
    theta0: Optional[np.ndarray] = None,
    seed0: int = 0,
    n_antennas: Optional[Union[int, Sequence[int]]] = None,
    invert_channel: bool = False,
    h_min: float = 0.3,
    pc: Optional[Union[ProblemConstants,
                       Sequence[ProblemConstants]]] = None,
    momentum: float = 0.9,
    power_budget: Optional[Union[float, Sequence[float]]] = None,
    shard_seeds: Optional[bool] = None,
    batch_frac: Union[float, Sequence[float]] = 1.0,
    ota_impl: Optional[str] = None,
    rng_plan: Optional[str] = None,
    seed_chunk: Optional[int] = None,
    keep_seed_curves: Optional[bool] = None,
    plan: Union[ExecPlan, str, None] = None,
    resume_dir: Optional[str] = None,
    memory_budget_bytes: Optional[int] = None,
    participation: Union[float, Sequence[float]] = 1.0,
) -> MCResult:
    """Run `seeds` Monte Carlo trajectories for each batch row.

    A row is a (problem, channel, algo, stepsize) tuple; `problem` and
    `algo` broadcast when a single one is given. Passing a sequence of
    problems (node counts may differ — they are padded to N_max) or a
    sequence of algos runs the whole sweep in ONE engine compile.

    Seed s uses `jax.random.key(seed0 + s)` — the same stream the sequential
    reference path (`benchmarks.common.average_runs`) consumes, so results
    are directly comparable. With `pc` supplied (one `ProblemConstants` or
    one per row) the Theorem-1 bound rides along — only when every row is
    single-antenna 'gbma', the setting Theorem 1 covers; mixed-algo calls
    get `bounds=None`.

    `n_antennas`: the edge antenna count M. An int broadcasts (static;
    OTA algos take the MRC path, blind algos combine over M). A sequence
    gives one M per row AS DATA — the antenna axis pads to max(M) and an
    M-sweep batches into the same single compile (each row's key split
    replays `split(key, m)` for its true m). Required for blind/blind_ec.

    `power_budget`: per-slot, per-node transmit budget in squared-norm
    units of the transmitted vector (scalar or one per row; default
    unbounded). Only `blind_ec` rows enforce it, carrying the truncated
    remainder in their local residual.

    `shard_seeds` shards the seed axis over devices on a 'mc' mesh axis
    (None: auto when divisible; no-op on one device).

    `batch_frac` (scalar or one per row): fraction of each node's local
    samples drawn per slot for stochastic problem kinds (`logistic`). 1.0
    (default) computes the exact full-batch gradient with no sampling —
    bit-identical to a deterministic registration of the same problem;
    fractions < 1 draw with-replacement minibatches per slot, and a
    per-row fraction sweep is one compile.

    `ota_impl`: 'auto' (inline einsum; pallas kernel on TPU when the node
    count is static), 'pallas' or 'ref' force the
    `repro.kernels.ota.ota_edge_aggregate` path for the single-antenna OTA
    superposition.

    Execution strategy (docs/performance.md): HOW the sweep executes is
    one `repro.core.mc.plan.ExecPlan`. Three ways to choose it:

    `plan=` an `ExecPlan` pins every field (rng_plan, seed_chunk,
    n_shards, row_shards, keep_seed_curves, ota_impl); `plan="auto"`
    derives one from the analytic memory model, the per-device memory
    budget (`memory_budget_bytes=`, default: backend-reported limit or
    2 GiB) and the visible device topology via `auto_plan`; or leave
    `plan=None` and set the legacy knobs below — they build the
    equivalent plan (behavior-pinned), and mixing them with `plan=` is
    an error. The resolved plan is recorded on `MCResult.plan`.

    `rng_plan`: 'hoisted' (default) materializes every randomness stream
    in one batched counter-based draw per stream outside the scan —
    stream-identical to the per-slot split chains, leaving the scan body
    pure linear algebra; 'inscan' keeps the legacy in-scan draws (the
    benchmark baseline).

    `seed_chunk`: run the seed axis in blocks of this size through one
    compiled program, bounding peak device memory to
    O(C · seed_chunk · steps · n_max); must divide `seeds`. None (default)
    runs all seeds in one call.

    `keep_seed_curves`: False reduces the per-seed curves to (mean, ci95)
    on device — only (C, steps+1) statistics transfer to host, and
    `MCResult.risks`/`cum_energy` are None (so `energy_to_target`, which
    needs per-seed curves, requires the default True).

    `resume_dir`: chunked reduced sweeps (`seed_chunk` set,
    `keep_seed_curves=False`) checkpoint their (chunk cursor, Chan
    moments) to this directory after every chunk and restore from it on
    the next call — an interrupted-then-resumed sweep is bit-identical
    to an uninterrupted one (counter-based RNG; see
    `exec.run_chunked`).

    `participation` (scalar or one per row): per-slot node participation
    probability p ∈ (0, 1] — each step each node independently transmits
    with probability p and stays silent (zero transmission, zero energy)
    otherwise, drawn from one extra hoisted counter-based stream
    (disjoint fold_in constant, so enabling dropout shifts no other
    draw). The edge still normalizes by the full N — the paper-level
    graceful-degradation setting (ROADMAP item b, arXiv 2310.03371) —
    and a per-row p sweep is ONE compile (p is data). The default 1.0
    statically disables the stream and is bit-identical to a run without
    the knob.
    """
    ch_batch = channels if isinstance(channels, ChannelBatch) \
        else ChannelBatch.stack(list(channels))
    n_rows = len(ch_batch)
    betas = jnp.asarray(betas, jnp.float32)
    if betas.shape != (n_rows,):
        raise ValueError(f"need one stepsize per row: "
                         f"{betas.shape} vs C={n_rows}")
    algos = (algo,) * n_rows if isinstance(algo, str) else tuple(algo)
    if len(algos) != n_rows:
        raise ValueError(f"need one algo per row: {len(algos)} vs C={n_rows}")
    for a in algos:
        if a not in ALGO_REGISTRY:
            raise ValueError(f"unknown algo {a!r}; expected one of "
                             f"{tuple(ALGO_REGISTRY)}")
    specs = [ALGO_REGISTRY[a] for a in algos]
    if rng_plan is not None and rng_plan not in ("hoisted", "inscan"):
        raise ValueError(
            f"rng_plan must be 'hoisted' or 'inscan', got {rng_plan!r}")
    if plan is not None:
        clash = [name for name, v in (
            ("rng_plan", rng_plan), ("seed_chunk", seed_chunk),
            ("keep_seed_curves", keep_seed_curves),
            ("ota_impl", ota_impl), ("shard_seeds", shard_seeds))
            if v is not None]
        if clash:
            raise ValueError(
                f"plan= already pins the execution strategy; drop the "
                f"conflicting legacy knob(s) {clash} or encode them in "
                "the ExecPlan")
        if isinstance(plan, str) and plan != "auto":
            raise ValueError(
                f"plan must be an ExecPlan or the string 'auto', "
                f"got {plan!r}")
    if memory_budget_bytes is not None and plan != "auto":
        raise ValueError(
            "memory_budget_bytes only parameterizes plan='auto' — an "
            "explicit ExecPlan or the legacy knobs already fix the chunk "
            "size")

    # ---- normalize the antenna axis ------------------------------------
    if n_antennas is None or isinstance(n_antennas, (int, np.integer)):
        if n_antennas is not None:
            n_antennas = int(n_antennas)
        m_per_row, m_sizes = None, ()
    else:
        m_per_row = tuple(int(m) for m in n_antennas)
        if len(m_per_row) != n_rows:
            raise ValueError(f"need one antenna count per row: "
                             f"{len(m_per_row)} vs C={n_rows}")
        if any(m < 1 for m in m_per_row):
            raise ValueError(f"antenna counts must be >= 1: {m_per_row}")
        m_sizes = tuple(sorted(set(m_per_row)))
        n_antennas = None  # the static broadcast arg is off in per-row mode
    if any(s.blind for s in specs) and n_antennas is None and not m_sizes:
        raise ValueError(
            "blind/blind_ec need n_antennas (the edge antenna count M)")

    # ---- normalize the problem axis ------------------------------------
    if isinstance(problem, MCProblemBatch):
        batch_prob = problem
    elif isinstance(problem, MCProblem):
        batch_prob = None  # closure path: one problem shared by all rows
    else:
        probs = list(problem)
        if len(probs) == 1:
            batch_prob = None
            problem = probs[0]
        else:
            if len(probs) != n_rows:
                raise ValueError(
                    f"need one problem per row: {len(probs)} vs C={n_rows}")
            batch_prob = MCProblemBatch.stack(probs)

    # stochastic minibatching needs the row-based data path; lift a single
    # broadcast problem into a C-row batch (cheap: data is small)
    sto_spec, b_max, b_counts = _resolve_batch_frac(
        batch_frac, n_rows, batch_prob, problem)
    sgrad_fn = sto_spec.stochastic_grad_row if sto_spec is not None else None
    if sgrad_fn is not None and batch_prob is None:
        batch_prob = MCProblemBatch.stack([problem] * n_rows)

    if batch_prob is not None:
        row_based = True
        grad_fn, risk_fn = batch_prob.grad_fn, batch_prob.risk_fn
        data = dict(batch_prob.data)
        n_nodes = batch_prob.n_nodes
        dim, n_max = batch_prob.dim, batch_prob.n_max
    else:
        row_based = False
        grad_fn, risk_fn = problem.grad_fn, problem.risk_fn
        n_nodes = (problem.n_nodes,) * n_rows
        dim, n_max = problem.dim, problem.n_nodes
        data = {"mask": jnp.ones((n_rows, n_max), jnp.float32)}

    n_sizes = tuple(sorted(set(n_nodes)))
    algo_set = tuple(dict.fromkeys(algos))

    # ---- normalize node participation ----------------------------------
    if isinstance(participation, (int, float, np.integer, np.floating)):
        parts = (float(participation),) * n_rows
    else:
        parts = tuple(float(p) for p in participation)
        if len(parts) != n_rows:
            raise ValueError(f"need one participation per row: "
                             f"{len(parts)} vs C={n_rows}")
    if any(not (0.0 < p <= 1.0) for p in parts):
        raise ValueError(f"participation must be in (0, 1], got {parts}")
    # static on/off only — the probabilities themselves are data, so a
    # per-row p sweep shares one compile; p = 1.0 everywhere disables the
    # mask stream entirely (bit-identical to a run without the knob, and
    # params stays key-identical so resume fingerprints don't shift)
    participation_on = any(p < 1.0 for p in parts)

    # ---- resolve the execution plan ------------------------------------
    # Three sources, one record: an explicit ExecPlan, "auto" (derived
    # from the memory model + topology), or the legacy kwargs building
    # the equivalent plan. The legacy shim is behavior-pinned: every
    # sentinel (None) maps to the exact pre-plan default, and
    # shard_seeds=True resolves through the legacy rule (including its
    # divisibility error) before the plan is built.
    if isinstance(plan, ExecPlan):
        eff_plan = plan
    elif plan == "auto":
        eff_plan = auto_plan(
            n_rows=n_rows, seeds=seeds, steps=steps, n_max=n_max, dim=dim,
            algo_set=algo_set, n_antennas=n_antennas, m_sizes=m_sizes,
            b_max=b_max, invert_channel=invert_channel,
            participation_on=participation_on,
            memory_budget_bytes=memory_budget_bytes)
    else:
        shim_shards: Optional[int] = None
        if shard_seeds is False:
            shim_shards = 0
        elif shard_seeds is True:
            shim_shards = _resolve_n_shards(
                seed_chunk if seed_chunk is not None else seeds, True)
        eff_plan = ExecPlan(
            rng_plan="hoisted" if rng_plan is None else rng_plan,
            seed_chunk=seed_chunk,
            n_shards=shim_shards,
            row_shards=1,
            keep_seed_curves=(True if keep_seed_curves is None
                              else keep_seed_curves),
            ota_impl="auto" if ota_impl is None else ota_impl)
    validate_plan(eff_plan, seeds=seeds, n_rows=n_rows)
    n_shards = resolve_seed_shards(eff_plan, seeds)
    if resume_dir is not None and (eff_plan.seed_chunk is None
                                   or eff_plan.keep_seed_curves):
        raise ValueError(
            "resume_dir requires a chunked reduced sweep — a plan with "
            "seed_chunk set and keep_seed_curves=False (only the chunk "
            "cursor and moment accumulators are checkpointed)")

    ota_resolved = _resolve_ota_impl(eff_plan.ota_impl, n_sizes)
    # static promise for the hoisted plan's phase-stream shortcut: every
    # row's phase draw is over [-0, 0] (cos(0)=1, value-identical to
    # skip). Only hoist-eligible calls (hoisted plan, one algorithm WITH
    # a hoist twin) set it — elsewhere nothing reads it, and a static
    # True/False split would needlessly fragment the jit cache across
    # phase settings that the legacy body treats as pure data.
    phase_zero = (
        eff_plan.rng_plan == "hoisted" and len(algo_set) == 1
        and ALGO_REGISTRY[algo_set[0]].hoist_draws is not None
        and all(float(c.phase_error_max) == 0.0
                for c in ch_batch.configs))
    params = dict(ch_batch.params)
    params["n_nodes"] = jnp.asarray(n_nodes, jnp.float32)
    params["n_idx"] = jnp.asarray(
        [n_sizes.index(n) for n in n_nodes], jnp.int32)
    params["algo_idx"] = jnp.asarray(
        [algo_set.index(a) for a in algos], jnp.int32)
    params["gamma"] = jnp.asarray(
        [momentum if s.uses_gamma else 0.0 for s in specs], jnp.float32)
    params["nest"] = jnp.asarray(
        [1.0 if s.nesterov else 0.0 for s in specs], jnp.float32)
    params["ec"] = jnp.asarray(
        [1.0 if s.error_feedback else 0.0 for s in specs], jnp.float32)
    if power_budget is None:
        budgets = (float("inf"),) * n_rows
    elif isinstance(power_budget, (int, float, np.integer, np.floating)):
        budgets = (float(power_budget),) * n_rows
    else:
        budgets = tuple(float(b) for b in power_budget)
        if len(budgets) != n_rows:
            raise ValueError(f"need one power budget per row: "
                             f"{len(budgets)} vs C={n_rows}")
    params["tx_budget"] = jnp.asarray(budgets, jnp.float32)
    if m_sizes:
        params["n_antennas"] = jnp.asarray(m_per_row, jnp.float32)
        params["m_idx"] = jnp.asarray(
            [m_sizes.index(m) for m in m_per_row], jnp.int32)
    if b_counts is not None:
        # int32, NOT float32: a lane count is integral and must survive
        # exactly (float32 rounds above 2^24); the single consumer divides
        # by it after an explicit float cast
        params["b_count"] = jnp.asarray(b_counts, jnp.int32)
    if participation_on:
        params["participation"] = jnp.asarray(parts, jnp.float32)

    t0 = jnp.zeros((dim,), jnp.float32) if theta0 is None \
        else jnp.asarray(theta0, jnp.float32)
    seed_ints = np.arange(seed0, seed0 + seeds, dtype=np.int32)
    core_kwargs = dict(
        grad_fn=grad_fn, risk_fn=risk_fn, row_based=row_based,
        algo_set=algo_set, fading=ch_batch.fading, steps=steps,
        n_sizes=n_sizes, n_antennas=n_antennas, m_sizes=m_sizes,
        invert_channel=invert_channel, h_min=h_min,
        sgrad_fn=sgrad_fn, b_max=b_max, ota_impl=ota_resolved,
        rng_plan=eff_plan.rng_plan, phase_zero=phase_zero,
        sample_idx_fn=(sto_spec.sample_indices_row
                       if sto_spec is not None else None),
        sgrad_idx_fn=(sto_spec.stochastic_grad_from_idx
                      if sto_spec is not None else None),
        participation_on=participation_on)
    if eff_plan.seed_chunk is not None:
        risks, cum_e, mean, ci95 = exec_mod.run_chunked(
            params, betas, t0, seed_ints, data,
            seed_chunk=eff_plan.seed_chunk,
            keep_seed_curves=eff_plan.keep_seed_curves,
            n_shards=n_shards, row_shards=eff_plan.row_shards,
            core_kwargs=core_kwargs, resume_dir=resume_dir,
            retry=eff_plan.retry)
    else:
        seed_arr = jnp.asarray(seed_ints)
        if eff_plan.keep_seed_curves:
            risks, cum_e = _mc_core(params, betas, t0, seed_arr, data,
                                    n_shards=n_shards,
                                    row_shards=eff_plan.row_shards,
                                    **core_kwargs)
            risks, cum_e = np.asarray(risks), np.asarray(cum_e)
            mean, ci95 = exec_mod.host_seed_stats(risks)
        else:
            mean, ci95 = exec_mod._mc_stats(
                params, betas, t0, seed_arr, data, n_shards=n_shards,
                row_shards=eff_plan.row_shards, **core_kwargs)
            mean, ci95 = np.asarray(mean), np.asarray(ci95)
            risks = cum_e = None
    bounds = None
    if pc is not None:
        pcs = [pc] * n_rows if isinstance(pc, ProblemConstants) else list(pc)
        if len(pcs) != n_rows:
            raise ValueError(f"need one ProblemConstants per row: "
                             f"{len(pcs)} vs C={n_rows}")
        if all(s.theorem1 for s in specs) and n_antennas is None \
                and not m_sizes:
            ks = np.arange(1, steps + 2)
            bounds = np.stack([
                theorem1_bound(ks, float(b), row_pc, cfg, n)
                for b, cfg, row_pc, n in zip(
                    np.asarray(betas), ch_batch.configs, pcs, n_nodes)])
    return MCResult(
        risks=risks, mean=mean.astype(np.float32),
        ci95=ci95.astype(np.float32), cum_energy=cum_e,
        bounds=bounds, plan=eff_plan)


def slice_result(res: MCResult, rows: Union[slice, Sequence[int]]) -> MCResult:
    """A per-row view of an `MCResult`: the given row slice (or index
    sequence) of every (C, ...) array, `None` leaves passed through.

    The row axis is the sweep axis — a coalesced batch (several callers'
    sweeps packed into one engine call, `repro.serving.mc_server`) demuxes
    back into per-caller results with one `slice_result` per caller. The
    sliced arrays are numpy views of the batch result, and `plan` (a
    whole-call property) rides along unchanged.
    """
    idx = rows if isinstance(rows, slice) else list(rows)
    pick = lambda a: None if a is None else a[idx]
    return MCResult(risks=pick(res.risks), mean=pick(res.mean),
                    ci95=pick(res.ci95), cum_energy=pick(res.cum_energy),
                    bounds=pick(res.bounds), plan=res.plan)


def energy_to_target(res: MCResult, target: float) -> np.ndarray:
    """Per-row mean (over seeds) total transmitted energy until the risk
    curve first hits `target` (paper Fig. 6).

    risks[k] is the risk of θ_k, reached after k transmission slots, and
    cum_energy[j] is the energy of slots 1..j+1 — so a first hit at index
    k costs cum_energy[k-1], and a target already met at initialization
    (k == 0) costs nothing. Seeds that never hit spend the full-horizon
    energy.
    """
    if res.risks is None or res.cum_energy is None:
        raise ValueError(
            "energy_to_target needs per-seed curves — run with the default "
            "keep_seed_curves=True")
    c, s, kp1 = res.risks.shape
    hit_mask = res.risks <= target
    hit = np.argmax(hit_mask, axis=2)  # first True, 0 when none
    hit = np.where(hit_mask.any(axis=2), hit, kp1 - 1)
    # prepend the zero-cost column so index k charges cum_energy[k-1]
    ce = np.concatenate(
        [np.zeros((c, s, 1), res.cum_energy.dtype), res.cum_energy], axis=2)
    per_seed = np.take_along_axis(ce, hit[:, :, None], axis=2)[..., 0]
    return per_seed.mean(axis=1)
