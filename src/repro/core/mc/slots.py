"""Per-slot algorithm updates behind an open registry.

One MAC slot maps the transmitted per-node vectors (n_max, d) to the
received update (d,). Each algorithm registers a `slot_fn(g, key, ctx)`
via `register_algo(...)` together with the flags the engine needs
(momentum/Nesterov/error-feedback carries, antenna requirements, gain
hoisting, Theorem-1 applicability); `ALGOS` is derived from the registry,
and the old `_slot_update` if-chain is now a table lookup. Adding an
algorithm is a registration — the engine (`mc/engine.py`) builds its
dispatch switch and scan carries from the flags.

The `SlotCtx` bundles everything a slot sees besides the transmitted
vectors and the slot key: the static compile choices (fading family, node
and antenna size grids, `invert_channel`, `h_min`, the OTA kernel impl)
plus the row's traced params `p` and mask. RNG notes live on each slot fn
— the split orders mirror the reference simulators exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mc.sampling import (
    _antenna_keys,
    _dynamic_threefry_ok,
    _magnitude_m2,
    _normal_dynamic_n,
    _normal_padded,
    _row_complex_gains,
    _row_gains,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SlotCtx:
    """Slot-call context: static engine choices + this row's traced params.

    p:        traced per-row params (channel scalars, n_nodes, flags).
    mask:     (n_max,) validity mask of the padded node axis.
    n_sizes:  distinct true node counts in the batch (static).
    n_antennas: static broadcast antenna count (None = single antenna,
              RNG-identical to `GBMASimulator`).
    m_sizes:  distinct per-row antenna counts (static; empty = broadcast).
    h_slot:   this slot's pre-sampled gain vector when the legacy inscan
              plan hoisted the gain sampling out of the scan (node-count
              sweeps); drawn from exactly the k_h the slot fn would have
              split off.
    ota_impl: 'inline' (engine einsum) or 'pallas'/'ref'/'auto' to route
              the OTA superposition through `repro.kernels.ota`.
    phase_zero: static promise that every row's phase_error_max is 0 —
              lets the hoisted draw twins skip the precoded-phase stream
              (value-identical; see `sampling._sample_gains`).
    draws:    this slot's pre-materialized draw dict under the execution
              layer's hoisted RNG plan (`mc/exec.py`) — the per-step slice
              of whatever this algorithm's `hoist_draws` returned. None =
              draw from the slot key inside the slot fn (inscan plan, or
              an algorithm registered without a hoist twin).
    """

    fading: str
    p: dict
    mask: Array
    n_sizes: tuple
    n_antennas: Optional[int]
    m_sizes: tuple
    invert_channel: bool
    h_min: float
    h_slot: Optional[Array] = None
    ota_impl: str = "inline"
    phase_zero: bool = False
    draws: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """One registered algorithm.

    slot_fn(g, key, ctx) -> (d,) received update for transmitted g.
    ota:            receives the OTA superposition of Eq. (8) (the MAC
                    slot is shared) — the old `_OTA_ALGOS` membership.
    blind:          no-CSI transmitter family (M-antenna MRC edge) — the
                    old `_BLIND_ALGOS`; requires `n_antennas`.
    uses_gamma:     row takes the `run_mc(momentum=)` coefficient (the
                    momentum carry is universal; γ=0 rows reduce to
                    vanilla GD bit-exactly).
    nesterov:       gradient evaluated at the lookahead θ − βγm.
    error_feedback: row carries the per-node residual + power-budget
                    truncation in the scan (`blind_ec` semantics).
    hoist_gains(invert_channel) -> bool: whether the slot's scalar-gain
                    draw may be hoisted out of the scan on node-count
                    sweeps under the LEGACY inscan plan (single-antenna
                    only; the engine checks the antenna config
                    separately).
    hoist_draws(step_keys, ctx, n_max, d) -> dict: the algorithm's draw
                    twin for the execution layer's hoisted RNG plan
                    (`mc/exec.py`): materializes every random stream the
                    slot fn consumes for ALL steps at once — key-split
                    order identical to the in-scan draws — returning a
                    dict of (steps, ...) arrays whose per-step slices
                    arrive back as `ctx.draws`. None = the algorithm only
                    runs its in-scan draw path (the hoisted plan passes
                    `draws=None` for it).
    theorem1:       the Theorem-1 bound applies (single-antenna precoded
                    GBMA — the setting the theorem covers).
    """

    name: str
    slot_fn: Callable[[Array, Array, SlotCtx], Array]
    ota: bool = False
    blind: bool = False
    uses_gamma: bool = False
    nesterov: bool = False
    error_feedback: bool = False
    hoist_gains: Callable[[bool], bool] = staticmethod(lambda inv: False)
    hoist_draws: Optional[Callable] = None
    theorem1: bool = False


ALGO_REGISTRY: dict = {}  # name -> AlgoSpec, insertion-ordered


def register_algo(name: str,
                  slot_fn: Callable[[Array, Array, SlotCtx], Array],
                  *, ota: bool = False, blind: bool = False,
                  uses_gamma: bool = False, nesterov: bool = False,
                  error_feedback: bool = False,
                  hoist_gains: Optional[Callable[[bool], bool]] = None,
                  hoist_draws: Optional[Callable] = None,
                  theorem1: bool = False,
                  overwrite: bool = False) -> AlgoSpec:
    """Register a per-slot algorithm under `name` (the `run_mc(algo=)`
    value). Returns the spec; `ALGOS` updates automatically. Algorithms
    registered without a `hoist_draws` twin still run under the hoisted
    RNG plan — they just keep drawing inside the scan."""
    if name in ALGO_REGISTRY and not overwrite:
        raise ValueError(f"algo {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    spec = AlgoSpec(name=name, slot_fn=slot_fn, ota=ota, blind=blind,
                    uses_gamma=uses_gamma, nesterov=nesterov,
                    error_feedback=error_feedback,
                    hoist_gains=hoist_gains or (lambda inv: False),
                    hoist_draws=hoist_draws,
                    theorem1=theorem1)
    ALGO_REGISTRY[name] = spec
    return spec


def __getattr__(name: str):
    # live views derived from the registry, so late registrations show up
    if name == "ALGOS":
        return tuple(ALGO_REGISTRY)
    if name == "_OTA_ALGOS":
        return tuple(n for n, s in ALGO_REGISTRY.items() if s.ota)
    if name == "_BLIND_ALGOS":
        return tuple(n for n, s in ALGO_REGISTRY.items() if s.blind)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------
# slot implementations (mirror the reference simulators' RNG usage)
#
# Each slot fn consumes `ctx.draws` when the hoisted RNG plan supplied it
# and falls back to drawing from the slot key otherwise; each family's
# `*_hoist_draws` twin vmaps the SAME draw code over the step keys, so the
# two plans are value-identical by construction.
# --------------------------------------------------------------------------
def _ctx_with_draws(ctx: SlotCtx, draws) -> SlotCtx:
    return dataclasses.replace(ctx, draws=draws)


def _step_antenna_keys(key: Array, ctx: SlotCtx) -> Array:
    """One slot's antenna keys: the per-row counts-as-data replay when the
    antenna count is row data, the plain split for a static M."""
    if ctx.m_sizes:
        return _antenna_keys(key, ctx.m_sizes, ctx.p)
    return jax.random.split(key, ctx.n_antennas)


def _gains_deterministic(ctx: SlotCtx) -> bool:
    """Whether this batch's precoded gains consume NO randomness (equal
    fading with the phase stream statically zero): the hoist twins then
    leave 'h' out of the draw dict — materializing a (steps, n_max)
    broadcast buffer is pure memory traffic — and the slot fns recompute
    the broadcast inline, which is value-identical by definition."""
    return ctx.fading == "equal" and ctx.phase_zero


def _deterministic_gains(key: Array, ctx: SlotCtx, n_max: int) -> Array:
    """The inline (n_max,) gain vector for `_gains_deterministic` batches,
    multiplied by the validity mask to make it an OPAQUE operand: without
    that, XLA sees a scalar broadcast and lowers the slot superposition as
    an unvectorized full reduce (measured ~2.4x slower than the matvec at
    N=4096) instead of the matvec every random h takes. The multiply is
    bit-exact: valid lanes hold exactly 1.0 (h·1 == h), and padded lanes
    are exactly 0 on both sides (the padded samplers zero-pad).
    (`lax.optimization_barrier` would be the canonical tool, but it has no
    vmap batching rule on the supported JAX range.)"""
    h = _row_gains(key, ctx.fading, ctx.p, ctx.n_sizes, n_max,
                   ctx.phase_zero)
    return h * ctx.mask


def _ota_draw(key: Array, ctx: SlotCtx, n_max: int, d: int) -> dict:
    """One OTA slot's draws — the k → (k_h, k_w) chain of `_ota_slot`:
    the (n_max,) channel gains and the (d,) edge noise."""
    k_h, k_w = jax.random.split(key)
    out = {"w": jax.random.normal(k_w, (d,), jnp.float32)}
    if not _gains_deterministic(ctx):
        out["h"] = _row_gains(k_h, ctx.fading, ctx.p, ctx.n_sizes, n_max,
                              ctx.phase_zero)
    return out


def _ota_slot(g: Array, key: Array, ctx: SlotCtx, h_slot=None) -> Array:
    """Single-antenna OTA superposition (Eq. 8): v = (1/N) Σ h_n g_n + w.

    slot key → (k_h, k_w); k_h draws the (n_max,) gains unless the caller
    hoisted them (`ctx.draws` under the hoisted plan, `h_slot` under the
    legacy N-sweep hoist), k_w the (d,) edge noise — split-for-split
    identical to `gbma.ota_aggregate`. With `ctx.ota_impl != 'inline'` the
    superposition + noise-add routes through the tiled
    `repro.kernels.ota.ota_edge_aggregate` kernel (pallas on TPU, jnp
    oracle otherwise); the traced noise std folds into the noise operand so
    the kernel's static `noise_scale` stays 1.
    """
    p = ctx.p
    if ctx.draws is not None:
        w = ctx.draws["w"]
        h = ctx.draws.get("h")
        if h is None:  # deterministic gains were (rightly) not hoisted
            h = _deterministic_gains(key, ctx, g.shape[0])
    else:
        k_h, k_w = jax.random.split(key)
        h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, g.shape[0]) \
            if h_slot is None else h_slot
        w = jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype)
    std = p["noise_std"] / (p["n_nodes"] * jnp.sqrt(p["energy"]))
    if ctx.ota_impl != "inline":
        from repro.kernels.ota.ops import ota_edge_aggregate

        # valid only when every row transmits at the full static node count
        # (run_mc enforces this): the kernel normalizes by the static N.
        # out_dtype matches the inline einsum's promotion (f32 gains x g),
        # so bf16-transmit blocks still emit an f32 received update.
        return ota_edge_aggregate(g, h, std * w, noise_scale=1.0,
                                  impl=ctx.ota_impl,
                                  interpret=jax.default_backend() != "tpu",
                                  out_dtype=jnp.promote_types(
                                      g.dtype, jnp.float32))
    v = jnp.einsum("n,nd->d", h, g) / p["n_nodes"]
    return v + std * w


def _gbma_hoist_draws(step_keys: Array, ctx: SlotCtx, n_max: int,
                      d: int) -> dict:
    """All-steps draw twin of `_gbma_slot`: single-antenna slots hoist to
    {'h': (steps, n_max), 'w': (steps, d)}; antenna paths (static M or
    per-row counts) insert an antenna axis after steps."""
    if ctx.n_antennas is None and not ctx.m_sizes:
        return jax.vmap(lambda k: _ota_draw(k, ctx, n_max, d))(step_keys)
    return jax.vmap(lambda k: jax.vmap(
        lambda ak: _ota_draw(ak, ctx, n_max, d))(
            _step_antenna_keys(k, ctx)))(step_keys)


def _gbma_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Precoded OTA aggregation, shared by gbma/momentum/nesterov.

    n_antennas=None: single-antenna edge, RNG-identical to `GBMASimulator`.
    An integer (1 included) takes the MRC path of
    `ota_aggregate_multiantenna`, whose extra key split changes the stream
    even for M=1 — mirrored so fixed seeds reproduce exactly. Per-row
    counts (m_sizes) take the masked-MRC path: each row consumes exactly
    the first m of its replayed split(key, m).
    """
    p = ctx.p
    if ctx.m_sizes or ctx.n_antennas is not None:
        if ctx.draws is not None:
            v = jax.vmap(lambda dr: _ota_slot(
                g, key, _ctx_with_draws(ctx, dr)))(ctx.draws)
        else:
            v = jax.vmap(lambda k: _ota_slot(g, k, ctx))(
                _step_antenna_keys(key, ctx))
        if ctx.m_sizes:
            amask = (jnp.arange(v.shape[0])
                     < p["n_antennas"]).astype(v.dtype)
            return jnp.einsum("m,md->d", amask, v) / p["n_antennas"]
        return jnp.mean(v, axis=0)
    return _ota_slot(g, key, ctx, ctx.h_slot)


def _centralized_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Noiseless benchmark GD: the slot key is unused (and there is no
    hoist twin — nothing random to hoist)."""
    return jnp.sum(g, axis=0) / ctx.p["n_nodes"]


def _blind_antenna_draw(key: Array, ctx: SlotCtx, n_max: int,
                        d: int) -> dict:
    """One antenna's draw chain in `_blind_slot` — k → (k_h, k_w): the
    complex gain parts (a, b) and the stacked real/imag edge noise."""
    k_h, k_w = jax.random.split(key)
    a, b = _row_complex_gains(k_h, ctx.fading, ctx.p, ctx.n_sizes, n_max)
    return {"a": a, "b": b,
            "z": jax.random.normal(k_w, (2, d), jnp.float32)}


def _blind_hoist_draws(step_keys: Array, ctx: SlotCtx, n_max: int,
                       d: int) -> dict:
    """All-steps draw twin of `_blind_slot`: (steps, m, ...) complex-gain
    and edge-noise streams (m = static M or the padded per-row axis)."""
    return jax.vmap(lambda k: jax.vmap(
        lambda ak: _blind_antenna_draw(ak, ctx, n_max, d))(
            _step_antenna_keys(k, ctx)))(step_keys)


def _blind_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Blind transmitters (1907.03909): nodes send g uncoded; antenna m
    receives y_m = Σ_n h~_{n,m} g_n + z~_m (complex); the edge MRC-
    combines with receiver CSI, normalized by M·E[h²] — mirrors
    `gbma.blind_ota_aggregate` split-for-split."""
    p = ctx.p
    n_max = g.shape[0]
    m2 = _magnitude_m2(ctx.fading, p)
    std = p["noise_std"] / jnp.sqrt(p["energy"])

    def combine(a, b, z):
        y_r = jnp.einsum("n,nd->d", a, g) + std * z[0]
        y_i = jnp.einsum("n,nd->d", b, g) + std * z[1]
        return jnp.sum(a) * y_r + jnp.sum(b) * y_i

    def antenna(k):
        dr = _blind_antenna_draw(k, ctx, n_max, g.shape[1])
        return combine(dr["a"], dr["b"], dr["z"])

    if ctx.draws is not None:
        s = jax.vmap(lambda dr: combine(dr["a"], dr["b"], dr["z"]))(
            ctx.draws)
    else:
        s = jax.vmap(antenna)(_step_antenna_keys(key, ctx))
    m_true = p["n_antennas"] if ctx.m_sizes else jnp.float32(ctx.n_antennas)
    amask = (jnp.arange(s.shape[0]) < m_true).astype(g.dtype)
    return jnp.einsum("m,md->d", amask, s) / (m_true * p["n_nodes"] * m2)


def _fdm_draw(key: Array, ctx: SlotCtx, n_max: int, d: int) -> dict:
    """`_fdm_slot`'s per-slot draws: the (n_max, d) per-node noise and —
    unless the channel is inverted (gain equalized; k_h split off but
    unconsumed, matching `baselines.FDMGD`) — the (n_max,) gains."""
    p = ctx.p
    k_h, k_w = jax.random.split(key)
    if len(ctx.n_sizes) > 1 and _dynamic_threefry_ok():
        raw = _normal_dynamic_n(k_w, p["n_nodes"].astype(jnp.int32),
                                n_max, d)
    else:
        raw = _normal_padded(k_w, p["n_idx"], ctx.n_sizes, n_max, d,
                             jnp.float32)
    out = {"noise_raw": raw}
    if not ctx.invert_channel and not _gains_deterministic(ctx):
        out["h"] = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max,
                              ctx.phase_zero)
    return out


def _fdm_hoist_draws(step_keys: Array, ctx: SlotCtx, n_max: int,
                     d: int) -> dict:
    return jax.vmap(lambda k: _fdm_draw(k, ctx, n_max, d))(step_keys)


def _fdm_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Orthogonal-channel GD: independent per-node (d,) noise; with
    `invert_channel` the gain is equalized (k_h split off but unconsumed,
    matching `baselines.FDMGD`)."""
    p = ctx.p
    n_max = g.shape[0]
    if ctx.draws is not None:
        raw = ctx.draws["noise_raw"]
        h = ctx.draws.get("h")
        if h is None and not ctx.invert_channel:
            h = _deterministic_gains(key, ctx, n_max)
    else:
        k_h, k_w = jax.random.split(key)
        if len(ctx.n_sizes) > 1 and _dynamic_threefry_ok():
            raw = _normal_dynamic_n(
                k_w, p["n_nodes"].astype(jnp.int32), n_max, g.shape[1])
        else:
            raw = _normal_padded(
                k_w, p["n_idx"], ctx.n_sizes, n_max, g.shape[1], g.dtype)
        h = None
        if not ctx.invert_channel:
            h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max) \
                if ctx.h_slot is None else ctx.h_slot
    noise = p["noise_std"] / jnp.sqrt(p["energy"]) * raw
    if ctx.invert_channel:
        rx = g + noise
    else:
        rx = h[:, None] * g + noise
    return jnp.sum(rx * ctx.mask[:, None], axis=0) / p["n_nodes"]


def _pc_draw(key: Array, ctx: SlotCtx, n_max: int, d: int) -> dict:
    """`_power_control_slot`'s per-slot draws: gains + (d,) edge noise."""
    k_h, k_w = jax.random.split(key)
    out = {"w": jax.random.normal(k_w, (d,), jnp.float32)}
    if not _gains_deterministic(ctx):
        out["h"] = _row_gains(k_h, ctx.fading, ctx.p, ctx.n_sizes, n_max,
                              ctx.phase_zero)
    return out


def _pc_hoist_draws(step_keys: Array, ctx: SlotCtx, n_max: int,
                    d: int) -> dict:
    return jax.vmap(lambda k: _pc_draw(k, ctx, n_max, d))(step_keys)


def _power_control_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """CA-DSGD-style truncated channel inversion [11]: nodes below `h_min`
    stay silent; the active set inverts its gains."""
    p = ctx.p
    n_max = g.shape[0]
    if ctx.draws is not None:
        w_raw = ctx.draws["w"]
        h = ctx.draws.get("h")
        if h is None:  # deterministic gains were (rightly) not hoisted
            h = _deterministic_gains(key, ctx, n_max)
    else:
        k_h, k_w = jax.random.split(key)
        h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max) \
            if ctx.h_slot is None else ctx.h_slot
        w_raw = jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype)
    active = (h >= ctx.h_min).astype(g.dtype) * ctx.mask
    n_active = jnp.maximum(jnp.sum(active), 1.0)
    sup = jnp.einsum("n,nd->d", active, g)
    w = p["noise_std"] / (n_active * jnp.sqrt(p["energy"])) * w_raw
    return sup / n_active + w


# --------------------------------------------------------------------------
# built-in registrations (order defines the historical ALGOS tuple)
# --------------------------------------------------------------------------
register_algo("gbma", _gbma_slot, ota=True,
              hoist_gains=lambda inv: True,
              hoist_draws=_gbma_hoist_draws, theorem1=True)
register_algo("centralized", _centralized_slot)
register_algo("fdm", _fdm_slot, hoist_gains=lambda inv: not inv,
              hoist_draws=_fdm_hoist_draws)
register_algo("power_control", _power_control_slot,
              hoist_gains=lambda inv: True,
              hoist_draws=_pc_hoist_draws)
register_algo("momentum", _gbma_slot, ota=True, uses_gamma=True,
              hoist_gains=lambda inv: True,
              hoist_draws=_gbma_hoist_draws)
register_algo("nesterov", _gbma_slot, ota=True, uses_gamma=True,
              nesterov=True, hoist_gains=lambda inv: True,
              hoist_draws=_gbma_hoist_draws)
register_algo("blind", _blind_slot, blind=True,
              hoist_draws=_blind_hoist_draws)
register_algo("blind_ec", _blind_slot, blind=True, error_feedback=True,
              hoist_draws=_blind_hoist_draws)


def hoist_draw_elems(name: str, *, steps: int, n_max: int, dim: int,
                     m_live: int, invert_channel: bool) -> int:
    """f32-element count of one trajectory's hoisted draw streams for the
    named algorithm — the registry's side of the execution layer's
    analytic memory model (`exec.estimate_peak_bytes`). Lives next to
    `register_algo` so a new algorithm's `hoist_draws` twin and its
    memory footprint stay in one file. Unregistered algorithms and those
    without a hoist twin draw in-scan: 0 hoisted elements."""
    spec = ALGO_REGISTRY.get(name)
    if spec is None or spec.hoist_draws is None:
        return 0
    if spec.blind:
        # complex gain pair (m, n_max) + edge noise (m, 2, dim)
        return steps * m_live * 2 * (n_max + dim)
    if name == "fdm":
        # per-node noise (n_max, dim) + gains unless inverted
        # (the inverted channel is equalized — no gain stream)
        return steps * n_max * (dim + (0 if invert_channel else 1))
    # gbma family / power_control: gains + edge noise
    return steps * m_live * (n_max + dim)


# --------------------------------------------------------------------------
# block-shaped entry point (the channel-transport layer's tiling surface)
# --------------------------------------------------------------------------
# d-axis layout of the hoisted draw dicts: these keys carry a trailing
# axis of length d and slice per column block; every other key ('h', 'a',
# 'b') is per-node/per-antenna only and is shared by all blocks of a slot.
_DRAW_D_KEYS = ("w", "z", "noise_raw")


def slice_draws(draws: Optional[dict], lo: int, hi: int) -> Optional[dict]:
    """Column-block [lo, hi) view of one slot's draw dict.

    Slicing the d-carrying streams ('w' (d,), 'z' (..., 2, d),
    'noise_raw' (n_max, d)) on their LAST axis and passing the per-node
    streams through whole keeps block-tiled slot evaluation value-
    identical to the untiled call: every slot computation is
    per-coordinate given its draws, so coordinate c of the update depends
    only on column c of g and of the d-carrying draws. (The draws match
    bitwise; the one residual tiling artifact is XLA reassociating the
    f32 node-superposition reduction per block shape — a few ulp.)"""
    if draws is None:
        return None
    return {k: (v[..., lo:hi] if k in _DRAW_D_KEYS else v)
            for k, v in draws.items()}


def slot_update_block(algo: str, g: Array, key: Array, ctx: SlotCtx,
                      lo: int, hi: int) -> Array:
    """One column block of a slot update: `g` is the (n_max, hi-lo) block
    of the transmitted vectors, `ctx.draws` the FULL-d draw dict (sliced
    here). Requires hoisted draws for any algorithm that consumes
    randomness — re-running a slot fn's in-scan draw path per block would
    repeat the same key (correlated noise across blocks) and break the
    tiled==untiled guarantee. `repro.core.transport` enforces that."""
    spec = ALGO_REGISTRY[algo]
    if ctx.draws is None and spec.hoist_draws is not None:
        raise ValueError(
            f"slot_update_block({algo!r}) needs pre-materialized draws "
            "(ctx.draws): per-block in-slot draws would reuse the slot key "
            "across blocks")
    ctx_blk = dataclasses.replace(ctx, draws=slice_draws(ctx.draws, lo, hi))
    return spec.slot_fn(g, key, ctx_blk)


def _slot_update(g: Array, key: Array, *, algo: str, fading: str, p: dict,
                 mask: Array, n_sizes: tuple, n_antennas: Optional[int],
                 m_sizes: tuple, invert_channel: bool, h_min: float,
                 h_slot=None, ota_impl: str = "inline") -> Array:
    """Back-compat wrapper over the registry dispatch: one MAC slot,
    transmitted per-node vectors (n_max, d) -> received update (d,).

    `g` is whatever the nodes put on the channel this slot — the masked
    local gradients for most algorithms; for `blind_ec` rows the scan body
    has already folded in the local residual and the power-budget
    truncation before calling here.

    Padded node rows carry exactly-zero vectors (the problem grad fns
    mask them) and zero-padded channel gains, so every per-node reduction
    normalizes by the row's true node count p['n_nodes'], and shaped noise
    draws (fdm) are masked before the node average.
    """
    if algo not in ALGO_REGISTRY:
        raise ValueError(
            f"unknown algo {algo!r}; expected one of {tuple(ALGO_REGISTRY)}")
    ctx = SlotCtx(fading=fading, p=p, mask=mask, n_sizes=n_sizes,
                  n_antennas=n_antennas, m_sizes=m_sizes,
                  invert_channel=invert_channel, h_min=h_min,
                  h_slot=h_slot, ota_impl=ota_impl)
    return ALGO_REGISTRY[algo].slot_fn(g, key, ctx)
