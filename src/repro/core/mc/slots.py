"""Per-slot algorithm updates behind an open registry.

One MAC slot maps the transmitted per-node vectors (n_max, d) to the
received update (d,). Each algorithm registers a `slot_fn(g, key, ctx)`
via `register_algo(...)` together with the flags the engine needs
(momentum/Nesterov/error-feedback carries, antenna requirements, gain
hoisting, Theorem-1 applicability); `ALGOS` is derived from the registry,
and the old `_slot_update` if-chain is now a table lookup. Adding an
algorithm is a registration — the engine (`mc/engine.py`) builds its
dispatch switch and scan carries from the flags.

The `SlotCtx` bundles everything a slot sees besides the transmitted
vectors and the slot key: the static compile choices (fading family, node
and antenna size grids, `invert_channel`, `h_min`, the OTA kernel impl)
plus the row's traced params `p` and mask. RNG notes live on each slot fn
— the split orders mirror the reference simulators exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mc.sampling import (
    _antenna_keys,
    _dynamic_threefry_ok,
    _magnitude_m2,
    _normal_dynamic_n,
    _normal_padded,
    _row_complex_gains,
    _row_gains,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SlotCtx:
    """Slot-call context: static engine choices + this row's traced params.

    p:        traced per-row params (channel scalars, n_nodes, flags).
    mask:     (n_max,) validity mask of the padded node axis.
    n_sizes:  distinct true node counts in the batch (static).
    n_antennas: static broadcast antenna count (None = single antenna,
              RNG-identical to `GBMASimulator`).
    m_sizes:  distinct per-row antenna counts (static; empty = broadcast).
    h_slot:   this slot's pre-sampled gain vector when the engine hoisted
              the gain sampling out of the scan (node-count sweeps); drawn
              from exactly the k_h the slot fn would have split off.
    ota_impl: 'inline' (engine einsum) or 'pallas'/'ref'/'auto' to route
              the OTA superposition through `repro.kernels.ota`.
    """

    fading: str
    p: dict
    mask: Array
    n_sizes: tuple
    n_antennas: Optional[int]
    m_sizes: tuple
    invert_channel: bool
    h_min: float
    h_slot: Optional[Array] = None
    ota_impl: str = "inline"


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """One registered algorithm.

    slot_fn(g, key, ctx) -> (d,) received update for transmitted g.
    ota:            receives the OTA superposition of Eq. (8) (the MAC
                    slot is shared) — the old `_OTA_ALGOS` membership.
    blind:          no-CSI transmitter family (M-antenna MRC edge) — the
                    old `_BLIND_ALGOS`; requires `n_antennas`.
    uses_gamma:     row takes the `run_mc(momentum=)` coefficient (the
                    momentum carry is universal; γ=0 rows reduce to
                    vanilla GD bit-exactly).
    nesterov:       gradient evaluated at the lookahead θ − βγm.
    error_feedback: row carries the per-node residual + power-budget
                    truncation in the scan (`blind_ec` semantics).
    hoist_gains(invert_channel) -> bool: whether the slot's scalar-gain
                    draw may be hoisted out of the scan on node-count
                    sweeps (single-antenna only; the engine checks the
                    antenna config separately).
    theorem1:       the Theorem-1 bound applies (single-antenna precoded
                    GBMA — the setting the theorem covers).
    """

    name: str
    slot_fn: Callable[[Array, Array, SlotCtx], Array]
    ota: bool = False
    blind: bool = False
    uses_gamma: bool = False
    nesterov: bool = False
    error_feedback: bool = False
    hoist_gains: Callable[[bool], bool] = staticmethod(lambda inv: False)
    theorem1: bool = False


ALGO_REGISTRY: dict = {}  # name -> AlgoSpec, insertion-ordered


def register_algo(name: str,
                  slot_fn: Callable[[Array, Array, SlotCtx], Array],
                  *, ota: bool = False, blind: bool = False,
                  uses_gamma: bool = False, nesterov: bool = False,
                  error_feedback: bool = False,
                  hoist_gains: Optional[Callable[[bool], bool]] = None,
                  theorem1: bool = False,
                  overwrite: bool = False) -> AlgoSpec:
    """Register a per-slot algorithm under `name` (the `run_mc(algo=)`
    value). Returns the spec; `ALGOS` updates automatically."""
    if name in ALGO_REGISTRY and not overwrite:
        raise ValueError(f"algo {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    spec = AlgoSpec(name=name, slot_fn=slot_fn, ota=ota, blind=blind,
                    uses_gamma=uses_gamma, nesterov=nesterov,
                    error_feedback=error_feedback,
                    hoist_gains=hoist_gains or (lambda inv: False),
                    theorem1=theorem1)
    ALGO_REGISTRY[name] = spec
    return spec


def __getattr__(name: str):
    # live views derived from the registry, so late registrations show up
    if name == "ALGOS":
        return tuple(ALGO_REGISTRY)
    if name == "_OTA_ALGOS":
        return tuple(n for n, s in ALGO_REGISTRY.items() if s.ota)
    if name == "_BLIND_ALGOS":
        return tuple(n for n, s in ALGO_REGISTRY.items() if s.blind)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------
# slot implementations (mirror the reference simulators' RNG usage)
# --------------------------------------------------------------------------
def _ota_slot(g: Array, key: Array, ctx: SlotCtx, h_slot=None) -> Array:
    """Single-antenna OTA superposition (Eq. 8): v = (1/N) Σ h_n g_n + w.

    slot key → (k_h, k_w); k_h draws the (n_max,) gains unless the caller
    hoisted them (`h_slot`), k_w the (d,) edge noise — split-for-split
    identical to `gbma.ota_aggregate`. With `ctx.ota_impl != 'inline'` the
    superposition + noise-add routes through the tiled
    `repro.kernels.ota.ota_edge_aggregate` kernel (pallas on TPU, jnp
    oracle otherwise); the traced noise std folds into the noise operand so
    the kernel's static `noise_scale` stays 1.
    """
    p = ctx.p
    k_h, k_w = jax.random.split(key)
    h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, g.shape[0]) \
        if h_slot is None else h_slot
    std = p["noise_std"] / (p["n_nodes"] * jnp.sqrt(p["energy"]))
    if ctx.ota_impl != "inline":
        from repro.kernels.ota.ops import ota_edge_aggregate

        z = jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype)
        # valid only when every row transmits at the full static node count
        # (run_mc enforces this): the kernel normalizes by the static N
        return ota_edge_aggregate(g, h, std * z, noise_scale=1.0,
                                  impl=ctx.ota_impl,
                                  interpret=jax.default_backend() != "tpu")
    v = jnp.einsum("n,nd->d", h, g) / p["n_nodes"]
    return v + std * jax.random.normal(k_w, v.shape, dtype=v.dtype)


def _gbma_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Precoded OTA aggregation, shared by gbma/momentum/nesterov.

    n_antennas=None: single-antenna edge, RNG-identical to `GBMASimulator`.
    An integer (1 included) takes the MRC path of
    `ota_aggregate_multiantenna`, whose extra key split changes the stream
    even for M=1 — mirrored so fixed seeds reproduce exactly. Per-row
    counts (m_sizes) take the masked-MRC path: each row consumes exactly
    the first m of its replayed split(key, m).
    """
    p = ctx.p
    if ctx.m_sizes:
        keys = _antenna_keys(key, ctx.m_sizes, p)
        v = jax.vmap(lambda k: _ota_slot(g, k, ctx))(keys)
        amask = (jnp.arange(v.shape[0]) < p["n_antennas"]).astype(v.dtype)
        return jnp.einsum("m,md->d", amask, v) / p["n_antennas"]
    if ctx.n_antennas is None:
        return _ota_slot(g, key, ctx, ctx.h_slot)
    keys = jax.random.split(key, ctx.n_antennas)
    v = jax.vmap(lambda k: _ota_slot(g, k, ctx))(keys)
    return jnp.mean(v, axis=0)


def _centralized_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Noiseless benchmark GD: the slot key is unused."""
    return jnp.sum(g, axis=0) / ctx.p["n_nodes"]


def _blind_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Blind transmitters (1907.03909): nodes send g uncoded; antenna m
    receives y_m = Σ_n h~_{n,m} g_n + z~_m (complex); the edge MRC-
    combines with receiver CSI, normalized by M·E[h²] — mirrors
    `gbma.blind_ota_aggregate` split-for-split."""
    p = ctx.p
    n_max = g.shape[0]
    m2 = _magnitude_m2(ctx.fading, p)
    std = p["noise_std"] / jnp.sqrt(p["energy"])

    def antenna(k):
        k_h, k_w = jax.random.split(k)
        a, b = _row_complex_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max)
        z = jax.random.normal(k_w, (2, g.shape[1]), dtype=g.dtype)
        y_r = jnp.einsum("n,nd->d", a, g) + std * z[0]
        y_i = jnp.einsum("n,nd->d", b, g) + std * z[1]
        return jnp.sum(a) * y_r + jnp.sum(b) * y_i

    if ctx.m_sizes:
        keys = _antenna_keys(key, ctx.m_sizes, p)
        m_true = p["n_antennas"]
    else:
        keys = jax.random.split(key, ctx.n_antennas)
        m_true = jnp.float32(ctx.n_antennas)
    s = jax.vmap(antenna)(keys)
    amask = (jnp.arange(s.shape[0]) < m_true).astype(g.dtype)
    return jnp.einsum("m,md->d", amask, s) / (m_true * p["n_nodes"] * m2)


def _fdm_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """Orthogonal-channel GD: independent per-node (d,) noise; with
    `invert_channel` the gain is equalized (k_h split off but unconsumed,
    matching `baselines.FDMGD`)."""
    p = ctx.p
    n_max = g.shape[0]
    k_h, k_w = jax.random.split(key)
    if len(ctx.n_sizes) > 1 and _dynamic_threefry_ok():
        raw = _normal_dynamic_n(
            k_w, p["n_nodes"].astype(jnp.int32), n_max, g.shape[1])
    else:
        raw = _normal_padded(
            k_w, p["n_idx"], ctx.n_sizes, n_max, g.shape[1], g.dtype)
    noise = p["noise_std"] / jnp.sqrt(p["energy"]) * raw
    if ctx.invert_channel:
        rx = g + noise
    else:
        h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max) \
            if ctx.h_slot is None else ctx.h_slot
        rx = h[:, None] * g + noise
    return jnp.sum(rx * ctx.mask[:, None], axis=0) / p["n_nodes"]


def _power_control_slot(g: Array, key: Array, ctx: SlotCtx) -> Array:
    """CA-DSGD-style truncated channel inversion [11]: nodes below `h_min`
    stay silent; the active set inverts its gains."""
    p = ctx.p
    n_max = g.shape[0]
    k_h, k_w = jax.random.split(key)
    h = _row_gains(k_h, ctx.fading, p, ctx.n_sizes, n_max) \
        if ctx.h_slot is None else ctx.h_slot
    active = (h >= ctx.h_min).astype(g.dtype) * ctx.mask
    n_active = jnp.maximum(jnp.sum(active), 1.0)
    sup = jnp.einsum("n,nd->d", active, g)
    w = p["noise_std"] / (n_active * jnp.sqrt(p["energy"])) * (
        jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype))
    return sup / n_active + w


# --------------------------------------------------------------------------
# built-in registrations (order defines the historical ALGOS tuple)
# --------------------------------------------------------------------------
register_algo("gbma", _gbma_slot, ota=True,
              hoist_gains=lambda inv: True, theorem1=True)
register_algo("centralized", _centralized_slot)
register_algo("fdm", _fdm_slot, hoist_gains=lambda inv: not inv)
register_algo("power_control", _power_control_slot,
              hoist_gains=lambda inv: True)
register_algo("momentum", _gbma_slot, ota=True, uses_gamma=True,
              hoist_gains=lambda inv: True)
register_algo("nesterov", _gbma_slot, ota=True, uses_gamma=True,
              nesterov=True, hoist_gains=lambda inv: True)
register_algo("blind", _blind_slot, blind=True)
register_algo("blind_ec", _blind_slot, blind=True, error_feedback=True)


def _slot_update(g: Array, key: Array, *, algo: str, fading: str, p: dict,
                 mask: Array, n_sizes: tuple, n_antennas: Optional[int],
                 m_sizes: tuple, invert_channel: bool, h_min: float,
                 h_slot=None, ota_impl: str = "inline") -> Array:
    """Back-compat wrapper over the registry dispatch: one MAC slot,
    transmitted per-node vectors (n_max, d) -> received update (d,).

    `g` is whatever the nodes put on the channel this slot — the masked
    local gradients for most algorithms; for `blind_ec` rows the scan body
    has already folded in the local residual and the power-budget
    truncation before calling here.

    Padded node rows carry exactly-zero vectors (the problem grad fns
    mask them) and zero-padded channel gains, so every per-node reduction
    normalizes by the row's true node count p['n_nodes'], and shaped noise
    draws (fdm) are masked before the node average.
    """
    if algo not in ALGO_REGISTRY:
        raise ValueError(
            f"unknown algo {algo!r}; expected one of {tuple(ALGO_REGISTRY)}")
    ctx = SlotCtx(fading=fading, p=p, mask=mask, n_sizes=n_sizes,
                  n_antennas=n_antennas, m_sizes=m_sizes,
                  invert_channel=invert_channel, h_min=h_min,
                  h_slot=h_slot, ota_impl=ota_impl)
    return ALGO_REGISTRY[algo].slot_fn(g, key, ctx)
