"""Measured per-workload cost model for the Monte Carlo engine.

The execution layer so far priced its choices with an *analytic* memory
model (`exec.estimate_peak_bytes`) and an assumed cache-resident chunk
target. This module closes ROADMAP's remaining self-tuning item: fold
the MEASURED roofline into the planner's and the serving router's
decisions.

Three pieces:

* **Calibration** (`calibrate` / `python -m repro.core.mc.costmodel`):
  a small one-time microbench suite — per-slot warm step time over an
  (algo family × N × dim) grid, a dispatch-overhead probe (chunked vs
  all-live on the same workload), a chunk-size working-set profile
  (warm step time vs per-device live bytes), a compile-time probe, and
  the machine peaks (`measure_machine_peaks`: f32 matmul GFLOP/s +
  big-copy GiB/s — the same microbench `benchmarks/roofline.py`
  renders). Results persist as a **versioned JSON calibration
  artifact** keyed by `<platform>/<device_count>`
  (`benchmarks/CALIBRATION_mc.json` by default; override with the
  `REPRO_CALIBRATION_PATH` env var). A version bump or a
  platform/device-count mismatch makes an entry stale — it is simply
  not loaded.

* **`CostModel`** — `predict_step_us(plan, workload)` and
  `predict_run_us(plan, workload)`: the predicted per-(row, seed, step)
  slot time and total wall-clock of one engine call under a given
  `ExecPlan`. Slot time is a nonnegative linear fit over the analytic
  slot FLOPs (`mc_slot_model`), scaled by the measured working-set
  profile factor at the plan's per-device live bytes; run time adds a
  per-engine-call `dispatch_us` for every seed chunk and divides the
  compute term over the plan's device mesh. Every term is clamped
  nonnegative, so predictions are **monotone non-decreasing in N,
  seeds and steps** (pinned in `tests/test_costmodel.py`).
  `analytic_cost_model()` builds the same interface from the closed-form
  slot model and nominal CPU-class peaks — the fallback when no
  calibration artifact exists, so cost-model consumers always work.

* **Consumers** — `plan.auto_plan(..., cost_model="measured")` picks
  `seed_chunk` by predicted wall-clock under the memory budget
  (conservative: it deviates from the analytic choice only for a
  predicted win > 5%, and falls back to the analytic path exactly when
  no calibration entry matches — behavior-pinned); the sweep server
  (`repro.serving.mc_server`) prices merged-vs-separate batches with
  `predict_run_us` plus `compile_s` for unseen shape classes, making
  the coalescer pad-waste-aware (docs/serving.md).

`cached_machine_peaks` additionally lets repeated roofline/bench
invocations reuse the artifact's peaks instead of re-measuring —
`benchmarks/roofline.py` routes through it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time
from typing import Optional

import numpy as np

CALIBRATION_VERSION = 1
# nominal CPU-class ceilings for the analytic fallback model (2-core CI
# container scale); a calibration artifact replaces them with measurement
_NOMINAL_PEAKS = {"peak_gflops": 8.0, "peak_gibs": 6.0}
_US = 1e6


def default_calibration_path() -> str:
    """The artifact location: `REPRO_CALIBRATION_PATH` when set, else the
    tracked `benchmarks/CALIBRATION_mc.json` next to the bench records."""
    env = os.environ.get("REPRO_CALIBRATION_PATH")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))))
    return os.path.join(root, "benchmarks", "CALIBRATION_mc.json")


def platform_key(device_count: Optional[int] = None,
                 platform: Optional[str] = None) -> str:
    """Artifact entry key: `<platform>/<device_count>` — the staleness
    axes. A calibration measured on cpu/1 never serves a cpu/4 or tpu/8
    process."""
    import jax

    plat = platform if platform is not None else jax.default_backend()
    ndev = device_count if device_count is not None else jax.device_count()
    return f"{plat}/{int(ndev)}"


# --------------------------------------------------------------------------
# analytic slot model + machine peaks (the roofline's microbench machinery)
# --------------------------------------------------------------------------
def mc_slot_model(algo: str, n: int, d: int, m: int = 1) -> dict:
    """Analytic per-(row, seed, step) cost of one engine slot, f32.

    Counts the dominant O(N·d) terms of the quadratic-problem scan body
    (`benchmarks/roofline.py` renders this next to measured step times):

    gbma (single antenna, hoisted plan):
      flops: grad 4·N·d (X@θ, residual scale, +λθ) + energy 2·N·d +
             superposition einsum 2·N·d + risk 2·d² → 8·N·d + 2·d²
      bytes: X streamed twice (grad passes) + g materialized once and read
             twice (energy, einsum) + gains N → (5·N·d + N) · 4

    blind (M antennas): the M-antenna MRC combine adds per antenna two
      real einsums over g (4·N·d) and the complex gain pair (2·N reads):
      flops: 6·N·d + 2·d² + M·(4·N·d + 6·d)
      bytes: (3·N·d + M·(2·N·d + 2·N)) · 4

    A model, not an HLO count: XLA fusion removes some traffic (fused
    grad→einsum skips one g pass) and adds some (padding); treat ratios,
    not digits, as the signal.
    """
    if algo == "gbma":
        flops = 8 * n * d + 2 * d * d
        bytes_ = (5 * n * d + n) * 4
    elif algo == "blind":
        flops = 6 * n * d + 2 * d * d + m * (4 * n * d + 6 * d)
        bytes_ = (3 * n * d + m * (2 * n * d + 2 * n)) * 4
    else:
        raise ValueError(f"no slot model for algo {algo!r}")
    return {"flops": flops, "bytes": bytes_,
            "intensity": flops / bytes_}


def _algo_family(algo: str) -> str:
    """Map any registered algorithm onto the slot-model family whose
    dominant terms it shares: blind (M-antenna MRC) or gbma (everything
    single-antenna — momentum/nesterov/power_control add O(d) work the
    O(N·d) model absorbs)."""
    from repro.core.mc.slots import ALGO_REGISTRY

    spec = ALGO_REGISTRY.get(algo)
    return "blind" if (spec is not None and spec.blind) else "gbma"


def measure_machine_peaks(dim: int = 1536, reps: int = 3) -> dict:
    """Microbenchmarked machine peaks: f32 matmul GFLOP/s and big-copy
    GiB/s — the two roofline ceilings. In-process so the numbers share
    the calling run's thermal/contention conditions."""
    import jax
    import jax.numpy as jnp

    a = jnp.asarray(np.random.rand(dim, dim), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    jax.block_until_ready(mm(a))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(mm(a))
        best = min(best, time.perf_counter() - t0)
    peak_flops = 2 * dim**3 / best

    big = jnp.asarray(np.random.rand(64 * 2**20 // 4), jnp.float32)  # 64 MiB
    cp = jax.jit(lambda x: x + 1.0)
    jax.block_until_ready(cp(big))
    best_bw = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(cp(big))
        best_bw = min(best_bw, time.perf_counter() - t0)
    peak_bw = 2 * big.size * 4 / best_bw  # read + write
    return {"peak_gflops": peak_flops / 1e9,
            "peak_gibs": peak_bw / 2**30}


def cached_machine_peaks(dim: int = 1536, reps: int = 3, *,
                         path: Optional[str] = None,
                         device_count: Optional[int] = None,
                         measure=measure_machine_peaks,
                         write: bool = True) -> dict:
    """Machine peaks through the calibration artifact: return the stored
    peaks when this platform/device-count has an entry, else measure
    once and (best-effort) persist a peaks-only entry so repeated
    roofline/bench invocations stop re-measuring. The staleness check is
    the entry key itself — a different platform or device count never
    reuses foreign peaks."""
    path = default_calibration_path() if path is None else path
    key = platform_key(device_count)
    data = _read_artifact(path)
    entry = (data or {}).get("entries", {}).get(key)
    if entry and "peaks" in entry:
        return dict(entry["peaks"])
    peaks = measure(dim=dim, reps=reps)
    if write:
        try:
            _write_entry(path, key, {"peaks": peaks, "peaks_dim": dim})
        except OSError:
            pass  # read-only checkout: serve the measurement, skip caching
    return peaks


def _read_artifact(path: str) -> Optional[dict]:
    """The artifact dict, or None when missing/unreadable/stale-version."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(data, dict) \
            or data.get("version") != CALIBRATION_VERSION:
        return None
    return data


def _write_entry(path: str, key: str, entry: dict) -> None:
    data = _read_artifact(path) or {"version": CALIBRATION_VERSION,
                                    "entries": {}}
    merged = dict(data["entries"].get(key, {}))
    merged.update(entry)
    data["entries"][key] = merged
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


# --------------------------------------------------------------------------
# configuration / workload records
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CalibrationConfig:
    """The calibration suite's knobs (documented in docs/performance.md).

    n_grid / dim_grid: the (N, dim) grid each algo family's warm slot
        time is sampled on — the regressor of the linear step-time fit.
    steps / seeds: horizon and seed count of every calibration run
        (small: the suite measures warm steady state, not convergence).
    chunk_probe: seed_chunk of the chunked side of the dispatch probe
        (all-live vs chunked on one workload isolates per-call cost).
    probe_seeds: seed count of the working-set profile probe — large
        enough that the all-live side leaves cache on CI-class hosts.
    warm_reps: best-of repetitions per timed measurement.
    algos: algorithm families to fit (one coefficient pair each).
    peaks_dim: matmul size of the machine-peaks microbench.
    """

    n_grid: tuple = (64, 256, 1024)
    dim_grid: tuple = (8, 24)
    steps: int = 60
    seeds: int = 8
    chunk_probe: int = 2
    probe_seeds: int = 128
    warm_reps: int = 3
    algos: tuple = ("gbma", "blind")
    peaks_dim: int = 1536

    @classmethod
    def smoke(cls) -> "CalibrationConfig":
        """CI-size suite: every probe exercised, nothing slow."""
        return cls(n_grid=(16, 48), dim_grid=(4, 8), steps=20, seeds=4,
                   chunk_probe=2, probe_seeds=16, warm_reps=2,
                   peaks_dim=256)


@dataclasses.dataclass(frozen=True)
class Workload:
    """The cost-relevant shape of one engine call (padded batch view):
    `n_max` is the padded node count every row pays, `m_sizes` the
    antenna counts present (max is the padded M)."""

    n_rows: int
    seeds: int
    steps: int
    n_max: int
    dim: int
    algo_set: tuple = ("gbma",)
    m_sizes: tuple = ()
    b_max: int = 0


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CostModel:
    """Predicted engine-call cost under an `ExecPlan` (module docstring).

    coeffs: per-family nonnegative (c0_us, c1_us_per_flop) of the linear
        slot-time fit `step_us = c0 + c1 · slot_flops`.
    dispatch_us: fixed per-engine-call overhead (row assembly, jit
        dispatch, host transfer) — every seed chunk pays it once.
    compile_s: one XLA compile of an unseen program shape — consumers
        add it for shape classes they have not executed yet.
    chunk_profile: ((live_bytes, factor), ...) — measured slowdown of
        the slot time as the per-device live working set grows past
        cache; factors are non-decreasing in live_bytes by construction.
    peaks: microbenchmarked {peak_gflops, peak_gibs}.
    source: 'measured' (calibration artifact) or 'analytic' (fallback).
    """

    coeffs: tuple  # ((family, c0_us, c1_us), ...)
    dispatch_us: float
    compile_s: float
    chunk_profile: tuple  # ((live_bytes, factor), ...) sorted, monotone
    peaks: tuple  # (("peak_gflops", v), ("peak_gibs", v))
    source: str = "analytic"

    def _coeff(self, family: str) -> Optional[tuple]:
        for fam, c0, c1 in self.coeffs:
            if fam == family:
                return c0, c1
        return None

    def _profile_factor(self, live_bytes: float) -> float:
        prof = self.chunk_profile
        if not prof:
            return 1.0
        if live_bytes <= prof[0][0]:
            return prof[0][1]
        for (b0, f0), (b1, f1) in zip(prof, prof[1:]):
            if live_bytes <= b1:
                t = (live_bytes - b0) / max(b1 - b0, 1.0)
                return f0 + t * (f1 - f0)
        return prof[-1][1]  # clamp: beyond the probed range

    def step_us(self, algo: str, n: int, dim: int, m: int = 1,
                live_bytes: Optional[float] = None) -> float:
        """Predicted per-(row, seed, step) slot time in microseconds."""
        fam = _algo_family(algo)
        model = mc_slot_model(fam, n, dim, max(m, 1))
        co = self._coeff(fam)
        if co is not None:
            base = co[0] + co[1] * model["flops"]
        else:
            peaks = dict(self.peaks)
            base = _US * max(
                model["flops"] / (peaks["peak_gflops"] * 1e9),
                model["bytes"] / (peaks["peak_gibs"] * 2**30))
        if live_bytes is not None:
            base *= self._profile_factor(float(live_bytes))
        return base

    def _live_bytes(self, plan, wl: Workload,
                    device_count: Optional[int] = None) -> int:
        from repro.core.mc.exec import estimate_peak_bytes
        from repro.core.mc.plan import resolve_seed_shards

        n_sh = resolve_seed_shards(plan, wl.seeds,
                                   device_count=device_count)
        est = estimate_peak_bytes(
            n_rows=wl.n_rows, seeds=wl.seeds, steps=wl.steps,
            n_max=wl.n_max, dim=wl.dim, algo_set=tuple(wl.algo_set),
            seed_chunk=plan.seed_chunk, m_sizes=tuple(wl.m_sizes),
            b_max=wl.b_max, keep_seed_curves=False,
            rng_plan=plan.rng_plan, n_shards=max(n_sh, 1),
            row_shards=max(plan.row_shards, 1))
        return est["per_device_peak_bytes"]

    def predict_step_us(self, plan, wl: Workload,
                        device_count: Optional[int] = None) -> float:
        """Per-(row, seed, step) slot time of `wl` under `plan` — the
        padded n_max every row pays, at the plan's working set."""
        live = self._live_bytes(plan, wl, device_count)
        m = max(wl.m_sizes) if wl.m_sizes else 1
        return max(self.step_us(a, wl.n_max, wl.dim, m, live_bytes=live)
                   for a in wl.algo_set)

    def predict_run_us(self, plan, wl: Workload,
                       device_count: Optional[int] = None) -> float:
        """Total predicted wall-clock (µs) of one engine call under
        `plan`: the compute term divided over the plan's device mesh,
        plus `dispatch_us` per seed chunk. Monotone non-decreasing in
        N, seeds and steps (all coefficients are clamped ≥ 0)."""
        from repro.core.mc.plan import resolve_seed_shards

        step = self.predict_step_us(plan, wl, device_count)
        chunk = plan.seed_chunk if plan.seed_chunk else wl.seeds
        n_calls = -(-wl.seeds // max(chunk, 1))
        n_sh = resolve_seed_shards(plan, wl.seeds,
                                   device_count=device_count)
        mesh = max(n_sh, 1) * max(plan.row_shards, 1)
        compute = wl.n_rows * wl.seeds * wl.steps * step / mesh
        return compute + n_calls * self.dispatch_us


def analytic_cost_model(peaks: Optional[dict] = None) -> CostModel:
    """The calibration-free fallback: closed-form slot costs over nominal
    (or supplied) peaks, heuristic dispatch/compile/profile constants.
    Keeps every cost-model consumer functional when no artifact exists;
    `auto_plan` additionally pins its analytic *selection* path in that
    case (this model only serves the server's merge decisions)."""
    from repro.core.mc.plan import DEFAULT_CHUNK_TARGET_BYTES

    p = dict(_NOMINAL_PEAKS if peaks is None else peaks)
    return CostModel(
        coeffs=(),
        dispatch_us=500.0,
        compile_s=1.0,
        chunk_profile=((DEFAULT_CHUNK_TARGET_BYTES, 1.0),
                       (8 * DEFAULT_CHUNK_TARGET_BYTES, 2.0)),
        peaks=tuple(sorted(p.items())),
        source="analytic")


def load_cost_model(path: Optional[str] = None, *,
                    platform: Optional[str] = None,
                    device_count: Optional[int] = None
                    ) -> Optional[CostModel]:
    """The measured model from the calibration artifact, or None when the
    file is missing, its version is stale, or no entry matches this
    platform/device count (peaks-only entries don't count — they carry
    no fitted coefficients)."""
    path = default_calibration_path() if path is None else path
    data = _read_artifact(path)
    if data is None:
        return None
    entry = data.get("entries", {}).get(
        platform_key(device_count, platform))
    if not entry or "coeffs" not in entry:
        return None
    coeffs = tuple((fam, float(c["c0_us"]), float(c["c1_us"]))
                   for fam, c in sorted(entry["coeffs"].items()))
    profile = tuple((float(b), float(f))
                    for b, f in entry.get("chunk_profile", ()))
    return CostModel(
        coeffs=coeffs,
        dispatch_us=float(entry.get("dispatch_us", 500.0)),
        compile_s=float(entry.get("compile_s", 1.0)),
        chunk_profile=profile,
        peaks=tuple(sorted(entry.get("peaks", _NOMINAL_PEAKS).items())),
        source="measured")


# --------------------------------------------------------------------------
# the calibration suite
# --------------------------------------------------------------------------
def _calib_problem(n: int, dim: int, seed: int = 0):
    from repro.core.mc.problems import quadratic_mc_problem

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    return quadratic_mc_problem(x, y, 0.1, np.zeros(dim, np.float32))


def _timed_run(prob, algo: str, steps: int, seeds: int, *,
               seed_chunk: Optional[int] = None,
               warm_reps: int = 3) -> float:
    """Warm best-of wall-clock of one engine call (host results
    included — the figure every cost-model consumer actually pays)."""
    from repro.core.channel import ChannelConfig
    from repro.core.mc.engine import run_mc

    ch = ChannelConfig(fading="rayleigh", noise_std=0.5)
    m = 2 if _algo_family(algo) == "blind" else None

    def call():
        return run_mc(prob, [ch], algo, [0.05], steps, seeds,
                      n_antennas=m, seed_chunk=seed_chunk,
                      keep_seed_curves=True, shard_seeds=False)

    call()  # compile + warm-up
    best = float("inf")
    for _ in range(warm_reps):
        t0 = time.perf_counter()
        call()
        best = min(best, time.perf_counter() - t0)
    return best


def _fit_nonneg(x: np.ndarray, y: np.ndarray) -> tuple:
    """Least-squares line with both coefficients clamped ≥ 0 — the
    clamp is what makes every downstream prediction monotone."""
    x, y = np.asarray(x, float), np.asarray(y, float)
    vx = np.sum((x - x.mean()) ** 2)
    c1 = max(0.0, float(np.sum((x - x.mean()) * (y - y.mean())) / vx)) \
        if vx > 0 else 0.0
    c0 = max(0.0, float(y.mean() - c1 * x.mean()))
    return c0, c1


def calibrate(cfg: Optional[CalibrationConfig] = None, *,
              path: Optional[str] = None,
              device_count: Optional[int] = None,
              verbose: bool = False) -> dict:
    """Run the calibration suite and persist its artifact entry keyed by
    `<platform>/<device_count>`. Returns the entry dict. See the module
    docstring for what is measured; total runtime is dominated by one
    XLA compile per grid point (seconds each), not by the runs."""
    import jax

    from repro.core.mc.exec import estimate_peak_bytes

    cfg = CalibrationConfig() if cfg is None else cfg
    path = default_calibration_path() if path is None else path
    key = platform_key(device_count)

    def log(msg):
        if verbose:
            print(f"calibrate[{key}]: {msg}", flush=True)

    peaks = measure_machine_peaks(dim=cfg.peaks_dim)
    log(f"peaks: {peaks['peak_gflops']:.2f} GFLOP/s, "
        f"{peaks['peak_gibs']:.2f} GiB/s")

    samples, coeffs = [], {}
    for algo in cfg.algos:
        fam = _algo_family(algo)
        xs, ys = [], []
        for n in cfg.n_grid:
            for dim in cfg.dim_grid:
                prob = _calib_problem(n, dim)
                t = _timed_run(prob, algo, cfg.steps, cfg.seeds,
                               warm_reps=cfg.warm_reps)
                m = 2 if fam == "blind" else 1
                step_us = t / (cfg.steps * cfg.seeds) * _US
                flops = mc_slot_model(fam, n, dim, m)["flops"]
                xs.append(flops)
                ys.append(step_us)
                samples.append([algo, int(n), int(dim),
                                round(step_us, 3)])
                log(f"{algo} N={n} d={dim}: {step_us:.1f} us/slot")
        c0, c1 = _fit_nonneg(xs, ys)
        coeffs[fam] = {"c0_us": round(c0, 4), "c1_us": c1}
        log(f"{fam}: step_us = {c0:.2f} + {c1:.3e} * flops")

    # dispatch probe: the same tiny workload all-live vs chunked — the
    # per-call difference is row assembly + jit dispatch + host transfer
    n0, d0 = cfg.n_grid[0], cfg.dim_grid[0]
    prob0 = _calib_problem(n0, d0)
    t_live = _timed_run(prob0, "gbma", cfg.steps, cfg.seeds,
                        warm_reps=cfg.warm_reps)
    t_chunk = _timed_run(prob0, "gbma", cfg.steps, cfg.seeds,
                         seed_chunk=cfg.chunk_probe,
                         warm_reps=cfg.warm_reps)
    k = max(cfg.seeds // cfg.chunk_probe, 2)
    dispatch_us = max(50.0, (t_chunk - t_live) / (k - 1) * _US)
    log(f"dispatch: {dispatch_us:.0f} us/call")

    # working-set profile: warm step time vs per-device live bytes on a
    # probe workload, one point per seed_chunk (dispatch overhead
    # subtracted so the factor isolates the memory effect)
    n_p, d_p = cfg.n_grid[-1], cfg.dim_grid[-1]
    prob_p = _calib_problem(n_p, d_p)
    profile_pts = []
    chunks = sorted({max(1, cfg.probe_seeds // 16),
                     max(1, cfg.probe_seeds // 4), cfg.probe_seeds})
    for chunk in chunks:
        t = _timed_run(prob_p, "gbma", cfg.steps, cfg.probe_seeds,
                       seed_chunk=None if chunk >= cfg.probe_seeds
                       else chunk, warm_reps=cfg.warm_reps)
        calls = -(-cfg.probe_seeds // chunk)
        t_adj = max(t - (calls - 1) * dispatch_us / _US, 1e-9)
        live = estimate_peak_bytes(
            n_rows=1, seeds=cfg.probe_seeds, steps=cfg.steps, n_max=n_p,
            dim=d_p, algo_set=("gbma",),
            seed_chunk=None if chunk >= cfg.probe_seeds else chunk,
            keep_seed_curves=False)["per_device_peak_bytes"]
        step_us = t_adj / (cfg.steps * cfg.probe_seeds) * _US
        profile_pts.append((live, step_us))
        log(f"profile chunk={chunk}: {step_us:.1f} us/slot "
            f"@ {live / 2**20:.1f} MiB live")
    profile_pts.sort()
    base = min(s for _, s in profile_pts)
    factors = np.maximum.accumulate(
        [max(1.0, s / base) for _, s in profile_pts])
    chunk_profile = [[int(b), round(float(f), 4)]
                     for (b, _), f in zip(profile_pts, factors)]

    # compile probe: a grid-foreign shape's first call minus its warm
    # steady state — one fresh `_mc_core` trace at calibration scale
    n_c = cfg.n_grid[-1] + 1
    prob_c = _calib_problem(n_c, cfg.dim_grid[0])
    t0 = time.perf_counter()
    _timed_run(prob_c, "gbma", cfg.steps, cfg.seeds, warm_reps=1)
    t_cold_total = time.perf_counter() - t0
    t_warm_c = _timed_run(prob_c, "gbma", cfg.steps, cfg.seeds,
                          warm_reps=cfg.warm_reps)
    compile_s = max(0.05, t_cold_total - 2 * t_warm_c)
    log(f"compile: {compile_s:.2f} s")

    entry = {
        "config": dataclasses.asdict(cfg),
        "peaks": peaks,
        "peaks_dim": cfg.peaks_dim,
        "coeffs": coeffs,
        "dispatch_us": round(dispatch_us, 1),
        "compile_s": round(compile_s, 3),
        "chunk_profile": chunk_profile,
        "samples": samples,
        "jax_version": jax.__version__,
    }
    _write_entry(path, key, entry)
    log(f"artifact -> {path}")
    return entry


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Calibrate the MC cost model and persist the "
                    "versioned JSON artifact (module docstring).")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size suite (CalibrationConfig.smoke())")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: REPRO_CALIBRATION_PATH "
                         "or benchmarks/CALIBRATION_mc.json)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    cfg = CalibrationConfig.smoke() if args.smoke else CalibrationConfig()
    entry = calibrate(cfg, path=args.out, verbose=not args.quiet)
    coeffs = ", ".join(
        f"{fam}: {c['c0_us']:.2f}+{c['c1_us']:.2e}*flops us"
        for fam, c in entry["coeffs"].items())
    print(f"costmodel,calibrated,{platform_key()},{coeffs},"
          f"dispatch_us={entry['dispatch_us']},"
          f"compile_s={entry['compile_s']}")


if __name__ == "__main__":
    main()
