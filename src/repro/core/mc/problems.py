"""Problem registry for the Monte Carlo engine.

An engine problem is (a) a per-node gradient map `theta -> (N, d)` and (b) a
scalar risk metric `theta -> float`, both traceable. The engine batches
problems with different node counts into one compile by padding per-node
arrays to N_max (see `MCProblemBatch`), which needs three things per problem
*kind*: row-based grad/risk functions with stable identities (the jit cache
of `_mc_core` keys on them), the per-node data fields and their pad values,
and — for stochastic problems — a minibatch gradient that draws sample
indices inside the scan.

All of that lives in the open `PROBLEMS` registry: `register_problem(...)`
replaces the hard-coded `_ROW_FNS` / `_PER_NODE_FIELDS` dicts of the old
monolith, so a new workload is a registration plus a constructor — no
engine edits. Built-ins: `quadratic` (Eq. 27), `localization` (§VI-B), and
the stochastic `logistic` (federated logistic regression on a non-iid
partition, beyond-paper Fig. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """One registered problem kind.

    grad_row / risk_row take `(row, theta)` where `row` is the problem's
    data dict for one batch row (per-node leaves padded to N_max, plus the
    validity `row['mask']`); grad_row must return exactly-zero gradients
    for padded node rows (multiply by the mask). Identities must be stable
    (module-level functions), or every `run_mc` call recompiles the engine.

    pad_values maps each per-node data field to its pad constant — chosen
    so the padded rows stay FINITE before masking (0 * inf = nan would
    poison the row; e.g. localization pads sensor positions far away, not
    at the source).

    stochastic_grad_row, when given, makes the kind stochastic-capable:
    `(row, theta, key, b_count, b_max)` draws a size-`b_max` minibatch of
    per-node sample indices from `key` inside the scan, uses the first
    `b_count` (traced, per-row — an int32 lane count) lanes, and returns
    the minibatch gradient. `sample_axis_field` names the data field whose
    axis 1 is the per-node sample axis (sets the full-batch size the
    `batch_frac` knob scales).

    sample_indices_row / stochastic_grad_from_idx optionally split the
    stochastic gradient into its index draw (`(row, key, b_max) ->
    (n_max, b_max)` int indices) and the gradient over given indices
    (`(row, theta, idx, b_count)`), with stochastic_grad_row ≡ their
    composition. The split lets the execution layer's hoisted RNG plan
    (`mc/exec.py`) materialize the minibatch-index stream outside the
    scan like every channel stream; kinds registered without it simply
    keep drawing indices in-scan.
    """

    kind: str
    grad_row: Callable[[dict, Array], Array]
    risk_row: Callable[[dict, Array], Array]
    pad_values: dict
    stochastic_grad_row: Optional[Callable] = None
    sample_axis_field: Optional[str] = None
    sample_indices_row: Optional[Callable] = None
    stochastic_grad_from_idx: Optional[Callable] = None


PROBLEMS: dict = {}  # kind -> ProblemSpec, insertion-ordered


def register_problem(
    kind: str,
    grad_row: Callable[[dict, Array], Array],
    risk_row: Callable[[dict, Array], Array],
    pad_values: dict,
    *,
    stochastic_grad_row: Optional[Callable] = None,
    sample_axis_field: Optional[str] = None,
    sample_indices_row: Optional[Callable] = None,
    stochastic_grad_from_idx: Optional[Callable] = None,
    overwrite: bool = False,
) -> ProblemSpec:
    """Register a problem kind so library-built `MCProblem`s of that kind
    stack into padded node-count sweeps (and, with `stochastic_grad_row`,
    run minibatch SGD inside the scan — plus hoisted index draws when the
    `sample_indices_row`/`stochastic_grad_from_idx` split is supplied).
    Returns the spec."""
    if kind in PROBLEMS and not overwrite:
        raise ValueError(f"problem kind {kind!r} is already registered "
                         "(pass overwrite=True to replace it)")
    if (stochastic_grad_row is None) != (sample_axis_field is None):
        raise ValueError("stochastic_grad_row and sample_axis_field must "
                         "be given together")
    if (sample_indices_row is None) != (stochastic_grad_from_idx is None):
        raise ValueError("sample_indices_row and stochastic_grad_from_idx "
                         "must be given together")
    if sample_indices_row is not None and stochastic_grad_row is None:
        raise ValueError("the index/gradient split refines a "
                         "stochastic_grad_row; register that too")
    spec = ProblemSpec(kind=kind, grad_row=grad_row, risk_row=risk_row,
                       pad_values=dict(pad_values),
                       stochastic_grad_row=stochastic_grad_row,
                       sample_axis_field=sample_axis_field,
                       sample_indices_row=sample_indices_row,
                       stochastic_grad_from_idx=stochastic_grad_from_idx)
    PROBLEMS[kind] = spec
    return spec


# --------------------------------------------------------------------------
# problem containers
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MCProblem:
    """On-device problem: per-node gradients plus a scalar risk metric.

    grad_fn: theta (d,) -> (N, d) all nodes' local gradients.
    risk_fn: theta (d,) -> scalar excess risk / error, fully traceable.

    `kind`/`data` are filled by the library constructors
    (`quadratic_mc_problem`, `localization_mc_problem`,
    `logistic_mc_problem`) and let `MCProblemBatch.stack` pad several
    problems with different node counts into one batch. Hand-built problems
    may leave them unset; they then run on the closure path (single node
    count per call). `stochastic=True` (set when the registered kind has a
    `stochastic_grad_row`) lets `run_mc(batch_frac=...)` draw per-slot
    minibatches inside the scan.
    """

    grad_fn: Callable[[Array], Array]
    risk_fn: Callable[[Array], Array]
    dim: int
    n_nodes: int
    kind: str = ""
    data: Optional[dict] = None
    stochastic: bool = False


@dataclasses.dataclass(frozen=True)
class MCProblemBatch:
    """C problems stacked along a batch axis, node dims padded to N_max.

    data leaves carry a leading (C,) axis; per-node leaves are zero-padded
    to `n_max` and `data['mask']` (C, n_max) marks the valid rows. grad/risk
    take (row, theta) and are the registered `PROBLEMS[kind]` row fns.
    """

    kind: str
    grad_fn: Callable[[dict, Array], Array]
    risk_fn: Callable[[dict, Array], Array]
    data: dict
    n_nodes: tuple  # true node count per row (host ints)
    dim: int
    n_max: int
    stochastic: bool = False

    @classmethod
    def stack(cls, problems: Sequence[MCProblem]) -> "MCProblemBatch":
        kinds = {p.kind for p in problems}
        if len(kinds) != 1 or "" in kinds or problems[0].data is None:
            raise ValueError(
                "MCProblemBatch.stack needs library-built problems of one "
                f"kind (got kinds={sorted(kinds)}); hand-built MCProblems "
                "run on the closure path, one node count per call")
        kind = problems[0].kind
        if kind not in PROBLEMS:
            raise ValueError(
                f"problem kind {kind!r} is not registered; call "
                "register_problem(kind, grad_row, risk_row, pad_values)")
        if any(p.data is None for p in problems):
            raise ValueError(
                "every stacked problem needs a data dict (hand-built "
                "MCProblems without data run on the closure path)")
        dims = {p.dim for p in problems}
        if len(dims) != 1:
            raise ValueError(f"problems must share dim, got {sorted(dims)}")
        spec = PROBLEMS[kind]
        n_nodes = tuple(p.n_nodes for p in problems)
        n_max = max(n_nodes)
        pads = spec.pad_values
        leaves = {}
        for name in problems[0].data:
            rows = []
            for p in problems:
                leaf = p.data[name]
                if name in pads:
                    pad = [(0, n_max - p.n_nodes)] + [(0, 0)] * (leaf.ndim - 1)
                    leaf = jnp.pad(leaf, pad, constant_values=pads[name])
                rows.append(leaf)
            try:
                leaves[name] = jnp.stack(rows)
            except (ValueError, TypeError) as e:
                raise ValueError(
                    f"data field {name!r} does not stack across the batch "
                    f"(shapes {[np.shape(r) for r in rows]}); non-node "
                    "dims must match row-for-row") from e
        mask = np.zeros((len(problems), n_max), np.float32)
        for i, n in enumerate(n_nodes):
            mask[i, :n] = 1.0
        leaves["mask"] = jnp.asarray(mask)
        return cls(kind=kind, grad_fn=spec.grad_row, risk_fn=spec.risk_row,
                   data=leaves, n_nodes=n_nodes, dim=problems[0].dim,
                   n_max=n_max,
                   stochastic=any(p.stochastic for p in problems))

    def __len__(self) -> int:
        return len(self.n_nodes)

    @property
    def spec(self) -> ProblemSpec:
        return PROBLEMS[self.kind]


# --------------------------------------------------------------------------
# quadratic (regularized least squares, Eq. 27)
# --------------------------------------------------------------------------
def _quadratic_grad_row(row: dict, theta: Array) -> Array:
    resid = row["X"] @ theta - row["y"]
    g = resid[:, None] * row["X"] + row["lam"] * theta[None, :]
    return g * row["mask"][:, None]


def _quadratic_risk_row(row: dict, theta: Array) -> Array:
    diff = theta - row["theta_star"]
    return 0.5 * diff @ (row["H"] @ diff)


def quadratic_mc_problem(
    X: np.ndarray, y: np.ndarray, lam: float, theta_star: np.ndarray
) -> MCProblem:
    """Regularized least squares (Eq. 27), one sample per node.

    The excess risk uses the exact quadratic form around the minimizer:
    F(θ) - F* = 0.5 (θ-θ*)ᵀ (A + λI) (θ-θ*) with A = XᵀX/N — closed form,
    no F* cancellation, safe in f32.
    """
    n, d = X.shape
    H64 = X.T.astype(np.float64) @ X.astype(np.float64) / n + lam * np.eye(d)
    Xj, yj = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32)
    Hj = jnp.asarray(H64, jnp.float32)
    ts = jnp.asarray(theta_star, jnp.float32)

    def grad_fn(theta):
        return (Xj @ theta - yj)[:, None] * Xj + lam * theta[None, :]

    def risk_fn(theta):
        diff = theta - ts
        return 0.5 * diff @ (Hj @ diff)

    data = {"X": Xj, "y": yj, "H": Hj, "theta_star": ts,
            "lam": jnp.float32(lam)}
    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=d, n_nodes=n,
                     kind="quadratic", data=data)


# --------------------------------------------------------------------------
# localization (paper §VI-B)
# --------------------------------------------------------------------------
def _localization_grad_row(row: dict, theta: Array) -> Array:
    diff = theta[None, :] - row["r"]
    d2 = jnp.sum(diff**2, axis=1)
    resid = row["x"] - row["signal_a"] / d2
    g = (4.0 * row["signal_a"] * resid / d2**2)[:, None] * diff
    return g * row["mask"][:, None]


def _localization_risk_row(row: dict, theta: Array) -> Array:
    return jnp.sum((theta - row["src"]) ** 2)


def localization_mc_problem(
    r: np.ndarray, x: np.ndarray, src: np.ndarray, signal_a: float
) -> MCProblem:
    """Source localization of paper §VI-B; risk = squared position error."""
    rj, xj = jnp.asarray(r, jnp.float32), jnp.asarray(x, jnp.float32)
    srcj = jnp.asarray(src, jnp.float32)

    def grad_fn(theta):
        diff = theta[None, :] - rj  # (N, 2)
        d2 = jnp.sum(diff**2, axis=1)
        resid = xj - signal_a / d2
        return (4.0 * signal_a * resid / d2**2)[:, None] * diff

    def risk_fn(theta):
        return jnp.sum((theta - srcj) ** 2)

    data = {"r": rj, "x": xj, "src": srcj, "signal_a": jnp.float32(signal_a)}
    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=2,
                     n_nodes=r.shape[0], kind="localization", data=data)


# --------------------------------------------------------------------------
# logistic (federated logistic regression, stochastic-capable — Fig. 8)
# --------------------------------------------------------------------------
def _logistic_margin(row: dict, theta: Array) -> Array:
    """y_i <x_i, θ> per (node, local sample)."""
    return row["yn"] * jnp.einsum("nkf,f->nk", row["Xn"], theta)


def _logistic_grad_row(row: dict, theta: Array) -> Array:
    """Full-batch per-node gradient of the regularized logistic loss:
    g_n = (1/k) Σ_i −σ(−m_i) y_i x_i + λ θ, masked to zero on padded
    rows."""
    k = row["Xn"].shape[1]
    coef = -jax.nn.sigmoid(-_logistic_margin(row, theta)) * row["yn"]
    g = jnp.einsum("nk,nkf->nf", coef, row["Xn"]) / jnp.float32(k)
    g = g + row["lam"] * theta[None, :]
    return g * row["mask"][:, None]


def _logistic_sample_idx_row(row: dict, key: Array, b_max: int) -> Array:
    """The logistic minibatch index draw: (n_max, b_max) with-replacement
    per-node sample indices for one slot key.

    Index entry (n, j) draws as a SCALAR from
    `fold_in(fold_in(key, j), n)` rather than one (n_max, b_max)-shaped
    draw: threefry streams are shape-dependent, so a shaped draw would
    make each row's minibatch depend on the sweep-wide b_max AND n_max —
    per-(lane, node) scalar keys keep every entry identical across all
    sweep paddings, so one-compile fraction sweeps and node-count sweeps
    both reproduce their dedicated runs row-for-row (the same invariant
    `mc/sampling.py` maintains for the channel draws)."""
    n_max, k, _ = row["Xn"].shape
    nodes = jnp.arange(n_max, dtype=jnp.uint32)
    lane_keys = [jax.random.fold_in(key, j) for j in range(b_max)]
    return jnp.stack(
        [jax.vmap(lambda n, kj=kj: jax.random.randint(
            jax.random.fold_in(kj, n), (), 0, k))(nodes)
         for kj in lane_keys], axis=1)


def _logistic_sgrad_from_idx_row(row: dict, theta: Array, idx: Array,
                                 b_count: Array) -> Array:
    """Minibatch logistic gradient over ALREADY-DRAWN indices: uses the
    first `b_count` (traced int32 — the per-row `batch_frac` knob) of the
    idx lanes and averages. The lane count divides as float32 at this
    single use site; it is carried as int32 so large per-node sample
    counts survive exactly (a float32 lane count silently rounds above
    2^24)."""
    b_max = idx.shape[1]
    Xs = jnp.take_along_axis(row["Xn"], idx[:, :, None], axis=1)
    ys = jnp.take_along_axis(row["yn"], idx, axis=1)
    lane = (jnp.arange(b_max) < b_count).astype(jnp.float32)[None, :]
    m = ys * jnp.einsum("nbf,f->nb", Xs, theta)
    coef = -jax.nn.sigmoid(-m) * ys * lane
    g = jnp.einsum("nb,nbf->nf", coef, Xs) \
        / jnp.asarray(b_count, jnp.float32)
    g = g + row["lam"] * theta[None, :]
    return g * row["mask"][:, None]


def _logistic_sgrad_row(row: dict, theta: Array, key: Array,
                        b_count: Array, b_max: int) -> Array:
    """Minibatch twin of `_logistic_grad_row`: every node draws `b_max`
    with-replacement sample indices from ITS local shard (one key per
    slot), uses the first `b_count` (traced — the per-row `batch_frac`
    knob) lanes, and averages. At b_count == k this is an unbiased
    bootstrap estimate, not the full-batch gradient — the exact full-batch
    limit is the static `batch_frac == 1.0` path, which skips sampling
    entirely. Composition of the registered index/gradient split, so the
    in-scan and hoisted-index plans are value-identical."""
    idx = _logistic_sample_idx_row(row, key, b_max)
    return _logistic_sgrad_from_idx_row(row, theta, idx, b_count)


def _logistic_risk_row(row: dict, theta: Array) -> Array:
    """Excess risk F(θ) − F* of the GLOBAL objective: masked mean of
    log(1 + e^{−m}) over the row's true N·k samples plus the L2 term,
    minus the host-side F* (f64 Newton, stored in the data)."""
    loss = jnp.logaddexp(jnp.float32(0.0), -_logistic_margin(row, theta))
    w = row["mask"][:, None]
    n_samples = jnp.sum(row["mask"]) * row["Xn"].shape[1]
    f = jnp.sum(loss * w) / n_samples \
        + 0.5 * row["lam"] * jnp.sum(theta * theta)
    return f - row["f_star"]


def _logistic_solve(X: np.ndarray, y: np.ndarray, lam: float,
                    iters: int = 60) -> tuple:
    """Host-side f64 Newton solve of the regularized logistic objective;
    returns (theta_star, f_star)."""
    n, d = X.shape
    theta = np.zeros(d, np.float64)
    for _ in range(iters):
        m = y * (X @ theta)
        s = 1.0 / (1.0 + np.exp(m))  # σ(−m)
        grad = -(X.T @ (s * y)) / n + lam * theta
        w = s * (1.0 - s)
        H = (X.T * w) @ X / n + lam * np.eye(d)
        step = np.linalg.solve(H, grad)
        theta = theta - step
        if np.linalg.norm(step) < 1e-12:
            break
    f_star = float(np.mean(np.logaddexp(0.0, -y * (X @ theta)))
                   + 0.5 * lam * np.sum(theta**2))
    return theta, f_star


def logistic_mc_problem(
    X: np.ndarray, y: np.ndarray, n_nodes: int, lam: float = 0.1,
    *, noniid: bool = True,
) -> MCProblem:
    """Federated logistic regression on a label-sorted (non-iid) partition.

    The global batch is partitioned into `n_nodes` equal shards via
    `repro.data.federated` — label-sorted first when `noniid=True`, so each
    node's local distribution is skewed (the federated-SGD setting of
    Amiri & Gündüz, arXiv:1907.09769). Labels are ±1. The risk is the
    global excess objective F(θ) − F*, with F* from a host-side f64 Newton
    solve. The kind is stochastic-capable: `run_mc(batch_frac=...)` draws
    per-slot local minibatches inside the scan.
    """
    from repro.data.federated import partition_noniid, partition_rows

    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    if not np.all(np.abs(y) == 1.0):
        raise ValueError("logistic labels must be ±1")
    parts = (partition_noniid(X, y, n_nodes) if noniid
             else partition_rows(X, y, n_nodes))
    k = parts[0][0].shape[0]
    if any(px.shape[0] != k for px, _ in parts):
        raise ValueError(
            f"samples ({X.shape[0]}) must split evenly over {n_nodes} nodes")
    theta_star, f_star = _logistic_solve(X, y, lam)
    Xn = jnp.asarray(np.stack([px for px, _ in parts]), jnp.float32)
    yn = jnp.asarray(np.stack([py for _, py in parts]), jnp.float32)
    d = X.shape[1]
    data = {"Xn": Xn, "yn": yn, "lam": jnp.float32(lam),
            "f_star": jnp.float32(f_star),
            "theta_star": jnp.asarray(theta_star, jnp.float32)}
    full_mask = {"mask": jnp.ones((n_nodes, 1), jnp.float32)[:, 0]}

    def grad_fn(theta):
        return _logistic_grad_row({**data, **full_mask}, theta)

    def risk_fn(theta):
        return _logistic_risk_row({**data, **full_mask}, theta)

    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=d,
                     n_nodes=n_nodes, kind="logistic", data=data,
                     stochastic=True)


# --------------------------------------------------------------------------
# built-in registrations
# --------------------------------------------------------------------------
# Localization sensor positions pad far from the search region so the
# padded rows' 1/d² terms stay finite (they are masked to zero afterwards,
# but inf·0 would poison the row).
register_problem("quadratic", _quadratic_grad_row, _quadratic_risk_row,
                 {"X": 0.0, "y": 0.0})
register_problem("localization", _localization_grad_row,
                 _localization_risk_row, {"r": 1.0e6, "x": 0.0})
register_problem("logistic", _logistic_grad_row, _logistic_risk_row,
                 {"Xn": 0.0, "yn": 0.0},
                 stochastic_grad_row=_logistic_sgrad_row,
                 sample_axis_field="Xn",
                 sample_indices_row=_logistic_sample_idx_row,
                 stochastic_grad_from_idx=_logistic_sgrad_from_idx_row)


def _per_node_fields() -> dict:
    """Back-compat view of the old `_PER_NODE_FIELDS` dict (kind -> pad
    values), derived from the registry."""
    return {kind: dict(spec.pad_values) for kind, spec in PROBLEMS.items()}


def _row_fns() -> dict:
    """Back-compat view of the old `_ROW_FNS` dict (kind -> (grad, risk)),
    derived from the registry."""
    return {kind: (spec.grad_row, spec.risk_row)
            for kind, spec in PROBLEMS.items()}
