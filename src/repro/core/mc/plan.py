"""Execution plans: the explicit plan → place → run → reduce pipeline.

`run_mc` used to expose the execution layer as a bag of hand-set knobs
(`rng_plan`, `seed_chunk`, `keep_seed_curves`, `ota_impl`, `shard_seeds`).
An `ExecPlan` makes the whole execution strategy one explicit, inspectable
record:

  * **plan**   — `auto_plan(...)` derives every field from the analytic
    memory model (`exec.estimate_peak_bytes`), a device-memory budget and
    the visible device topology; or build an `ExecPlan` by hand.
  * **place**  — `n_shards` / `row_shards` lay the seed and sweep-row axes
    out over a real `(rows, mc)` device mesh (`compat.shard_map`). The
    hoisted counter-based RNG plan materializes each trajectory's streams
    *inside* the mapped region, so every device draws exactly the streams
    of the seeds it owns — chunk streams are location-independent by
    construction and curves do not depend on placement.
  * **run**    — the seed-chunked scheduler (`exec.run_chunked`) feeds
    chunks through one compiled program; `run_mc(resume_dir=...)`
    checkpoints the running moments between chunks (`repro.checkpoint`)
    so an interrupted sweep resumes bit-identically.
  * **reduce** — per-chunk two-pass moments merged with Chan's parallel
    algorithm (`exec.chan_merge`), tree-reduced across devices
    (`lax.psum` over the 'mc' axis) into donated accumulators.

The legacy kwargs still work: `run_mc` builds the equivalent plan from
them (behavior-pinned — see `engine.run_mc`). Pass `plan="auto"` to let
`auto_plan` choose, or an `ExecPlan` to pin every field.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import jax

# The CI-class container the scheduler is sized against (the same figure
# the benchmark's MEM_BUDGET_GIB uses) — the fallback when the backend
# does not report a device memory limit.
DEFAULT_MEMORY_BUDGET_BYTES = 2 * 2**30
# Per-device working-set target for chunk sizing: chunks small enough to
# run cache-resident on CPU-class devices (the measured regime of the
# `large_chunked` benchmark entry — ~100 MiB at the hand-tuned chunk=32),
# while staying big enough to amortize per-chunk dispatch.
DEFAULT_CHUNK_TARGET_BYTES = 128 * 2**20


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped-exponential-backoff retry budget for one seed chunk (or one
    serving quantum). A chunk that raises — injected fault, OOM, XLA
    error, executor death — is re-attempted up to `max_attempts` times
    total, waiting `delay_s(attempt)` between attempts. Counter-based RNG
    makes the retried chunk replay its exact streams, so a sweep that
    survives k faults within budget is bit-identical to the fault-free
    run (pinned in tests/test_fault_tolerance.py).

    max_attempts: total attempts per chunk (1 = no retry).
    base_delay_s: backoff before the 2nd attempt; doubles per attempt.
    cap_delay_s:  backoff ceiling.
    sleep:        injectable sleep callable (tests/serving pass a virtual
                  clock's sleep; None = `time.sleep`).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    cap_delay_s: float = 2.0
    sleep: Optional[Callable] = None

    def delay_s(self, attempt: int) -> float:
        """Backoff after failed attempt number `attempt` (1-based)."""
        return min(self.cap_delay_s,
                   self.base_delay_s * 2 ** max(attempt - 1, 0))

    def wait(self, attempt: int) -> None:
        (self.sleep if self.sleep is not None else time.sleep)(
            self.delay_s(attempt))


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """One sweep's complete execution strategy (see module docstring).

    rng_plan:   'hoisted' (counter-based streams materialized outside the
                scan) or 'inscan' (legacy per-slot draw chains).
    seed_chunk: run the seed axis in blocks of this size through one
                compiled program; None = all seeds live in a single call.
                Must divide the seed count.
    n_shards:   seed-axis placement — the 'mc' mesh axis size. None =
                auto (use every visible device when the live seed count
                divides evenly, like the legacy `shard_seeds=None`);
                0 or 1 = single-device; k >= 2 places each chunk's seed
                axis across k devices (k must divide the live seed count).
    row_shards: sweep-row placement — the 'rows' mesh axis size (must
                divide the row count). 1 = rows stay on the seed mesh.
    keep_seed_curves: False reduces per-seed curves to (mean, ci95) on
                device — Chan-merged moments under chunking.
    ota_impl:   'auto' | 'pallas' | 'ref' routing of the OTA slot.
    retry:      a `RetryPolicy` for chunk-level fault isolation in
                `exec.run_chunked` (None = fail fast, the legacy
                behavior). Retried chunks replay their counter-based RNG
                streams, so surviving a fault never perturbs results.
    """

    rng_plan: str = "hoisted"
    seed_chunk: Optional[int] = None
    n_shards: Optional[int] = None
    row_shards: int = 1
    keep_seed_curves: bool = True
    ota_impl: str = "auto"
    retry: Optional[RetryPolicy] = None

    def replace(self, **kw) -> "ExecPlan":
        """A copy with the given fields swapped (frozen dataclass)."""
        return dataclasses.replace(self, **kw)

    def asdict(self) -> dict:
        """Plain-dict view (benchmark/topology records). The retry
        policy's injectable sleep callable is not JSON material — it is
        recorded by qualname (or None)."""
        d = dataclasses.asdict(self)
        if d.get("retry") is not None and d["retry"].get("sleep") is not None:
            sleep = d["retry"]["sleep"]
            d["retry"]["sleep"] = getattr(sleep, "__qualname__", repr(sleep))
        return d


def validate_plan(plan: ExecPlan, *, seeds: int, n_rows: int) -> None:
    """Shape-level plan validation against one call's (seeds, rows)."""
    if plan.rng_plan not in ("hoisted", "inscan"):
        raise ValueError(
            f"rng_plan must be 'hoisted' or 'inscan', got {plan.rng_plan!r}")
    if plan.seed_chunk is not None:
        if plan.seed_chunk <= 0:
            raise ValueError(
                f"seed_chunk must be positive, got {plan.seed_chunk}")
        if seeds % plan.seed_chunk != 0:
            raise ValueError(
                f"seeds ({seeds}) must divide into seed_chunk "
                f"({plan.seed_chunk}) blocks — pad the seed count or pick "
                "a chunk that divides it")
    s_live = plan.seed_chunk if plan.seed_chunk is not None else seeds
    if plan.n_shards is not None and plan.n_shards > 1 \
            and s_live % plan.n_shards != 0:
        raise ValueError(
            f"n_shards={plan.n_shards} must divide the live seed count "
            f"({s_live} = seed_chunk or seeds)")
    if plan.row_shards < 1 or n_rows % plan.row_shards != 0:
        raise ValueError(
            f"row_shards={plan.row_shards} must be >= 1 and divide the "
            f"row count ({n_rows})")
    if plan.retry is not None:
        if plan.retry.max_attempts < 1:
            raise ValueError(
                f"retry.max_attempts must be >= 1, "
                f"got {plan.retry.max_attempts}")
        if plan.retry.base_delay_s < 0 or plan.retry.cap_delay_s < 0:
            raise ValueError(
                "retry delays must be nonnegative, got "
                f"base_delay_s={plan.retry.base_delay_s}, "
                f"cap_delay_s={plan.retry.cap_delay_s}")


def resolve_seed_shards(plan: ExecPlan, seeds: int,
                        device_count: Optional[int] = None) -> int:
    """The concrete 'mc' mesh size for this call: 0 = no seed placement.

    `n_shards=None` keeps the legacy auto rule (`shard_seeds=None`): every
    visible device when the live seed count divides evenly, else off.
    """
    s_live = plan.seed_chunk if plan.seed_chunk is not None else seeds
    ndev = jax.device_count() if device_count is None else device_count
    if plan.n_shards is None:
        return ndev if (ndev > 1 and s_live % ndev == 0) else 0
    n_sh = int(plan.n_shards)
    n_sh = 0 if n_sh <= 1 else n_sh
    if n_sh * plan.row_shards > ndev:
        raise ValueError(
            f"plan places {n_sh or 1} x {plan.row_shards} shards but only "
            f"{ndev} device(s) are visible — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=K to force "
            "host devices, or shrink the plan")
    return n_sh


def device_memory_budget_bytes() -> int:
    """Per-device memory budget: the backend-reported limit when available
    (TPU/GPU `memory_stats()['bytes_limit']`, at 80% headroom), else the
    CI-class default the scheduler is sized against."""
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and stats.get("bytes_limit"):
            return int(0.8 * stats["bytes_limit"])
    except Exception:
        pass
    return DEFAULT_MEMORY_BUDGET_BYTES


def _divisors_desc(n: int) -> list:
    ds = set()
    for i in range(1, int(math.isqrt(n)) + 1):
        if n % i == 0:
            ds.add(i)
            ds.add(n // i)
    return sorted(ds, reverse=True)


def auto_plan(*, n_rows: int, seeds: int, steps: int, n_max: int, dim: int,
              algo_set=("gbma",), n_antennas=None, m_sizes=(),
              b_max: int = 0, invert_channel: bool = False,
              participation_on: bool = False,
              keep_seed_curves: Optional[bool] = None,
              rng_plan: str = "hoisted", ota_impl: str = "auto",
              memory_budget_bytes: Optional[int] = None,
              target_chunk_bytes: Optional[int] = None,
              device_count: Optional[int] = None,
              cost_model: str = "analytic",
              calibration_path: Optional[str] = None,
              _model=None) -> ExecPlan:
    """Derive an `ExecPlan` from the workload, the analytic memory model
    and the device topology. Fully deterministic given its inputs: every
    returned field is concrete (no `None` placement), so the plan is a
    complete record of how the sweep will execute.

    Placement: the seed axis takes `gcd(seeds, device_count)` shards, the
    row axis the largest divisor of `n_rows` that fits the remaining
    devices — the full mesh is used whenever the axes divide.

    Chunking: the sweep chunks when the all-live per-device estimate
    (`exec.estimate_peak_bytes`) exceeds `target_chunk_bytes` (default
    128 MiB — the cache-resident regime the `large_chunked` benchmark
    measures); the chunk is the largest divisor of `seeds` (a multiple of
    the seed shards) whose per-device estimate fits the target, bounded
    by `memory_budget_bytes` in any case.

    `keep_seed_curves=None` resolves to False exactly when the plan
    chunks (the throughput configuration — only (C, steps+1) statistics
    transfer); pass True explicitly when per-seed curves are needed
    (`energy_to_target`).

    `cost_model="measured"` re-prices the seed-chunk choice with the
    calibration-fed cost model (`repro.core.mc.costmodel`): every
    shardable chunk that fits the memory budget is a candidate, ranked
    by `CostModel.predict_run_us` (compute at the measured slot rate ×
    the working-set profile factor, plus per-call dispatch). The choice
    is conservative: it deviates from the analytic chunk only when the
    predicted win exceeds 5% — microbench fits are not trusted for
    coin-flip margins. When no calibration artifact matches this
    platform/device count (`costmodel.load_cost_model` → None) the
    analytic path runs EXACTLY — behavior-pinned in
    `tests/test_costmodel.py`. `_model` injects a `CostModel` directly
    (tests); `calibration_path` overrides the artifact location.
    """
    from repro.core.mc.exec import estimate_peak_bytes

    if cost_model not in ("analytic", "measured"):
        raise ValueError(
            f"cost_model must be 'analytic' or 'measured', "
            f"got {cost_model!r}")

    ndev = jax.device_count() if device_count is None else int(device_count)
    budget = device_memory_budget_bytes() if memory_budget_bytes is None \
        else int(memory_budget_bytes)
    target = DEFAULT_CHUNK_TARGET_BYTES if target_chunk_bytes is None \
        else int(target_chunk_bytes)
    target = min(target, budget)

    n_sh = math.gcd(seeds, max(ndev, 1))
    row_sh = math.gcd(n_rows, max(ndev // max(n_sh, 1), 1))

    def per_device(chunk: Optional[int]) -> int:
        est = estimate_peak_bytes(
            n_rows=n_rows, seeds=seeds, steps=steps, n_max=n_max, dim=dim,
            algo_set=tuple(algo_set), seed_chunk=chunk,
            n_antennas=n_antennas, m_sizes=tuple(m_sizes), b_max=b_max,
            keep_seed_curves=False, rng_plan=rng_plan,
            invert_channel=invert_channel,
            participation_on=participation_on,
            n_shards=max(n_sh, 1), row_shards=max(row_sh, 1))
        return est["per_device_peak_bytes"]

    seed_chunk: Optional[int] = None
    if per_device(None) > target:
        fits_target = [c for c in _divisors_desc(seeds)
                       if c % max(n_sh, 1) == 0 and per_device(c) <= target]
        if fits_target:
            seed_chunk = fits_target[0]
        else:
            # nothing meets the cache target: fall back to the smallest
            # shardable chunk that at least fits the hard budget (or the
            # smallest chunk outright — best effort, never an error)
            candidates = [c for c in reversed(_divisors_desc(seeds))
                          if c % max(n_sh, 1) == 0]
            fits_budget = [c for c in candidates if per_device(c) <= budget]
            seed_chunk = (max(fits_budget) if fits_budget
                          else candidates[0])
        if seed_chunk >= seeds:
            seed_chunk = None  # chunking the full axis is the all-live call

    if cost_model == "measured":
        model = _model
        if model is None:
            from repro.core.mc import costmodel as _costmodel
            model = _costmodel.load_cost_model(calibration_path,
                                               device_count=ndev)
        if model is not None:
            from repro.core.mc.costmodel import Workload

            wl = Workload(n_rows=n_rows, seeds=seeds, steps=steps,
                          n_max=n_max, dim=dim, algo_set=tuple(algo_set),
                          m_sizes=tuple(m_sizes), b_max=b_max)

            def candidate(chunk: Optional[int]) -> ExecPlan:
                return ExecPlan(
                    rng_plan=rng_plan, seed_chunk=chunk,
                    n_shards=0 if n_sh <= 1 else n_sh,
                    row_shards=max(row_sh, 1),
                    keep_seed_curves=False, ota_impl=ota_impl)

            chunks = [None if c >= seeds else c
                      for c in _divisors_desc(seeds)
                      if c % max(n_sh, 1) == 0]
            fits = [c for c in chunks if per_device(c) <= budget]
            if fits:
                pred = {c: model.predict_run_us(candidate(c), wl,
                                                device_count=ndev)
                        for c in fits}
                best = min(fits, key=lambda c: (pred[c],
                                                -(c or seeds)))
                # conservative: keep the analytic chunk inside a 5%
                # prediction band — deviate only for a clear win
                if seed_chunk in pred \
                        and pred[seed_chunk] <= 1.05 * pred[best]:
                    best = seed_chunk
                seed_chunk = best

    if keep_seed_curves is None:
        keep_seed_curves = seed_chunk is None
    return ExecPlan(
        rng_plan=rng_plan, seed_chunk=seed_chunk,
        n_shards=0 if n_sh <= 1 else n_sh, row_shards=max(row_sh, 1),
        keep_seed_curves=bool(keep_seed_curves), ota_impl=ota_impl)
