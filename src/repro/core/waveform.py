"""Sample-level waveform simulation of the analog MAC (paper §III, Eq. 5–8).

This module exists to validate the *abstract* channel model used everywhere
else: nodes modulate their gradient entries onto d orthonormal baseband
waveforms s_m(t), transmit simultaneously, the edge receives the superposition
through per-node complex fading plus AWGN, and matched-filters with each
waveform. The matched-filter output must equal Eq. (7):

    v~_k[m] = sum_n sqrt(E_N) h_{n,k} g_n[m] + w~_k[m]

We build the orthonormal family from discrete cosines sampled at T_s; tests
assert the end-to-end pipeline agrees with the abstract model to numerical
precision, closing the loop between the physical layer and `core/gbma.py`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def shaping_waveforms(d: int, n_samples: int) -> Array:
    """d orthonormal discrete waveforms, shape (d, n_samples).

    Discrete cosine family: s_m[t] = sqrt(2/T) cos(pi (m+1/2)(t+1/2)/T) is an
    orthonormal basis of R^T (DCT-II rows); we take the first d rows. Requires
    n_samples >= d.
    """
    if n_samples < d:
        raise ValueError("need at least d samples for d orthogonal waveforms")
    t = jnp.arange(n_samples)[None, :] + 0.5
    m = jnp.arange(d)[:, None] + 0.5
    s = jnp.sqrt(2.0 / n_samples) * jnp.cos(jnp.pi * m * t / n_samples)
    return s  # rows orthonormal: s @ s.T = I_d


def transmit(
    grads: Array,  # (N, d) local gradients g_n(theta_k)
    gains: Array,  # (N,) complex or real channel gains h~_{n,k} (post phase-corr)
    waveforms: Array,  # (d, T)
    energy: float,
    noise_std: float,
    key: Array,
) -> Array:
    """Simulate Eq. (6): superposed received waveform r_k(t), shape (T,)."""
    amp = jnp.sqrt(jnp.asarray(energy, grads.dtype))
    # each node transmits sqrt(E_N) g_n^T s(t); channel multiplies by h_n
    per_node = amp * (grads @ waveforms)  # (N, T)
    rx = jnp.sum(gains[:, None] * per_node, axis=0)
    w = noise_std * jax.random.normal(key, rx.shape, dtype=rx.dtype)
    return rx + w


def matched_filter(rx: Array, waveforms: Array) -> Array:
    """Project r_k(t) on each s_m(t): returns v~_k, shape (d,) (Eq. 7)."""
    return waveforms @ rx


def edge_estimate(rx: Array, waveforms: Array, n_nodes: int, energy: float) -> Array:
    """Full edge processing: matched filter then 1/(N sqrt(E_N)) scaling (Eq. 8)."""
    return matched_filter(rx, waveforms) / (n_nodes * jnp.sqrt(jnp.asarray(energy)))
