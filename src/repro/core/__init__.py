"""Core contribution of the paper: GBMA over-the-air gradient aggregation."""
from repro.core.channel import (
    ChannelConfig,
    edge_noise_std,
    received_snr_db,
    sample_complex_gains,
    sample_gains,
)
from repro.core.gbma import (
    GBMAConfig,
    blind_ota_aggregate,
    GBMASimulator,
    gbma_value_and_grad,
    node_weights,
    ota_aggregate,
    perturb_gradients,
    shard_map_aggregate,
)
from repro.core.baselines import CentralizedGD, FDMGD, PowerControlOTA
from repro.core.montecarlo import (
    ChannelBatch,
    MCProblem,
    MCProblemBatch,
    MCResult,
    localization_mc_problem,
    logistic_mc_problem,
    quadratic_mc_problem,
    register_algo,
    register_problem,
    run_mc,
)
from repro.core import theory, waveform

__all__ = [
    "ChannelBatch",
    "ChannelConfig",
    "MCProblem",
    "MCProblemBatch",
    "MCResult",
    "localization_mc_problem",
    "logistic_mc_problem",
    "quadratic_mc_problem",
    "register_algo",
    "register_problem",
    "run_mc",
    "GBMAConfig",
    "GBMASimulator",
    "CentralizedGD",
    "FDMGD",
    "PowerControlOTA",
    "edge_noise_std",
    "received_snr_db",
    "sample_complex_gains",
    "sample_gains",
    "blind_ota_aggregate",
    "gbma_value_and_grad",
    "node_weights",
    "ota_aggregate",
    "perturb_gradients",
    "shard_map_aggregate",
    "theory",
    "waveform",
]
