"""Theorem 1/2 error bounds, stepsize design rules, and energy scaling laws
(paper §V). These are used to (a) pick provably-convergent stepsizes in the
experiments and (b) overlay theoretical bounds on the empirical error curves
(Figs. 2–3), validating the reproduction against the paper's own claims.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.channel import ChannelConfig


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Constants of the objective F = (1/N) Σ f_n (paper §II, §V)."""

    mu: float  # strong convexity of F
    L: float  # Lipschitz gradient of F
    L_bar: float  # max_n L_n over local losses
    delta: float  # diameter of the parameter set Theta
    r0_sq: float  # ||theta_0 - theta*||^2
    dim: int  # d


def stepsize_theorem1(pc: ProblemConstants, ch: ChannelConfig, n_nodes: int,
                      safety: float = 0.5) -> float:
    """Largest provably-valid constant stepsize under Eq. (15), scaled by
    `safety` (<1) to sit strictly inside the open interval."""
    mu_h, sh2 = ch.mu_h, ch.sigma_h2
    b1 = 2.0 / (mu_h * (pc.mu + pc.L))
    if sh2 <= 0.0:
        return safety * b1
    b2 = (2.0 * mu_h * pc.mu * pc.L * n_nodes) / (
        sh2 * pc.L_bar**2 * (1.0 + 2.0 * pc.delta) * (pc.mu + pc.L)
    )
    return safety * min(b1, b2)


def stepsize_theorem2(pc: ProblemConstants, ch: ChannelConfig,
                      safety: float = 0.5) -> float:
    """Constant stepsize under Eq. (18) (equal gains) / Eq. (20) (fading)."""
    return safety / (pc.L * max(ch.mu_h, 1e-12))


def contraction_c(beta: float, pc: ProblemConstants, ch: ChannelConfig,
                  n_nodes: int) -> float:
    """c = 1 - 2 beta mu_h mu L/(mu+L) + beta^2 sigma_h^2 Lbar^2 (1+2 delta)/N
    (Theorem 1). The linear-convergence contraction factor."""
    return (
        1.0
        - 2.0 * beta * ch.mu_h * pc.mu * pc.L / (pc.mu + pc.L)
        + beta**2 * ch.sigma_h2 * pc.L_bar**2 * (1.0 + 2.0 * pc.delta) / n_nodes
    )


def theorem1_bound(k: np.ndarray, beta: float, pc: ProblemConstants,
                   ch: ChannelConfig, n_nodes: int) -> np.ndarray:
    """RHS of Eq. (16): E[F(theta_k)] - F* bound for each iteration in `k`."""
    c = contraction_c(beta, pc, ch, n_nodes)
    if not (0.0 < c < 1.0):
        raise ValueError(f"contraction factor c={c:.4f} outside (0,1); "
                         "stepsize violates condition (15)")
    distortion = ch.sigma_h2 * pc.delta * pc.L_bar**2 * (2.0 + pc.delta) / n_nodes
    noise = pc.dim * ch.noise_std**2 / (ch.energy * n_nodes**2)
    steady = pc.L * beta**2 / (2.0 * (1.0 - c)) * (distortion + noise)
    return (c ** np.asarray(k, dtype=np.float64)) * pc.r0_sq * pc.L / 2.0 + steady


def theorem2_bound(k: np.ndarray, beta: float, pc: ProblemConstants,
                   ch: ChannelConfig, n_nodes: int, b_of_n: float,
                   equal_gains: bool = False) -> np.ndarray:
    """RHS of Eq. (19) (equal gains) or Eq. (21) (fading)."""
    k = np.asarray(k, dtype=np.float64)
    noise = pc.dim * ch.noise_std**2 / (ch.energy * n_nodes**2)
    if equal_gains:
        return pc.r0_sq / (2.0 * beta * k) + beta * noise
    mu_h = ch.mu_h
    distortion = b_of_n * ch.sigma_h2 / n_nodes
    return pc.r0_sq / (2.0 * beta * mu_h * k) + (beta / mu_h) * (distortion + noise)


def centralized_bound(k: np.ndarray, beta: float, pc: ProblemConstants) -> np.ndarray:
    """Centralized GD bound, Eq. (22), the benchmark rate."""
    c = 1.0 - 2.0 * beta * pc.mu * pc.L / (pc.mu + pc.L)
    return (c ** np.asarray(k, dtype=np.float64)) * pc.r0_sq * pc.L / 2.0


def energy_for_scaling(n_nodes: int, epsilon: float) -> float:
    """E_N = N^{epsilon-2}: the paper's sufficient per-node energy (§V-C.2)."""
    return float(n_nodes) ** (epsilon - 2.0)


def total_network_energy(n_nodes: int, e_n: float, grad_power: float = 1.0) -> float:
    """Total per-slot energy N * E_N * E[||g||^2]; under E_N = N^{eps-2} with
    eps < 1 this vanishes as N grows (paper Fig. 6)."""
    return n_nodes * e_n * grad_power


def quadratic_constants(A: np.ndarray, lam: float, theta0: np.ndarray,
                        theta_star: np.ndarray, delta: float) -> ProblemConstants:
    """Problem constants for the regularized least-squares objective (27):
    f_n = 0.5 (x_n^T theta - y_n)^2 + lam/2 ||theta||^2, where A = (1/N) X^T X.
    F's Hessian is A + lam I; per-node Hessians are x_n x_n^T + lam I.
    """
    eig = np.linalg.eigvalsh(A)
    mu = float(eig[0] + lam)
    L = float(eig[-1] + lam)
    return ProblemConstants(
        mu=mu,
        L=L,
        L_bar=L,  # callers with per-node rows should override with max_n ||x_n||^2+lam
        delta=delta,
        r0_sq=float(np.sum((theta0 - theta_star) ** 2)),
        dim=int(theta0.shape[0]),
    )
