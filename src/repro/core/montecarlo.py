"""Batched, jitted Monte Carlo engine for the paper experiments (Figs. 2–6).

The figures reproduce the expectation in Eq. (14) by averaging excess-risk
curves over seeds. The seed implementation looped over seeds in Python and
evaluated the objective per trajectory point on the host (numpy); this engine
runs the whole sweep as one compiled call:

    shard_map(seeds over 'mc' devices) ∘ vmap(rows) ∘ vmap(seeds) ∘ scan(steps)

with the excess-risk curve computed **on-device inside the scan**. For the
quadratic objective (27) the excess risk is the closed form
``0.5 (θ-θ*)ᵀ H (θ-θ*)`` (H = A + λI), which is exact — no cancellation
against F* — so the trajectory of estimates never leaves the device.

Algorithms (``algo=``) mirror the reference simulators step-for-step,
including their PRNG split order, so a fixed seed reproduces the trajectory
of `GBMASimulator.run` / `FDMGD.run` / `PowerControlOTA.run` up to float32
rounding (~1e-7 relative; a few host-side f64 scalar constants round
differently when computed in traced f32):

  * ``gbma``          — Eq. (8)–(9); an integer ``n_antennas`` gives the
                        MRC multi-antenna edge of related work [12].
  * ``centralized``   — noiseless benchmark GD.
  * ``fdm``           — orthogonal-channel GD (``invert_channel`` as in
                        `FDMGD`).
  * ``power_control`` — CA-DSGD-style truncated channel inversion [11].
  * ``momentum``      — GBMA aggregation + heavy-ball step
                        θ_{k+1} = θ_k − β m_{k+1}, m_{k+1} = γ m_k + v_k
                        (accelerated GD over MAC, Paul/Friedman/Cohen 2021).
  * ``nesterov``      — GBMA aggregation + Nesterov lookahead: the gradient
                        is evaluated at θ_k − βγ m_k.
  * ``blind``         — NO transmitter CSI (Amiri/Duman/Gündüz,
                        arXiv:1907.03909): nodes send the raw analog
                        gradient, the M-antenna edge MRC-combines with
                        receiver CSI; interference and noise vanish as 1/M
                        (channel hardening). Needs ``n_antennas``.
  * ``blind_ec``      — ``blind`` + local error accumulation
                        (arXiv:1907.09769): each node carries the part of
                        its update that the per-slot power budget
                        (``power_budget``, squared-norm units) truncated
                        and re-adds it next slot.

``n_antennas`` may be a per-row sequence: the antenna axis is padded to
M_max and each row's key split replays ``jax.random.split(key, m)`` for its
true m with the count as data, so an M-sweep batches in the same single
compile as everything else (see `_antenna_keys`).

A batch row is a (problem, channel params, algo, stepsize) tuple:

  * `ChannelBatch.stack` batches any mix of scale, noise_std, energy
    (e.g. the paper's E_N = N^{ε-2} sweep), phase error and Rician K;
    the fading *family* stays static (it picks the sampling code path).
  * `MCProblemBatch.stack` batches problems with *different node counts*:
    per-node arrays are zero-padded to N_max with a validity mask, and the
    random draws per row go through a `lax.switch` over the distinct true
    node counts so each row consumes *exactly* the draws the unpadded
    per-N run would (threefry streams are shape-dependent, so plain padded
    sampling would change the trajectories).
  * a per-row `algo` tuple batches algorithms the same way (one
    `lax.switch` per slot); RNG per branch matches the per-algo reference.

Hence fig2–fig6 N-sweeps and algorithm comparisons each run in ONE
`_mc_core` compile (`trace_count()` exposes the compile counter). The seed
axis is sharded over devices with `repro.compat.shard_map` on a `'mc'` mesh
axis when the seed count divides the device count — transparent (bit-equal,
no-op) on a single device.

Adding a new channel scenario = building new `ChannelConfig`s and calling
`run_mc`; no new per-figure script code (see docs/montecarlo.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.channel import ChannelConfig
from repro.core.theory import ProblemConstants, theorem1_bound

Array = jax.Array

ALGOS = ("gbma", "centralized", "fdm", "power_control", "momentum",
         "nesterov", "blind", "blind_ec")
# algos that receive the OTA superposition of Eq. (8) (MAC slot is shared)
_OTA_ALGOS = ("gbma", "momentum", "nesterov")
# no-CSI transmitters, M-antenna MRC edge (Amiri/Duman/Gündüz)
_BLIND_ALGOS = ("blind", "blind_ec")


# --------------------------------------------------------------------------
# problems
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MCProblem:
    """On-device problem: per-node gradients plus a scalar risk metric.

    grad_fn: theta (d,) -> (N, d) all nodes' local gradients.
    risk_fn: theta (d,) -> scalar excess risk / error, fully traceable.

    `kind`/`data` are filled by the library constructors
    (`quadratic_mc_problem`, `localization_mc_problem`) and let
    `MCProblemBatch.stack` pad several problems with different node counts
    into one batch. Hand-built problems may leave them unset; they then run
    on the closure path (single node count per call).
    """

    grad_fn: Callable[[Array], Array]
    risk_fn: Callable[[Array], Array]
    dim: int
    n_nodes: int
    kind: str = ""
    data: Optional[dict] = None


def quadratic_mc_problem(
    X: np.ndarray, y: np.ndarray, lam: float, theta_star: np.ndarray
) -> MCProblem:
    """Regularized least squares (Eq. 27), one sample per node.

    The excess risk uses the exact quadratic form around the minimizer:
    F(θ) - F* = 0.5 (θ-θ*)ᵀ (A + λI) (θ-θ*) with A = XᵀX/N — closed form,
    no F* cancellation, safe in f32.
    """
    n, d = X.shape
    H64 = X.T.astype(np.float64) @ X.astype(np.float64) / n + lam * np.eye(d)
    Xj, yj = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32)
    Hj = jnp.asarray(H64, jnp.float32)
    ts = jnp.asarray(theta_star, jnp.float32)

    def grad_fn(theta):
        return (Xj @ theta - yj)[:, None] * Xj + lam * theta[None, :]

    def risk_fn(theta):
        diff = theta - ts
        return 0.5 * diff @ (Hj @ diff)

    data = {"X": Xj, "y": yj, "H": Hj, "theta_star": ts,
            "lam": jnp.float32(lam)}
    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=d, n_nodes=n,
                     kind="quadratic", data=data)


def localization_mc_problem(
    r: np.ndarray, x: np.ndarray, src: np.ndarray, signal_a: float
) -> MCProblem:
    """Source localization of paper §VI-B; risk = squared position error."""
    rj, xj = jnp.asarray(r, jnp.float32), jnp.asarray(x, jnp.float32)
    srcj = jnp.asarray(src, jnp.float32)

    def grad_fn(theta):
        diff = theta[None, :] - rj  # (N, 2)
        d2 = jnp.sum(diff**2, axis=1)
        resid = xj - signal_a / d2
        return (4.0 * signal_a * resid / d2**2)[:, None] * diff

    def risk_fn(theta):
        return jnp.sum((theta - srcj) ** 2)

    data = {"r": rj, "x": xj, "src": srcj, "signal_a": jnp.float32(signal_a)}
    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=2,
                     n_nodes=r.shape[0], kind="localization", data=data)


# per-node leaves to pad when stacking, and the pad value. Localization
# sensor positions pad far from the search region so the padded rows'
# 1/d² terms stay finite (they are masked to zero afterwards, but inf·0
# would poison the row).
_PER_NODE_FIELDS = {
    "quadratic": {"X": 0.0, "y": 0.0},
    "localization": {"r": 1.0e6, "x": 0.0},
}

# module-level row-based grad/risk functions: stable identities keep the
# jit cache of `_mc_core` stable across `run_mc` calls.
def _quadratic_grad_row(row: dict, theta: Array) -> Array:
    resid = row["X"] @ theta - row["y"]
    g = resid[:, None] * row["X"] + row["lam"] * theta[None, :]
    return g * row["mask"][:, None]


def _quadratic_risk_row(row: dict, theta: Array) -> Array:
    diff = theta - row["theta_star"]
    return 0.5 * diff @ (row["H"] @ diff)


def _localization_grad_row(row: dict, theta: Array) -> Array:
    diff = theta[None, :] - row["r"]
    d2 = jnp.sum(diff**2, axis=1)
    resid = row["x"] - row["signal_a"] / d2
    g = (4.0 * row["signal_a"] * resid / d2**2)[:, None] * diff
    return g * row["mask"][:, None]


def _localization_risk_row(row: dict, theta: Array) -> Array:
    return jnp.sum((theta - row["src"]) ** 2)


_ROW_FNS = {
    "quadratic": (_quadratic_grad_row, _quadratic_risk_row),
    "localization": (_localization_grad_row, _localization_risk_row),
}


@dataclasses.dataclass(frozen=True)
class MCProblemBatch:
    """C problems stacked along a batch axis, node dims padded to N_max.

    data leaves carry a leading (C,) axis; per-node leaves are zero-padded
    to `n_max` and `data['mask']` (C, n_max) marks the valid rows. grad/risk
    take (row, theta) and are the module-level `_ROW_FNS[kind]`.
    """

    kind: str
    grad_fn: Callable[[dict, Array], Array]
    risk_fn: Callable[[dict, Array], Array]
    data: dict
    n_nodes: tuple  # true node count per row (host ints)
    dim: int
    n_max: int

    @classmethod
    def stack(cls, problems: Sequence[MCProblem]) -> "MCProblemBatch":
        kinds = {p.kind for p in problems}
        if len(kinds) != 1 or "" in kinds or problems[0].data is None:
            raise ValueError(
                "MCProblemBatch.stack needs library-built problems of one "
                f"kind (got kinds={sorted(kinds)}); hand-built MCProblems "
                "run on the closure path, one node count per call")
        kind = problems[0].kind
        dims = {p.dim for p in problems}
        if len(dims) != 1:
            raise ValueError(f"problems must share dim, got {sorted(dims)}")
        n_nodes = tuple(p.n_nodes for p in problems)
        n_max = max(n_nodes)
        pads = _PER_NODE_FIELDS[kind]
        leaves = {}
        for name in problems[0].data:
            rows = []
            for p in problems:
                leaf = p.data[name]
                if name in pads:
                    pad = [(0, n_max - p.n_nodes)] + [(0, 0)] * (leaf.ndim - 1)
                    leaf = jnp.pad(leaf, pad, constant_values=pads[name])
                rows.append(leaf)
            leaves[name] = jnp.stack(rows)
        mask = np.zeros((len(problems), n_max), np.float32)
        for i, n in enumerate(n_nodes):
            mask[i, :n] = 1.0
        leaves["mask"] = jnp.asarray(mask)
        grad_fn, risk_fn = _ROW_FNS[kind]
        return cls(kind=kind, grad_fn=grad_fn, risk_fn=risk_fn, data=leaves,
                   n_nodes=n_nodes, dim=problems[0].dim, n_max=n_max)

    def __len__(self) -> int:
        return len(self.n_nodes)


# --------------------------------------------------------------------------
# batched channel parameters
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChannelBatch:
    """Stack of C `ChannelConfig`s sharing one fading family.

    The family string is static (it selects the gain-sampling code path);
    everything else is a (C,) f32 array and vmaps in a single compile.
    """

    fading: str
    params: dict  # {'scale','noise_std','energy','phase_error_max','rician_k'}
    configs: tuple  # the original ChannelConfigs (host side, for bounds)

    @classmethod
    def stack(cls, cfgs: Sequence[ChannelConfig]) -> "ChannelBatch":
        fams = {c.fading for c in cfgs}
        if len(fams) != 1:
            raise ValueError(
                f"one ChannelBatch = one fading family, got {sorted(fams)}; "
                "issue one run_mc call per family")
        arr = lambda name: jnp.asarray(
            [getattr(c, name) for c in cfgs], jnp.float32)
        return cls(
            fading=cfgs[0].fading,
            params={
                "scale": arr("scale"),
                "noise_std": arr("noise_std"),
                "energy": arr("energy"),
                "phase_error_max": arr("phase_error_max"),
                "rician_k": arr("rician_k"),
            },
            configs=tuple(cfgs),
        )

    def __len__(self) -> int:
        return len(self.configs)


def _sample_magnitude(k_mag: Array, fading: str, p: dict,
                      shape: tuple) -> Array:
    """Traceable twin of `channel._sample_magnitude` over dynamic scalar
    params: the per-family |h~| draw, shared by the precoded sampler
    (`_sample_gains`) and the complex no-CSI one (`_sample_complex_gains`)."""
    scale = p["scale"]
    if fading == "equal":
        return jnp.broadcast_to(scale.astype(jnp.float32), shape)
    if fading == "rayleigh":
        u = jax.random.uniform(k_mag, shape, minval=1e-12, maxval=1.0)
        return scale * jnp.sqrt(-2.0 * jnp.log(u))
    if fading == "rician":
        nu = jnp.sqrt(p["rician_k"] * 2.0) * scale
        xy = jax.random.normal(k_mag, shape + (2,)) * scale
        return jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    if fading == "lognormal":
        return jnp.exp(scale * jax.random.normal(k_mag, shape))
    raise ValueError(f"unknown fading model: {fading}")


def _magnitude_m2(fading: str, p: dict) -> Array:
    """Traceable twin of `ChannelConfig.magnitude_m2`: E[h²] of the raw
    magnitude gain — the blind-MRC combiner's normalizer."""
    scale = p["scale"]
    if fading == "equal":
        return scale**2
    if fading == "rayleigh":
        return 2.0 * scale**2
    if fading == "rician":
        return 2.0 * scale**2 * (1.0 + p["rician_k"])
    if fading == "lognormal":
        return jnp.exp(2.0 * scale**2)
    raise ValueError(f"unknown fading model: {fading}")


def _sample_gains(key: Array, fading: str, p: dict, shape: tuple) -> Array:
    """Traceable twin of `channel.sample_gains` over dynamic scalar params.

    Split order and draw shapes match `sample_gains` exactly, so a fixed key
    yields the same random draws as the reference simulators (trajectories
    then agree to f32 rounding). The phase factor is applied
    unconditionally: with phase_error_max == 0 the uniform draw is 0 and
    cos(0) == 1, identical to the skipped branch.
    """
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, fading, p, shape)
    phi = jax.random.uniform(k_ph, shape, minval=-p["phase_error_max"],
                             maxval=p["phase_error_max"])
    return (h * jnp.cos(phi)).astype(jnp.float32)


def _sample_complex_gains(key: Array, fading: str, p: dict,
                          shape: tuple) -> tuple:
    """Traceable twin of `channel.sample_complex_gains`: (real, imag) parts
    of h~ = h e^{jφ} with the FULL uniform phase φ ~ Unif[-π, π) — no
    precoding in the blind-transmitter setting, so nothing bounds the
    phase. Same split order as the reference."""
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude(k_mag, fading, p, shape)
    phi = jax.random.uniform(k_ph, shape, minval=-np.pi, maxval=np.pi)
    return ((h * jnp.cos(phi)).astype(jnp.float32),
            (h * jnp.sin(phi)).astype(jnp.float32))


def _sample_gains_padded(key: Array, fading: str, p: dict,
                         n_sizes: tuple, n_max: int) -> Array:
    """(n_max,) gains whose first n entries equal the unpadded (n,) draw.

    Threefry streams depend on the draw shape, so sampling (n_max,) and
    masking would NOT reproduce the per-N reference draws. Instead the
    row's true node count (p['n_idx'] indexes the static `n_sizes`) selects
    a branch that samples at the true static shape and zero-pads. With a
    single full-size branch this is the plain sampler (no switch traced).
    """
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return _sample_gains(key, fading, p, (n_max,))
    branches = [
        (lambda k, n=n: jnp.pad(_sample_gains(k, fading, p, (n,)),
                                (0, n_max - n)))
        for n in n_sizes
    ]
    return jax.lax.switch(p["n_idx"], branches, key)


def _sample_complex_gains_padded(key: Array, fading: str, p: dict,
                                 n_sizes: tuple, n_max: int) -> tuple:
    """(a, b) complex-gain parts, zero-padded like `_sample_gains_padded`
    (per-N branches sample at the true static shape)."""
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return _sample_complex_gains(key, fading, p, (n_max,))
    branches = [
        (lambda k, n=n: jnp.pad(
            jnp.stack(_sample_complex_gains(k, fading, p, (n,))),
            ((0, 0), (0, n_max - n))))
        for n in n_sizes
    ]
    ab = jax.lax.switch(p["n_idx"], branches, key)
    return ab[0], ab[1]


def _normal_padded(key: Array, n_idx: Array, n_sizes: tuple, n_max: int,
                   d: int, dtype) -> Array:
    """(n_max, d) normal draw matching the unpadded (n, d) draw per row
    (same shape-dependent-stream issue as `_sample_gains_padded`)."""
    if len(n_sizes) == 1 and n_sizes[0] == n_max:
        return jax.random.normal(key, (n_max, d), dtype=dtype)
    branches = [
        (lambda k, n=n: jnp.pad(jax.random.normal(k, (n, d), dtype=dtype),
                                ((0, n_max - n), (0, 0))))
        for n in n_sizes
    ]
    return jax.lax.switch(n_idx, branches, key)


# --------------------------------------------------------------------------
# dynamic-length draws with static shapes (node-count sweeps, fast path)
#
# Threefry draws depend on the requested shape: `uniform(key, (n,))` hashes
# counter pairs (j, j + ceil(n/2)), so every distinct N needs its own draw
# program, and the `lax.switch` over those programs is what makes the padded
# sweep expensive to compile. But the counters are just uint32 DATA — by
# calling the raw threefry2x32 primitive on counter vectors computed from a
# *traced* n, one static-shape (n_max) program reproduces the (n,)-shaped
# draw bit-for-bit in lanes [0, n). The bits->float transforms below are
# copied from `jax._src.random._uniform` / `_normal_real` so the values
# match exactly. Only valid for the default threefry PRNG — callers must
# check `compat.threefry_is_default()` and fall back to the switch sampler.
# --------------------------------------------------------------------------
def _dynamic_bits(kd: Array, size: Array, out_max: int) -> Array:
    """uint32 bits equal to `random_bits(key, 32, (size,))` in lanes
    [0, size); `size` is traced (<= out_max), `out_max` static."""
    m_max = (out_max + 1) // 2
    m = (size + 1) // 2  # half-width of the counter vector (incl. odd pad)
    i = jnp.arange(m_max, dtype=jnp.int32)
    x0 = i.astype(jnp.uint32)
    # second counter half: j + m, with the odd-size pad slot hashed on 0
    x1 = jnp.where(i + m < size, i + m, 0).astype(jnp.uint32)
    # merge batch dims BEFORE the bind: the primitive's batching rule
    # mis-broadcasts when keys are vmapped over different axes (seeds,
    # steps) than the counts (configs). `| zero` stamps every operand with
    # the union of batch dims through ordinary elementwise batching (x1
    # carries the config dims via `m`; kd carries the seed/step dims).
    zero = (kd[0] & jnp.uint32(0)) | (x1 & jnp.uint32(0))
    o0, o1 = compat.threefry2x32(kd[0] | zero, kd[1] | zero,
                                 x0 | zero, x1 | zero)
    j = jnp.arange(out_max, dtype=jnp.int32)
    bits0 = o0[jnp.minimum(j, m_max - 1)]
    bits1 = o1[jnp.clip(j - m, 0, m_max - 1)]
    return jnp.where(j < m, bits0, bits1)


_F32_ONE_BITS = np.float32(1.0).view(np.uint32)
_NORMAL_LO = np.nextafter(np.float32(-1.0), np.float32(0.0))


def _bits_to_u01(bits: Array) -> Array:
    """uint32 bits -> uniform [0, 1) floats, as `_uniform` builds them."""
    fb = (bits >> jnp.uint32(9)) | jnp.uint32(_F32_ONE_BITS)
    return jax.lax.bitcast_convert_type(fb, jnp.float32) - jnp.float32(1.0)


def _u01_to_uniform(u01: Array, minval, maxval) -> Array:
    return jnp.maximum(minval, u01 * (maxval - minval) + minval)


def _u01_to_normal(u01: Array) -> Array:
    lo = jnp.float32(_NORMAL_LO)
    u = jnp.maximum(lo, u01 * (jnp.float32(1.0) - lo) + lo)
    return jnp.float32(np.sqrt(2.0)) * jax.lax.erf_inv(u)


def _normal_dynamic_n(key: Array, n: Array, n_max: int, d: int) -> Array:
    """Zero-padded (n_max, d) twin of `normal(key, (n, d))` for traced n
    (the fdm per-node noise on node-count sweeps) — same counts-as-data
    trick as `_sample_gains_dynamic_n`, so the scan body stays free of
    per-N `lax.switch` branches."""
    kd = jax.random.key_data(key)
    z = _u01_to_normal(_bits_to_u01(_dynamic_bits(kd, n * d, n_max * d)))
    z = jnp.where(jnp.arange(n_max * d) < n * d, z, jnp.float32(0.0))
    return z.reshape(n_max, d)


def _sample_magnitude_dynamic_n(kd_mag: Array, fading: str, p: dict,
                                n: Array, n_max: int) -> Array:
    """Dynamic-count twin of `_sample_magnitude` (traced n, static n_max);
    lanes ≥ n are garbage until the caller masks them."""
    scale = p["scale"]
    if fading == "equal":
        return jnp.broadcast_to(scale.astype(jnp.float32), (n_max,))
    if fading == "rayleigh":
        u01 = _bits_to_u01(_dynamic_bits(kd_mag, n, n_max))
        u = _u01_to_uniform(u01, jnp.float32(1e-12), jnp.float32(1.0))
        return scale * jnp.sqrt(-2.0 * jnp.log(u))
    if fading == "rician":
        nu = jnp.sqrt(p["rician_k"] * 2.0) * scale
        z = _u01_to_normal(_bits_to_u01(
            _dynamic_bits(kd_mag, 2 * n, 2 * n_max)))
        xy = z.reshape(n_max, 2) * scale
        return jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    if fading == "lognormal":
        z = _u01_to_normal(_bits_to_u01(_dynamic_bits(kd_mag, n, n_max)))
        return jnp.exp(scale * z)
    raise ValueError(f"unknown fading model: {fading}")


def _sample_gains_dynamic_n(key: Array, fading: str, p: dict,
                            n_max: int) -> Array:
    """Bit-exact twin of `_sample_gains(key, fading, p, (n,))` zero-padded
    to (n_max,), with n = p['n_nodes'] traced — one static-shape program
    covers every node count in the sweep."""
    n = p["n_nodes"].astype(jnp.int32)
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude_dynamic_n(jax.random.key_data(k_mag), fading, p,
                                    n, n_max)
    a = p["phase_error_max"]
    phi = _u01_to_uniform(
        _bits_to_u01(_dynamic_bits(jax.random.key_data(k_ph), n, n_max)),
        -a, a)
    h = (h * jnp.cos(phi)).astype(jnp.float32)
    return jnp.where(jnp.arange(n_max) < n, h, jnp.float32(0.0))


def _sample_complex_gains_dynamic_n(key: Array, fading: str, p: dict,
                                    n_max: int) -> tuple:
    """Dynamic-count twin of `_sample_complex_gains(key, fading, p, (n,))`
    zero-padded to (n_max,) — the blind family's per-antenna gain draw on
    node-count sweeps."""
    n = p["n_nodes"].astype(jnp.int32)
    k_mag, k_ph = jax.random.split(key)
    h = _sample_magnitude_dynamic_n(jax.random.key_data(k_mag), fading, p,
                                    n, n_max)
    phi = _u01_to_uniform(
        _bits_to_u01(_dynamic_bits(jax.random.key_data(k_ph), n, n_max)),
        jnp.float32(-np.pi), jnp.float32(np.pi))
    lane = jnp.arange(n_max) < n
    a = jnp.where(lane, (h * jnp.cos(phi)).astype(jnp.float32), 0.0)
    b = jnp.where(lane, (h * jnp.sin(phi)).astype(jnp.float32), 0.0)
    return a, b


def _dynamic_threefry_ok() -> bool:
    """Counts-as-data fast paths need the raw primitive AND the default
    threefry PRNG (the bit-level replication is only valid then)."""
    return compat.threefry2x32 is not None and compat.threefry_is_default()


def _row_gains(key: Array, fading: str, p: dict, n_sizes: tuple,
               n_max: int) -> Array:
    """This row's (n_max,) zero-padded slot gains: dynamic-count program
    when available (no per-N branches), per-N `lax.switch` otherwise."""
    if len(n_sizes) > 1 and _dynamic_threefry_ok():
        return _sample_gains_dynamic_n(key, fading, p, n_max)
    return _sample_gains_padded(key, fading, p, n_sizes, n_max)


def _row_complex_gains(key: Array, fading: str, p: dict, n_sizes: tuple,
                       n_max: int) -> tuple:
    """Complex counterpart of `_row_gains` for the blind family."""
    if len(n_sizes) > 1 and _dynamic_threefry_ok():
        return _sample_complex_gains_dynamic_n(key, fading, p, n_max)
    return _sample_complex_gains_padded(key, fading, p, n_sizes, n_max)


def _antenna_keys(key: Array, m_sizes: tuple, p: dict) -> Array:
    """(m_max,) antenna keys whose first m entries (m = this row's true
    antenna count, `p['n_antennas']`) equal `jax.random.split(key, m)`.

    Antenna counts suffer the same shape-dependent-stream problem as node
    counts: `split` is itself a threefry draw over `iota(2m)` counters, so
    splitting at m_max and masking would change every row's stream. The
    fast path replays the original split layout with the row's count as
    DATA (`_dynamic_bits` over 2m counters, reshaped (m_max, 2)); its
    validity is verified empirically by `compat.threefry_split_is_original`
    (False under `jax_threefry_partitionable`). The fallback is a
    `lax.switch` over the distinct static counts. Lanes ≥ m hold
    well-formed garbage keys — callers mask the antenna axis."""
    m_max = max(m_sizes)
    if len(m_sizes) == 1:
        return jax.random.split(key, m_max)
    if compat.threefry2x32 is not None \
            and compat.threefry_split_is_original():
        m = p["n_antennas"].astype(jnp.int32)
        bits = _dynamic_bits(jax.random.key_data(key), 2 * m, 2 * m_max)
        return jax.random.wrap_key_data(bits.reshape(m_max, 2))
    branches = [
        (lambda k, m=m: jnp.pad(
            jax.random.key_data(jax.random.split(k, m)),
            ((0, m_max - m), (0, 0))))
        for m in m_sizes
    ]
    return jax.random.wrap_key_data(
        jax.lax.switch(p["m_idx"], branches, key))


# --------------------------------------------------------------------------
# per-slot aggregation (mirrors the reference simulators' RNG usage)
# --------------------------------------------------------------------------
def _ota_slot(g: Array, key: Array, fading: str, p: dict,
              n_sizes: tuple, n_max: int, h_slot=None) -> Array:
    k_h, k_w = jax.random.split(key)
    h = _row_gains(k_h, fading, p, n_sizes, n_max) \
        if h_slot is None else h_slot
    v = jnp.einsum("n,nd->d", h, g) / p["n_nodes"]
    std = p["noise_std"] / (p["n_nodes"] * jnp.sqrt(p["energy"]))
    return v + std * jax.random.normal(k_w, v.shape, dtype=v.dtype)


def _slot_update(g: Array, key: Array, *, algo: str, fading: str, p: dict,
                 mask: Array, n_sizes: tuple, n_antennas: int,
                 m_sizes: tuple, invert_channel: bool, h_min: float,
                 h_slot=None) -> Array:
    """One MAC slot: transmitted per-node vectors (n_max, d) -> received
    update (d,).

    `g` is whatever the nodes put on the channel this slot — the masked
    local gradients for most algorithms; for `blind_ec` rows the scan body
    has already folded in the local residual and the power-budget
    truncation before calling here.

    Padded node rows carry exactly-zero vectors (the problem grad fns
    mask them) and zero-padded channel gains, so every per-node reduction
    normalizes by the row's true node count p['n_nodes'], and shaped noise
    draws (fdm) are masked before the node average.

    `m_sizes` non-empty means per-row antenna counts (`p['n_antennas']` is
    data, the antenna axis is padded to max(m_sizes) and masked); otherwise
    the static `n_antennas` broadcast applies.

    `h_slot` is this slot's pre-sampled gain vector when the caller hoisted
    the gain sampling out of the scan (node-count sweeps: the per-N
    `lax.switch` branches would otherwise be traced into the scan body and
    dominate XLA compile time). It is drawn from exactly the k_h this
    function would have split off, so the stream is unchanged.
    """
    n_max, n_true = g.shape[0], p["n_nodes"]
    if algo == "centralized":
        return jnp.sum(g, axis=0) / n_true
    if algo in _OTA_ALGOS:
        # n_antennas=None: single-antenna edge, RNG-identical to
        # `GBMASimulator`. An integer (1 included) takes the MRC path of
        # `ota_aggregate_multiantenna`, whose extra key split changes the
        # stream even for M=1 — mirrored so fixed seeds reproduce exactly.
        # Per-row counts (m_sizes) take the masked-MRC path: each row
        # consumes exactly the first m of its replayed split(key, m).
        if m_sizes:
            keys = _antenna_keys(key, m_sizes, p)
            v = jax.vmap(
                lambda k: _ota_slot(g, k, fading, p, n_sizes, n_max))(keys)
            amask = (jnp.arange(v.shape[0]) < p["n_antennas"]).astype(
                v.dtype)
            return jnp.einsum("m,md->d", amask, v) / p["n_antennas"]
        if n_antennas is None:
            return _ota_slot(g, key, fading, p, n_sizes, n_max, h_slot)
        keys = jax.random.split(key, n_antennas)
        v = jax.vmap(
            lambda k: _ota_slot(g, k, fading, p, n_sizes, n_max))(keys)
        return jnp.mean(v, axis=0)
    if algo in _BLIND_ALGOS:
        # Blind transmitters (1907.03909): nodes send g uncoded; antenna m
        # receives y_m = Σ_n h~_{n,m} g_n + z~_m (complex); the edge MRC-
        # combines with receiver CSI, normalized by M·E[h²] — mirrors
        # `gbma.blind_ota_aggregate` split-for-split.
        m2 = _magnitude_m2(fading, p)
        std = p["noise_std"] / jnp.sqrt(p["energy"])

        def antenna(k):
            k_h, k_w = jax.random.split(k)
            a, b = _row_complex_gains(k_h, fading, p, n_sizes, n_max)
            z = jax.random.normal(k_w, (2, g.shape[1]), dtype=g.dtype)
            y_r = jnp.einsum("n,nd->d", a, g) + std * z[0]
            y_i = jnp.einsum("n,nd->d", b, g) + std * z[1]
            return jnp.sum(a) * y_r + jnp.sum(b) * y_i

        if m_sizes:
            keys = _antenna_keys(key, m_sizes, p)
            m_true = p["n_antennas"]
        else:
            keys = jax.random.split(key, n_antennas)
            m_true = jnp.float32(n_antennas)
        s = jax.vmap(antenna)(keys)
        amask = (jnp.arange(s.shape[0]) < m_true).astype(g.dtype)
        return jnp.einsum("m,md->d", amask, s) / (m_true * n_true * m2)
    if algo == "fdm":
        k_h, k_w = jax.random.split(key)
        if len(n_sizes) > 1 and _dynamic_threefry_ok():
            raw = _normal_dynamic_n(
                k_w, p["n_nodes"].astype(jnp.int32), n_max, g.shape[1])
        else:
            raw = _normal_padded(
                k_w, p["n_idx"], n_sizes, n_max, g.shape[1], g.dtype)
        noise = p["noise_std"] / jnp.sqrt(p["energy"]) * raw
        if invert_channel:
            rx = g + noise
        else:
            h = _row_gains(k_h, fading, p, n_sizes, n_max) \
                if h_slot is None else h_slot
            rx = h[:, None] * g + noise
        return jnp.sum(rx * mask[:, None], axis=0) / n_true
    if algo == "power_control":
        k_h, k_w = jax.random.split(key)
        h = _row_gains(k_h, fading, p, n_sizes, n_max) \
            if h_slot is None else h_slot
        active = (h >= h_min).astype(g.dtype) * mask
        n_active = jnp.maximum(jnp.sum(active), 1.0)
        sup = jnp.einsum("n,nd->d", active, g)
        w = p["noise_std"] / (n_active * jnp.sqrt(p["energy"])) * (
            jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype))
        return sup / n_active + w
    raise ValueError(f"unknown algo {algo!r}; expected one of {ALGOS}")


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
@dataclasses.dataclass
class MCResult:
    """Host-side result of one engine call.

    risks:      (C, S, steps+1) per-row per-seed excess-risk curves.
    mean:       (C, steps+1) seed average (the Eq. 14 expectation estimate).
    ci95:       (C, steps+1) 1.96 * standard error over seeds (0 if S == 1).
    cum_energy: (C, S, steps) cumulative transmitted energy Σ E_N ||x_k||²
                of the actually-transmitted vectors — x_k = g_k for every
                algorithm except `blind_ec`, whose power budget truncates
                x_k = α(g_k + e_k).
    bounds:     (C, steps+1) Theorem-1 bound per row (None unless problem
                constants were supplied AND every row is single-antenna
                'gbma' — the setting Theorem 1 covers).
    """

    risks: np.ndarray
    mean: np.ndarray
    ci95: np.ndarray
    cum_energy: np.ndarray
    bounds: Optional[np.ndarray]


_TRACE_COUNT = 0


def trace_count() -> int:
    """Number of times `_mc_core` has been traced (== XLA compiles of the
    engine, since the python body runs once per jit cache miss)."""
    return _TRACE_COUNT


def clear_cache() -> bool:
    """Drop the engine's compiled-program cache (compile-count tests, cold
    benchmark timings). Returns False on JAX versions without jit
    clear_cache support — callers should then skip compile-count asserts."""
    if hasattr(_mc_core, "clear_cache"):
        _mc_core.clear_cache()
        return True
    return False


@functools.partial(
    jax.jit,
    static_argnames=("grad_fn", "risk_fn", "row_based", "algo_set", "fading",
                     "steps", "n_sizes", "n_antennas", "m_sizes",
                     "invert_channel", "h_min", "n_shards"),
)
def _mc_core(params, betas, theta0, seeds, data, *, grad_fn, risk_fn,
             row_based, algo_set, fading, steps, n_sizes, n_antennas,
             m_sizes, invert_channel, h_min, n_shards):
    """(C,)-batched rows × (S,) seeds × scan(steps), seeds sharded on 'mc'.

    `algo_set` is the deduped algorithm tuple; the row-to-algorithm
    assignment is traced data (params['algo_idx']), so re-assigning rows
    among the same algorithms reuses the compiled program. Rows sharing one
    algorithm skip the dispatch switch. The momentum carry unifies all step
    rules: m_{k+1} = γ m_k + v_k and θ_{k+1} = θ_k − β m_{k+1} reduce
    bit-exactly to vanilla GD at γ = 0 (0·m = 0, 0 + v = v), and the
    Nesterov lookahead θ − nest·βγ·m is exactly θ when the row's nest flag
    is 0.

    When `algo_set` contains 'blind_ec' the scan carry additionally holds
    the per-node residual e (n_max, d): rows flagged p['ec']=1 transmit
    x = α(g + e) with the power-budget scaling α = min(1, √(B/‖g+e‖²))
    per node and carry e ← (g+e) − x forward (error accumulation of
    1907.09769); all other rows select α = 1 and reduce bit-exactly to
    x = g — even when their own α expression is NaN (an overflowing row
    under the default unbounded budget hits inf/inf). The transmitted
    energy is always computed from x — identical to the g-based accounting
    whenever no truncation happened.
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # python side effect: runs once per trace/compile

    # gains-consuming slot types, single-antenna: eligible for hoisting the
    # per-N sampling switch out of the scan (see `hoist` below)
    hoistable = n_antennas is None and not m_sizes and any(
        a in _OTA_ALGOS or a == "power_control"
        or (a == "fdm" and not invert_channel) for a in algo_set)
    use_ec = "blind_ec" in algo_set

    def trajectory(p, beta, row, seed, t0):
        key = jax.random.key(seed)

        def slot(g, k, h_slot):
            if len(algo_set) == 1:
                return _slot_update(
                    g, k, algo=algo_set[0], fading=fading, p=p,
                    mask=row["mask"], n_sizes=n_sizes, n_antennas=n_antennas,
                    m_sizes=m_sizes, invert_channel=invert_channel,
                    h_min=h_min, h_slot=h_slot)
            branches = [
                (lambda kk, a=a: _slot_update(
                    g, kk, algo=a, fading=fading, p=p, mask=row["mask"],
                    n_sizes=n_sizes, n_antennas=n_antennas, m_sizes=m_sizes,
                    invert_channel=invert_channel, h_min=h_min,
                    h_slot=h_slot))
                for a in algo_set
            ]
            return jax.lax.switch(p["algo_idx"], branches, k)

        def body(carry, x):
            k, h_slot = x
            if use_ec:
                theta, m, e_res, cum_e = carry
            else:
                theta, m, cum_e = carry
            theta_eval = theta - p["nest"] * beta * p["gamma"] * m
            g = (grad_fn(row, theta_eval) if row_based
                 else grad_fn(theta_eval))
            risk = risk_fn(row, theta) if row_based else risk_fn(theta)
            if use_ec:
                u = g + p["ec"] * e_res
                sq = jnp.sum(u * u, axis=1)
                alpha = jnp.minimum(1.0, jnp.sqrt(
                    p["tx_budget"] / jnp.maximum(sq, 1e-30)))
                # select, don't blend: inf/inf above is NaN (e.g. an
                # overflowing row with the default unbounded budget) and
                # 0*NaN would leak it into ec=0 rows
                alpha = jnp.where(p["ec"] > 0, alpha, 1.0)
                x_tx = alpha[:, None] * u
                e_res = p["ec"] * (u - x_tx)
            else:
                x_tx = g
            cum_e = cum_e + p["energy"] * jnp.sum(
                x_tx.astype(jnp.float32) ** 2)
            v = slot(x_tx, k, h_slot)
            m = p["gamma"] * m + v
            theta = theta - beta * m
            carry = (theta, m, e_res, cum_e) if use_ec \
                else (theta, m, cum_e)
            return carry, (risk, cum_e)

        step_keys = jax.random.split(key, steps)
        h_all = None
        if len(n_sizes) > 1 and hoistable:
            # Node-count sweep: sample every slot's gains up front, once,
            # instead of tracing the per-N `lax.switch` branches into the
            # scan body (which multiplies the XLA program and its compile
            # time — the very cost the padded N axis exists to remove).
            # Stream-identical: each step key is split exactly as
            # `_slot_update` would split it, and the k_h half feeds the
            # same padded sampler. The dynamic-count sampler (one
            # static-shape threefry program for all N) is preferred; the
            # per-N `lax.switch` sampler is the fallback when the raw
            # primitive is unavailable or a non-threefry PRNG is active.
            n_max_ = row["mask"].shape[0]
            k_hs = jax.vmap(lambda k: jax.random.split(k)[0])(step_keys)
            if _dynamic_threefry_ok():
                sample = lambda kh: _sample_gains_dynamic_n(
                    kh, fading, p, n_max_)
            else:
                sample = lambda kh: _sample_gains_padded(
                    kh, fading, p, n_sizes, n_max_)
            h_all = jax.vmap(sample)(k_hs)
        carry0 = (t0, jnp.zeros_like(t0), jnp.float32(0.0))
        if use_ec:
            carry0 = (t0, jnp.zeros_like(t0),
                      jnp.zeros((row["mask"].shape[0], t0.shape[0]),
                                jnp.float32), jnp.float32(0.0))
        carry_fin, (risks, cum_e) = jax.lax.scan(
            body, carry0, (step_keys, h_all))
        theta_fin = carry_fin[0]
        fin = risk_fn(row, theta_fin) if row_based else risk_fn(theta_fin)
        risks = jnp.concatenate([risks, fin[None]])
        return risks, cum_e  # (steps+1,), (steps,)

    def seed_block(seeds_blk, params, betas, theta0, data):
        per_config = jax.vmap(
            lambda p, b, row: jax.vmap(
                lambda s: trajectory(p, b, row, s, theta0))(seeds_blk))
        return per_config(params, betas, data)

    if n_shards > 0:
        mesh = compat.make_mesh((n_shards,), ("mc",))
        seed_block = compat.shard_map(
            seed_block, mesh=mesh,
            in_specs=(P("mc"), P(), P(), P(), P()),
            out_specs=(P(None, "mc"), P(None, "mc")))
    return seed_block(seeds, params, betas, theta0, data)


def _resolve_n_shards(n_seeds: int, shard_seeds: Optional[bool]) -> int:
    """0 = plain path; k > 0 = shard_map over a ('mc',) mesh of k devices."""
    if shard_seeds is False:
        return 0
    ndev = jax.device_count()
    if shard_seeds is None:
        return ndev if (ndev > 1 and n_seeds % ndev == 0) else 0
    if n_seeds % ndev != 0:
        raise ValueError(
            f"shard_seeds=True needs seeds ({n_seeds}) divisible by the "
            f"device count ({ndev})")
    return ndev


def run_mc(
    problem: Union[MCProblem, MCProblemBatch, Sequence[MCProblem]],
    channels: Sequence[ChannelConfig] | ChannelBatch,
    algo: str | Sequence[str],
    betas: Sequence[float] | np.ndarray,
    steps: int,
    seeds: int,
    *,
    theta0: Optional[np.ndarray] = None,
    seed0: int = 0,
    n_antennas: Optional[Union[int, Sequence[int]]] = None,
    invert_channel: bool = False,
    h_min: float = 0.3,
    pc: Optional[Union[ProblemConstants,
                       Sequence[ProblemConstants]]] = None,
    momentum: float = 0.9,
    power_budget: Optional[Union[float, Sequence[float]]] = None,
    shard_seeds: Optional[bool] = None,
) -> MCResult:
    """Run `seeds` Monte Carlo trajectories for each batch row.

    A row is a (problem, channel, algo, stepsize) tuple; `problem` and
    `algo` broadcast when a single one is given. Passing a sequence of
    problems (node counts may differ — they are padded to N_max) or a
    sequence of algos runs the whole sweep in ONE engine compile.

    Seed s uses `jax.random.key(seed0 + s)` — the same stream the sequential
    reference path (`benchmarks.common.average_runs`) consumes, so results
    are directly comparable. With `pc` supplied (one `ProblemConstants` or
    one per row) the Theorem-1 bound rides along — only when every row is
    single-antenna 'gbma', the setting Theorem 1 covers; mixed-algo calls
    get `bounds=None`.

    `n_antennas`: the edge antenna count M. An int broadcasts (static;
    OTA algos take the MRC path, blind algos combine over M). A sequence
    gives one M per row AS DATA — the antenna axis pads to max(M) and an
    M-sweep batches into the same single compile (each row's key split
    replays `split(key, m)` for its true m). Required for blind/blind_ec.

    `power_budget`: per-slot, per-node transmit budget in squared-norm
    units of the transmitted vector (scalar or one per row; default
    unbounded). Only `blind_ec` rows enforce it, carrying the truncated
    remainder in their local residual.

    `shard_seeds` shards the seed axis over devices on a 'mc' mesh axis
    (None: auto when divisible; no-op on one device).
    """
    ch_batch = channels if isinstance(channels, ChannelBatch) \
        else ChannelBatch.stack(list(channels))
    n_rows = len(ch_batch)
    betas = jnp.asarray(betas, jnp.float32)
    if betas.shape != (n_rows,):
        raise ValueError(f"need one stepsize per row: "
                         f"{betas.shape} vs C={n_rows}")
    algos = (algo,) * n_rows if isinstance(algo, str) else tuple(algo)
    if len(algos) != n_rows:
        raise ValueError(f"need one algo per row: {len(algos)} vs C={n_rows}")
    for a in algos:
        if a not in ALGOS:
            raise ValueError(f"unknown algo {a!r}; expected one of {ALGOS}")

    # ---- normalize the antenna axis ------------------------------------
    if n_antennas is None or isinstance(n_antennas, (int, np.integer)):
        if n_antennas is not None:
            n_antennas = int(n_antennas)
        m_per_row, m_sizes = None, ()
    else:
        m_per_row = tuple(int(m) for m in n_antennas)
        if len(m_per_row) != n_rows:
            raise ValueError(f"need one antenna count per row: "
                             f"{len(m_per_row)} vs C={n_rows}")
        if any(m < 1 for m in m_per_row):
            raise ValueError(f"antenna counts must be >= 1: {m_per_row}")
        m_sizes = tuple(sorted(set(m_per_row)))
        n_antennas = None  # the static broadcast arg is off in per-row mode
    if any(a in _BLIND_ALGOS for a in algos) \
            and n_antennas is None and not m_sizes:
        raise ValueError(
            "blind/blind_ec need n_antennas (the edge antenna count M)")

    # ---- normalize the problem axis ------------------------------------
    if isinstance(problem, MCProblemBatch):
        batch_prob = problem
    elif isinstance(problem, MCProblem):
        batch_prob = None  # closure path: one problem shared by all rows
    else:
        probs = list(problem)
        if len(probs) == 1:
            batch_prob = None
            problem = probs[0]
        else:
            if len(probs) != n_rows:
                raise ValueError(
                    f"need one problem per row: {len(probs)} vs C={n_rows}")
            batch_prob = MCProblemBatch.stack(probs)

    if batch_prob is not None:
        row_based = True
        grad_fn, risk_fn = batch_prob.grad_fn, batch_prob.risk_fn
        data = dict(batch_prob.data)
        n_nodes = batch_prob.n_nodes
        dim, n_max = batch_prob.dim, batch_prob.n_max
    else:
        row_based = False
        grad_fn, risk_fn = problem.grad_fn, problem.risk_fn
        n_nodes = (problem.n_nodes,) * n_rows
        dim, n_max = problem.dim, problem.n_nodes
        data = {"mask": jnp.ones((n_rows, n_max), jnp.float32)}

    n_sizes = tuple(sorted(set(n_nodes)))
    algo_set = tuple(dict.fromkeys(algos))
    params = dict(ch_batch.params)
    params["n_nodes"] = jnp.asarray(n_nodes, jnp.float32)
    params["n_idx"] = jnp.asarray(
        [n_sizes.index(n) for n in n_nodes], jnp.int32)
    params["algo_idx"] = jnp.asarray(
        [algo_set.index(a) for a in algos], jnp.int32)
    params["gamma"] = jnp.asarray(
        [momentum if a in ("momentum", "nesterov") else 0.0 for a in algos],
        jnp.float32)
    params["nest"] = jnp.asarray(
        [1.0 if a == "nesterov" else 0.0 for a in algos], jnp.float32)
    params["ec"] = jnp.asarray(
        [1.0 if a == "blind_ec" else 0.0 for a in algos], jnp.float32)
    if power_budget is None:
        budgets = (float("inf"),) * n_rows
    elif isinstance(power_budget, (int, float, np.integer, np.floating)):
        budgets = (float(power_budget),) * n_rows
    else:
        budgets = tuple(float(b) for b in power_budget)
        if len(budgets) != n_rows:
            raise ValueError(f"need one power budget per row: "
                             f"{len(budgets)} vs C={n_rows}")
    params["tx_budget"] = jnp.asarray(budgets, jnp.float32)
    if m_sizes:
        params["n_antennas"] = jnp.asarray(m_per_row, jnp.float32)
        params["m_idx"] = jnp.asarray(
            [m_sizes.index(m) for m in m_per_row], jnp.int32)

    t0 = jnp.zeros((dim,), jnp.float32) if theta0 is None \
        else jnp.asarray(theta0, jnp.float32)
    seed_ints = jnp.arange(seed0, seed0 + seeds, dtype=jnp.int32)
    n_shards = _resolve_n_shards(seeds, shard_seeds)
    risks, cum_e = _mc_core(
        params, betas, t0, seed_ints, data,
        grad_fn=grad_fn, risk_fn=risk_fn, row_based=row_based,
        algo_set=algo_set, fading=ch_batch.fading, steps=steps,
        n_sizes=n_sizes, n_antennas=n_antennas, m_sizes=m_sizes,
        invert_channel=invert_channel, h_min=h_min, n_shards=n_shards)
    risks = np.asarray(risks)
    mean = np.mean(risks, axis=1)
    if seeds > 1:
        ci95 = 1.96 * np.std(risks, axis=1, ddof=1) / np.sqrt(seeds)
    else:
        ci95 = np.zeros_like(mean)
    bounds = None
    if pc is not None:
        pcs = [pc] * n_rows if isinstance(pc, ProblemConstants) else list(pc)
        if len(pcs) != n_rows:
            raise ValueError(f"need one ProblemConstants per row: "
                             f"{len(pcs)} vs C={n_rows}")
        if all(a == "gbma" for a in algos) and n_antennas is None \
                and not m_sizes:
            ks = np.arange(1, steps + 2)
            bounds = np.stack([
                theorem1_bound(ks, float(b), row_pc, cfg, n)
                for b, cfg, row_pc, n in zip(
                    np.asarray(betas), ch_batch.configs, pcs, n_nodes)])
    return MCResult(
        risks=risks, mean=mean.astype(np.float32),
        ci95=ci95.astype(np.float32), cum_energy=np.asarray(cum_e),
        bounds=bounds)


def energy_to_target(res: MCResult, target: float) -> np.ndarray:
    """Per-row mean (over seeds) total transmitted energy until the risk
    curve first hits `target` (paper Fig. 6).

    risks[k] is the risk of θ_k, reached after k transmission slots, and
    cum_energy[j] is the energy of slots 1..j+1 — so a first hit at index
    k costs cum_energy[k-1], and a target already met at initialization
    (k == 0) costs nothing. Seeds that never hit spend the full-horizon
    energy.
    """
    c, s, kp1 = res.risks.shape
    hit_mask = res.risks <= target
    hit = np.argmax(hit_mask, axis=2)  # first True, 0 when none
    hit = np.where(hit_mask.any(axis=2), hit, kp1 - 1)
    # prepend the zero-cost column so index k charges cum_energy[k-1]
    ce = np.concatenate(
        [np.zeros((c, s, 1), res.cum_energy.dtype), res.cum_energy], axis=2)
    per_seed = np.take_along_axis(ce, hit[:, :, None], axis=2)[..., 0]
    return per_seed.mean(axis=1)
