"""Back-compat façade over the `repro.core.mc` package.

The Monte Carlo engine used to live here as a single module; it is now a
package split along its natural layers:

  * `repro.core.mc.problems` — `MCProblem` / `MCProblemBatch`, the open
    `PROBLEMS` registry (`register_problem`) and the library constructors
    (`quadratic_mc_problem`, `localization_mc_problem`,
    `logistic_mc_problem`).
  * `repro.core.mc.sampling` — the reference-twin RNG samplers (padded /
    dynamic-count threefry draws, antenna key replay).
  * `repro.core.mc.slots`    — per-slot algorithm updates behind
    `register_algo` (`ALGOS` is derived from the registry) + the
    `hoist_draws` RNG-plan twins.
  * `repro.core.mc.exec`     — the execution layer: the compiled
    `_mc_core`, hoisted RNG plan, seed-chunked scheduler, on-device seed
    reduction, memory model (docs/performance.md).
  * `repro.core.mc.engine`   — row assembly + `run_mc`, `MCResult`,
    `ChannelBatch`, `energy_to_target`, the compile counter.

Every name importable from `repro.core.montecarlo` before the split —
public API and the underscore helpers exercised by tests and notebooks —
still resolves here (guarded by `tests/test_backcompat.py`); new code
should import from `repro.core.mc` directly.
"""
from __future__ import annotations

from repro.core.mc import exec as _exec
from repro.core.mc import plan as _plan
from repro.core.mc import problems as _problems
from repro.core.mc import sampling as _sampling
from repro.core.mc import slots as _slots
from repro.core.mc.engine import (
    Array,
    ChannelBatch,
    MCResult,
    _mc_core,
    _resolve_n_shards,
    clear_cache,
    energy_to_target,
    run_mc,
    trace_count,
)
from repro.core.mc.problems import (
    MCProblem,
    MCProblemBatch,
    PROBLEMS,
    ProblemSpec,
    localization_mc_problem,
    logistic_mc_problem,
    quadratic_mc_problem,
    register_problem,
)
from repro.core.mc.slots import (
    ALGO_REGISTRY,
    AlgoSpec,
    SlotCtx,
    _slot_update,
    register_algo,
)

_SUBMODULES = (_slots, _sampling, _problems, _exec, _plan)


def __getattr__(name: str):
    # registry-derived views must stay live (late register_* calls show up)
    if name in ("ALGOS", "_OTA_ALGOS", "_BLIND_ALGOS"):
        return getattr(_slots, name)
    if name == "_PER_NODE_FIELDS":
        return _problems._per_node_fields()
    if name == "_ROW_FNS":
        return _problems._row_fns()
    # underscore helpers (samplers, row fns, ...) kept importable from the
    # old module path without enumerating them one by one
    for mod in _SUBMODULES:
        if hasattr(mod, name):
            return getattr(mod, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
