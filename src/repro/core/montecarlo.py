"""Batched, jitted Monte Carlo engine for the paper experiments (Figs. 2–6).

The figures reproduce the expectation in Eq. (14) by averaging excess-risk
curves over seeds. The seed implementation looped over seeds in Python and
evaluated the objective per trajectory point on the host (numpy); this engine
runs the whole sweep as one compiled call:

    vmap(channel configs) ∘ vmap(seeds) ∘ scan(steps)

with the excess-risk curve computed **on-device inside the scan**. For the
quadratic objective (27) the excess risk is the closed form
``0.5 (θ-θ*)ᵀ H (θ-θ*)`` (H = A + λI), which is exact — no cancellation
against F* — so the trajectory of estimates never leaves the device.

Algorithms (``algo=``) mirror the reference simulators step-for-step,
including their PRNG split order, so a fixed seed reproduces the trajectory
of `GBMASimulator.run` / `FDMGD.run` / `PowerControlOTA.run` up to float32
rounding (~1e-7 relative; a few host-side f64 scalar constants round
differently when computed in traced f32):

  * ``gbma``          — Eq. (8)–(9); an integer ``n_antennas`` gives the
                        MRC multi-antenna edge of related work [12].
  * ``centralized``   — noiseless benchmark GD.
  * ``fdm``           — orthogonal-channel GD (``invert_channel`` as in
                        `FDMGD`).
  * ``power_control`` — CA-DSGD-style truncated channel inversion [11].

Channel configs are batched with `ChannelBatch.stack`: any mix of scale,
noise_std, energy (e.g. the paper's E_N = N^{ε-2} sweep), phase error and
Rician K vmaps in one compile as long as the fading *family* is shared (the
family picks the sampling code path and is a static argument). A node-count
sweep changes array shapes, hence one compile per N.

Adding a new channel scenario = building new `ChannelConfig`s and calling
`run_mc`; no new per-figure script code (see docs/montecarlo.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.theory import ProblemConstants, theorem1_bound

Array = jax.Array

ALGOS = ("gbma", "centralized", "fdm", "power_control")


# --------------------------------------------------------------------------
# problems
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MCProblem:
    """On-device problem: per-node gradients plus a scalar risk metric.

    grad_fn: theta (d,) -> (N, d) all nodes' local gradients.
    risk_fn: theta (d,) -> scalar excess risk / error, fully traceable.
    """

    grad_fn: Callable[[Array], Array]
    risk_fn: Callable[[Array], Array]
    dim: int
    n_nodes: int


def quadratic_mc_problem(
    X: np.ndarray, y: np.ndarray, lam: float, theta_star: np.ndarray
) -> MCProblem:
    """Regularized least squares (Eq. 27), one sample per node.

    The excess risk uses the exact quadratic form around the minimizer:
    F(θ) - F* = 0.5 (θ-θ*)ᵀ (A + λI) (θ-θ*) with A = XᵀX/N — closed form,
    no F* cancellation, safe in f32.
    """
    n, d = X.shape
    H64 = X.T.astype(np.float64) @ X.astype(np.float64) / n + lam * np.eye(d)
    Xj, yj = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32)
    Hj = jnp.asarray(H64, jnp.float32)
    ts = jnp.asarray(theta_star, jnp.float32)

    def grad_fn(theta):
        return (Xj @ theta - yj)[:, None] * Xj + lam * theta[None, :]

    def risk_fn(theta):
        diff = theta - ts
        return 0.5 * diff @ (Hj @ diff)

    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=d, n_nodes=n)


def localization_mc_problem(
    r: np.ndarray, x: np.ndarray, src: np.ndarray, signal_a: float
) -> MCProblem:
    """Source localization of paper §VI-B; risk = squared position error."""
    rj, xj = jnp.asarray(r, jnp.float32), jnp.asarray(x, jnp.float32)
    srcj = jnp.asarray(src, jnp.float32)

    def grad_fn(theta):
        diff = theta[None, :] - rj  # (N, 2)
        d2 = jnp.sum(diff**2, axis=1)
        resid = xj - signal_a / d2
        return (4.0 * signal_a * resid / d2**2)[:, None] * diff

    def risk_fn(theta):
        return jnp.sum((theta - srcj) ** 2)

    return MCProblem(grad_fn=grad_fn, risk_fn=risk_fn, dim=2,
                     n_nodes=r.shape[0])


# --------------------------------------------------------------------------
# batched channel parameters
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChannelBatch:
    """Stack of C `ChannelConfig`s sharing one fading family.

    The family string is static (it selects the gain-sampling code path);
    everything else is a (C,) f32 array and vmaps in a single compile.
    """

    fading: str
    params: dict  # {'scale','noise_std','energy','phase_error_max','rician_k'}
    configs: tuple  # the original ChannelConfigs (host side, for bounds)

    @classmethod
    def stack(cls, cfgs: Sequence[ChannelConfig]) -> "ChannelBatch":
        fams = {c.fading for c in cfgs}
        if len(fams) != 1:
            raise ValueError(
                f"one ChannelBatch = one fading family, got {sorted(fams)}; "
                "issue one run_mc call per family")
        arr = lambda name: jnp.asarray(
            [getattr(c, name) for c in cfgs], jnp.float32)
        return cls(
            fading=cfgs[0].fading,
            params={
                "scale": arr("scale"),
                "noise_std": arr("noise_std"),
                "energy": arr("energy"),
                "phase_error_max": arr("phase_error_max"),
                "rician_k": arr("rician_k"),
            },
            configs=tuple(cfgs),
        )

    def __len__(self) -> int:
        return len(self.configs)


def _sample_gains(key: Array, fading: str, p: dict, shape: tuple) -> Array:
    """Traceable twin of `channel.sample_gains` over dynamic scalar params.

    Split order and draw shapes match `sample_gains` exactly, so a fixed key
    yields the same random draws as the reference simulators (trajectories
    then agree to f32 rounding). The phase factor is applied
    unconditionally: with phase_error_max == 0 the uniform draw is 0 and
    cos(0) == 1, identical to the skipped branch.
    """
    k_mag, k_ph = jax.random.split(key)
    scale = p["scale"]
    if fading == "equal":
        h = jnp.broadcast_to(scale.astype(jnp.float32), shape)
    elif fading == "rayleigh":
        u = jax.random.uniform(k_mag, shape, minval=1e-12, maxval=1.0)
        h = scale * jnp.sqrt(-2.0 * jnp.log(u))
    elif fading == "rician":
        nu = jnp.sqrt(p["rician_k"] * 2.0) * scale
        xy = jax.random.normal(k_mag, shape + (2,)) * scale
        h = jnp.sqrt((xy[..., 0] + nu) ** 2 + xy[..., 1] ** 2)
    elif fading == "lognormal":
        h = jnp.exp(scale * jax.random.normal(k_mag, shape))
    else:
        raise ValueError(f"unknown fading model: {fading}")
    phi = jax.random.uniform(k_ph, shape, minval=-p["phase_error_max"],
                             maxval=p["phase_error_max"])
    return (h * jnp.cos(phi)).astype(jnp.float32)


# --------------------------------------------------------------------------
# per-slot aggregation (mirrors the reference simulators' RNG usage)
# --------------------------------------------------------------------------
def _ota_slot(g: Array, key: Array, fading: str, p: dict) -> Array:
    n = g.shape[0]
    k_h, k_w = jax.random.split(key)
    h = _sample_gains(k_h, fading, p, (n,))
    v = jnp.einsum("n,nd->d", h, g) / n
    std = p["noise_std"] / (n * jnp.sqrt(p["energy"]))
    return v + std * jax.random.normal(k_w, v.shape, dtype=v.dtype)


def _slot_update(g: Array, key: Array, *, algo: str, fading: str, p: dict,
                 n_antennas: int, invert_channel: bool, h_min: float) -> Array:
    """One MAC slot: local gradients (N, d) -> received update direction (d,)."""
    n = g.shape[0]
    if algo == "centralized":
        return jnp.mean(g, axis=0)
    if algo == "gbma":
        # n_antennas=None: single-antenna edge, RNG-identical to
        # `GBMASimulator`. An integer (1 included) takes the MRC path of
        # `ota_aggregate_multiantenna`, whose extra key split changes the
        # stream even for M=1 — mirrored so fixed seeds reproduce exactly.
        if n_antennas is None:
            return _ota_slot(g, key, fading, p)
        keys = jax.random.split(key, n_antennas)
        v = jax.vmap(lambda k: _ota_slot(g, k, fading, p))(keys)
        return jnp.mean(v, axis=0)
    if algo == "fdm":
        k_h, k_w = jax.random.split(key)
        noise = p["noise_std"] / jnp.sqrt(p["energy"]) * jax.random.normal(
            k_w, g.shape, dtype=g.dtype)
        if invert_channel:
            rx = g + noise
        else:
            h = _sample_gains(k_h, fading, p, (n,))
            rx = h[:, None] * g + noise
        return jnp.mean(rx, axis=0)
    if algo == "power_control":
        k_h, k_w = jax.random.split(key)
        h = _sample_gains(k_h, fading, p, (n,))
        active = (h >= h_min).astype(g.dtype)
        n_active = jnp.maximum(jnp.sum(active), 1.0)
        sup = jnp.einsum("n,nd->d", active, g)
        w = p["noise_std"] / (n_active * jnp.sqrt(p["energy"])) * (
            jax.random.normal(k_w, (g.shape[1],), dtype=g.dtype))
        return sup / n_active + w
    raise ValueError(f"unknown algo {algo!r}; expected one of {ALGOS}")


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
@dataclasses.dataclass
class MCResult:
    """Host-side result of one engine call.

    risks:      (C, S, steps+1) per-config per-seed excess-risk curves.
    mean:       (C, steps+1) seed average (the Eq. 14 expectation estimate).
    ci95:       (C, steps+1) 1.96 * standard error over seeds (0 if S == 1).
    cum_energy: (C, S, steps) cumulative transmitted energy Σ E_N ||g_k||².
    bounds:     (C, steps+1) Theorem-1 bound per config (None unless the
                problem constants were supplied and algo == 'gbma').
    """

    risks: np.ndarray
    mean: np.ndarray
    ci95: np.ndarray
    cum_energy: np.ndarray
    bounds: Optional[np.ndarray]


@functools.partial(
    jax.jit,
    static_argnames=("grad_fn", "risk_fn", "algo", "fading", "steps",
                     "n_antennas", "invert_channel", "h_min"),
)
def _mc_core(params, betas, theta0, seed_keys, *, grad_fn, risk_fn, algo,
             fading, steps, n_antennas, invert_channel, h_min):
    """(C,)-batched channel params × (S,) seed keys × scan(steps)."""

    def trajectory(p, beta, key):
        def body(carry, k):
            theta, cum_e = carry
            g = grad_fn(theta)
            risk = risk_fn(theta)
            cum_e = cum_e + p["energy"] * jnp.sum(g.astype(jnp.float32) ** 2)
            v = _slot_update(g, k, algo=algo, fading=fading, p=p,
                             n_antennas=n_antennas,
                             invert_channel=invert_channel, h_min=h_min)
            return (theta - beta * v, cum_e), (risk, cum_e)

        step_keys = jax.random.split(key, steps)
        (theta_fin, _), (risks, cum_e) = jax.lax.scan(
            body, (theta0, jnp.float32(0.0)), step_keys)
        risks = jnp.concatenate([risks, risk_fn(theta_fin)[None]])
        return risks, cum_e  # (steps+1,), (steps,)

    per_config = jax.vmap(
        lambda p, b: jax.vmap(lambda k: trajectory(p, b, k))(seed_keys))
    risks, cum_e = per_config(params, betas)  # (C,S,steps+1), (C,S,steps)
    mean = jnp.mean(risks, axis=1)
    n_seeds = risks.shape[1]
    if n_seeds > 1:
        ci95 = 1.96 * jnp.std(risks, axis=1, ddof=1) / jnp.sqrt(n_seeds)
    else:
        ci95 = jnp.zeros_like(mean)
    return risks, mean, ci95, cum_e


def run_mc(
    problem: MCProblem,
    channels: Sequence[ChannelConfig] | ChannelBatch,
    algo: str,
    betas: Sequence[float] | np.ndarray,
    steps: int,
    seeds: int,
    *,
    theta0: Optional[np.ndarray] = None,
    seed0: int = 0,
    n_antennas: Optional[int] = None,
    invert_channel: bool = False,
    h_min: float = 0.3,
    pc: Optional[ProblemConstants] = None,
) -> MCResult:
    """Run `seeds` Monte Carlo trajectories for each channel config.

    Seed s uses `jax.random.key(seed0 + s)` — the same stream the sequential
    reference path (`benchmarks.common.average_runs`) consumes, so results
    are directly comparable. With `pc` supplied and algo='gbma' the Theorem-1
    bound for each config rides along in the result.
    """
    batch = channels if isinstance(channels, ChannelBatch) \
        else ChannelBatch.stack(list(channels))
    betas = jnp.asarray(betas, jnp.float32)
    if betas.shape != (len(batch),):
        raise ValueError(f"need one stepsize per config: "
                         f"{betas.shape} vs C={len(batch)}")
    t0 = jnp.zeros((problem.dim,), jnp.float32) if theta0 is None \
        else jnp.asarray(theta0, jnp.float32)
    seed_keys = jnp.stack([jax.random.key(seed0 + s) for s in range(seeds)])
    risks, mean, ci95, cum_e = _mc_core(
        batch.params, betas, t0, seed_keys,
        grad_fn=problem.grad_fn, risk_fn=problem.risk_fn, algo=algo,
        fading=batch.fading, steps=steps, n_antennas=n_antennas,
        invert_channel=invert_channel, h_min=h_min)
    bounds = None
    if pc is not None and algo == "gbma" and n_antennas is None:
        ks = np.arange(1, steps + 2)
        bounds = np.stack([
            theorem1_bound(ks, float(b), pc, cfg, problem.n_nodes)
            for b, cfg in zip(np.asarray(betas), batch.configs)])
    return MCResult(
        risks=np.asarray(risks), mean=np.asarray(mean),
        ci95=np.asarray(ci95), cum_energy=np.asarray(cum_e), bounds=bounds)


def energy_to_target(res: MCResult, target: float) -> np.ndarray:
    """Per-config mean (over seeds) total transmitted energy until the risk
    curve first hits `target` (paper Fig. 6). Seeds that never hit spend the
    full-horizon energy."""
    c, s, kp1 = res.risks.shape
    out = np.zeros((c,))
    for ci in range(c):
        per_seed = []
        for si in range(s):
            risks = res.risks[ci, si]
            hit = int(np.argmax(risks <= target)) if np.any(risks <= target) \
                else kp1 - 1
            per_seed.append(res.cum_energy[ci, si, min(hit, kp1 - 2)])
        out[ci] = float(np.mean(per_seed))
    return out
