"""Unified channel-transport layer: any registered MAC algorithm on a
gradient PYTREE.

The paper's core object — the analog superposition of local gradients over
a noisy fading MAC (Eq. 8) — was implemented twice: as tree-level helpers
in `core/gbma.py` (the production training path: gbma/fdm/centralized
only) and as the per-slot algo registry in `core/mc/slots.py` (all eight
algorithms, validated by the Monte Carlo engine). This module is the
single seam between them: it applies ANY `slots.ALGO_REGISTRY` entry to a
gradient pytree of per-node gradients, so blind / blind_ec / momentum /
nesterov / power_control train real models over exactly the simulated MAC
the engine validates.

How a slot evaluates (flash-attention-style IO-aware tiling):

  * the tree's leaves are viewed as (N, size) column panels of one logical
    (N, D) transmission (D = total parameter count) — the concatenated
    matrix is NEVER materialized;
  * each slot's random draws are materialized ONCE for the full D via the
    algorithm's registered `hoist_draws` twin (the same replay machinery
    the engine's hoisted RNG plan uses), then column-sliced per block
    (`slots.slice_draws`) — so every block consumes ITS coordinates of THE
    slot's streams. The draws are therefore bit-identical across tilings —
    all slot computations are per-coordinate given their draws — and the
    only tiling artifact left is XLA reassociating the f32 node-
    superposition reduction differently per block shape: tiled and untiled
    agree to a few ulp (the tests pin <= 1e-6);
  * blocks stream through the slot fn (and, with `ota_impl != 'inline'`,
    through the pallas OTA kernel) one (N, block_d) tile at a time,
    accumulating in f32;
  * `transmit_dtype='bfloat16'` casts the transmitted blocks to bf16 (half
    the superposition memory traffic) while gains, noise and accumulation
    stay f32 — the received update is always f32. `centralized` is exempt
    (it models no channel, so there is nothing to quantize — and its plain
    node sum would otherwise accumulate in bf16).

Slot state (what the engine carries in its scan) lives in an explicit
state dict from `init_state`: `'m'` — the receiver-side momentum carry of
the momentum/nesterov algorithms (γ m + v, applied as the update);
`'e'` — blind_ec's per-node residual tree with the power-budget truncation
α = min(1, √(B/‖g+e‖²)) computed over the FULL per-node vector (a global
reduction across all blocks, handled here — the one slot quantity that is
not per-coordinate). Training integration: `training/train_step.py`
resolves `TrainConfig.aggregator` through this layer.

RNG contract: one slot consumes one key exactly as the engine's slot fns
split it. `step_key(base, step, mc_steps=steps)` replays the engine's
`split(key(seed), steps)[step]` schedule (threefry split streams depend on
the total count, so the engine's steps must be known) — the transport↔
engine parity tests drive both stacks from the same stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import tree_flatten, tree_map, tree_unflatten
from repro.core.channel import ChannelConfig
from repro.core.mc.slots import (ALGO_REGISTRY, AlgoSpec, SlotCtx,
                                 slot_update_block)

Array = jax.Array
PyTree = Any

# block_d sentinel: one slot call on the concatenated (N, D) matrix — the
# untiled reference the bench compares the tiled path against
FULL_CONCAT = -1


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """The MAC transport of one training run.

    n_nodes: transmitting nodes N; every gradient leaf carries a leading
      node axis of this length.
    channel: the fading-MAC model (shared with the engine's ChannelBatch).
    n_antennas: edge antenna count M — required for the blind family,
      optional MRC path for the precoded family (None = single antenna,
      RNG-identical to `GBMASimulator`).
    gamma: receiver momentum coefficient of the uses_gamma algorithms
      (`run_mc(momentum=)`'s default 0.9).
    stepsize: the optimizer stepsize β, consumed ONLY by the nesterov
      lookahead θ − βγm (the engine's θ_eval); keep it equal to the
      optimizer's.
    power_budget: blind_ec's per-slot per-node budget B (squared norm of
      the transmitted vector; inf = unbounded).
    invert_channel / h_min: fdm gain equalization and the power-control
      silence threshold — engine defaults.
    block_d: column tile width. None (default) = one block per leaf (no
      copies, no splitting); an int tiles leaves into <= block_d columns;
      FULL_CONCAT materializes the whole (N, D) matrix in one slot call
      (the untiled reference).
    transmit_dtype: None (f32 faithful baseline) or 'bfloat16' — cast the
      transmitted blocks, keep gains/noise/accumulation f32.
    ota_impl: 'inline' | 'auto' | 'pallas' | 'ref' for the single-antenna
      OTA superposition ('auto' = pallas on TPU, inline otherwise).
    mc_steps: when set, `step_key` replays the engine's
      `split(key(seed), mc_steps)` slot-key schedule for trajectory parity
      with `run_mc`; None uses the training stack's `fold_in` schedule.
    """

    n_nodes: int = 16
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    n_antennas: Optional[int] = None
    gamma: float = 0.9
    stepsize: float = 0.0
    power_budget: float = math.inf
    invert_channel: bool = False
    h_min: float = 0.3
    block_d: Optional[int] = None
    transmit_dtype: Optional[str] = None
    ota_impl: str = "inline"
    mc_steps: Optional[int] = None


def resolve(algo: str) -> AlgoSpec:
    """Registry lookup with the engine's error message."""
    if algo not in ALGO_REGISTRY:
        raise ValueError(
            f"unknown algo {algo!r}; expected one of {tuple(ALGO_REGISTRY)}")
    return ALGO_REGISTRY[algo]


def has_state(algo: str) -> bool:
    """Whether `aggregate` for this algorithm carries transport state
    (momentum carry and/or error-feedback residual) between steps."""
    spec = resolve(algo)
    return spec.uses_gamma or spec.error_feedback


def init_state(algo: str, params: PyTree, cfg: TransportConfig) -> dict:
    """Zero transport state for `aggregate`: 'm' — the (params-shaped f32)
    receiver momentum of uses_gamma algorithms; 'e' — blind_ec's
    (n_nodes, *leaf.shape) f32 per-node residual tree."""
    spec = resolve(algo)
    st = {}
    if spec.uses_gamma:
        st["m"] = tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if spec.error_feedback:
        st["e"] = tree_map(
            lambda p: jnp.zeros((cfg.n_nodes,) + p.shape, jnp.float32),
            params)
    return st


def step_key(base_key: Array, step, mc_steps: Optional[int] = None) -> Array:
    """This step's slot key. Default: `fold_in(base_key, step)` (the
    training stack's schedule — any horizon, O(1) per step). With
    `mc_steps`, replay the engine's `split(jax.random.key(seed), steps)`
    schedule instead: threefry's split-element streams depend on the TOTAL
    split count, so engine-parity keys require the engine's full horizon
    (and O(steps) key material per step — a parity-testing mode, not a
    production schedule)."""
    if mc_steps is None:
        return jax.random.fold_in(base_key, step)
    return jax.random.split(base_key, mc_steps)[step]


def lookahead_params(algo: str, params: PyTree, state: Optional[dict],
                     cfg: TransportConfig) -> PyTree:
    """Nesterov lookahead θ_eval = θ − βγm (the engine's gradient
    evaluation point); identity for every other algorithm."""
    spec = resolve(algo)
    if not spec.nesterov or not state or "m" not in state:
        return params
    la = cfg.stepsize * cfg.gamma
    return tree_map(
        lambda p, m: (p.astype(jnp.float32) - la * m).astype(p.dtype),
        params, state["m"])


def add_tree_noise(grads: PyTree, key: Array, std, noise_dtype=jnp.float32
                   ) -> PyTree:
    """Per-leaf i.i.d. normal noise with scalar std: leaf keys come from
    `split(key, n_leaves)` so the tree structure defines the stream
    (SPMD-safe: same key on every device draws identical noise). The
    single definition behind `gbma.perturb_gradients` and the fdm
    training baseline — bit-compatible with both."""
    leaves, treedef = tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (g + std * jax.random.normal(k, g.shape, dtype=noise_dtype)
         .astype(g.dtype))
        for g, k in zip(leaves, keys)
    ]
    return tree_unflatten(treedef, noisy)


# --------------------------------------------------------------------------
# internals
# --------------------------------------------------------------------------
def _params_dict(cfg: TransportConfig) -> dict:
    """The traced scalar params a slot fn reads — the single-row analogue
    of the engine's ChannelBatch params."""
    ch = cfg.channel
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    return {
        "scale": f32(ch.scale),
        "noise_std": f32(ch.noise_std),
        "energy": f32(ch.energy),
        "phase_error_max": f32(ch.phase_error_max),
        "rician_k": f32(ch.rician_k),
        "n_nodes": f32(cfg.n_nodes),
        "n_idx": jnp.asarray(0, jnp.int32),
    }


def _resolve_ota_impl(cfg: TransportConfig) -> str:
    if cfg.ota_impl not in ("inline", "auto", "pallas", "ref"):
        raise ValueError(
            f"ota_impl must be 'inline', 'auto', 'pallas' or 'ref', "
            f"got {cfg.ota_impl!r}")
    if cfg.ota_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "inline"
    return cfg.ota_impl


def make_ctx(cfg: TransportConfig, spec: AlgoSpec) -> SlotCtx:
    """The SlotCtx of one transport slot (full node count, no padding)."""
    if spec.blind and cfg.n_antennas is None:
        raise ValueError(
            f"{spec.name!r} needs TransportConfig.n_antennas (the edge "
            "antenna count M)")
    n = cfg.n_nodes
    return SlotCtx(
        fading=cfg.channel.fading, p=_params_dict(cfg),
        mask=jnp.ones((n,), jnp.float32), n_sizes=(n,),
        n_antennas=cfg.n_antennas, m_sizes=(),
        invert_channel=cfg.invert_channel, h_min=cfg.h_min,
        ota_impl=_resolve_ota_impl(cfg),
        phase_zero=(cfg.channel.phase_error_max == 0.0))


def _flat_leaves(grads: PyTree, n: int) -> Tuple[list, list, Any]:
    leaves, treedef = tree_flatten(grads)
    if not leaves:
        raise ValueError("aggregate() needs a non-empty gradient tree")
    for g in leaves:
        if g.ndim < 1 or g.shape[0] != n:
            raise ValueError(
                f"every gradient leaf needs a leading node axis of length "
                f"n_nodes={n}; got leaf shape {g.shape}")
    flat = [g.reshape(n, -1) for g in leaves]
    sizes = [f.shape[1] for f in flat]
    return flat, sizes, treedef


def _block_ranges(sizes: list, block_d: Optional[int]) -> list:
    """(leaf_idx, lo, hi, flat_lo) column tiles; flat_lo is the leaf's
    offset in the concatenated D axis (the draw-stream coordinate)."""
    out, off = [], 0
    for li, sz in enumerate(sizes):
        width = sz if block_d is None else max(1, int(block_d))
        for lo in range(0, sz, width):
            out.append((li, lo, min(lo + width, sz), off))
        off += sz
    return out


def aggregate(
    algo: str,
    node_grads: PyTree,  # leaves (n_nodes, *shape): per-node local grads
    key: Array,  # this slot's key (one per step; see `step_key`)
    cfg: TransportConfig,
    state: Optional[dict] = None,
) -> Tuple[PyTree, Optional[dict], dict]:
    """One MAC slot over a gradient pytree: returns
    `(update, new_state, aux)`.

    `update` is the received update v (or the momentum carry m for
    uses_gamma algorithms) as an f32 tree shaped like one node's
    gradients — feed it to the optimizer (`gd(β)` reproduces the engine's
    θ ← θ − βm step rule). `state` must come from `init_state` for
    stateful algorithms (`has_state`) and is returned updated; stateless
    algorithms accept and return None. `aux['tx_energy']` is the slot's
    transmitted energy E_N Σ_n ‖x_n‖² of the actually-transmitted vectors
    (after blind_ec's truncation, before any transmit-dtype cast —
    matching the engine's accounting).

    Tiling: per-coordinate slot semantics + one full-D draw
    materialization make every `block_d` choice value-identical up to f32
    reduction-order reassociation in the node superposition — a few ulp,
    pinned <= 1e-6 by the tests (see module docstring).
    Algorithms registered WITHOUT a `hoist_draws` twin cannot be
    column-tiled (their in-slot draws would repeat per block), so any
    random twin-less algorithm runs as one FULL_CONCAT slot;
    `centralized` (draw-free) tiles normally.
    """
    spec = resolve(algo)
    n = cfg.n_nodes
    ctx = make_ctx(cfg, spec)
    flat, sizes, treedef = _flat_leaves(node_grads, n)
    total_d = sum(sizes)

    if spec.uses_gamma or spec.error_feedback:
        if state is None or (spec.uses_gamma and "m" not in state) \
                or (spec.error_feedback and "e" not in state):
            raise ValueError(
                f"{algo!r} carries transport state — pass "
                "transport.init_state(algo, params, cfg) and thread the "
                "returned state")
    new_state = dict(state) if state else None

    # ---- error feedback: residual add + power-budget truncation --------
    # α is a per-node GLOBAL norm over the full D vector — the one slot
    # quantity that is not per-coordinate, so it is computed here across
    # all leaves before any block is transmitted (engine scan-body
    # semantics: u = g + e; α = min(1, √(B/max(‖u‖², 1e-30)));
    # x = α u; e ← u − x).
    if spec.error_feedback:
        e_leaves = tree_flatten(state["e"])[0]
        u = [f.astype(jnp.float32) + e.reshape(n, -1)
             for f, e in zip(flat, e_leaves)]
        sq = sum(jnp.sum(x * x, axis=1) for x in u)  # (n,)
        alpha = jnp.minimum(1.0, jnp.sqrt(
            jnp.float32(cfg.power_budget) / jnp.maximum(sq, 1e-30)))
        tx = [alpha[:, None] * x for x in u]
        new_state["e"] = tree_unflatten(treedef, [
            (x - t).reshape(e.shape)
            for x, t, e in zip(u, tx, e_leaves)])
    else:
        tx = flat

    aux = {"tx_energy": cfg.channel.energy * sum(
        jnp.sum(x.astype(jnp.float32) ** 2) for x in tx)}

    if cfg.transmit_dtype is not None and algo != "centralized":
        tx = [x.astype(cfg.transmit_dtype) for x in tx]

    # ---- one full-D draw materialization (the tiling enabler) ----------
    if spec.hoist_draws is not None:
        draws = spec.hoist_draws(key[None], ctx, n, total_d)
        draws = tree_map(lambda a: a[0], draws)
        ctx = dataclasses.replace(ctx, draws=draws)

    # ---- block-tiled slot evaluation -----------------------------------
    block_d = cfg.block_d
    if spec.hoist_draws is None and algo != "centralized":
        block_d = FULL_CONCAT  # random twin-less algo: single slot call
    if block_d == FULL_CONCAT:
        g_full = tx[0] if len(tx) == 1 else jnp.concatenate(tx, axis=1)
        v = slot_update_block(algo, g_full, key, ctx, 0,
                              total_d).astype(jnp.float32)
        parts, off = [], 0
        for sz in sizes:
            parts.append(v[off:off + sz])
            off += sz
    else:
        parts = [[] for _ in sizes]
        for li, lo, hi, flat_lo in _block_ranges(sizes, block_d):
            v_blk = slot_update_block(algo, tx[li][:, lo:hi], key, ctx,
                                      flat_lo + lo, flat_lo + hi)
            parts[li].append(v_blk.astype(jnp.float32))
        parts = [ps[0] if len(ps) == 1 else jnp.concatenate(ps)
                 for ps in parts]

    v_leaves = [p.reshape(g.shape[1:]) for p, g in
                zip(parts, tree_flatten(node_grads)[0])]
    v_tree = tree_unflatten(treedef, v_leaves)

    # ---- receiver momentum carry (engine: m ← γm + v, update = m) ------
    if spec.uses_gamma:
        m_new = tree_map(lambda m, v_: cfg.gamma * m + v_,
                         state["m"], v_tree)
        new_state["m"] = m_new
        return m_new, new_state, aux
    return v_tree, new_state, aux
