"""Numpy-based pytree checkpointing (offline container: no orbax).

Leaves are stored in an .npz keyed by '/'-joined tree paths; restore
validates structure against a template tree and re-casts dtypes.

Crash-safety contract (docs/performance.md, "Fault tolerance"):

* **Atomic writes** — `save` writes to a temp file, fsyncs it (and,
  best-effort, its directory) before `os.replace`-ing it into place, so
  a crash mid-write can never leave a half-written artifact under the
  final name.
* **Content hash** — every artifact carries a sha256 of its own leaves
  (dtype/shape headers + raw bytes) under the reserved `__sha256__` key;
  `peek`/`restore` verify it, so a bit-flipped or torn file raises a
  typed `CheckpointCorrupt` instead of silently restoring garbage.
  Legacy artifacts without the hash still load (unverified).
* **Keep-last-2 rotation** — `save` rotates the previous artifact to
  `<path>.prev` before replacing, so one good checkpoint survives even
  a corrupting crash during the newest write; consumers (the resume
  path of `repro.core.mc.exec.run_chunked`) fall back to it on
  `CheckpointCorrupt`.
"""
from __future__ import annotations

import hashlib
import os
from typing import Any

import jax
import numpy as np

PyTree = Any

# reserved leaf: the artifact's own content sha256 as a (32,) uint8 array
_SHA_KEY = "__sha256__"
# keep-last-2 rotation: the previous artifact survives under this suffix
PREV_SUFFIX = ".prev"


class CheckpointCorrupt(Exception):
    """A checkpoint file that cannot be trusted: unreadable archive
    (zero-length, truncated, torn write) or content-hash mismatch (bit
    flip). Carries the `path` and a human-readable `reason`."""

    def __init__(self, path: str, reason: str):
        self.path = path
        self.reason = reason
        super().__init__(f"corrupt checkpoint at {path}: {reason}")


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _content_sha(flat: dict) -> np.ndarray:
    """sha256 over the artifact's leaves (sorted keys; dtype/shape headers
    + raw bytes — the same leaf-hashing scheme the resume fingerprint
    uses), as a (32,) uint8 array npz can round-trip."""
    h = hashlib.sha256()
    for key in sorted(flat):
        if key == _SHA_KEY:
            continue
        arr = np.asarray(flat[key])
        h.update(f"{key}:{arr.dtype.str}:{arr.shape};".encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return np.frombuffer(h.digest(), np.uint8)


def save(path: str, tree: PyTree) -> None:
    """Atomically persist `tree` at `path` with a content sha256 and
    keep-last-2 rotation (previous artifact -> `path + '.prev'`)."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    flat = _flatten(tree)
    flat[_SHA_KEY] = _content_sha(flat)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        os.replace(path, path + PREV_SUFFIX)
    os.replace(tmp, path)
    try:  # directory fsync: makes the replace itself durable (best-effort
        # — not every filesystem supports opening a directory)
        dfd = os.open(parent, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass


def _load_verified(path: str) -> dict:
    """{flat_key: array} of a checkpoint, sha-verified. Raises
    `CheckpointCorrupt` on a missing/unreadable archive (zero-length,
    truncated, torn write) or a content-hash mismatch (bit flip)."""
    if not os.path.exists(path):
        raise CheckpointCorrupt(path, "file does not exist")
    if os.path.getsize(path) == 0:
        raise CheckpointCorrupt(path, "zero-length file")
    try:
        with np.load(path, allow_pickle=False) as data:
            flat = dict(data.items())
    except Exception as e:  # BadZipFile / EOFError / zlib error / OSError
        raise CheckpointCorrupt(
            path, f"unreadable archive (truncated or torn write): "
                  f"{type(e).__name__}: {e}") from e
    sha = flat.pop(_SHA_KEY, None)
    if sha is not None and not np.array_equal(
            np.asarray(sha, np.uint8).ravel(), _content_sha(flat)):
        raise CheckpointCorrupt(
            path, "content sha256 mismatch (bit flip or partial write)")
    return flat


def peek(path: str) -> dict:
    """Raw {flat_key: array} view of a checkpoint, no template needed.

    For callers that must inspect identity/cursor leaves (e.g. a workload
    fingerprint) before they can know what shapes to validate against —
    the resume path of `repro.core.mc.exec.run_chunked`. Verifies the
    content sha256 and raises `CheckpointCorrupt` on a zero-length,
    truncated or bit-flipped file."""
    return _load_verified(path)


def restore(path: str, template: PyTree) -> PyTree:
    flat = _load_verified(path)
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, t in leaves_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(t.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {t.shape}")
        leaves.append(np.asarray(jax.numpy.asarray(arr).astype(t.dtype)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
