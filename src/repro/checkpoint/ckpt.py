"""Numpy-based pytree checkpointing (offline container: no orbax).

Leaves are stored in an .npz keyed by '/'-joined tree paths; restore
validates structure against a template tree and re-casts dtypes.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)


def peek(path: str) -> dict:
    """Raw {flat_key: array} view of a checkpoint, no template needed.

    For callers that must inspect identity/cursor leaves (e.g. a workload
    fingerprint) before they can know what shapes to validate against —
    the resume path of `repro.core.mc.exec.run_chunked`."""
    with np.load(path, allow_pickle=False) as data:
        return dict(data.items())


def restore(path: str, template: PyTree) -> PyTree:
    with np.load(path, allow_pickle=False) as data:
        flat = dict(data.items())
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, t in leaves_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(t.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {t.shape}")
        leaves.append(np.asarray(jax.numpy.asarray(arr).astype(t.dtype)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
