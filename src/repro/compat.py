"""JAX version-portability layer.

The repo targets both JAX 0.4.x and >= 0.5 APIs. The moved/renamed symbols
used by the codebase are resolved here, once, so call sites never touch
`jax.experimental` or version-sniff on their own:

  * `shard_map`  — top-level `jax.shard_map` (>= 0.4.35 on some builds /
    >= 0.5) with fallback to `jax.experimental.shard_map.shard_map`.
  * `make_mesh`  — top-level `jax.make_mesh` (>= 0.4.35) with fallback to
    building a `Mesh` from `mesh_utils.create_device_mesh`.
  * `tree_map` / `tree_leaves` / `tree_flatten` / `tree_unflatten` /
    `tree_structure` — the `jax.tree_util` spellings (stable across both
    lines; re-exported so future renames are one-line fixes here).
  * `threefry2x32` — the raw Threefry-2x32 hash primitive (private
    `jax._src.prng` location), used by the Monte Carlo engine to draw
    node-count-dependent random vectors with static shapes (counts as
    data). `None` when the internals moved; callers must fall back to
    shaped draws. `threefry_is_default()` reports whether `jax.random.key`
    produces threefry keys (the bit-level replication is only valid then).

Policy (see docs/montecarlo.md): production modules and tests import these
from `repro.compat`; only this file may probe `jax.experimental`,
`jax._src`, or the JAX version string.
"""
from __future__ import annotations

import jax

__all__ = [
    "JAX_VERSION",
    "shard_map",
    "make_mesh",
    "tree_map",
    "tree_leaves",
    "tree_flatten",
    "tree_unflatten",
    "tree_structure",
    "threefry2x32",
    "threefry_is_default",
]

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit()
)

# ---- shard_map -----------------------------------------------------------
if hasattr(jax, "shard_map"):  # JAX >= 0.5 (also late 0.4.x nightlies)
    shard_map = jax.shard_map
else:  # JAX 0.4.x: the experimental location
    from jax.experimental.shard_map import shard_map  # type: ignore

# ---- make_mesh -----------------------------------------------------------
if hasattr(jax, "make_mesh"):
    make_mesh = jax.make_mesh
else:  # pre-0.4.35

    def make_mesh(axis_shapes, axis_names, *, devices=None):
        from jax.experimental import mesh_utils
        from jax.sharding import Mesh

        if devices is None:
            devices = jax.devices()
        return Mesh(
            mesh_utils.create_device_mesh(axis_shapes, devices=list(devices)),
            axis_names,
        )


# ---- threefry primitive --------------------------------------------------
try:
    from jax._src.prng import threefry2x32_p as _threefry2x32_p

    def threefry2x32(k1, k2, x0, x1):
        """Raw Threefry-2x32 hash: two uint32 key words, two equal-length
        uint32 count vectors -> the two hashed output vectors."""
        return _threefry2x32_p.bind(k1, k2, x0, x1)

except Exception:  # pragma: no cover - future JAX moved the primitive
    threefry2x32 = None


def threefry_is_default() -> bool:
    """Whether `jax.random.key` uses the threefry2x32 impl (the default
    unless `jax_default_prng_impl` was overridden). Evaluated fresh each
    call — it guards trace-time decisions and the config can change
    between traces."""
    return "fry" in str(jax.random.key(0).dtype)


# ---- tree utils ----------------------------------------------------------
tree_map = jax.tree_util.tree_map
tree_leaves = jax.tree_util.tree_leaves
tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
tree_structure = jax.tree_util.tree_structure
