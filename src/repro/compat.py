"""JAX version-portability layer.

The repo targets both JAX 0.4.x and >= 0.5 APIs. The moved/renamed symbols
used by the codebase are resolved here, once, so call sites never touch
`jax.experimental` or version-sniff on their own:

  * `shard_map`  — top-level `jax.shard_map` (>= 0.4.35 on some builds /
    >= 0.5) with fallback to `jax.experimental.shard_map.shard_map`.
  * `make_mesh`  — top-level `jax.make_mesh` (>= 0.4.35) with fallback to
    building a `Mesh` from `mesh_utils.create_device_mesh`.
  * `tree_map` / `tree_leaves` / `tree_flatten` / `tree_unflatten` /
    `tree_structure` — the `jax.tree_util` spellings (stable across both
    lines; re-exported so future renames are one-line fixes here).
  * `threefry2x32` — the raw Threefry-2x32 hash primitive (private
    `jax._src.prng` location), used by the Monte Carlo engine to draw
    node-count-dependent random vectors with static shapes (counts as
    data). `None` when the internals moved; callers must fall back to
    shaped draws. `threefry_is_default()` reports whether `jax.random.key`
    produces threefry keys (the bit-level replication is only valid then).

Policy (see docs/montecarlo.md): production modules and tests import these
from `repro.compat`; only this file may probe `jax.experimental`,
`jax._src`, or the JAX version string.
"""
from __future__ import annotations

import jax

__all__ = [
    "JAX_VERSION",
    "shard_map",
    "make_mesh",
    "tree_map",
    "tree_leaves",
    "tree_flatten",
    "tree_unflatten",
    "tree_structure",
    "threefry2x32",
    "threefry_is_default",
    "threefry_split_is_original",
]

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit()
)

# ---- shard_map -----------------------------------------------------------
if hasattr(jax, "shard_map"):  # JAX >= 0.5 (also late 0.4.x nightlies)
    shard_map = jax.shard_map
else:  # JAX 0.4.x: the experimental location
    from jax.experimental.shard_map import shard_map  # type: ignore

# ---- make_mesh -----------------------------------------------------------
if hasattr(jax, "make_mesh"):
    make_mesh = jax.make_mesh
else:  # pre-0.4.35

    def make_mesh(axis_shapes, axis_names, *, devices=None):
        from jax.experimental import mesh_utils
        from jax.sharding import Mesh

        if devices is None:
            devices = jax.devices()
        return Mesh(
            mesh_utils.create_device_mesh(axis_shapes, devices=list(devices)),
            axis_names,
        )


# ---- threefry primitive --------------------------------------------------
try:
    from jax._src.prng import threefry2x32_p as _threefry2x32_p

    def threefry2x32(k1, k2, x0, x1):
        """Raw Threefry-2x32 hash: two uint32 key words, two equal-length
        uint32 count vectors -> the two hashed output vectors."""
        return _threefry2x32_p.bind(k1, k2, x0, x1)

except Exception:  # pragma: no cover - future JAX moved the primitive
    threefry2x32 = None


def threefry_is_default() -> bool:
    """Whether `jax.random.key` uses the threefry2x32 impl (the default
    unless `jax_default_prng_impl` was overridden). Evaluated fresh each
    call — it guards trace-time decisions and the config can change
    between traces."""
    return "fry" in str(jax.random.key(0).dtype)


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _split_layout_is_original(_partitionable: bool, _impl: str) -> bool:
    # cache key = the two config knobs that can change the split layout at
    # runtime; `ensure_compile_time_eval` keeps the probe concrete even
    # when the first call happens inside a jit/scan trace
    import jax.numpy as jnp

    with jax.ensure_compile_time_eval():
        key = jax.random.key(0)
        kd = jax.random.key_data(key)
        ref = jax.random.key_data(jax.random.split(key, 3)).ravel()
        x0 = jnp.arange(3, dtype=jnp.uint32)
        o0, o1 = threefry2x32(kd[0], kd[1], x0, x0 + jnp.uint32(3))
        return bool(jnp.all(jnp.concatenate([o0, o1]) == ref))


def threefry_split_is_original() -> bool:
    """Whether `jax.random.split` produces the ORIGINAL threefry layout:
    `threefry2x32(key, iota(2*num))` in `random_bits` counter order,
    reshaped to `(num, 2)`. The Monte Carlo engine's counts-as-data key
    splitting (per-row antenna counts with static shapes) replicates that
    layout; `jax_threefry_partitionable` (default on newer JAX) changes it,
    so the layout is *verified empirically* — one tiny concrete split,
    cached per PRNG-config state — rather than version-sniffed. Callers
    fall back to a `lax.switch` over per-count splits when False."""
    if threefry2x32 is None or not threefry_is_default():
        return False
    part = bool(getattr(jax.config, "jax_threefry_partitionable", False))
    return _split_layout_is_original(part, str(jax.random.key(0).dtype))


# ---- tree utils ----------------------------------------------------------
tree_map = jax.tree_util.tree_map
tree_leaves = jax.tree_util.tree_leaves
tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
tree_structure = jax.tree_util.tree_structure
