"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, interleaved dense/MoE
layers with chunked local attention, early-fusion backbone.
[hf:meta-llama/Llama-4-Scout-17B-16E / Llama-4-Maverick-17B-128E]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    rope_theta=500000.0,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_layer_step=2,          # MoE every other layer
    sliding_window=8192,       # chunked local attention on dense layers
    capacity_factor=1.25,
    fsdp=True,
)
