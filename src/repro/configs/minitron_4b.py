"""minitron-4b [dense] — width/depth-pruned Nemotron-4: squared-ReLU MLP,
GQA. [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    citation="arXiv:2407.14679",
    act="relu2",
    fsdp=True,
    glu=False,
    rope_theta=10000.0,
)
