"""repro-100m — ~110M-parameter dense decoder used by the end-to-end GBMA
training example (examples/train_100m.py) and integration tests."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="repro-100m",
    family="dense",
    n_layers=14,
    d_model=640,
    n_heads=10,
    n_kv_heads=10,
    head_dim=64,
    d_ff=2560,
    vocab_size=32000,
    citation="this repo",
    tie_embeddings=True,
    dtype="float32",
    remat=False,
    logit_chunk=256,
    attn_block_q=128,
    attn_block_kv=256,
)
