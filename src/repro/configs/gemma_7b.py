"""gemma-7b [dense] — GeGLU, head_dim=256 (q_dim > d_model), MHA (kv=16).
[arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    citation="arXiv:2403.08295",
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    fsdp=True,
)
