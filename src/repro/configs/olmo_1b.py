"""olmo-1b [dense] — non-parametric LayerNorm, SwiGLU, tied embeddings.
[arXiv:2402.00838]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    citation="arXiv:2402.00838",
    norm="ln_nonparam",
    tie_embeddings=True,
)
