"""gemma2-9b [dense] — alternating local(4096)/global attention, attn+final
logit softcaps, sandwich norms, GeGLU. [arXiv:2408.00118]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    citation="arXiv:2408.00118",
    layer_pattern="alt_local_global",
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm_style="sandwich",
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
)
