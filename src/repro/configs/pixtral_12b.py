"""pixtral-12b [vlm] — mistral-nemo-style decoder consuming Pixtral-ViT
patch embeddings; the vision encoder + projector is a stub supplying patch
embeddings (assignment carve-out). [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    citation="hf:mistralai/Pixtral-12B-2409",
    rope_theta=1000000.0,
    n_patches=1024,        # stub: e.g. 4 images x 256 patches
    fsdp=True,
)
