"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer,
128 meta tokens, sliding-window attention with 3 global layers, ssm_state=16.
[arXiv:2411.13676]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    citation="arXiv:2411.13676",
    layer_pattern="hymba_global_set",
    global_layer_ids=(0, 15, 31),
    sliding_window=1024,
    ssm_state=16,
    ssm_conv=4,
    dt_rank=100,
    meta_tokens=128,
)
