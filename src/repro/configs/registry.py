"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "minitron-4b": "minitron_4b",
    "gemma2-9b": "gemma2_9b",
    "olmo-1b": "olmo_1b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-small": "whisper_small",
    "gemma-7b": "gemma_7b",
    "hymba-1.5b": "hymba_1p5b",
    "pixtral-12b": "pixtral_12b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "repro-100m": "repro_100m",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "repro-100m")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


# ---------------------------------------------------------------------------
# which (arch x shape) pairs run, per DESIGN.md §5 skip table
# ---------------------------------------------------------------------------
LONG_CONTEXT_OK = {
    "rwkv6-7b",            # O(1)-state decode
    "hymba-1.5b",          # SSM + sliding window
    "gemma2-9b",           # sliding-window variant (global layers windowed)
    "llama4-maverick-400b-a17b",  # chunked local attention variant
}
SKIPS: dict[tuple, str] = {}
for _a in ARCH_IDS:
    if _a not in LONG_CONTEXT_OK:
        SKIPS[(_a, "long_500k")] = (
            "pure full-attention stack; no sub-quadratic variant in the "
            "source model (DESIGN.md §5)")


def pair_runnable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    reason = SKIPS.get((arch_id, shape_name))
    return (reason is None), (reason or "")
