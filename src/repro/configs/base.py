"""ModelConfig — the single config dataclass all architectures instantiate."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    # --- attention variants -------------------------------------------------
    rope_theta: float = 10000.0
    use_rope: bool = True  # whisper uses sinusoidal absolute positions instead
    attn_softcap: Optional[float] = None  # gemma2 attn logit softcap (50.0)
    final_softcap: Optional[float] = None  # gemma2 final logit softcap (30.0)
    sliding_window: Optional[int] = None  # window for 'local' layers
    layer_pattern: str = "global"  # global | alt_local_global | hymba_global_set
    global_layer_ids: Tuple[int, ...] = ()  # for hymba_global_set
    qk_norm: bool = False

    # --- norm & mlp ----------------------------------------------------------
    norm: str = "rms"  # rms | ln_nonparam
    act: str = "silu"  # silu | gelu | relu2
    glu: bool = True
    norm_style: str = "pre"  # pre | sandwich (gemma2 pre+post norms)
    embed_scale: bool = False  # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    moe_layer_step: int = 1  # MoE every k-th layer within the stack
    first_dense_layers: int = 0  # deepseek-v3: first 3 layers dense
    moe_d_ff: Optional[int] = None  # expert hidden dim if != d_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_scoring: str = "softmax"  # softmax | sigmoid (deepseek-v3)

    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False  # multi-token prediction block (train loss only)

    # --- SSM / RWKV / hybrid ---------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    dt_rank: int = 0
    meta_tokens: int = 0  # hymba learned prefix tokens

    # --- encoder-decoder (whisper) --------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0  # stub frame count (1500)

    # --- VLM (pixtral) ---------------------------------------------------------
    n_patches: int = 0  # stub patch-embedding count prepended in train/prefill

    # --- compute / distribution ------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    fsdp: bool = False
    # --- §Perf hillclimb switches (default False = paper-faithful baseline) --
    opt_bf16_dispatch: bool = False  # MoE combine/dispatch in bf16 not f32
    opt_pad_heads: bool = False  # pad attention heads to the model-axis size
    opt_shardmap_moe: bool = False  # explicit all_to_all for the MoE reshard
    # (GSPMD falls back to replicate-then-repartition on the 3-axis mesh)
    opt_flash_vjp: bool = False  # flash custom-VJP attention backward
    # (saves (out, lse) instead of remat-recomputing the whole forward)
    opt_int8_cache: bool = False  # int8 KV cache (per-token-per-head scales)
    # — halves the decode memory roofline term
    logit_chunk: int = 1024
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    scan_layers: bool = True

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests
        (<=2 layers, d_model<=512, <=4 experts)."""
        d_model = min(self.d_model, 256)
        head_dim = min(self.head_dim, 32)
        n_heads = max(1, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        if n_heads % n_kv:
            n_kv = 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=(min(self.sliding_window, 16)
                            if self.sliding_window else None),
            logit_chunk=64,
            attn_block_q=32,
            attn_block_kv=32,
            dtype="float32",
            fsdp=False,
            remat=False,
        )
        if self.n_experts:
            kw.update(
                n_experts=4,
                top_k=min(self.top_k, 2),
                first_dense_layers=min(self.first_dense_layers, 1),
                moe_d_ff=min(self.expert_ff, 256),
            )
        if self.use_mla:
            kw.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                      qk_rope_dim=16, v_head_dim=32, head_dim=48)
        if self.ssm_state:
            kw.update(dt_rank=max(8, d_model // 16))
        if self.n_enc_layers:
            kw.update(n_enc_layers=2, enc_seq=16)
        if self.n_patches:
            kw.update(n_patches=8)
        if self.meta_tokens:
            kw.update(meta_tokens=8)
        if self.global_layer_ids:
            kw.update(global_layer_ids=(0,))
        return self.with_(**kw)
