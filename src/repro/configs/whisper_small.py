"""whisper-small [audio] — encoder-decoder transformer backbone; the
mel+conv frontend is a stub supplying 1500 frame embeddings (assignment
carve-out). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="encdec",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    citation="arXiv:2212.04356",
    act="gelu",
    glu=False,
    use_rope=False,       # sinusoidal absolute positions
    tie_embeddings=True,
)
