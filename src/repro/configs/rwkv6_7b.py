"""rwkv6-7b [ssm] — Finch: attention-free, token-shift + data-dependent
per-channel decay WKV recurrence. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads, head_dim 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    citation="arXiv:2404.05892",
    fsdp=True,
)
