"""deepseek-v3-671b [moe] — MLA attention (latent kv cache), 1 shared + 256
routed experts top-8 (sigmoid scoring), first 3 layers dense, MTP head.
[arXiv:2412.19437]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,          # qk_nope + qk_rope
    d_ff=2048,             # per-expert hidden (fine-grained experts)
    vocab_size=129280,
    citation="arXiv:2412.19437",
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    first_dense_layers=3,
    router_scoring="sigmoid",
    capacity_factor=1.0,
    mtp=True,
    fsdp=True,
)
