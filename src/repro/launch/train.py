"""Training launcher: `python -m repro.launch.train --arch repro-100m
--steps 200 --aggregator gbma`. Runs on the local device(s); the production
mesh path is exercised by dryrun.py (this container has one real CPU core).

`--aggregator` accepts EVERY algorithm in the MAC registry
(`mc/slots.ALGO_REGISTRY`): gbma/fdm/centralized run the fused production
path; blind/blind_ec/momentum/nesterov/power_control route through the
channel-transport layer (per-node gradients over the simulated MAC — see
docs/training.md). The blind family needs `--antennas`; `--power-budget`
bounds blind_ec's per-node slot energy; `--block-d` / `--transmit-dtype`
expose the transport's tiling and bf16-transmit knobs.
"""
from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.core import transport
from repro.core.channel import ChannelConfig
from repro.core.gbma import GBMAConfig
from repro.core.mc.slots import ALGO_REGISTRY
from repro.data.synthetic import SyntheticTokens, TokenDatasetConfig
from repro.models.model import build_model
from repro.optim.gd import get_optimizer
from repro.training.loop import run_training
from repro.training.train_step import (TrainConfig, build_train_step,
                                       resolve_route)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--aggregator", default="gbma",
                    choices=tuple(ALGO_REGISTRY))
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--noise-std", type=float, default=0.01)
    ap.add_argument("--energy-eps", type=float, default=None,
                    help="E_N = nodes^(eps-2); default E_N = 1")
    ap.add_argument("--fading", default="rayleigh")
    ap.add_argument("--optimizer", default="momentum")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--antennas", type=int, default=None,
                    help="edge antenna count M (required for blind/"
                         "blind_ec; MRC path for precoded aggregators)")
    ap.add_argument("--power-budget", type=float, default=None,
                    help="blind_ec per-node per-slot squared-norm budget")
    ap.add_argument("--gamma", type=float, default=0.9,
                    help="receiver momentum of momentum/nesterov "
                         "aggregators")
    ap.add_argument("--block-d", type=int, default=None,
                    help="transport column-tile width (default: one block "
                         "per parameter leaf)")
    ap.add_argument("--transmit-dtype", default=None,
                    choices=(None, "bfloat16"),
                    help="cast transmitted gradient blocks (transport "
                         "route); accumulation stays f32")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduced config")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"aggregator={args.aggregator} nodes={args.nodes}")

    energy = (args.nodes ** (args.energy_eps - 2.0)
              if args.energy_eps is not None else 1.0)
    channel = ChannelConfig(fading=args.fading, noise_std=args.noise_std,
                            energy=energy)
    route = resolve_route(TrainConfig(aggregator=args.aggregator))
    tcfg = TrainConfig(
        aggregator=args.aggregator,
        gbma=GBMAConfig(n_nodes=args.nodes, channel=channel),
        transport=transport.TransportConfig(
            n_nodes=args.nodes, channel=channel, n_antennas=args.antennas,
            power_budget=(args.power_budget if args.power_budget is not None
                          else math.inf),
            gamma=args.gamma, stepsize=args.lr, block_d=args.block_d,
            transmit_dtype=args.transmit_dtype)
        if route == "transport" else None)
    opt = get_optimizer(args.optimizer, args.lr)
    step = build_train_step(model, tcfg, opt)

    ds = SyntheticTokens(TokenDatasetConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def batches():
        for tokens in ds:
            b = {"tokens": tokens}
            if cfg.n_patches:
                b["patch_embed"] = jnp.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model))
                b["tokens"] = tokens[:, : args.seq - cfg.n_patches + 1]
            if model.kind == "encdec":
                b["frames"] = jnp.zeros((args.batch, cfg.enc_seq,
                                         cfg.d_model))
            yield b

    params, opt_state, hist = run_training(
        step, params, step.init_state(params), batches(), args.steps,
        log_every=max(args.steps // 20, 1))
    if args.checkpoint:
        ckpt.save(args.checkpoint, params)
        print(f"saved checkpoint to {args.checkpoint}")
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
