"""Serving launcher: `python -m repro.launch.serve --arch olmo-1b --reduced`
— batched prefill + decode with the unified engine."""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_new_tokens=args.new_tokens,
                                            temperature=args.temperature))
    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab_size)}
    if cfg.n_patches:
        batch["patch_embed"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model))
    if model.kind == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model))
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"arch={cfg.arch_id} generated {out.shape} in {dt:.1f}s "
          f"({tput:.1f} tok/s)")


if __name__ == "__main__":
    main()
